#!/usr/bin/env python3
"""CI smoke driver for `tardis serve` (the serve-smoke job).

Usage: serve_smoke.py --port N --out PAYLOAD.json [--no-shutdown]

Connects to a freshly started server (retrying while it binds),
submits a 4-point batch through the sync reference client with
progress streaming on, checks the stream and the columnar result
shape, dumps the raw payload to --out (for validate_serve.py), and —
unless --no-shutdown — asks the server to drain and exit so the CI
job can `wait` on a clean exit code.
"""

import argparse
import json
import os
import sys
import time

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "python")
)

from client import TardisClient, validate_payload  # noqa: E402

POINTS = [
    {"workload": "fft", "protocol": "tardis", "cores": 4, "trace_len": 4096},
    {"workload": "fft", "protocol": "msi", "cores": 4, "trace_len": 4096},
    {"workload": "barnes", "protocol": "tardis", "cores": 4, "trace_len": 4096},
    {"workload": "volrend", "protocol": "ackwise", "cores": 4, "trace_len": 4096},
]


def connect(port, deadline_s=30.0):
    last = None
    deadline = time.monotonic() + deadline_s
    while time.monotonic() < deadline:
        try:
            return TardisClient(port=port, timeout=300.0)
        except OSError as e:
            last = e
            time.sleep(0.2)
    raise SystemExit(f"server on port {port} never came up: {last}")


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--out", required=True, help="payload dump for validate_serve.py")
    ap.add_argument("--no-shutdown", action="store_true")
    args = ap.parse_args(argv[1:])

    with connect(args.port) as c:
        banner = c.hello()
        print(f"connected: {banner['server']} schema={banner['schema']} "
              f"workers={banner['workers']}")
        c.ping()

        bid = c.submit_sweep(POINTS, seed=2718, progress_every=500)
        events = 0
        done = 0
        for ev in c.iter_progress(bid):
            events += 1
            if ev["type"] == "point_done":
                done += 1
        if done != len(POINTS):
            raise SystemExit(f"expected {len(POINTS)} point_done frames, got {done}")
        print(f"batch {bid}: {events} stream events, {done} points done")

        payload = c.fetch_payload(bid)
        cols = validate_payload(payload)
        got = list(zip(cols["workload"], cols["variant"]))
        want = [(p["workload"], p["protocol"]) for p in POINTS]
        if got != want:
            raise SystemExit(f"column order diverged: {got} != {want}")
        if any(v <= 0 for v in cols["sim_cycles"]):
            raise SystemExit(f"non-positive sim_cycles: {cols['sim_cycles']}")

        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.out} ({payload['n_points']} points, "
              f"{len(cols)} columns)")

        if not args.no_shutdown:
            c.shutdown()
            print("server acknowledged shutdown")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
