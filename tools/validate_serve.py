#!/usr/bin/env python3
"""Validate a tardis-serve-v1 columnar payload dump.

Usage: validate_serve.py FILE [FILE...]

FILE is a JSON dump of the payload a `tardis serve` batch returns
(the `payload` member of a `result` frame; `tools/serve_smoke.py`
writes one).  Checks the envelope, the columnar invariants (every
column a list, every length == n_points), the full per-stat column
set mirrored from `SimStats::columns()` via `schema_common.py`, and
basic positivity.  Exits non-zero with a diagnostic on the first
violation.
"""

import json
import sys

from schema_common import STAT_COLUMNS, check_keys, load

TOP_KEYS = {
    "schema": str,
    "batch_id": str,
    "seed": (int, type(None)),
    "n_points": int,
    "workers": int,
    "timing": dict,
    "columns": dict,
}

TIMING_KEYS = {
    "wall_s": (int, float),
    "queue_depth_at_submit": int,
}

# Identity columns lead; the stat columns mirror SimStats; wall_s is
# the per-point host time.
STR_COLUMNS = ("workload", "variant")
INT_COLUMNS = ("cores",) + STAT_COLUMNS
FLOAT_COLUMNS = ("wall_s",)

# Columns that must be strictly positive for any real simulation.
POSITIVE_COLUMNS = ("cores", "sim_cycles", "memops", "events")


def validate(path):
    doc = load(path)
    check_keys(doc, TOP_KEYS, "top level")
    if doc["schema"] != "tardis-serve-v1":
        raise ValueError(f"unknown schema {doc['schema']!r}")
    check_keys(doc["timing"], TIMING_KEYS, "timing")
    if doc["timing"]["wall_s"] < 0 or doc["timing"]["queue_depth_at_submit"] < 0:
        raise ValueError("timing values must be non-negative")
    n = doc["n_points"]
    if n < 1:
        raise ValueError("n_points must be >= 1 (the server rejects empty sweeps)")
    if doc["workers"] < 1:
        raise ValueError("workers must be >= 1")

    columns = doc["columns"]
    expected = set(STR_COLUMNS) | set(INT_COLUMNS) | set(FLOAT_COLUMNS)
    missing = expected - set(columns)
    if missing:
        raise ValueError(f"missing columns {sorted(missing)}")
    extra = set(columns) - expected
    if extra:
        raise ValueError(f"unknown columns {sorted(extra)}")

    for name, col in columns.items():
        where = f"columns[{name!r}]"
        if not isinstance(col, list):
            raise ValueError(f"{where}: not a list")
        if len(col) != n:
            raise ValueError(f"{where}: {len(col)} values for {n} points")
        if name in STR_COLUMNS:
            ok = all(isinstance(v, str) and v for v in col)
        elif name in FLOAT_COLUMNS:
            ok = all(isinstance(v, (int, float)) and v >= 0 for v in col)
        else:
            # bool is an int subclass; a True in a counter column is a bug.
            ok = all(
                isinstance(v, int) and not isinstance(v, bool) and v >= 0
                for v in col
            )
        if not ok:
            raise ValueError(f"{where}: value of the wrong type or range")
        if name in POSITIVE_COLUMNS and not all(v > 0 for v in col):
            raise ValueError(f"{where}: must be strictly positive")
    return n


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    for path in argv[1:]:
        try:
            n = validate(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            return 1
        print(f"ok {path}: {n} points, {len(STAT_COLUMNS)} stat columns")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
