#!/usr/bin/env python3
"""Validate a BENCH_*.json perf record against the tardis-bench-v1 schema.

Usage: validate_bench.py FILE [FILE...]

Emitted by `tardis bench` (rust/src/coordinator/bench.rs) and checked
by the CI bench-smoke job for both freshly generated reports and the
BENCH_*.json trajectory files committed at the repo root.  Exits
non-zero with a diagnostic on the first schema violation.
"""

import json
import sys

from schema_common import check_keys, check_provenance, load

TOP_KEYS = {
    "schema": str,
    "label": str,
    "provenance": str,
    "unix_time": int,
    "n_cores": int,
    "iters": int,
    "scale_down": int,
    "points": list,
    "aggregate": dict,
}

# Topology keys arrived with the ccNUMA subsystem; pre-topology files
# omit them and are treated as flat.
TOP_OPTIONAL_KEYS = {
    "topology": str,
    "sockets": int,
    "numa_ratio": int,
}

TOPOLOGY_VALUES = {"flat", "numa"}

POINT_KEYS = {
    "workload": str,
    "variant": str,
    "sim_cycles": int,
    "memops": int,
    "events": int,
    "wall_s": (int, float),
    "events_per_sec": (int, float),
    "sim_cycles_per_sec": (int, float),
}

# Socket-split counters: optional on flat reports, REQUIRED on every
# point of a non-flat report (a numa bench without the split is not a
# usable trajectory record).
POINT_SOCKET_KEYS = {
    "intra_socket_msgs": int,
    "inter_socket_msgs": int,
}

# Per-point core count: emitted by multi-scale suites (lease matrix)
# and current single-scale reports; absent from pre-topology files.
POINT_OPTIONAL_KEYS = {
    "cores": int,
}

# Interval metrics arrived with the flight-recorder subsystem; emitted
# together on every point of current reports, absent from older files.
POINT_METRIC_KEYS = {
    "renew_rate": (int, float),
    "avg_lease": (int, float),
}

# Parallel-engine keys arrived with the sharded PDES engine; emitted
# together on every point of a `bench --threads N` (N > 1) report and
# absent from serial reports.  The sync/balance counters (null_msgs,
# rebalances, imbalance) arrived with the null-message engine; older
# threaded reports legitimately omit them, but when present they must
# accompany `threads` and respect their bounds.
POINT_PARALLEL_KEYS = {
    "threads": int,
    "parallel_efficiency": (int, float),
}

POINT_PARALLEL_V2_KEYS = {
    "null_msgs": int,
    "rebalances": int,
    "imbalance": (int, float),
}

AGGREGATE_KEYS = {
    "wall_s": (int, float),
    "events": int,
    "sim_cycles": int,
    "events_per_sec": (int, float),
    "sim_cycles_per_sec": (int, float),
}


def validate(path):
    doc = load(path)
    check_keys(doc, TOP_KEYS, "top level", optional=TOP_OPTIONAL_KEYS)
    if doc["schema"] != "tardis-bench-v1":
        raise ValueError(f"unknown schema {doc['schema']!r}")
    topology = doc.get("topology", "flat")
    if topology not in TOPOLOGY_VALUES:
        raise ValueError(
            f"unknown topology {topology!r} (expected one of {sorted(TOPOLOGY_VALUES)})"
        )
    if topology != "flat":
        if doc.get("sockets", 0) < 2:
            raise ValueError(f"{topology} report needs sockets >= 2")
        if doc.get("numa_ratio", 0) < 1:
            raise ValueError(f"{topology} report needs numa_ratio >= 1")
    check_provenance(doc, path, "cargo run --release -- bench --out <file>")
    if not doc["points"]:
        raise ValueError("points must be non-empty")
    if doc["iters"] < 1 or doc["n_cores"] < 1 or doc["scale_down"] < 1:
        raise ValueError("iters, n_cores, and scale_down must be >= 1")
    for i, point in enumerate(doc["points"]):
        where = f"points[{i}]"
        if not isinstance(point, dict):
            raise ValueError(f"{where}: not an object")
        check_keys(
            point,
            POINT_KEYS,
            where,
            optional={
                **POINT_SOCKET_KEYS,
                **POINT_OPTIONAL_KEYS,
                **POINT_METRIC_KEYS,
                **POINT_PARALLEL_KEYS,
                **POINT_PARALLEL_V2_KEYS,
            },
        )
        if ("renew_rate" in point) != ("avg_lease" in point):
            raise ValueError(
                f"{where}: renew_rate and avg_lease must appear together"
            )
        if "renew_rate" in point and not 0 <= point["renew_rate"] <= 1:
            raise ValueError(f"{where}: renew_rate must be in [0, 1]")
        if "avg_lease" in point and point["avg_lease"] < 0:
            raise ValueError(f"{where}: avg_lease must be non-negative")
        if "cores" in point and point["cores"] < 1:
            raise ValueError(f"{where}: cores must be >= 1")
        if ("threads" in point) != ("parallel_efficiency" in point):
            raise ValueError(
                f"{where}: threads and parallel_efficiency must appear together"
            )
        if "threads" in point:
            if point["threads"] < 2:
                raise ValueError(
                    f"{where}: threads must be >= 2 (serial points omit the key)"
                )
            eff = point["parallel_efficiency"]
            if not 0 < eff <= point["threads"]:
                raise ValueError(
                    f"{where}: parallel_efficiency {eff} outside (0, threads]"
                )
        for key in POINT_PARALLEL_V2_KEYS:
            if key in point and "threads" not in point:
                raise ValueError(
                    f"{where}: {key} only makes sense on threaded points"
                )
        for key in ("null_msgs", "rebalances"):
            if key in point and point[key] < 0:
                raise ValueError(f"{where}: {key} must be non-negative")
        if "imbalance" in point and point["imbalance"] < 1.0:
            raise ValueError(
                f"{where}: imbalance is a max/mean busy ratio and must be >= 1.0"
            )
        if topology != "flat":
            for key in POINT_SOCKET_KEYS:
                if key not in point:
                    raise ValueError(
                        f"{where}: {topology!r} report is missing the "
                        f"socket-split counter {key!r}"
                    )
        for key in POINT_SOCKET_KEYS:
            if key in point and point[key] < 0:
                raise ValueError(f"{where}: {key} must be non-negative")
        for key in ("sim_cycles", "memops", "events"):
            if point[key] <= 0:
                raise ValueError(f"{where}: {key} must be positive")
        if point["wall_s"] < 0:
            raise ValueError(f"{where}: wall_s must be non-negative")
    check_keys(doc["aggregate"], AGGREGATE_KEYS, "aggregate")
    if doc["aggregate"]["events"] != sum(p["events"] for p in doc["points"]):
        raise ValueError("aggregate.events != sum of point events")
    if doc["aggregate"]["sim_cycles"] != sum(p["sim_cycles"] for p in doc["points"]):
        raise ValueError("aggregate.sim_cycles != sum of point sim_cycles")
    return len(doc["points"])


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    for path in argv[1:]:
        try:
            n = validate(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            return 1
        print(f"ok {path}: {n} points")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
