#!/usr/bin/env python3
"""Shared schema machinery for the tools/ validators.

The three machine-readable record formats — ``tardis-bench-v1``
(`validate_bench.py`), ``tardis-verif-v1`` (`validate_verif.py`), and
``tardis-serve-v1`` (`validate_serve.py`) — share key-checking,
loading, and provenance conventions, plus the per-stat column
vocabulary: the serve payload's columns mirror the BENCH per-point
field names, and this module is the single home of that list (kept in
lockstep with ``SimStats::columns()`` in rust/src/stats/mod.rs).
"""

import json
import sys

# "measured" = emitted by a local run of the tool; "estimate" =
# projected numbers committed from an environment that could not run
# the pipeline (allowed, but warned on so estimates never silently
# read as real trajectory points).
PROVENANCE_VALUES = {"measured", "estimate"}

# One entry per SimStats counter, in the stable wire order the serve
# payload emits (rust/src/stats/mod.rs `columns()`).  The first
# handful double as the BENCH_*.json per-point field names.
STAT_COLUMNS = (
    "sim_cycles",
    "events",
    "memops",
    "loads",
    "stores",
    "atomics",
    "l1_hits",
    "l1_misses",
    "llc_accesses",
    "dram_accesses",
    "renew_requests",
    "renew_success",
    "misspeculations",
    "rollback_cycles",
    "invalidations_sent",
    "broadcasts",
    "sb_stores",
    "sb_forwards",
    "sb_full_stalls",
    "spin_cycles",
    "locks_acquired",
    "barriers_passed",
    "request_flits",
    "data_flits",
    "control_flits",
    "renew_flits",
    "invalidation_flits",
    "dram_flits",
    "total_flits",
    "intra_socket_msgs",
    "inter_socket_msgs",
    "link_crossings",
    "inter_socket_flits",
    "pts_increase_total",
    "pts_increase_self_inc",
    "leases_granted",
    "lease_total",
    "livelock_escalations",
)


def load(path):
    """Load one JSON document from ``path``."""
    with open(path) as f:
        return json.load(f)


def check_keys(obj, spec, where, optional=None):
    """Require every key in ``spec`` with its type, allow ``optional``
    keys with theirs, and reject anything else.  ``spec``/``optional``
    map key -> type or tuple of types."""
    optional = optional or {}
    for key, typ in spec.items():
        if key not in obj:
            raise ValueError(f"{where}: missing key {key!r}")
        if not isinstance(obj[key], typ):
            raise ValueError(
                f"{where}: key {key!r} has type {type(obj[key]).__name__}, "
                f"expected {typ}"
            )
    for key, typ in optional.items():
        if key in obj and not isinstance(obj[key], typ):
            raise ValueError(
                f"{where}: key {key!r} has type {type(obj[key]).__name__}, "
                f"expected {typ}"
            )
    extra = set(obj) - set(spec) - set(optional)
    if extra:
        raise ValueError(f"{where}: unknown keys {sorted(extra)}")


def check_provenance(doc, path, regen_hint):
    """Validate the ``provenance`` field and warn (on stderr) when the
    record is an estimate rather than a measured run."""
    if doc["provenance"] not in PROVENANCE_VALUES:
        raise ValueError(
            f"unknown provenance {doc['provenance']!r} "
            f"(expected one of {sorted(PROVENANCE_VALUES)})"
        )
    if doc["provenance"] != "measured":
        print(
            f"WARNING {path}: provenance is {doc['provenance']!r} — these "
            f"numbers were not produced by a local run; regenerate with "
            f"`{regen_hint}`",
            file=sys.stderr,
        )
