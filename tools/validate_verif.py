#!/usr/bin/env python3
"""Validate a VERIF_*.json report against the tardis-verif-v1 schema.

Usage: validate_verif.py [--baseline BASE.json] FILE [FILE...]

Emitted by `tardis verify` (rust/src/verif/report.rs) and checked by
the CI verify-smoke job.  Exits non-zero with a diagnostic on the
first schema violation, on any failed run, or — with --baseline — on
any explored-state-count drift against the baseline report: exhaustive
exploration with exact state keys is deterministic, so two runs at the
same bounds must visit exactly the same number of states.
"""

import argparse
import json
import sys

from schema_common import check_keys, load

TOP_KEYS = {
    "schema": str,
    "unix_time": int,
    "cores": int,
    "lines": int,
    "max_ts": int,
    "lease": int,
    "sb_entries": int,
    "passed": bool,
    "runs": list,
}

RUN_KEYS = {
    "protocol": str,
    "consistency": str,
    "states_explored": int,
    "transitions": int,
    "max_depth": int,
    "terminal_states": int,
    "trace_checks": int,
    "passed": bool,
    "invariants": list,
    "counterexample": (dict, type(None)),
}

INVARIANT_KEYS = {
    "name": str,
    "checked": int,
    "violations": int,
}

COUNTEREXAMPLE_KEYS = {
    "invariant": str,
    "detail": str,
    "events": list,
}

PROTOCOL_VALUES = {"tardis", "msi"}
CONSISTENCY_VALUES = {"sc", "tso"}


def validate(path, require_pass):
    doc = load(path)
    check_keys(doc, TOP_KEYS, "top level")
    if doc["schema"] != "tardis-verif-v1":
        raise ValueError(f"unknown schema {doc['schema']!r}")
    for key in ("cores", "lines", "max_ts", "lease", "sb_entries"):
        if doc[key] < 1:
            raise ValueError(f"{key} must be >= 1")
    if not doc["runs"]:
        raise ValueError("runs must be non-empty")
    pairs = set()
    for i, run in enumerate(doc["runs"]):
        where = f"runs[{i}]"
        if not isinstance(run, dict):
            raise ValueError(f"{where}: not an object")
        check_keys(run, RUN_KEYS, where)
        if run["protocol"] not in PROTOCOL_VALUES:
            raise ValueError(f"{where}: unknown protocol {run['protocol']!r}")
        if run["consistency"] not in CONSISTENCY_VALUES:
            raise ValueError(f"{where}: unknown consistency {run['consistency']!r}")
        pair = (run["protocol"], run["consistency"])
        if pair in pairs:
            raise ValueError(f"{where}: duplicate run for {pair}")
        pairs.add(pair)
        if run["states_explored"] < 1 or run["transitions"] < 1:
            raise ValueError(f"{where}: an exploration must visit states")
        if run["passed"] and run["terminal_states"] < 1:
            raise ValueError(f"{where}: a clean run must reach a quiescent end state")
        if not run["invariants"]:
            raise ValueError(f"{where}: invariants must be non-empty")
        violations = 0
        for j, inv in enumerate(run["invariants"]):
            iw = f"{where}.invariants[{j}]"
            if not isinstance(inv, dict):
                raise ValueError(f"{iw}: not an object")
            check_keys(inv, INVARIANT_KEYS, iw)
            if inv["checked"] < 1:
                raise ValueError(f"{iw}: invariant {inv['name']!r} was never evaluated")
            if inv["violations"] < 0:
                raise ValueError(f"{iw}: negative violation count")
            violations += inv["violations"]
        cex = run["counterexample"]
        if run["passed"]:
            if cex is not None or violations != 0:
                raise ValueError(f"{where}: passed run carries a violation")
        else:
            if cex is None:
                raise ValueError(f"{where}: failed run has no counterexample")
            check_keys(cex, COUNTEREXAMPLE_KEYS, f"{where}.counterexample")
            if not cex["events"]:
                raise ValueError(f"{where}: counterexample trace is empty")
            if not all(isinstance(e, str) for e in cex["events"]):
                raise ValueError(f"{where}: counterexample events must be strings")
    if doc["passed"] != all(r["passed"] for r in doc["runs"]):
        raise ValueError("top-level passed does not match the runs")
    if require_pass and not doc["passed"]:
        raise ValueError("report records a protocol violation")
    return doc


def compare_baseline(doc, base, path, base_path):
    for key in ("cores", "lines", "max_ts", "lease", "sb_entries"):
        if doc[key] != base[key]:
            raise ValueError(
                f"bounds mismatch vs {base_path}: {key} {doc[key]} != {base[key]}"
            )
    base_runs = {(r["protocol"], r["consistency"]): r for r in base["runs"]}
    for run in doc["runs"]:
        pair = (run["protocol"], run["consistency"])
        if pair not in base_runs:
            raise ValueError(f"{pair} missing from baseline {base_path}")
        for key in ("states_explored", "transitions", "terminal_states"):
            got, want = run[key], base_runs[pair][key]
            if got != want:
                raise ValueError(
                    f"{pair}: {key} drifted from baseline: {got} != {want} "
                    "(exact-state exploration must be deterministic)"
                )
    print(f"ok {path}: state counts match baseline {base_path}")


def main(argv):
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--baseline", help="earlier report to diff state counts against")
    ap.add_argument(
        "--allow-fail",
        action="store_true",
        help="accept reports that record a violation (schema check only)",
    )
    ap.add_argument("files", nargs="+")
    args = ap.parse_args(argv[1:])
    try:
        base = validate(args.baseline, False) if args.baseline else None
        for path in args.files:
            doc = validate(path, require_pass=not args.allow_fail)
            print(f"ok {path}: {len(doc['runs'])} runs, passed={doc['passed']}")
            if base is not None:
                compare_baseline(doc, base, path, args.baseline)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
