#!/usr/bin/env python3
"""Validate a Chrome trace export against the tardis-trace-v1 schema.

Usage: validate_trace.py FILE [FILE...]

Emitted by `tardis trace --out FILE` / `tardis run --trace-out FILE`
(rust/src/obs/mod.rs `export_chrome`) and checked by the CI
trace-smoke job.  Exits non-zero with a diagnostic on the first
schema violation.

The document is standard Chrome trace-event JSON with two processes:
pid 1 is the simulated-time protocol stream (cat "proto", ts =
cycles, deterministic and byte-diffable across engine modes), pid 2
is opt-in host-time PDES telemetry (cat "host", every event tagged
with which clock its ts uses).
"""

import json
import sys

from schema_common import check_keys, load

SCHEMA = "tardis-trace-v1"

# The protocol event vocabulary (rust/src/obs/mod.rs EventKind::name).
PROTO_NAMES = {
    "demand",
    "lease_expire",
    "renew_ok",
    "renew_fail",
    "lease_grant",
    "pts_jump",
    "livelock",
    "sb_stall",
}

# Host-process vocabulary: shard spans plus execution markers.
HOST_NAMES = {"shard_busy", "shard_wait", "rebalance", "window"}

METADATA_NAMES = {"process_name", "thread_name"}

TOP_KEYS = {
    "displayTimeUnit": str,
    "otherData": dict,
    "traceEvents": list,
}

OTHER_DATA_KEYS = {
    "schema": str,
    "events": int,
    "dropped": int,
    "hot_lines": list,
    "hot_cores": list,
}

HOT_ROW_KEYS = {
    "key": (str, int),
    "demand": int,
    "expiries": int,
    "renew_ok": int,
    "renew_fail": int,
    "pressure": int,
}


def check_hot_table(rows, where, hex_keys):
    prev = None
    for i, row in enumerate(rows):
        here = f"{where}[{i}]"
        if not isinstance(row, dict):
            raise ValueError(f"{here}: not an object")
        check_keys(row, HOT_ROW_KEYS, here)
        if hex_keys:
            if not (isinstance(row["key"], str) and row["key"].startswith("0x")):
                raise ValueError(f"{here}: line keys must be hex strings")
        elif not isinstance(row["key"], int):
            raise ValueError(f"{here}: core keys must be integers")
        # Pressure is the ranking metric: demand misses plus
        # renewal-triggering expiries (renewals are the *consequence*).
        total = row["demand"] + row["expiries"]
        if row["pressure"] != total:
            raise ValueError(
                f"{here}: pressure {row['pressure']} != demand + expiries ({total})"
            )
        if prev is not None and row["pressure"] > prev:
            raise ValueError(f"{here}: hot table not sorted by descending pressure")
        prev = row["pressure"]


def check_event(ev, where, last_sim_ts):
    """Validate one trace event; returns the updated pid-1 ts watermark."""
    if not isinstance(ev, dict):
        raise ValueError(f"{where}: not an object")
    for key in ("name", "ph", "pid", "tid"):
        if key not in ev:
            raise ValueError(f"{where}: missing key {key!r}")
    name, ph, pid = ev["name"], ev["ph"], ev["pid"]
    if ph == "M":
        if name not in METADATA_NAMES:
            raise ValueError(f"{where}: unknown metadata record {name!r}")
        if "name" not in ev.get("args", {}):
            raise ValueError(f"{where}: metadata must carry args.name")
        return last_sim_ts
    if ph not in ("i", "X"):
        raise ValueError(f"{where}: unknown ph {ph!r}")
    if not isinstance(ev.get("ts"), int) or ev["ts"] < 0:
        raise ValueError(f"{where}: ts must be a non-negative integer")
    if ph == "X" and (not isinstance(ev.get("dur"), int) or ev["dur"] < 1):
        raise ValueError(f"{where}: complete events need an integer dur >= 1")
    if pid == 1:
        if ev.get("cat") != "proto":
            raise ValueError(f"{where}: pid-1 events must be cat 'proto'")
        if name not in PROTO_NAMES:
            raise ValueError(f"{where}: unknown protocol event {name!r}")
        if (name == "lease_grant") != (ph == "X"):
            raise ValueError(
                f"{where}: lease grants (and only they) are spans on pid 1"
            )
        if not str(ev.get("args", {}).get("addr", "")).startswith("0x"):
            raise ValueError(f"{where}: protocol events carry a hex args.addr")
        if ev["ts"] < last_sim_ts:
            raise ValueError(
                f"{where}: sim timeline went backwards "
                f"({ev['ts']} after {last_sim_ts})"
            )
        return ev["ts"]
    if pid == 2:
        if ev.get("cat") != "host":
            raise ValueError(f"{where}: pid-2 events must be cat 'host'")
        if name not in HOST_NAMES:
            raise ValueError(f"{where}: unknown host event {name!r}")
        clock = ev.get("args", {}).get("clock")
        if clock not in ("host_us", "sim"):
            raise ValueError(
                f"{where}: host events must tag their clock "
                f"(got {clock!r}, expected 'host_us' or 'sim')"
            )
        return last_sim_ts
    raise ValueError(f"{where}: unknown pid {pid}")


def validate(path):
    doc = load(path)
    check_keys(doc, TOP_KEYS, "top level")
    other = doc["otherData"]
    check_keys(other, OTHER_DATA_KEYS, "otherData")
    if other["schema"] != SCHEMA:
        raise ValueError(f"unknown schema {other['schema']!r}")
    if other["events"] < 0 or other["dropped"] < 0:
        raise ValueError("event and dropped counts must be non-negative")
    check_hot_table(other["hot_lines"], "otherData.hot_lines", hex_keys=True)
    check_hot_table(other["hot_cores"], "otherData.hot_cores", hex_keys=False)

    n_proto = 0
    last_sim_ts = 0
    for i, ev in enumerate(doc["traceEvents"]):
        last_sim_ts = check_event(ev, f"traceEvents[{i}]", last_sim_ts)
        if ev.get("pid") == 1 and ev.get("ph") != "M":
            n_proto += 1
    if n_proto != other["events"]:
        raise ValueError(
            f"otherData.events says {other['events']} protocol events, "
            f"found {n_proto}"
        )
    return n_proto


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    for path in argv[1:]:
        try:
            n = validate(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            return 1
        print(f"ok {path}: {n} protocol events")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
