//! Quickstart: run one synthetic workload under Tardis and print the
//! headline statistics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tardis_dsm::config::ProtocolKind;
use tardis_dsm::coordinator::experiments::base_cfg;
use tardis_dsm::runtime::{workload_or_synth, TraceRuntime};
use tardis_dsm::sim::run_workload;
use tardis_dsm::workloads;

fn main() -> anyhow::Result<()> {
    // 1. Pick a workload (the 12 SPLASH-2-signature benchmarks live in
    //    `workloads::all()`).
    let spec = workloads::by_name("fft").expect("known workload");

    // 2. Materialize its trace: through the AOT-compiled PJRT artifact
    //    when available (`make artifacts`), else the bit-exact rust
    //    mirror.
    let mut runtime = TraceRuntime::open_default().ok();
    if runtime.is_none() {
        eprintln!("note: artifacts not found; using the rust mirror (run `make artifacts`)");
    }
    let n_cores = 16;
    let workload = workload_or_synth(&mut runtime, n_cores, 2048, &spec.params);
    println!(
        "workload {} on {n_cores} cores: {} operations",
        spec.name,
        workload.total_ops()
    );

    // 3. Configure the system (paper Table V defaults) and run.
    for protocol in [ProtocolKind::Msi, ProtocolKind::Tardis] {
        let cfg = base_cfg(n_cores, protocol);
        let res = run_workload(cfg, &workload)?;
        let s = res.stats;
        println!("\n== {} ==", protocol.name());
        println!("  cycles          {}", s.cycles);
        println!("  throughput      {:.4} memops/cycle", s.throughput());
        println!("  L1 miss rate    {:.2}%", s.l1_miss_rate() * 100.0);
        println!("  traffic         {} flits", s.traffic.total());
        println!("  renewals        {} ({} ok)", s.renew_requests, s.renew_success);
        println!("  invalidations   {}", s.invalidations_sent);
        println!("  ts incr rate    {:.0} cycles/ts", s.ts_incr_rate());
    }
    Ok(())
}
