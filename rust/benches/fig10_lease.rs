//! Bench: regenerate Fig. 10 (lease sweep).
use tardis_dsm::benchutil::bench;
use tardis_dsm::coordinator::experiments::{fig10, EvalCtx};

fn main() {
    bench("fig10/lease sweep (scaled 1/8)", 3, || {
        let mut ctx = EvalCtx::new(None, 0);
        ctx.scale_down = 8;
        fig10(&mut ctx).unwrap()
    });
    let mut ctx = EvalCtx::new(None, 0);
    ctx.scale_down = 8;
    println!("\n{}", fig10(&mut ctx).unwrap().to_markdown());
}
