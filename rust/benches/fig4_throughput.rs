//! Bench: regenerate Fig. 4 (64-core throughput + traffic, four
//! protocol variants over all 12 workloads) on scaled-down traces and
//! time the end-to-end sweep.
use tardis_dsm::benchutil::bench;
use tardis_dsm::coordinator::experiments::{fig4, EvalCtx};

fn main() {
    bench("fig4/64-core sweep (scaled 1/8)", 3, || {
        let mut ctx = EvalCtx::new(None, 0);
        ctx.scale_down = 8;
        let t = fig4(&mut ctx).unwrap();
        assert_eq!(t.rows.len(), 13);
        t
    });
    // Print the table once for inspection.
    let mut ctx = EvalCtx::new(None, 0);
    ctx.scale_down = 8;
    println!("\n{}", fig4(&mut ctx).unwrap().to_markdown());
}
