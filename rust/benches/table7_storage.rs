//! Bench: regenerate Table VII (storage overhead — analytic).
use tardis_dsm::benchutil::bench;
use tardis_dsm::coordinator::experiments::table7;

fn main() {
    bench("table7/storage", 100, table7);
    println!("\n{}", table7().to_markdown());
}
