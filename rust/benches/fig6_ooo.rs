//! Bench: regenerate Fig. 6 (out-of-order cores).
use tardis_dsm::benchutil::bench;
use tardis_dsm::coordinator::experiments::{fig6, EvalCtx};

fn main() {
    bench("fig6/ooo sweep (scaled 1/8)", 2, || {
        let mut ctx = EvalCtx::new(None, 0);
        ctx.scale_down = 8;
        fig6(&mut ctx).unwrap()
    });
    let mut ctx = EvalCtx::new(None, 0);
    ctx.scale_down = 8;
    println!("\n{}", fig6(&mut ctx).unwrap().to_markdown());
}
