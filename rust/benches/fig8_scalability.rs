//! Bench: regenerate Fig. 8 (16- and 256-core scalability).
use tardis_dsm::benchutil::bench;
use tardis_dsm::coordinator::experiments::{fig8, EvalCtx};

fn main() {
    bench("fig8/scalability sweep (scaled 1/8)", 2, || {
        let mut ctx = EvalCtx::new(None, 0);
        ctx.scale_down = 8;
        fig8(&mut ctx).unwrap()
    });
    let mut ctx = EvalCtx::new(None, 0);
    ctx.scale_down = 8;
    let (a, b) = fig8(&mut ctx).unwrap();
    println!("\n{}\n{}", a.to_markdown(), b.to_markdown());
}
