//! Bench: regenerate Fig. 9 (delta-timestamp size sweep).
use tardis_dsm::benchutil::bench;
use tardis_dsm::coordinator::experiments::{fig9, EvalCtx};

fn main() {
    bench("fig9/ts-size sweep (scaled 1/8)", 3, || {
        let mut ctx = EvalCtx::new(None, 0);
        ctx.scale_down = 8;
        fig9(&mut ctx).unwrap()
    });
    let mut ctx = EvalCtx::new(None, 0);
    ctx.scale_down = 8;
    println!("\n{}", fig9(&mut ctx).unwrap().to_markdown());
}
