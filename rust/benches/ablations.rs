//! Ablation bench: the paper's §IV optimizations and extensions, one
//! at a time, on a renewal-heavy workload — speculation (§IV-A),
//! private-write optimization (§IV-C), E state (§IV-D), and dynamic
//! leases (§VI-C5 future work).
use tardis_dsm::api::SimBuilder;
use tardis_dsm::benchutil::bench;
use tardis_dsm::config::{LeasePolicyKind, ProtocolKind, SystemConfig, DEFAULT_MAX_LEASE};
use tardis_dsm::coordinator::experiments::base_cfg;
use tardis_dsm::coordinator::report::Table;
use tardis_dsm::trace::synth_workload;
use tardis_dsm::workloads;

fn main() {
    let spec = workloads::by_name("volrend").unwrap();
    let w = synth_workload(&spec.params, 16, 2048);
    let base = base_cfg(16, ProtocolKind::Msi);
    let msi = SimBuilder::from_config(base).workload(&w).run().unwrap().stats;

    let mut table = Table::new(
        "Ablations — VOLREND, 16 cores (normalized to MSI)",
        &["variant", "thr", "traffic", "renew%", "renew ok%"],
    );
    let variants: Vec<(&str, Box<dyn Fn(&mut SystemConfig)>)> = vec![
        ("tardis (default)", Box::new(|_| {})),
        ("no speculation", Box::new(|c| c.tardis.speculation = false)),
        ("no private-write opt", Box::new(|c| c.tardis.private_write_opt = false)),
        ("+ E state", Box::new(|c| c.tardis.exclusive_state = true)),
        ("+ dynamic lease", Box::new(|c| {
            c.tardis.lease_policy = LeasePolicyKind::Dynamic { max_lease: DEFAULT_MAX_LEASE };
        })),
        ("+ predictive lease", Box::new(|c| {
            c.tardis.lease_policy = LeasePolicyKind::Predictive { max_lease: DEFAULT_MAX_LEASE };
        })),
        ("+ E state + predictive", Box::new(|c| {
            c.tardis.exclusive_state = true;
            c.tardis.lease_policy = LeasePolicyKind::Predictive { max_lease: DEFAULT_MAX_LEASE };
        })),
    ];
    for (name, tweak) in variants {
        let s = bench(&format!("ablation/{name}"), 2, || {
            let mut cfg = base_cfg(16, ProtocolKind::Tardis);
            tweak(&mut cfg);
            SimBuilder::from_config(cfg).workload(&w).run().unwrap().stats
        });
        let _ = s;
        let mut cfg = base_cfg(16, ProtocolKind::Tardis);
        tweak(&mut cfg);
        let st = SimBuilder::from_config(cfg).workload(&w).run().unwrap().stats;
        let ok = if st.renew_requests == 0 {
            100.0
        } else {
            100.0 * st.renew_success as f64 / st.renew_requests as f64
        };
        table.row(vec![
            name.to_string(),
            format!("{:.3}", msi.cycles as f64 / st.cycles as f64),
            format!("{:.3}", st.traffic.total() as f64 / msi.traffic.total().max(1) as f64),
            format!("{:.1}%", st.renew_rate() * 100.0),
            format!("{ok:.1}%"),
        ]);
    }
    println!("\n{}", table.to_markdown());
    let _ = table.write("results", "ablations");
}
