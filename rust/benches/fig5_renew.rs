//! Bench: regenerate Fig. 5 (renew + misspeculation rates).
use tardis_dsm::benchutil::bench;
use tardis_dsm::coordinator::experiments::{fig5, EvalCtx};

fn main() {
    bench("fig5/renew-rate sweep (scaled 1/8)", 3, || {
        let mut ctx = EvalCtx::new(None, 0);
        ctx.scale_down = 8;
        fig5(&mut ctx).unwrap()
    });
    let mut ctx = EvalCtx::new(None, 0);
    ctx.scale_down = 8;
    println!("\n{}", fig5(&mut ctx).unwrap().to_markdown());
}
