//! Engine micro-benchmarks: the §Perf hot paths — raw simulation
//! throughput (memops/s) per protocol, trace generation, and the
//! event-queue core.
use tardis_dsm::benchutil::bench;
use tardis_dsm::config::{CoreModel, ProtocolKind, SystemConfig};
use tardis_dsm::coordinator::experiments::base_cfg;
use tardis_dsm::sim::run_workload;
use tardis_dsm::trace::{synth_raw, synth_workload};
use tardis_dsm::workloads;

fn main() {
    let spec = workloads::by_name("barnes").unwrap();
    let w64 = synth_workload(&spec.params, 64, 2048);
    let ops = w64.total_ops();

    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        let r = bench(&format!("engine/64c barnes {}", protocol.name()), 3, || {
            let mut cfg = base_cfg(64, protocol);
            cfg.record_accesses = false;
            run_workload(cfg, &w64).unwrap().stats.cycles
        });
        let mops = ops as f64 / r.mean.as_secs_f64() / 1e6;
        println!("  -> {:.2} M trace-ops/s ({} ops)", mops, ops);
    }

    let r = bench("engine/64c barnes tardis OoO", 2, || {
        let mut cfg = base_cfg(64, ProtocolKind::Tardis);
        cfg.record_accesses = false;
        cfg.core_model = CoreModel::OutOfOrder;
        run_workload(cfg, &w64).unwrap().stats.cycles
    });
    let mops = ops as f64 / r.mean.as_secs_f64() / 1e6;
    println!("  -> {:.2} M trace-ops/s", mops);

    bench("tracegen/rust-mirror 64x2048", 5, || synth_raw(&spec.params, 64, 2048));

    // Event-queue microbench.
    bench("event-queue/push-pop 100k", 10, || {
        use tardis_dsm::sim::{Event, EventQueue};
        let mut q = EventQueue::new();
        for i in 0..100_000u64 {
            q.push(i ^ 0x5555, Event::CoreWake((i % 64) as u32));
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        n
    });

    // SC-checking overhead (record + check).
    let w8 = synth_workload(&spec.params, 8, 512);
    bench("engine/8c with SC checking", 3, || {
        let cfg = SystemConfig::small(8, ProtocolKind::Tardis);
        let res = run_workload(cfg, &w8).unwrap();
        tardis_dsm::prog::checker::check(&res.log).unwrap().loads_checked
    });
}
