//! Engine micro-benchmarks: the §Perf hot paths — raw simulation
//! throughput (memops/s) per protocol, dispatch style (monomorphized
//! enum vs boxed trait object), trace generation, and the event-queue
//! core.
use tardis_dsm::api::SimBuilder;
use tardis_dsm::benchutil::bench;
use tardis_dsm::config::{CoreModel, ProtocolKind, SystemConfig};
use tardis_dsm::coordinator::experiments::base_cfg;
use tardis_dsm::proto::{Coherence, ProtocolDispatch};
use tardis_dsm::trace::{synth_raw, synth_workload};
use tardis_dsm::workloads;

fn main() {
    let spec = workloads::by_name("barnes").unwrap();
    let w64 = synth_workload(&spec.params, 64, 2048);
    let ops = w64.total_ops();

    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        let r = bench(&format!("engine/64c barnes {}", protocol.name()), 3, || {
            SimBuilder::from_config(base_cfg(64, protocol))
                .workload(&w64)
                .run()
                .unwrap()
                .stats
                .cycles
        });
        let mops = ops as f64 / r.mean.as_secs_f64() / 1e6;
        println!("  -> {:.2} M trace-ops/s ({} ops)", mops, ops);
    }

    let r = bench("engine/64c barnes tardis OoO", 2, || {
        SimBuilder::from_config(base_cfg(64, ProtocolKind::Tardis))
            .core_model(CoreModel::OutOfOrder)
            .workload(&w64)
            .run()
            .unwrap()
            .stats
            .cycles
    });
    let mops = ops as f64 / r.mean.as_secs_f64() / 1e6;
    println!("  -> {:.2} M trace-ops/s", mops);

    // Dispatch-style microbench: the engine's hottest protocol call
    // (`probe`) through the monomorphized enum vs the old
    // `Box<dyn Coherence>` path.  The enum must be no slower.
    dispatch_style_bench();

    bench("tracegen/rust-mirror 64x2048", 5, || synth_raw(&spec.params, 64, 2048));

    // Event-queue microbench.
    bench("event-queue/push-pop 100k", 10, || {
        use tardis_dsm::sim::{Event, EventQueue};
        let mut q = EventQueue::new();
        for i in 0..100_000u64 {
            q.push(i ^ 0x5555, Event::CoreWake((i % 64) as u32));
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        n
    });

    // SC-checking overhead (record + check).
    let w8 = synth_workload(&spec.params, 8, 512);
    bench("engine/8c with SC checking", 3, || {
        let res = SimBuilder::small(8, ProtocolKind::Tardis).workload(&w8).run().unwrap();
        res.check_sc().unwrap().loads_checked
    });
}

/// Hammer `probe` (the protocol call the in-order core makes while a
/// speculation window is open) through both dispatch styles on the
/// identical protocol state.
fn dispatch_style_bench() {
    use tardis_dsm::proto::tardis::Tardis;
    use tardis_dsm::types::SHARED_BASE;

    const CALLS: u64 = 2_000_000;
    let cfg = SystemConfig { protocol: ProtocolKind::Tardis, ..SystemConfig::default() };

    let enum_proto = ProtocolDispatch::new(&cfg);
    let r_static = bench("dispatch/enum probe 2M", 5, || {
        let mut acc = 0u64;
        for i in 0..CALLS {
            let p = enum_proto.probe((i % 64) as u32, SHARED_BASE + (i % 257));
            acc = acc.wrapping_add(p as u64);
        }
        acc
    });

    let dyn_proto: Box<dyn Coherence> = Box::new(Tardis::new(&cfg));
    let r_dyn = bench("dispatch/boxed-dyn probe 2M", 5, || {
        let mut acc = 0u64;
        for i in 0..CALLS {
            let p = dyn_proto.probe((i % 64) as u32, SHARED_BASE + (i % 257));
            acc = acc.wrapping_add(p as u64);
        }
        acc
    });

    let ratio = r_static.mean.as_secs_f64() / r_dyn.mean.as_secs_f64();
    println!(
        "  -> enum/dyn time ratio {:.3} ({} = static dispatch at least as fast)",
        ratio,
        if ratio <= 1.05 { "OK" } else { "REGRESSION?" }
    );
}
