//! Engine micro-benchmarks: the §Perf hot paths — raw simulation
//! throughput (memops/s) per protocol, the calendar event queue vs the
//! legacy binary heap, dispatch style (monomorphized enum vs boxed
//! trait object), trace generation, and the fig-4 macro sweep the
//! `tardis bench` pipeline records into `BENCH_*.json`.
use tardis_dsm::api::SimBuilder;
use tardis_dsm::benchutil::bench;
use tardis_dsm::config::{CoreModel, ProtocolKind, SystemConfig};
use tardis_dsm::coordinator::bench::run_macro_bench;
use tardis_dsm::coordinator::experiments::{base_cfg, EvalCtx};
use tardis_dsm::net::{Message, MsgKind, Node};
use tardis_dsm::proto::{Coherence, ProtocolDispatch};
use tardis_dsm::sim::{Event, EventQueue};
use tardis_dsm::trace::{synth_raw, synth_workload};
use tardis_dsm::workloads;

fn main() {
    let spec = workloads::by_name("barnes").unwrap();
    let w64 = synth_workload(&spec.params, 64, 2048);
    let ops = w64.total_ops();

    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        let r = bench(&format!("engine/64c barnes {}", protocol.name()), 3, || {
            SimBuilder::from_config(base_cfg(64, protocol))
                .workload(&w64)
                .run()
                .unwrap()
                .stats
                .cycles
        });
        let mops = ops as f64 / r.mean.as_secs_f64() / 1e6;
        println!("  -> {:.2} M trace-ops/s ({} ops)", mops, ops);
    }

    let r = bench("engine/64c barnes tardis OoO", 2, || {
        SimBuilder::from_config(base_cfg(64, ProtocolKind::Tardis))
            .core_model(CoreModel::OutOfOrder)
            .workload(&w64)
            .run()
            .unwrap()
            .stats
            .cycles
    });
    let mops = ops as f64 / r.mean.as_secs_f64() / 1e6;
    println!("  -> {:.2} M trace-ops/s", mops);

    // Dispatch-style microbench: the engine's hottest protocol call
    // (`probe`) through the monomorphized enum vs the old
    // `Box<dyn Coherence>` path.  The enum must be no slower.
    dispatch_style_bench();

    bench("tracegen/rust-mirror 64x2048", 5, || synth_raw(&spec.params, 64, 2048));

    // Queue-level microbenches: the calendar ring vs the legacy heap
    // on an engine-shaped schedule (§Perf; the calendar must win).
    queue_bench();

    // Protocol-level microbench: L1-hit `core_access` (the per-memop
    // fast path: set-assoc probe + timestamp bookkeeping, no network).
    l1_hit_bench();

    // SC-checking overhead (record + check).
    let w8 = synth_workload(&spec.params, 8, 512);
    bench("engine/8c with SC checking", 3, || {
        let res = SimBuilder::small(8, ProtocolKind::Tardis).workload(&w8).run().unwrap();
        res.check_sc().unwrap().loads_checked
    });

    // The tracked macro bench: one quick fig-4 sweep iteration (the
    // full-length record is `tardis bench`, which writes BENCH_*.json).
    let mut ctx = EvalCtx::new(None, 1);
    ctx.scale_down = 4;
    let report = run_macro_bench(&mut ctx, 16, 1).unwrap();
    println!("{}", report.summary());
}

/// Drive both queue implementations with an identical engine-shaped
/// schedule: a rolling now-cursor, mostly short deltas (hop + L2
/// latencies), ~3% DRAM-distance pushes, and a Deliver:Wake mix of
/// about 2:1 so the message slab is on the measured path.
fn queue_bench() {
    fn drive(mut q: EventQueue) -> u64 {
        let mut rng = tardis_dsm::testutil::Rng::new(0x2545_F491_4F6C_DD1D);
        let mut rand = move || rng.next_u64();
        let mut pops = 0u64;
        // Keep ~192 events in flight (64 cores + in-flight messages).
        for i in 0..192u64 {
            q.push(i % 16, Event::CoreWake((i % 64) as u32));
        }
        for _ in 0..400_000u64 {
            let (now, _ev) = q.pop().unwrap();
            pops += 1;
            let r = rand();
            let dt = if r % 32 == 0 { 100 + (r >> 8) % 60 } else { 1 + (r >> 8) % 24 };
            if r % 3 == 0 {
                q.push(now + dt, Event::CoreWake((r % 64) as u32));
            } else {
                q.push(
                    now + dt,
                    Event::Deliver(Message {
                        src: Node::Core((r % 64) as u32),
                        dst: Node::Slice(((r >> 6) % 64) as u32),
                        addr: r % 4096,
                        requester: (r % 64) as u32,
                        kind: MsgKind::ShRep { wts: now, rts: now + 10, value: r },
                    }),
                );
            }
        }
        while q.pop().is_some() {
            pops += 1;
        }
        pops
    }

    let r_cal = bench("queue/calendar 400k churn", 10, || drive(EventQueue::new()));
    let r_leg = bench("queue/legacy-heap 400k churn", 10, || drive(EventQueue::legacy_heap()));
    let speedup = r_leg.mean.as_secs_f64() / r_cal.mean.as_secs_f64();
    println!(
        "  -> calendar speedup {:.2}x over legacy heap ({})",
        speedup,
        if speedup >= 1.0 { "OK" } else { "REGRESSION?" }
    );
}

/// Hammer `core_access` — the call every committed memop makes — over
/// a line set that fits the L1: after warm-up this is the hit path
/// (masked set-assoc probe + Tardis lease/pts bookkeeping, §Perf).
/// Misses and renewals are resolved through a zero-latency message
/// loop standing in for the NoC + DRAM, so the protocol state machine
/// runs for real without an engine.
fn l1_hit_bench() {
    use tardis_dsm::proto::{AccessOutcome, MemOp, ProtoCtx};
    use tardis_dsm::stats::SimStats;
    use tardis_dsm::types::PRIV_BASE;

    const CALLS: u64 = 1_000_000;
    const LINES: u64 = 64; // well inside a 128x4 L1
    let cfg = SystemConfig { protocol: ProtocolKind::Tardis, ..SystemConfig::default() };
    let mut proto = ProtocolDispatch::new(&cfg);
    let mut stats = SimStats::default();
    let mut trace = tardis_dsm::obs::TraceBuf::default();
    let mut comps = Vec::new();

    // Deliver every outstanding message instantly; memory controllers
    // answer loads with zeros and swallow stores.
    fn resolve(
        proto: &mut ProtocolDispatch,
        now: u64,
        msgs: &mut Vec<Message>,
        comps: &mut Vec<tardis_dsm::proto::Completion>,
        stats: &mut SimStats,
        trace: &mut tardis_dsm::obs::TraceBuf,
    ) {
        while let Some(m) = msgs.pop() {
            match m.dst {
                Node::Mc(mc) => {
                    if matches!(m.kind, MsgKind::DramLdReq) {
                        msgs.push(Message {
                            src: Node::Mc(mc),
                            dst: m.src,
                            addr: m.addr,
                            requester: m.requester,
                            kind: MsgKind::DramLdRep { value: 0 },
                        });
                    }
                }
                _ => {
                    // Explicit reborrows: field init would move the
                    // `&mut` params and kill the next loop iteration.
                    let mut ctx = ProtoCtx {
                        now,
                        msgs: &mut *msgs,
                        completions: &mut *comps,
                        stats: &mut *stats,
                        trace: &mut *trace,
                    };
                    proto.on_message(m, &mut ctx);
                }
            }
        }
        comps.clear();
    }

    let mut msgs: Vec<Message> = Vec::new();
    bench("proto/core_access warm-L1 1M", 5, || {
        let mut hits = 0u64;
        for i in 0..CALLS {
            let op = if i % 4 == 0 { MemOp::Store { value: i } } else { MemOp::Load };
            let out = {
                let mut ctx = ProtoCtx {
                    now: i,
                    msgs: &mut msgs,
                    completions: &mut comps,
                    stats: &mut stats,
                    trace: &mut trace,
                };
                proto.core_access(0, PRIV_BASE + i % LINES, op, false, &mut ctx)
            };
            if matches!(out, AccessOutcome::Done(_)) {
                hits += 1;
            }
            if !msgs.is_empty() {
                resolve(&mut proto, i, &mut msgs, &mut comps, &mut stats, &mut trace);
            }
        }
        hits
    });
    println!("  -> note: hit fraction includes cold misses on the first iteration only");
}

/// Hammer `probe` (the protocol call the in-order core makes while a
/// speculation window is open) through both dispatch styles on the
/// identical protocol state.
fn dispatch_style_bench() {
    use tardis_dsm::proto::tardis::Tardis;
    use tardis_dsm::types::SHARED_BASE;

    const CALLS: u64 = 2_000_000;
    let cfg = SystemConfig { protocol: ProtocolKind::Tardis, ..SystemConfig::default() };

    let enum_proto = ProtocolDispatch::new(&cfg);
    let r_static = bench("dispatch/enum probe 2M", 5, || {
        let mut acc = 0u64;
        for i in 0..CALLS {
            let p = enum_proto.probe((i % 64) as u32, SHARED_BASE + (i % 257));
            acc = acc.wrapping_add(p as u64);
        }
        acc
    });

    let dyn_proto: Box<dyn Coherence> = Box::new(Tardis::new(&cfg));
    let r_dyn = bench("dispatch/boxed-dyn probe 2M", 5, || {
        let mut acc = 0u64;
        for i in 0..CALLS {
            let p = dyn_proto.probe((i % 64) as u32, SHARED_BASE + (i % 257));
            acc = acc.wrapping_add(p as u64);
        }
        acc
    });

    let ratio = r_static.mean.as_secs_f64() / r_dyn.mean.as_secs_f64();
    println!(
        "  -> enum/dyn time ratio {:.3} ({} = static dispatch at least as fast)",
        ratio,
        if ratio <= 1.05 { "OK" } else { "REGRESSION?" }
    );
}
