//! Bench: regenerate Table VI (timestamp statistics).
use tardis_dsm::benchutil::bench;
use tardis_dsm::coordinator::experiments::{table6, EvalCtx};

fn main() {
    bench("table6/timestamp stats (scaled 1/8)", 3, || {
        let mut ctx = EvalCtx::new(None, 0);
        ctx.scale_down = 8;
        table6(&mut ctx).unwrap()
    });
    let mut ctx = EvalCtx::new(None, 0);
    ctx.scale_down = 8;
    println!("\n{}", table6(&mut ctx).unwrap().to_markdown());
}
