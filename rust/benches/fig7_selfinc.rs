//! Bench: regenerate Fig. 7 (self-increment period sweep).
use tardis_dsm::benchutil::bench;
use tardis_dsm::coordinator::experiments::{fig7, EvalCtx};

fn main() {
    bench("fig7/self-inc sweep (scaled 1/8)", 3, || {
        let mut ctx = EvalCtx::new(None, 0);
        ctx.scale_down = 8;
        fig7(&mut ctx).unwrap()
    });
    let mut ctx = EvalCtx::new(None, 0);
    ctx.scale_down = 8;
    println!("\n{}", fig7(&mut ctx).unwrap().to_markdown());
}
