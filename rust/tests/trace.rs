//! Flight-recorder integration suite (DESIGN.md §12): the protocol
//! trace must be a pure function of (config, workload) — the same
//! canonical `(cycle, PushKey)` order whether the PDES engine runs
//! serial, epoch-synchronized, or null-message, at any thread count,
//! with or without rebalancing — and recording must be *observational*:
//! a traced run simulates the exact bits of an untraced one.

use tardis_dsm::api::SimBuilder;
use tardis_dsm::config::{PdesMode, ProtocolKind, SystemConfig};
use tardis_dsm::obs::{export_chrome, hot_cores, hot_lines, EventKind, ExportOpts, TRACE_SCHEMA};
use tardis_dsm::trace::synth_workload;
use tardis_dsm::workloads;

/// The tentpole determinism matrix: a serial traced run's default
/// export must be *byte-identical* to every parallel combination —
/// {epoch, null-message} x rebalance {off, every 3} x threads
/// {2, 3, 4} (3 threads over 8 cores shards unevenly).  Host-time
/// telemetry is excluded from the default export precisely so this
/// diff can be empty.
#[test]
fn trace_export_is_bit_identical_serial_vs_every_pdes_combo() {
    let spec = workloads::by_name("lu-nc").unwrap();
    let w = synth_workload(&spec.params, 8, 512);
    let run = |threads: u32, mode: PdesMode, rebalance: u32| {
        SimBuilder::from_config(SystemConfig::small(8, ProtocolKind::Tardis))
            .workload(&w)
            .threads(threads)
            .pdes_mode(mode)
            .rebalance_every(rebalance)
            .trace(true)
            .run()
            .unwrap()
    };
    let serial = run(1, PdesMode::Epoch, 0);
    assert!(serial.trace.enabled, "builder .trace(true) did not reach the engine");
    assert!(!serial.trace.events.is_empty(), "tardis run recorded no protocol events");
    assert_eq!(serial.trace.dropped, 0, "512-op trace must fit the ring buffer");
    assert!(
        serial.trace.events.windows(2).all(|p| p[0].cycle <= p[1].cycle),
        "recording is not in canonical nondecreasing-cycle order"
    );
    let baseline = export_chrome(&serial.trace, &serial.stats.parallel, &ExportOpts::default());
    assert!(baseline.contains(TRACE_SCHEMA), "export must carry the schema tag");
    assert!(
        !baseline.contains("\"cat\": \"host\""),
        "default export must exclude host-time spans"
    );
    for mode in [PdesMode::Epoch, PdesMode::NullMsg] {
        for rebalance in [0u32, 3] {
            for threads in [2u32, 3, 4] {
                let par = run(threads, mode, rebalance);
                let what = format!("{mode:?}/rb{rebalance}/t{threads}");
                assert_eq!(par.stats, serial.stats, "{what}: stats diverged");
                assert_eq!(
                    par.trace.events, serial.trace.events,
                    "{what}: merged event stream diverged from serial"
                );
                assert_eq!(par.trace.dropped, serial.trace.dropped, "{what}");
                let export = export_chrome(&par.trace, &par.stats.parallel, &ExportOpts::default());
                assert_eq!(export, baseline, "{what}: default export not byte-identical");
            }
        }
    }
    // Host spans are opt-in, tagged, and confined to pid 2: a parallel
    // run's opt-in export gains shard spans without touching pid 1.
    let par = run(4, PdesMode::Epoch, 0);
    let host = export_chrome(&par.trace, &par.stats.parallel, &ExportOpts { host_spans: true });
    assert!(host.contains("\"shard_busy\""), "opt-in export lost the PDES shard spans");
    assert!(host.contains("\"cat\": \"host\""));
}

/// Zero-cost contract: enabling the recorder must not perturb the
/// simulation.  A traced run and an untraced run of the same session
/// produce bit-identical statistics, access logs, and finish times —
/// and the untraced report carries no trace at all.
#[test]
fn tracing_is_observational_untraced_runs_are_unaffected() {
    let spec = workloads::by_name("fft").unwrap();
    let w = synth_workload(&spec.params, 8, 512);
    let run = |trace: bool| {
        SimBuilder::from_config(SystemConfig::small(8, ProtocolKind::Tardis))
            .record_accesses(true)
            .workload(&w)
            .trace(trace)
            .run()
            .unwrap()
    };
    let traced = run(true);
    let plain = run(false);
    assert_eq!(traced.stats, plain.stats, "recording perturbed the statistics");
    assert_eq!(traced.log.records, plain.log.records, "recording perturbed the access log");
    assert_eq!(traced.core_finish, plain.core_finish, "recording perturbed finish times");
    assert!(!plain.trace.enabled);
    assert!(plain.trace.events.is_empty(), "untraced run must record nothing");
    assert!(!traced.trace.events.is_empty());
    traced.check_sc().unwrap();
}

/// Cross-layer consistency: every recorded event kind must agree with
/// the aggregate counter the protocol already maintains — the trace is
/// the same information at event granularity, not a parallel universe.
#[test]
fn event_counts_match_the_statistics_counters() {
    let spec = workloads::by_name("volrend").unwrap();
    let w = synth_workload(&spec.params, 8, 512);
    let res = SimBuilder::from_config(SystemConfig::small(8, ProtocolKind::Tardis))
        .workload(&w)
        .trace(true)
        .run()
        .unwrap();
    let count =
        |kind: EventKind| res.trace.events.iter().filter(|e| e.kind == kind).count() as u64;
    assert_eq!(
        count(EventKind::LeaseGrant),
        res.stats.ts.leases_granted,
        "one LeaseGrant event per granted lease"
    );
    assert_eq!(
        count(EventKind::RenewOk),
        res.stats.renew_success,
        "one RenewOk event per successful renewal"
    );
    assert_eq!(
        count(EventKind::LeaseExpire),
        res.stats.renew_requests,
        "one LeaseExpire event per issued renewal"
    );
    assert!(count(EventKind::Demand) > 0, "misses must leave Demand events");
}

/// Hot-line attribution on a deliberately skewed workload: one shared
/// line hammered by every core (and core 0 issuing ~10x the traffic)
/// must top the per-line and per-core coherence-pressure tables.
#[test]
fn hot_line_attribution_ranks_the_contended_line_first() {
    use tardis_dsm::prog::{load, store, Program, Workload};

    let shared = 0x10u64;
    let mut programs = Vec::new();
    for core in 0..4u32 {
        let ops = if core == 0 { 480 } else { 48 };
        let base = 0x100 * (core as u64 + 1);
        let mut prog = Vec::new();
        for pc in 0..ops {
            prog.push(match pc % 4 {
                0 => load(base + (pc as u64 % 13)),
                1 => store(base + (pc as u64 % 13), Workload::store_value(core, pc)),
                2 => load(shared),
                _ => store(shared, Workload::store_value(core, pc)),
            });
        }
        programs.push(Program::new(prog));
    }
    let w = Workload::new(programs);
    let res = SimBuilder::from_config(SystemConfig::small(4, ProtocolKind::Tardis))
        .workload(&w)
        .trace(true)
        .run()
        .unwrap();
    let lines = hot_lines(&res.trace.events, 4);
    assert!(!lines.is_empty());
    assert_eq!(
        lines[0].key, shared,
        "the all-cores contended line must rank first by pressure"
    );
    assert!(
        lines[0].demand + lines[0].expiries > 0,
        "the hot line's pressure must come from recorded events"
    );
    let cores = hot_cores(&res.trace.events, 4);
    assert_eq!(cores[0].key, 0, "the 10x-traffic core must rank first");
    assert!(cores[0].total() > cores[cores.len() - 1].total());
}
