//! Property tests (in-tree harness — proptest is unavailable in this
//! image): random multi-core programs with locks and barriers must
//! satisfy the SC witness checker under every protocol and core model,
//! and protocol-independent functional invariants must hold.

use tardis_dsm::config::{CoreModel, ProtocolKind, SystemConfig};
use tardis_dsm::prog::checker;
use tardis_dsm::testutil::{prop_check, run_logged, ProgGen};

fn run_all_protocols(gen: &ProgGen, seed: u64, rng: &mut tardis_dsm::testutil::Rng, model: CoreModel) {
    let w = gen.generate(rng);
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        let mut cfg = SystemConfig::small(gen.n_cores, protocol);
        cfg.core_model = model;
        let res = run_logged(cfg, &w)
            .unwrap_or_else(|e| panic!("seed {seed:#x} {protocol:?}/{model:?}: {e}"));
        checker::check(&res.log)
            .unwrap_or_else(|v| panic!("seed {seed:#x} {protocol:?}/{model:?}: {v:?}"));
        // Functional invariants.
        let s = &res.stats;
        assert!(s.cycles > 0);
        assert_eq!(s.barriers_passed % gen.n_cores as u64, 0, "unbalanced barriers");
    }
}

#[test]
fn prop_random_programs_sc_inorder() {
    let gen = ProgGen { n_cores: 4, ops_per_core: 60, ..Default::default() };
    prop_check(25, 0xDEAD_BEEF, |seed, rng| {
        run_all_protocols(&gen, seed, rng, CoreModel::InOrder);
    });
}

#[test]
fn prop_random_programs_sc_ooo() {
    let gen = ProgGen { n_cores: 4, ops_per_core: 60, ..Default::default() };
    prop_check(25, 0xFACE_FEED, |seed, rng| {
        run_all_protocols(&gen, seed, rng, CoreModel::OutOfOrder);
    });
}

#[test]
fn prop_lock_heavy_sc() {
    let gen = ProgGen {
        n_cores: 4,
        ops_per_core: 50,
        lock_pct: 40,
        n_shared: 3,
        store_pct: 60,
        ..Default::default()
    };
    prop_check(20, 0x1234_5678, |seed, rng| {
        run_all_protocols(&gen, seed, rng, CoreModel::InOrder);
    });
}

#[test]
fn prop_barrier_heavy_sc() {
    let gen = ProgGen {
        n_cores: 8,
        ops_per_core: 48,
        barrier_every: 12,
        lock_pct: 0,
        ..Default::default()
    };
    prop_check(15, 0x0BAD_F00D, |seed, rng| {
        run_all_protocols(&gen, seed, rng, CoreModel::InOrder);
    });
}

#[test]
fn prop_hot_contention_sc() {
    // Few addresses, many writers: maximum invalidation / jump-ahead
    // churn.
    let gen = ProgGen {
        n_cores: 6,
        ops_per_core: 40,
        n_shared: 2,
        store_pct: 70,
        lock_pct: 5,
        max_gap: 1,
        ..Default::default()
    };
    prop_check(20, 0xCAFE_D00D, |seed, rng| {
        run_all_protocols(&gen, seed, rng, CoreModel::InOrder);
        run_all_protocols(&gen, seed, rng, CoreModel::OutOfOrder);
    });
}

#[test]
fn prop_tardis_determinism() {
    // Identical inputs must give identical stats (event-order
    // determinism is what makes the experiments reproducible).
    let gen = ProgGen { n_cores: 4, ops_per_core: 50, ..Default::default() };
    prop_check(10, 0x5EED, |_seed, rng| {
        let w = gen.generate(rng);
        let cfg = SystemConfig::small(4, ProtocolKind::Tardis);
        let a = run_logged(cfg.clone(), &w).unwrap();
        let b = run_logged(cfg, &w).unwrap();
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.stats.memops, b.stats.memops);
        assert_eq!(a.stats.traffic.total(), b.stats.traffic.total());
        assert_eq!(a.stats.renew_requests, b.stats.renew_requests);
    });
}

#[test]
fn prop_tardis_monotonic_timestamps() {
    // Rule 1 directly: per-core logged timestamps never decrease.
    let gen = ProgGen { n_cores: 4, ops_per_core: 60, store_pct: 50, ..Default::default() };
    prop_check(15, 0xA11CE, |seed, rng| {
        let w = gen.generate(rng);
        let cfg = SystemConfig::small(4, ProtocolKind::Tardis);
        let res = run_logged(cfg, &w).unwrap();
        let mut last = vec![0u64; 4];
        for r in res.log.records.iter().filter(|r| r.valid) {
            assert!(
                r.ts >= last[r.core as usize],
                "seed {seed:#x}: core {} ts {} < {}",
                r.core,
                r.ts,
                last[r.core as usize]
            );
            last[r.core as usize] = r.ts;
        }
    });
}

#[test]
fn prop_protocols_agree_on_final_memory() {
    // For programs where each shared address has a single writer (no
    // cross-core write races), the final value per address is the
    // writer's last store — identical across protocols.  (Racy
    // programs may legitimately end differently per protocol: lock
    // acquisition order is timing-dependent.)
    use tardis_dsm::prog::{load, store, Program, Workload};
    use tardis_dsm::types::SHARED_BASE;

    prop_check(10, 0xD15C0, |seed, rng| {
        let n_cores = 4u32;
        let mut progs = Vec::new();
        for c in 0..n_cores {
            let mut ops = Vec::new();
            for i in 0..40u64 {
                if rng.chance(40, 100) {
                    // Only core c writes SHARED_BASE + c.
                    ops.push(store(SHARED_BASE + c as u64, c as u64 * 1000 + i));
                } else {
                    ops.push(load(SHARED_BASE + rng.below(n_cores as u64)));
                }
            }
            progs.push(Program::new(ops));
        }
        let w = Workload::new(progs);
        let mut finals = Vec::new();
        for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            let cfg = SystemConfig::small(n_cores, protocol);
            let res = run_logged(cfg, &w).unwrap();
            checker::check(&res.log)
                .unwrap_or_else(|v| panic!("seed {seed:#x} {protocol:?}: {v:?}"));
            use std::collections::HashMap;
            let mut per_addr: HashMap<u64, (u64, (u64, u64, u64))> = HashMap::new();
            for r in res.log.records.iter().filter(|r| r.valid) {
                if let Some(wr) = r.value_written {
                    let key = r.key();
                    per_addr
                        .entry(r.addr)
                        .and_modify(|e| {
                            if key > e.1 {
                                *e = (wr, key);
                            }
                        })
                        .or_insert((wr, key));
                }
            }
            let mut v: Vec<(u64, u64)> =
                per_addr.into_iter().map(|(a, (val, _))| (a, val)).collect();
            v.sort();
            finals.push(v);
        }
        assert_eq!(finals[0], finals[1], "seed {seed:#x}: tardis vs msi final memory");
        assert_eq!(finals[1], finals[2], "seed {seed:#x}: msi vs ackwise final memory");
    });
}
