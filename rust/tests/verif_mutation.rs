//! Mutation smoke-check (ISSUE item: seeded faults): with a known bug
//! compiled into the Tardis controllers, `tardis verify`'s exploration
//! MUST report a violation with a non-empty, replayable counterexample
//! trace.  One regression test per seeded fault:
//!
//! - `verif-mutate-wts-skip` (l1.rs): a store keeps the stale version
//!   timestamp instead of bumping to the jumped ts — two different
//!   values end up sharing one wts, caught by version-value-agreement
//!   (or by write-after-expiry / linearization first, depending on
//!   which state BFS reaches earlier; any violation is a catch).
//! - `verif-mutate-over-lease` (tm.rs): the TM grants a sharer a lease
//!   1000 cycles past what it records — caught by lease-containment.
//!
//! Run with: `cargo test --features verif-mutate-<fault> --test
//! verif_mutation` (scoped to this file; the clean-protocol suites
//! would rightly fail under a seeded fault).
#![cfg(any(feature = "verif-mutate-wts-skip", feature = "verif-mutate-over-lease"))]

use tardis_dsm::config::{Consistency, ProtocolKind};
use tardis_dsm::proto::tardis::Tardis;
use tardis_dsm::verif::{self, replay, VerifBounds};

/// Shared body: verify Tardis/SC at the given bounds, assert the run
/// fails with a well-formed counterexample, and re-execute the trace
/// to confirm it reproduces the same violation deterministically.
fn assert_fault_caught(bounds: VerifBounds) {
    let report = verif::run_matrix(&[ProtocolKind::Tardis], &[Consistency::Sc], bounds)
        .expect("run_matrix should run (and fail its invariants), not error out");
    assert!(!report.passed(), "seeded fault escaped verification");
    let run = &report.runs[0];
    let cex = run
        .outcome
        .counterexample
        .as_ref()
        .expect("failed run must carry a counterexample");
    assert!(!cex.events.is_empty(), "counterexample trace is empty");
    assert_eq!(
        cex.labels.len(),
        cex.events.len(),
        "every counterexample event must carry a human-readable label"
    );
    assert!(!cex.detail.is_empty());

    // The violated invariant shows up in the per-invariant tallies
    // (unless the catch was a trace-linearization or deadlock failure,
    // which are accounted separately).
    if !matches!(cex.invariant.as_str(), "linearization" | "deadlock-freedom") {
        let stat = run
            .outcome
            .invariants
            .iter()
            .find(|s| s.name == cex.invariant)
            .expect("counterexample names an unknown invariant");
        assert!(stat.violations > 0);
    }

    // Replayability: the recorded event path reproduces the violation.
    let cfg = bounds.config(ProtocolKind::Tardis, Consistency::Sc);
    let (labels, violation) =
        replay(&|| Tardis::new(&cfg), bounds, Consistency::Sc, &cex.events);
    assert_eq!(labels, cex.labels, "replay labels diverged from the recorded trace");
    let (inv, _detail) = violation.expect("replaying the counterexample found no violation");
    assert_eq!(inv, cex.invariant, "replay blamed a different invariant");

    // The JSON report serializes the failure for the CI validator.
    let json = report.to_json();
    assert!(json.contains("\"passed\": false"));
    assert!(json.contains(&format!("\"invariant\": \"{}\"", cex.invariant)));

    // And the counterexample projects onto an engine-runnable
    // workload: the full timed engine (which compiled in the same
    // fault) must accept it as a regression input.  The engine's
    // fixed timing picks one interleaving, so only `replay` above is
    // guaranteed to reproduce the violation; here we assert the
    // projection is drivable end to end.
    let w = cex.to_workload(&bounds);
    assert!(w.total_ops() > 0);
    let sim = tardis_dsm::api::SimBuilder::from_config(
        bounds.config(ProtocolKind::Tardis, Consistency::Sc),
    )
    .record_accesses(true)
    .workload(&w)
    .run()
    .expect("engine must run the projected counterexample workload");
    assert!(sim.stats.cycles > 0);
}

/// A write that skips the wts bump lets one version timestamp carry
/// two different values.
#[cfg(feature = "verif-mutate-wts-skip")]
#[test]
fn wts_skip_fault_is_caught_with_replayable_trace() {
    assert_fault_caught(VerifBounds { max_ts: 2, ..VerifBounds::default() });
}

/// A lease grant longer than the TM records lets a sharer read a
/// version the TM believes expired.
#[cfg(feature = "verif-mutate-over-lease")]
#[test]
fn over_lease_fault_is_caught_with_replayable_trace() {
    assert_fault_caught(VerifBounds { max_ts: 2, ..VerifBounds::default() });
}
