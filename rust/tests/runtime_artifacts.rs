//! PJRT runtime integration: the AOT-compiled tracegen artifacts must
//! load, execute, and produce bit-identical traces to the pure-rust
//! mirror (which itself is pytest-verified against the jnp oracle) —
//! closing the cross-language loop python -> HLO -> PJRT -> rust.
//!
//! These tests are skipped when artifacts/ has not been built (run
//! `make artifacts`).

use tardis_dsm::runtime::TraceRuntime;
use tardis_dsm::trace::{synth_raw, TraceParams};
use tardis_dsm::workloads;

fn runtime() -> Option<TraceRuntime> {
    match TraceRuntime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping artifact tests ({e}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_lists_paper_core_counts() {
    let Some(rt) = runtime() else { return };
    let configs = rt.configs();
    for n in [16u32, 64, 256] {
        assert!(
            configs.iter().any(|&(c, _)| c == n),
            "missing artifact for {n} cores: {configs:?}"
        );
    }
}

#[test]
fn artifact_matches_rust_mirror_bit_exact() {
    let Some(mut rt) = runtime() else { return };
    let (n_cores, trace_len) = rt.config_for(2).expect("2-core artifact");
    let params = TraceParams::default();
    let pjrt = rt.generate_raw(n_cores, trace_len, &params.to_vec()).unwrap();
    let mirror = synth_raw(&params, n_cores, trace_len);
    assert_eq!(pjrt.len(), mirror.len());
    for (i, (a, b)) in pjrt.iter().zip(mirror.iter()).enumerate() {
        assert_eq!(a, b, "first divergence at flat index {i}");
    }
}

#[test]
fn artifact_matches_mirror_for_every_workload() {
    let Some(mut rt) = runtime() else { return };
    let (n_cores, trace_len) = rt.config_for(4).expect("4-core artifact");
    for spec in workloads::all() {
        let pjrt = rt.generate_raw(n_cores, trace_len, &spec.params.to_vec()).unwrap();
        let mirror = synth_raw(&spec.params, n_cores, trace_len);
        assert_eq!(pjrt, mirror, "workload {} diverges", spec.name);
    }
}

#[test]
fn artifact_decodes_into_runnable_workload() {
    use tardis_dsm::api::SimBuilder;
    use tardis_dsm::config::ProtocolKind;

    let Some(mut rt) = runtime() else { return };
    let spec = workloads::by_name("fft").unwrap();
    let (n_cores, trace_len) = rt.config_for(4).expect("4-core artifact");
    let w = rt.generate_workload(n_cores, trace_len, &spec.params).unwrap();
    assert_eq!(w.n_cores(), n_cores);
    assert_eq!(w.total_ops(), (n_cores * trace_len) as usize);
    let res = SimBuilder::small(n_cores, ProtocolKind::Tardis).workload(&w).run().unwrap();
    assert!(res.stats.cycles > 0);
    res.check_sc().unwrap();
}

#[test]
fn executables_are_cached_across_calls() {
    let Some(mut rt) = runtime() else { return };
    let (n_cores, trace_len) = rt.config_for(2).expect("2-core artifact");
    let p = TraceParams { seed: 1, ..Default::default() };
    let a = rt.generate_raw(n_cores, trace_len, &p.to_vec()).unwrap();
    // Second call exercises the compiled-executable cache.
    let b = rt.generate_raw(n_cores, trace_len, &p.to_vec()).unwrap();
    assert_eq!(a, b);
    // Different params produce different traces through the same
    // executable.
    let c = rt
        .generate_raw(n_cores, trace_len, &TraceParams { seed: 2, ..Default::default() }.to_vec())
        .unwrap();
    assert_ne!(a, c);
}
