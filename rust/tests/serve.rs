//! End-to-end tests of the serve subsystem over real TCP sockets:
//! wire-protocol round-trips, malformed-request rejection, concurrent
//! batches, graceful shutdown, and the acceptance criterion — a
//! batched sweep over the wire is bit-for-bit identical to serial
//! CLI-equivalent runs, on >= 4 concurrent workers.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use tardis_dsm::api::SimSpec;
use tardis_dsm::serve::json::{self, Json};
use tardis_dsm::serve::{ServeConfig, Server, SCHEMA};
use tardis_dsm::stats::SimStats;

fn start_server(workers: usize) -> Server {
    Server::start(ServeConfig { addr: "127.0.0.1:0".into(), workers }).expect("server start")
}

/// A minimal line-frame test client.
struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        // Generous: covers a full batch on a loaded CI machine.
        stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Self { reader, stream }
    }

    fn send(&mut self, line: &str) {
        self.stream.write_all(line.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        self.stream.flush().unwrap();
    }

    /// Read one frame; None at EOF.
    fn recv(&mut self) -> Option<Json> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => None,
            Ok(_) => Some(json::parse(&line).unwrap_or_else(|e| panic!("{line:?}: {e}"))),
            Err(e) => panic!("read: {e}"),
        }
    }

    /// Read frames until one of type `ty`, asserting everything
    /// skipped is stream chatter (progress / point_done).
    fn recv_type(&mut self, ty: &str) -> Json {
        loop {
            let v = self.recv().unwrap_or_else(|| panic!("EOF while waiting for {ty:?}"));
            let got = v.get("type").and_then(Json::as_str).unwrap().to_string();
            if got == ty {
                return v;
            }
            assert!(
                got == "progress" || got == "point_done",
                "unexpected {got:?} frame while waiting for {ty:?}: {v:?}"
            );
        }
    }
}

fn sweep_line(id: &str, seed: Option<u64>, progress_every: u64, points: &str) -> String {
    let seed = seed.map_or("null".to_string(), |s| s.to_string());
    format!(
        "{{\"type\":\"sweep\",\"id\":\"{id}\",\"seed\":{seed},\
         \"progress_every\":{progress_every},\"points\":[{points}]}}"
    )
}

#[test]
fn protocol_round_trip_over_tcp() {
    let server = start_server(2);
    let mut c = Client::connect(server.addr());

    c.send(r#"{"type":"hello"}"#);
    let hello = c.recv_type("hello");
    assert_eq!(hello.get("server").unwrap().as_str(), Some("tardis-serve"));
    assert_eq!(hello.get("schema").unwrap().as_str(), Some(SCHEMA));
    assert_eq!(hello.get("workers").unwrap().as_u64(), Some(2));

    c.send(r#"{"type":"ping"}"#);
    c.recv_type("pong");

    let points = r#"{"workload":"fft","cores":2,"trace_len":128},
                    {"workload":"barnes","cores":2,"trace_len":128,"protocol":"msi"}"#;
    c.send(&sweep_line("rt-1", Some(42), 50, points));
    let ack = c.recv_type("ack");
    assert_eq!(ack.get("batch_id").unwrap().as_str(), Some("rt-1"));
    assert_eq!(ack.get("n_points").unwrap().as_u64(), Some(2));
    assert!(ack.get("queue_depth").unwrap().as_u64().is_some());

    let result = c.recv_type("result");
    assert_eq!(result.get("batch_id").unwrap().as_str(), Some("rt-1"));
    let payload = result.get("payload").unwrap();
    assert_eq!(payload.get("schema").unwrap().as_str(), Some(SCHEMA));
    assert_eq!(payload.get("n_points").unwrap().as_u64(), Some(2));
    assert_eq!(payload.get("seed").unwrap().as_u64(), Some(42));
    assert_eq!(payload.get("workers").unwrap().as_u64(), Some(2));
    let timing = payload.get("timing").unwrap();
    assert!(timing.get("wall_s").unwrap().as_f64().unwrap() >= 0.0);
    assert!(timing.get("queue_depth_at_submit").unwrap().as_u64().is_some());
    let cols = payload.get("columns").unwrap();
    let workloads = cols.get("workload").unwrap().as_array().unwrap();
    assert_eq!(workloads[0].as_str(), Some("fft"));
    assert_eq!(workloads[1].as_str(), Some("barnes"));
    assert_eq!(cols.get("variant").unwrap().as_array().unwrap()[1].as_str(), Some("msi"));
    for (name, _) in SimStats::default().columns() {
        let col = cols.get(name).unwrap_or_else(|| panic!("missing column {name}"));
        assert_eq!(col.as_array().unwrap().len(), 2, "{name}");
    }
    assert!(cols.get("sim_cycles").unwrap().as_array().unwrap()[0].as_u64().unwrap() > 0);

    c.send(r#"{"type":"shutdown"}"#);
    c.recv_type("bye");
    assert!(c.recv().is_none(), "server must close after bye");
    server.join();
}

#[test]
fn progress_frames_stream_while_points_run() {
    let server = start_server(2);
    let mut c = Client::connect(server.addr());
    c.send(&sweep_line("pg", None, 25, r#"{"workload":"fft","cores":2,"trace_len":256}"#));
    c.recv_type("ack");
    let mut progress = 0;
    let mut point_done = 0;
    loop {
        let v = c.recv().expect("stream ended before result");
        match v.get("type").and_then(Json::as_str).unwrap() {
            "progress" => {
                progress += 1;
                assert_eq!(v.get("batch_id").unwrap().as_str(), Some("pg"));
                assert_eq!(v.get("point").unwrap().as_u64(), Some(0));
                assert!(v.get("memops").unwrap().as_u64().unwrap() > 0);
            }
            "point_done" => {
                point_done += 1;
                assert!(v.get("wall_s").unwrap().as_f64().unwrap() >= 0.0);
            }
            "result" => break,
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert!(progress > 0, "no progress frames for a 256-op trace at every-25");
    assert_eq!(point_done, 1);
    drop(c);
    server.shutdown();
}

/// Acceptance criterion: an 8-point batched sweep over the wire, run
/// on 4 concurrent workers, returns a schema-valid columnar payload
/// bit-for-bit equal to running each point serially through the
/// CLI's own lowering path (`SimSpec::builder().run()`).
#[test]
fn eight_point_wire_batch_matches_serial_cli_runs_bit_for_bit() {
    let workloads = ["fft", "barnes", "volrend", "radix"];
    let mut serial: Vec<SimStats> = Vec::new();
    let mut point_json = Vec::new();
    for (i, w) in workloads.iter().enumerate() {
        for protocol in ["tardis", "msi"] {
            // Serial reference: exactly what `tardis run --workload w
            // --protocol p --cores 4 --seed s` computes.
            let mut s = SimSpec::new(*w);
            s.protocol = tardis_dsm::config::ProtocolKind::parse(protocol).unwrap();
            s.cores = 4;
            s.trace_len = Some(256);
            s.seed = Some(7000 + i as u64);
            serial.push(s.builder().unwrap().run().unwrap().stats);
            point_json.push(format!(
                "{{\"workload\":\"{w}\",\"protocol\":\"{protocol}\",\"cores\":4,\
                 \"trace_len\":256,\"seed\":{}}}",
                7000 + i
            ));
        }
    }
    assert_eq!(serial.len(), 8);

    let server = start_server(4);
    assert_eq!(server.workers(), 4);
    let mut c = Client::connect(server.addr());
    c.send(&sweep_line("acc", None, 0, &point_json.join(",")));
    c.recv_type("ack");
    let result = c.recv_type("result");
    let cols = result.get("payload").unwrap().get("columns").unwrap();
    for (i, stats) in serial.iter().enumerate() {
        for (name, expect) in stats.columns() {
            let got = cols.get(name).unwrap().as_array().unwrap()[i].as_u64().unwrap();
            assert_eq!(got, expect, "point {i} column {name} diverged from serial run");
        }
    }
    drop(c);
    server.shutdown();
}

/// A `"threads": N` point runs on the sharded PDES engine inside the
/// serve worker, and its columnar result is bit-for-bit the serial
/// CLI run of the same point — the wire-level face of the engine's
/// determinism guarantee.
#[test]
fn threaded_wire_point_matches_the_serial_cli_run_bit_for_bit() {
    let mut s = SimSpec::new("fft");
    s.cores = 4;
    s.trace_len = Some(256);
    s.seed = Some(4242);
    let serial = s.builder().unwrap().run().unwrap().stats;

    let server = start_server(2);
    let mut c = Client::connect(server.addr());
    let points = r#"{"workload":"fft","cores":4,"trace_len":256,"seed":4242,"threads":4}"#;
    c.send(&sweep_line("pdes", None, 0, points));
    c.recv_type("ack");
    let result = c.recv_type("result");
    let cols = result.get("payload").unwrap().get("columns").unwrap();
    for (name, expect) in serial.columns() {
        let got = cols.get(name).unwrap().as_array().unwrap()[0].as_u64().unwrap();
        assert_eq!(got, expect, "column {name}: threaded wire point diverged from serial CLI run");
    }
    drop(c);
    server.shutdown();
}

#[test]
fn concurrent_sessions_get_their_own_correct_results() {
    let server = start_server(4);
    let addr = server.addr();
    let handles: Vec<_> = (0..3u64)
        .map(|k| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let id = format!("s{k}");
                let points = format!(
                    "{{\"workload\":\"fft\",\"cores\":2,\"trace_len\":128,\"seed\":{}}}",
                    100 + k
                );
                c.send(&sweep_line(&id, None, 0, &points));
                c.recv_type("ack");
                let result = c.recv_type("result");
                assert_eq!(result.get("batch_id").unwrap().as_str(), Some(id.as_str()));
                let cols = result.get("payload").unwrap().get("columns").unwrap();
                cols.get("sim_cycles").unwrap().as_array().unwrap()[0].as_u64().unwrap()
            })
        })
        .collect();
    let cycles: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // Each session ran its own seed: deterministic, and distinct
    // seeds give distinct traces.
    for &c in &cycles {
        let mut s = SimSpec::new("fft");
        s.cores = 2;
        s.trace_len = Some(128);
        // Recover which seed produced it — each must match exactly one.
        let matches = (0..3u64)
            .filter(|k| {
                let mut sk = s.clone();
                sk.seed = Some(100 + k);
                sk.builder().unwrap().run().unwrap().stats.cycles == c
            })
            .count();
        assert_eq!(matches, 1, "session result matched {matches} seeds");
    }
    server.shutdown();
}

#[test]
fn malformed_requests_are_rejected_without_killing_the_connection() {
    let server = start_server(1);
    let mut c = Client::connect(server.addr());
    let bads = [
        "this is not json",
        r#"{"type":"launch_missiles"}"#,
        r#"{"type":"sweep","id":"b","points":[]}"#,
        r#"{"type":"sweep","id":"b","points":[{"workload":"nope"}]}"#,
        r#"{"type":"sweep","id":"b","points":[{"workload":"fft","corez":4}]}"#,
        r#"{"type":"sweep","id":"b","points":[{"workload":"fft","numa_ratio":4}]}"#,
    ];
    for bad in bads {
        c.send(bad);
        let err = c.recv_type("error");
        assert!(
            !err.get("message").unwrap().as_str().unwrap().is_empty(),
            "error frame for {bad:?} carries no message"
        );
    }
    // Socket divisibility is a build-time geometry check (exactly as
    // on the CLI), so this sweep decodes, acks, and then fails as a
    // batch: the error frame carries the batch id.
    c.send(r#"{"type":"sweep","id":"geo","points":[{"workload":"fft","cores":6,"sockets":4}]}"#);
    c.recv_type("ack");
    let err = c.recv_type("error");
    assert_eq!(err.get("batch_id").unwrap().as_str(), Some("geo"));
    assert!(err.get("message").unwrap().as_str().unwrap().contains("point 0"));
    // The connection survives every rejection.
    c.send(r#"{"type":"ping"}"#);
    c.recv_type("pong");
    drop(c);
    server.shutdown();
}

/// Graceful shutdown drains in-flight sessions: a sweep submitted just
/// before `shutdown` still returns its full result before `bye`.
#[test]
fn shutdown_drains_in_flight_batches() {
    let server = start_server(2);
    let mut c = Client::connect(server.addr());
    let points = r#"{"workload":"fft","cores":2,"trace_len":256},
                    {"workload":"barnes","cores":2,"trace_len":256}"#;
    c.send(&sweep_line("drain", None, 0, points));
    c.send(r#"{"type":"shutdown"}"#);
    c.recv_type("ack");
    let result = c.recv_type("result");
    assert_eq!(
        result.get("payload").unwrap().get("n_points").unwrap().as_u64(),
        Some(2),
        "in-flight batch must complete through shutdown"
    );
    c.recv_type("bye");
    assert!(c.recv().is_none());
    server.join();
}
