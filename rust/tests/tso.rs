//! TSO end-to-end tests: the store buffer must make exactly the
//! store-buffering relaxation architecturally visible (SB's 0/0
//! outcome appears and is checker-accepted), while everything SC and
//! TSO agree on — MP, LB, CO, IRIW store atomicity, lock mutual
//! exclusion — stays forbidden.  Runs both core models and both
//! protocol families (Tardis timestamps and a physical-time
//! directory), since the buffer lives in the cores.

use tardis_dsm::api::{SimBuilder, SimReport};
use tardis_dsm::config::{Consistency, CoreModel, ProtocolKind, SystemConfig};
use tardis_dsm::prog::{litmus, load, store, Op, Program, Workload};
use tardis_dsm::testutil::{ProgGen, Rng};
use tardis_dsm::types::SHARED_BASE;

/// Jitter compute gaps to explore interleavings (deterministic per
/// seed).
fn jitter(w: &Workload, seed: u64) -> Workload {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1);
    let mut w = w.clone();
    for p in &mut w.programs {
        for op in &mut p.ops {
            match op {
                Op::Load { gap, .. } | Op::Store { gap, .. } => *gap = rng.below(12) as u32,
                _ => {}
            }
        }
    }
    w
}

fn observed(res: &SimReport, keys: &[(u32, u32)]) -> Vec<u64> {
    keys.iter()
        .map(|&(core, pc)| {
            res.log
                .records
                .iter()
                .find(|r| r.valid && r.core == core && r.pc == pc && r.value_read.is_some())
                .map(|r| r.value_read.unwrap())
                .unwrap_or(u64::MAX)
        })
        .collect()
}

fn run_litmus(
    w: &Workload,
    protocol: ProtocolKind,
    model: CoreModel,
    consistency: Consistency,
) -> SimReport {
    let mut cfg = SystemConfig::small(w.n_cores(), protocol);
    cfg.core_model = model;
    cfg.consistency = consistency;
    SimBuilder::from_config(cfg)
        .record_accesses(true)
        .workload(w)
        .run()
        .unwrap()
}

/// The acceptance-criterion pair: the identical SB program admits the
/// relaxed r0 = r1 = 0 outcome under TSO (observed and
/// checker-accepted) and never shows it under SC.
#[test]
fn sb_relaxed_outcome_under_tso_but_never_under_sc() {
    let lt = litmus::store_buffering();
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
        for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
            let mut relaxed_seen = false;
            for seed in 0..40u64 {
                let w = jitter(&lt.workload, seed);
                // TSO: every outcome TSO-legal, checker clean.
                let tso = run_litmus(&w, protocol, model, Consistency::Tso);
                let out = observed(&tso, &lt.observed);
                assert!(
                    (lt.allowed_tso)(&out),
                    "SB {protocol:?}/{model:?} seed {seed}: TSO-illegal outcome {out:?}"
                );
                tso.check_consistency().unwrap_or_else(|v| {
                    panic!("SB {protocol:?}/{model:?} seed {seed}: TSO violation {v:?}")
                });
                relaxed_seen |= out == [0, 0];
                // SC: the relaxed outcome must not appear.
                let sc = run_litmus(&w, protocol, model, Consistency::Sc);
                let out = observed(&sc, &lt.observed);
                assert!(
                    (lt.allowed)(&out),
                    "SB {protocol:?}/{model:?} seed {seed}: SC-forbidden outcome {out:?}"
                );
                sc.check_consistency().unwrap();
            }
            assert!(
                relaxed_seen,
                "SB {protocol:?}/{model:?}: store buffering never produced 0/0 under TSO"
            );
        }
    }
}

/// TSO is multi-copy atomic: IRIW's disagreeing-readers outcome stays
/// forbidden even with store buffers, because a store becomes visible
/// to all other cores at once (its drain).
#[test]
fn iriw_store_atomicity_holds_under_tso() {
    let lt = litmus::iriw();
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
        for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
            for seed in 0..40u64 {
                let w = jitter(&lt.workload, seed);
                let res = run_litmus(&w, protocol, model, Consistency::Tso);
                let out = observed(&res, &lt.observed);
                assert!(
                    (lt.allowed_tso)(&out),
                    "IRIW {protocol:?}/{model:?} seed {seed}: atomicity broken {out:?}"
                );
                res.check_consistency().unwrap();
            }
        }
    }
}

/// The full litmus suite under TSO: every outcome within the TSO
/// predicate and every log accepted by the TSO checker.
#[test]
fn litmus_suite_clean_under_tso() {
    for lt in litmus::all() {
        for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
                for seed in 0..15u64 {
                    let w = jitter(&lt.workload, seed);
                    let res = run_litmus(&w, protocol, model, Consistency::Tso);
                    let out = observed(&res, &lt.observed);
                    assert!(
                        (lt.allowed_tso)(&out),
                        "{} {protocol:?}/{model:?} seed {seed}: {out:?}",
                        lt.name
                    );
                    res.check_consistency().unwrap_or_else(|v| {
                        panic!("{} {protocol:?}/{model:?} seed {seed}: {v:?}", lt.name)
                    });
                }
            }
        }
    }
}

/// Store-to-load forwarding: a core reads its own buffered store (the
/// youngest one) before it drains; other cores still read the old
/// value until the drain.  The forwarded records are validated by the
/// checker's program-order rule.
#[test]
fn forwarding_returns_the_youngest_own_store() {
    let x = SHARED_BASE + 0x40;
    let w = Workload::new(vec![
        Program::new(vec![store(x, 1), store(x, 2), load(x)]),
        Program::new(vec![load(x)]),
    ]);
    for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
        let res = run_litmus(&w, ProtocolKind::Tardis, model, Consistency::Tso);
        res.check_consistency().unwrap();
        // Core 0's load must see its own youngest store.
        let own = observed(&res, &[(0, 2)]);
        assert_eq!(own, [2], "{model:?}: forwarding missed the youngest store");
        assert!(res.stats.sb_forwards > 0, "{model:?}: load was not forwarded");
        assert_eq!(res.stats.sb_stores, 2, "{model:?}: both stores should buffer");
    }
}

/// Fence-drain while the buffer is full: with a 2-entry buffer, a
/// burst of stores back-pressures issue (`sb_full_stalls`), and the
/// lock fence that follows must wait for a *complete* drain — the
/// full-buffer stall resumes on one free slot, the fence only on
/// empty, and the two wait conditions must not wedge each other.
#[test]
fn fence_drains_a_full_store_buffer() {
    use tardis_dsm::prog::{lock, unlock};
    use tardis_dsm::types::LOCK_BASE;
    let mut ops = Vec::new();
    for i in 0..6u64 {
        ops.push(store(SHARED_BASE + 0x80 + i, i + 1));
    }
    ops.push(lock(LOCK_BASE + 1));
    ops.push(load(SHARED_BASE + 0x80));
    ops.push(unlock(LOCK_BASE + 1));
    let w = Workload::new(vec![Program::new(ops), Program::new(vec![load(SHARED_BASE)])]);
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
        for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
            let mut cfg = SystemConfig::small(2, protocol);
            cfg.core_model = model;
            cfg.consistency = Consistency::Tso;
            cfg.sb_entries = 2;
            let res = SimBuilder::from_config(cfg)
                .record_accesses(true)
                .workload(&w)
                .run()
                .unwrap();
            res.check_consistency().unwrap_or_else(|v| {
                panic!("{protocol:?}/{model:?}: violation {v:?}")
            });
            assert_eq!(res.stats.sb_stores, 6, "{protocol:?}/{model:?}");
            assert!(
                res.stats.sb_full_stalls > 0,
                "{protocol:?}/{model:?}: a 6-store burst must fill a 2-entry buffer"
            );
            assert_eq!(res.stats.locks_acquired, 1, "{protocol:?}/{model:?}");
            // The post-fence load ran with the buffer drained: it read
            // the coherent value, not a forward.
            let post_fence = observed(&res, &[(0, 7)]);
            assert_eq!(post_fence, [1], "{protocol:?}/{model:?}: fence lost a store");
        }
    }
}

/// Retirement ordering under back-pressure: with a 1-entry buffer
/// every store drains before the next can retire, and the drained
/// stores must become globally visible in program order (TSO's
/// store-store order) — read off the access log's commit sequence.
#[test]
fn backpressured_drains_retire_in_program_order() {
    let addrs: Vec<u64> = (0..5).map(|i| SHARED_BASE + 0x100 + i).collect();
    let ops: Vec<Op> = addrs.iter().enumerate().map(|(i, &a)| store(a, i as u64)).collect();
    let w = Workload::new(vec![Program::new(ops), Program::new(vec![load(SHARED_BASE)])]);
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
        for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
            let mut cfg = SystemConfig::small(2, protocol);
            cfg.core_model = model;
            cfg.consistency = Consistency::Tso;
            cfg.sb_entries = 1;
            let res = SimBuilder::from_config(cfg)
                .record_accesses(true)
                .workload(&w)
                .run()
                .unwrap();
            res.check_consistency().unwrap();
            assert!(res.stats.sb_full_stalls > 0, "{protocol:?}/{model:?}: no back-pressure");
            // The store records in global commit order must carry
            // ascending pcs (drain order == program order).
            let drained_pcs: Vec<u32> = res
                .log
                .records
                .iter()
                .filter(|r| r.valid && r.core == 0 && r.value_written.is_some())
                .map(|r| r.pc)
                .collect();
            assert_eq!(
                drained_pcs,
                vec![0, 1, 2, 3, 4],
                "{protocol:?}/{model:?}: stores drained out of order"
            );
        }
    }
}

/// Forwarding with the buffer at capacity: the newest of multiple
/// same-address buffered stores wins even while the head is in
/// flight and later stores are stalled behind a full buffer.
#[test]
fn forwarding_picks_newest_store_under_full_buffer() {
    let x = SHARED_BASE + 0x140;
    let y = SHARED_BASE + 0x141;
    let w = Workload::new(vec![
        Program::new(vec![store(x, 1), store(y, 7), store(x, 2), load(x), load(y)]),
        Program::new(vec![load(SHARED_BASE)]),
    ]);
    for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
        let mut cfg = SystemConfig::small(2, ProtocolKind::Tardis);
        cfg.core_model = model;
        cfg.consistency = Consistency::Tso;
        cfg.sb_entries = 3;
        let res = SimBuilder::from_config(cfg)
            .record_accesses(true)
            .workload(&w)
            .run()
            .unwrap();
        res.check_consistency().unwrap();
        assert_eq!(observed(&res, &[(0, 3)]), [2], "{model:?}: stale forward for x");
        assert_eq!(observed(&res, &[(0, 4)]), [7], "{model:?}: wrong line forwarded for y");
        assert!(res.stats.sb_forwards >= 2, "{model:?}");
    }
}

/// Synchronization fences the buffer: lock-protected increments stay
/// mutually exclusive under TSO (the release store is not reordered
/// into the critical section of the next owner).
#[test]
fn locks_remain_mutually_exclusive_under_tso() {
    use tardis_dsm::prog::{lock, unlock};
    use tardis_dsm::types::LOCK_BASE;
    let mut progs = Vec::new();
    for c in 0..4u32 {
        let mut ops = vec![];
        for i in 0..8 {
            ops.push(lock(LOCK_BASE));
            ops.push(load(SHARED_BASE + 50));
            ops.push(store(SHARED_BASE + 50, (c as u64) * 100 + i));
            ops.push(unlock(LOCK_BASE));
        }
        progs.push(Program::new(ops));
    }
    let w = Workload::new(progs);
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
        for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
            let res = run_litmus(&w, protocol, model, Consistency::Tso);
            assert_eq!(res.stats.locks_acquired, 32, "{protocol:?}/{model:?}");
            res.check_consistency().unwrap_or_else(|v| {
                panic!("{protocol:?}/{model:?}: violation {v:?}")
            });
        }
    }
}

/// Random mixed programs (stores, loads, locks, barriers) stay
/// TSO-consistent on every protocol and core model — the property
/// net for the store-buffer state machines.
#[test]
fn random_programs_are_tso_consistent() {
    let gen = ProgGen {
        n_cores: 4,
        ops_per_core: 60,
        store_pct: 45,
        lock_pct: 10,
        barrier_every: 17,
        ..Default::default()
    };
    tardis_dsm::testutil::prop_check(10, 0x7503AB, |seed, rng| {
        let w = gen.generate(rng);
        for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
            for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
                let res = run_litmus(&w, protocol, model, Consistency::Tso);
                res.check_consistency().unwrap_or_else(|v| {
                    panic!("seed {seed:#x} {protocol:?}/{model:?}: {v:?}")
                });
                assert!(res.stats.sb_stores > 0, "seed {seed:#x}: no stores buffered");
            }
        }
    });
}

/// Under SC nothing touches the store buffer: the counters stay zero
/// and the engine's behavior is exactly the pre-TSO machine.
#[test]
fn sc_runs_never_touch_the_store_buffer() {
    let gen = ProgGen::default();
    let mut rng = Rng::new(0x5C);
    let w = gen.generate(&mut rng);
    for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
        let res = run_litmus(&w, ProtocolKind::Tardis, model, Consistency::Sc);
        assert_eq!(res.stats.sb_stores, 0);
        assert_eq!(res.stats.sb_forwards, 0);
        assert_eq!(res.stats.sb_full_stalls, 0);
        res.check_consistency().unwrap();
    }
}