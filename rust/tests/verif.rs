//! Integration tests for the verification subsystem (`verif::`): the
//! bounded exhaustive model checker must find the shipped controllers
//! clean at small bounds, repeat-run to identical state counts, and
//! reject configurations it cannot make exact claims about.
//!
//! Compiled out when a seeded fault feature is on — with a mutation in
//! the controllers the clean-run expectations below are *supposed* to
//! fail (that flip is asserted in `tests/verif_mutation.rs`).
#![cfg(not(any(feature = "verif-mutate-wts-skip", feature = "verif-mutate-over-lease")))]

use tardis_dsm::config::{Consistency, ProtocolKind};
use tardis_dsm::verif::{self, ExploreSchedule, VerifBounds};

fn bounds(max_ts: u32) -> VerifBounds {
    VerifBounds { max_ts, ..VerifBounds::default() }
}

/// The full protocol x consistency matrix is violation-free at the
/// smallest interesting bounds, and every run actually explored a
/// branching graph (not a single path).
#[test]
fn full_matrix_is_clean_at_tiny_bounds() {
    let report = verif::run_matrix(
        &[ProtocolKind::Tardis, ProtocolKind::Msi],
        &[Consistency::Sc, Consistency::Tso],
        bounds(1),
    )
    .unwrap();
    assert_eq!(report.runs.len(), 4);
    assert!(report.passed());
    for r in &report.runs {
        let o = &r.outcome;
        assert!(
            o.passed(),
            "{}/{}: counterexample {:#?}",
            r.protocol,
            r.consistency,
            o.counterexample
        );
        assert!(o.states > 10, "{}/{}: suspiciously small graph", r.protocol, r.consistency);
        assert!(o.terminal_states > 0, "{}/{}: no quiescent end state", r.protocol, r.consistency);
        assert!(o.trace_checks > 0, "{}/{}: linearization never ran", r.protocol, r.consistency);
        for inv in &o.invariants {
            assert!(inv.checked > 0, "{}: invariant {} never evaluated", r.protocol, inv.name);
            assert_eq!(inv.violations, 0);
        }
    }
    let json = report.to_json();
    assert!(json.contains("\"schema\": \"tardis-verif-v1\""));
    assert!(json.contains("\"counterexample\": null"));
}

/// Exact-state exploration is deterministic: the explored-state count
/// (and everything else in the outcome) is bit-identical across
/// repeat runs — the property the CI baseline comparison rests on.
#[test]
fn repeat_runs_explore_identical_state_counts() {
    let protocols = [ProtocolKind::Tardis, ProtocolKind::Msi];
    let models = [Consistency::Sc, Consistency::Tso];
    let a = verif::run_matrix(&protocols, &models, bounds(1)).unwrap();
    let b = verif::run_matrix(&protocols, &models, bounds(1)).unwrap();
    assert_eq!(a.runs, b.runs, "repeat exploration diverged");
}

/// Deeper Tardis run (more timestamps, SC + TSO): still clean, and the
/// graph grows strictly with the op budget.
#[test]
fn tardis_stays_clean_with_more_ops() {
    let shallow = verif::run_matrix(&[ProtocolKind::Tardis], &[Consistency::Sc], bounds(1))
        .unwrap();
    let deep = verif::run_matrix(
        &[ProtocolKind::Tardis],
        &[Consistency::Sc, Consistency::Tso],
        bounds(2),
    )
    .unwrap();
    assert!(deep.passed(), "counterexample: {:#?}", deep.runs[0].outcome.counterexample);
    assert!(
        deep.runs[0].outcome.states > shallow.runs[0].outcome.states,
        "doubling the op budget must enlarge the state graph"
    );
}

/// Two distinct lines exercise the line-index plumbing (and, for
/// Tardis, two independent lease books at the same TM).
#[test]
fn two_line_runs_are_clean() {
    let b = VerifBounds { lines: 2, max_ts: 1, ..VerifBounds::default() };
    let report = verif::run_matrix(
        &[ProtocolKind::Tardis, ProtocolKind::Msi],
        &[Consistency::Sc],
        b,
    )
    .unwrap();
    assert!(report.passed());
    for r in &report.runs {
        assert!(r.outcome.terminal_states > 0);
    }
}

/// The PDES engine's model-level soundness check: enumerating each
/// state's transitions in the sharded order (shard-major by the
/// engine's tile-block ownership rule) explores exactly the same
/// reachable-state space as the serial order — states, transitions,
/// depth, terminal states, and every invariant count bit-identical.
/// This is what `tools/validate_verif.py --baseline` pins in CI when
/// the sharded schedule runs: the report is indistinguishable from
/// the serial baseline.
#[test]
fn sharded_schedule_explores_the_same_state_space_as_serial() {
    let protocols = [ProtocolKind::Tardis, ProtocolKind::Msi];
    let models = [Consistency::Sc, Consistency::Tso];
    let serial = verif::run_matrix(&protocols, &models, bounds(1)).unwrap();
    for shards in [2u32, 4] {
        let sharded = verif::run_matrix_scheduled(
            &protocols,
            &models,
            bounds(1),
            ExploreSchedule::Sharded { shards },
        )
        .unwrap();
        assert_eq!(
            serial.runs, sharded.runs,
            "{shards}-shard schedule changed the explored state space"
        );
        assert_eq!(serial.to_json(), sharded.to_json(), "reports must diff clean");
    }
}

/// Ackwise's limited-pointer overflow is a conservative
/// over-approximation, so exact-state verification refuses it rather
/// than reporting a vacuous pass.
#[test]
fn ackwise_is_rejected() {
    let err = verif::run_matrix(&[ProtocolKind::Ackwise], &[Consistency::Sc], bounds(1))
        .unwrap_err();
    assert!(err.contains("ackwise"), "unhelpful error: {err}");
}

/// Out-of-range bounds are rejected up front with the flag name.
#[test]
fn bounds_are_validated() {
    let b = VerifBounds { cores: 9, ..VerifBounds::default() };
    let err = verif::run_matrix(&[ProtocolKind::Tardis], &[Consistency::Sc], b).unwrap_err();
    assert!(err.contains("--cores"), "unhelpful error: {err}");
}
