//! Determinism regression suite (§Perf): the simulator must be a pure
//! function of (config, workload).  Repeated runs of the same session
//! shape yield bit-identical statistics, access logs, and per-core
//! finish times — the property the calendar event queue, the message
//! slab, and the fixed-seed Fx hash maps are all required to preserve.
//! (The old-vs-new queue cross-check lives in `sim::engine`'s unit
//! tests, where the legacy-heap hook is compiled in.)

use tardis_dsm::api::{SimBuilder, SimReport};
use tardis_dsm::config::{
    Consistency, CoreModel, LeasePolicyKind, PdesMode, ProtocolKind, SocketInterleave,
    SystemConfig, TopologyConfig, DEFAULT_MAX_LEASE,
};
use tardis_dsm::testutil::{ProgGen, Rng};
use tardis_dsm::trace::synth_workload;
use tardis_dsm::workloads;

fn assert_identical(a: &SimReport, b: &SimReport, what: &str) {
    assert_eq!(a.stats, b.stats, "{what}: stats diverged");
    assert_eq!(a.log.records, b.log.records, "{what}: access logs diverged");
    assert_eq!(a.core_finish, b.core_finish, "{what}: finish times diverged");
}

#[test]
fn repeated_runs_are_bit_identical_across_protocols_and_core_models() {
    let spec = workloads::by_name("barnes").unwrap();
    let w = synth_workload(&spec.params, 8, 512);
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
            let run = || {
                SimBuilder::from_config(SystemConfig::small(8, protocol))
                    .core_model(model)
                    .record_accesses(true)
                    .workload(&w)
                    .run()
                    .unwrap()
            };
            let a = run();
            let b = run();
            assert!(a.stats.events > 0, "event counter must be populated");
            assert_identical(&a, &b, &format!("{protocol:?}/{model:?}"));
        }
    }
}

/// The timestamp-policy layer and the consistency generalization must
/// both be pure functions of (config, workload): every lease policy x
/// consistency model combination repeat-runs to bit-identical
/// [`tardis_dsm::SimStats`], access logs, and finish times — on both
/// core models (the TSO store buffer touches each differently).
#[test]
fn repeated_runs_are_bit_identical_across_lease_policies_and_consistency() {
    let spec = workloads::by_name("volrend").unwrap();
    let w = synth_workload(&spec.params, 8, 512);
    let policies = [
        LeasePolicyKind::Static,
        LeasePolicyKind::Dynamic { max_lease: DEFAULT_MAX_LEASE },
        LeasePolicyKind::Predictive { max_lease: DEFAULT_MAX_LEASE },
    ];
    for policy in policies {
        for model in [Consistency::Sc, Consistency::Tso] {
            for core_model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
                let run = || {
                    SimBuilder::from_config(SystemConfig::small(8, ProtocolKind::Tardis))
                        .core_model(core_model)
                        .consistency(model)
                        .lease_policy(policy)
                        .record_accesses(true)
                        .workload(&w)
                        .run()
                        .unwrap()
                };
                let a = run();
                let b = run();
                assert_identical(
                    &a,
                    &b,
                    &format!("{policy:?}/{model:?}/{core_model:?}"),
                );
                a.check_consistency().unwrap_or_else(|v| {
                    panic!("{policy:?}/{model:?}/{core_model:?}: violation {v:?}")
                });
                if model == Consistency::Tso {
                    assert!(
                        a.stats.sb_stores > 0,
                        "{policy:?}/{core_model:?}: TSO run never buffered a store"
                    );
                }
            }
        }
    }
}

/// The topology subsystem must also be a pure function of (config,
/// workload): every (sockets, numa-ratio, interleave) point
/// repeat-runs bit-identically — including the socket-split counters,
/// which live inside [`tardis_dsm::SimStats`]'s equality.  The
/// 1-socket point doubles as the flat-vs-legacy check: whatever the
/// numa knobs say, one socket must reproduce the default flat run
/// exactly (the deeper cross-config equality lives in
/// `tests/topology.rs`).
#[test]
fn repeated_runs_are_bit_identical_across_topologies() {
    let spec = workloads::by_name("fft").unwrap();
    let w = synth_workload(&spec.params, 8, 512);
    let flat_baseline = SimBuilder::from_config(SystemConfig::small(8, ProtocolKind::Tardis))
        .record_accesses(true)
        .workload(&w)
        .run()
        .unwrap();
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
        for sockets in [1u32, 2, 4] {
            for interleave in [SocketInterleave::Line, SocketInterleave::Block] {
                let run = || {
                    let mut cfg = SystemConfig::small(8, protocol);
                    cfg.topology =
                        TopologyConfig { sockets, numa_ratio: 4, interleave };
                    SimBuilder::from_config(cfg)
                        .record_accesses(true)
                        .workload(&w)
                        .run()
                        .unwrap()
                };
                let a = run();
                let b = run();
                assert_identical(&a, &b, &format!("{protocol:?}/{sockets}s/{interleave:?}"));
                if sockets == 1 {
                    assert_eq!(a.stats.socket.inter_msgs, 0);
                    if protocol == ProtocolKind::Tardis {
                        assert_identical(&a, &flat_baseline, "1-socket vs legacy flat");
                    }
                } else {
                    assert!(
                        a.stats.socket.inter_msgs > 0,
                        "{protocol:?}/{sockets}s: no cross-socket traffic"
                    );
                }
            }
        }
    }
}

/// Multi-socket points across the *numa knobs*: the inter-socket
/// latency ratio must only scale timing — never introduce
/// nondeterminism — and the TSO store buffer must compose with the
/// socket-sliced TM/directory exactly as reproducibly as SC does.
/// (Extends the matrix above, which pins numa_ratio and runs SC only.)
#[test]
fn repeated_runs_are_bit_identical_across_numa_ratios_and_tso() {
    let spec = workloads::by_name("ocean-c").unwrap();
    let w = synth_workload(&spec.params, 8, 512);
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
        for numa_ratio in [1u32, 8] {
            for sockets in [2u32, 4] {
                for model in [Consistency::Sc, Consistency::Tso] {
                    let run = || {
                        let mut cfg = SystemConfig::small(8, protocol);
                        cfg.topology = TopologyConfig {
                            sockets,
                            numa_ratio,
                            interleave: SocketInterleave::Line,
                        };
                        cfg.consistency = model;
                        SimBuilder::from_config(cfg)
                            .record_accesses(true)
                            .workload(&w)
                            .run()
                            .unwrap()
                    };
                    let a = run();
                    let b = run();
                    let what = format!("{protocol:?}/{sockets}s/ratio{numa_ratio}/{model:?}");
                    assert_identical(&a, &b, &what);
                    assert!(
                        a.stats.socket.inter_msgs > 0,
                        "{what}: no cross-socket traffic"
                    );
                    a.check_consistency()
                        .unwrap_or_else(|v| panic!("{what}: violation {v:?}"));
                    if model == Consistency::Tso {
                        assert!(
                            a.stats.sb_stores > 0,
                            "{what}: TSO run never buffered a store"
                        );
                    }
                }
            }
        }
    }
}

/// The serve execution path — [`SimSpec`]s fanned across a shared
/// [`WorkerPool`](tardis_dsm::coordinator::WorkerPool) — must return
/// the exact bits a serial `SimSpec::builder().run()` of each point
/// produces: pooled threads, submission order, and progress streaming
/// are all outside the (config, workload) pure function.
#[test]
fn pooled_batches_match_serial_runs_bit_for_bit() {
    use tardis_dsm::api::SimSpec;
    use tardis_dsm::coordinator::WorkerPool;
    use tardis_dsm::serve::{run_batch, SweepRequest};

    let mut points = Vec::new();
    for (i, workload) in ["fft", "barnes", "volrend", "radix"].iter().enumerate() {
        for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
            let mut s = SimSpec::new(*workload);
            s.protocol = protocol;
            s.cores = 4;
            s.trace_len = Some(256);
            s.seed = Some(1000 + i as u64);
            points.push(s);
        }
    }
    let serial: Vec<_> =
        points.iter().map(|s| s.builder().unwrap().run().unwrap().stats).collect();

    let pool = WorkerPool::new(4);
    let req = SweepRequest { id: "det".into(), seed: None, progress_every: 0, points };
    let batched = run_batch(&pool, &req, None).unwrap();
    assert_eq!(batched.len(), serial.len());
    for (i, (b, s)) in batched.iter().zip(&serial).enumerate() {
        assert_eq!(b.stats, *s, "point {i}: pooled run diverged from serial run");
    }
    // And the batch itself repeats bit-identically.
    let again = run_batch(&pool, &req, None).unwrap();
    for (b, a) in batched.iter().zip(&again) {
        assert_eq!(b.stats, a.stats, "re-batched run diverged");
    }
}

/// The parallel sharded PDES engine (§Perf, DESIGN.md §11): an
/// N-thread conservative-lookahead run must be *bit-for-bit* the
/// serial run — every `SimStats` counter, every access-log record
/// (including its global commit sequence), every per-core finish time
/// — across shard counts, fabrics, consistency models, and protocols.
/// This is the tentpole determinism matrix: threads x sockets x
/// {SC, TSO} x {tardis, msi} at 8 cores.
#[test]
fn parallel_shards_match_serial_bit_for_bit_across_the_matrix() {
    let spec = workloads::by_name("water-sp").unwrap();
    let w = synth_workload(&spec.params, 8, 512);
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
        for sockets in [1u32, 4] {
            for model in [Consistency::Sc, Consistency::Tso] {
                let run = |threads: u32| {
                    let mut cfg = SystemConfig::small(8, protocol);
                    if sockets > 1 {
                        cfg.topology = TopologyConfig {
                            sockets,
                            numa_ratio: 4,
                            interleave: SocketInterleave::Line,
                        };
                    }
                    cfg.consistency = model;
                    SimBuilder::from_config(cfg)
                        .record_accesses(true)
                        .workload(&w)
                        .threads(threads)
                        .run()
                        .unwrap()
                };
                let serial = run(1);
                serial
                    .check_consistency()
                    .unwrap_or_else(|v| panic!("{protocol:?}: violation {v:?}"));
                for threads in [2u32, 4] {
                    let par = run(threads);
                    assert_identical(
                        &par,
                        &serial,
                        &format!("{protocol:?}/{sockets}s/{model:?}/t{threads}"),
                    );
                    assert_eq!(par.stats.parallel.threads, threads);
                    assert_eq!(par.stats.parallel.shards.len(), threads as usize);
                    assert!(par.stats.parallel.lookahead >= 1);
                    assert!(par.stats.parallel.epochs > 0);
                }
            }
        }
    }
}

/// PR-9 synchronization/balancing matrix: both PDES modes, with and
/// without count-driven rebalancing, at even *and uneven* thread
/// counts (3 threads over 8 cores shards 3/3/2) must all reproduce
/// the serial run bit-for-bit.  Null-message runs additionally have
/// to exchange channel-clock promises — a NullMsg run with zero null
/// messages silently fell back to something else.
#[test]
fn pdes_modes_and_rebalancing_match_serial_bit_for_bit() {
    let spec = workloads::by_name("lu-nc").unwrap();
    let w = synth_workload(&spec.params, 8, 512);
    let run = |threads: u32, mode: PdesMode, rebalance: u32| {
        SimBuilder::from_config(SystemConfig::small(8, ProtocolKind::Tardis))
            .record_accesses(true)
            .workload(&w)
            .threads(threads)
            .pdes_mode(mode)
            .rebalance_every(rebalance)
            .run()
            .unwrap()
    };
    let serial = run(1, PdesMode::Epoch, 0);
    serial.check_sc().unwrap();
    for mode in [PdesMode::Epoch, PdesMode::NullMsg] {
        for rebalance in [0u32, 3] {
            for threads in [2u32, 3, 4] {
                let par = run(threads, mode, rebalance);
                let what = format!("{mode:?}/rb{rebalance}/t{threads}");
                assert_identical(&par, &serial, &what);
                assert_eq!(par.stats.parallel.threads, threads);
                assert_eq!(par.stats.parallel.shards.len(), threads as usize);
                if mode == PdesMode::NullMsg {
                    assert!(
                        par.stats.parallel.null_msgs > 0,
                        "{what}: null-message run exchanged no promises"
                    );
                } else {
                    assert_eq!(
                        par.stats.parallel.null_msgs, 0,
                        "{what}: epoch mode must not count null messages"
                    );
                }
            }
        }
    }
}

/// Deterministic load balancing must actually engage on a skewed
/// workload — one hot tile carrying ~10x the operations — and, being
/// driven purely by *simulated* event counts, must repartition the
/// same way every run: same `rebalances`, same `migrated_events`,
/// same simulated results, in both synchronization modes.
#[test]
fn skewed_workloads_trigger_deterministic_rebalancing() {
    use tardis_dsm::prog::{load, store, Program, Workload};

    let shared = 0x10u64;
    let mut programs = Vec::new();
    for core in 0..4u32 {
        let ops = if core == 0 { 480 } else { 48 };
        let base = 0x100 * (core as u64 + 1);
        let mut prog = Vec::new();
        for pc in 0..ops {
            prog.push(match pc % 4 {
                0 => load(base + (pc as u64 % 13)),
                1 => store(base + (pc as u64 % 13), Workload::store_value(core, pc)),
                2 => load(shared),
                _ => store(shared, Workload::store_value(core, pc)),
            });
        }
        programs.push(Program::new(prog));
    }
    let w = Workload::new(programs);

    let run = |threads: u32, mode: PdesMode, rebalance: u32| {
        SimBuilder::from_config(SystemConfig::small(4, ProtocolKind::Tardis))
            .record_accesses(true)
            .workload(&w)
            .threads(threads)
            .pdes_mode(mode)
            .rebalance_every(rebalance)
            .run()
            .unwrap()
    };
    let serial = run(1, PdesMode::Epoch, 0);
    serial.check_sc().unwrap();
    for mode in [PdesMode::Epoch, PdesMode::NullMsg] {
        let a = run(2, mode, 2);
        let what = format!("skewed/{mode:?}");
        assert_identical(&a, &serial, &what);
        assert!(
            a.stats.parallel.rebalances > 0,
            "{what}: the hot tile never triggered a repartition"
        );
        // Count-driven decisions repeat bit-identically run to run
        // (migrated_events may legitimately be 0 when the moved tile's
        // queue is empty at the cut, but it must repeat exactly).
        let b = run(2, mode, 2);
        assert_identical(&b, &serial, &what);
        assert_eq!(a.stats.parallel.rebalances, b.stats.parallel.rebalances, "{what}");
        assert_eq!(
            a.stats.parallel.migrated_events, b.stats.parallel.migrated_events,
            "{what}"
        );
    }
}

#[test]
fn repeated_runs_are_bit_identical_on_sync_heavy_programs() {
    // Lock/barrier microcode exercises spin wakes, parked cores, and
    // the channel-clock FIFO harder than plain traces.
    let mut rng = Rng::new(0xD37E_2217);
    let gen = ProgGen { lock_pct: 25, barrier_every: 11, ..ProgGen::default() };
    for trial in 0..3 {
        let w = gen.generate(&mut rng);
        for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi] {
            let run = || {
                SimBuilder::small(gen.n_cores, protocol)
                    .workload(&w)
                    .run()
                    .unwrap()
            };
            let a = run();
            let b = run();
            assert_identical(&a, &b, &format!("trial {trial} {protocol:?}"));
            a.check_sc().unwrap();
        }
    }
}
