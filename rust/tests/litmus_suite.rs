//! Litmus tests under all three protocols and both core models:
//! forbidden SC outcomes must never appear, and the full SC witness
//! checker must pass, across many interleaving perturbations.

use tardis_dsm::api::SimReport;
use tardis_dsm::config::{CoreModel, ProtocolKind, SystemConfig};
use tardis_dsm::prog::{checker, litmus, Op, Workload};
use tardis_dsm::testutil::{run_logged, Rng};

/// Jitter compute gaps to explore interleavings (deterministic per
/// seed).
fn jitter(w: &Workload, seed: u64) -> Workload {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1);
    let mut w = w.clone();
    for p in &mut w.programs {
        for op in &mut p.ops {
            match op {
                Op::Load { gap, .. } | Op::Store { gap, .. } => *gap = rng.below(12) as u32,
                _ => {}
            }
        }
    }
    w
}

fn observed(res: &SimReport, keys: &[(u32, u32)]) -> Vec<u64> {
    keys.iter()
        .map(|&(core, pc)| {
            res.log
                .records
                .iter()
                .find(|r| r.valid && r.core == core && r.pc == pc && r.value_read.is_some())
                .map(|r| r.value_read.unwrap())
                .unwrap_or(u64::MAX)
        })
        .collect()
}

fn run_litmus(protocol: ProtocolKind, model: CoreModel, seeds: u64) {
    for lt in litmus::all() {
        for seed in 0..seeds {
            let w = jitter(&lt.workload, seed);
            let mut cfg = SystemConfig::small(w.n_cores(), protocol);
            cfg.core_model = model;
            let res = run_logged(cfg, &w)
                .unwrap_or_else(|e| panic!("{} seed {seed}: {e}", lt.name));
            let out = observed(&res, &lt.observed);
            assert!(
                (lt.allowed)(&out),
                "{} under {:?}/{:?} seed {seed}: forbidden outcome {:?}",
                lt.name,
                protocol,
                model,
                out
            );
            checker::check(&res.log).unwrap_or_else(|v| {
                panic!("{} under {:?}/{:?} seed {seed}: SC violation {v:?}", lt.name, protocol, model)
            });
        }
    }
}

#[test]
fn litmus_tardis_inorder() {
    run_litmus(ProtocolKind::Tardis, CoreModel::InOrder, 40);
}

#[test]
fn litmus_tardis_ooo() {
    run_litmus(ProtocolKind::Tardis, CoreModel::OutOfOrder, 40);
}

#[test]
fn litmus_msi_inorder() {
    run_litmus(ProtocolKind::Msi, CoreModel::InOrder, 40);
}

#[test]
fn litmus_msi_ooo() {
    run_litmus(ProtocolKind::Msi, CoreModel::OutOfOrder, 40);
}

#[test]
fn litmus_ackwise_inorder() {
    run_litmus(ProtocolKind::Ackwise, CoreModel::InOrder, 40);
}

#[test]
fn litmus_ackwise_ooo() {
    run_litmus(ProtocolKind::Ackwise, CoreModel::OutOfOrder, 40);
}

/// The paper's §III-C3/§III-D2 claim: A=B=0 is impossible for the
/// store-buffering program even on out-of-order cores, because the
/// commit-time timestamp check forces at least one load to observe the
/// other core's store.
#[test]
fn store_buffering_never_zero_zero_tardis_ooo_wide_sweep() {
    let lt = litmus::store_buffering();
    for seed in 0..200u64 {
        let w = jitter(&lt.workload, seed);
        let mut cfg = SystemConfig::small(2, ProtocolKind::Tardis);
        cfg.core_model = CoreModel::OutOfOrder;
        cfg.ooo_window = 8;
        let res = run_logged(cfg, &w).unwrap();
        let out = observed(&res, &lt.observed);
        assert!(!(out[0] == 0 && out[1] == 0), "A=B=0 observed at seed {seed}");
    }
}

/// Tardis litmus under speculation pressure: shared traffic before the
/// message-passing pair forces expired lines and live renewals.
#[test]
fn litmus_with_speculation_pressure() {
    use tardis_dsm::prog::{load, store, Program};
    use tardis_dsm::types::SHARED_BASE;
    for seed in 0..20u64 {
        let mut p0 = vec![];
        let mut p1 = vec![];
        let mut rng = Rng::new(seed + 1);
        for i in 0..30 {
            p0.push(load(SHARED_BASE + 100 + (i % 5)));
            p1.push(store(SHARED_BASE + 100 + rng.below(5), i));
        }
        p0.push(store(litmus::A, 1));
        p0.push(store(litmus::F, 1));
        p1.push(load(litmus::F));
        p1.push(load(litmus::A));
        let w = Workload::new(vec![Program::new(p0), Program::new(p1)]);
        let cfg = SystemConfig::small(2, ProtocolKind::Tardis);
        let res = run_logged(cfg, &w).unwrap();
        checker::check(&res.log).unwrap_or_else(|v| panic!("seed {seed}: {v:?}"));
        // MP outcome: F=1 implies A=1.
        let f = observed(&res, &[(1, 30)])[0];
        let a = observed(&res, &[(1, 31)])[0];
        assert!(!(f == 1 && a == 0), "MP violation at seed {seed}");
    }
}
