//! The §V case study (Listing 2): the program runs on both Tardis and
//! MSI; Tardis must avoid MSI's invalidation stalls (finishing at
//! least as fast) and may produce the paper's "time-traveling"
//! interleaving — the second L(B) of core 0 logically ordered before
//! both stores to B despite committing physically later.

use tardis_dsm::config::{ProtocolKind, SystemConfig};
use tardis_dsm::prog::{checker, litmus};
use tardis_dsm::testutil::run_logged;

#[test]
fn case_study_runs_clean_on_both_protocols() {
    let w = litmus::case_study();
    for protocol in [ProtocolKind::Msi, ProtocolKind::Tardis] {
        let res = run_logged(SystemConfig::small(2, protocol), &w).unwrap();
        checker::check(&res.log).unwrap_or_else(|v| panic!("{protocol:?}: {v:?}"));
        assert_eq!(res.stats.memops, 8, "{protocol:?}: 5 + 3 ops");
    }
}

#[test]
fn tardis_is_not_slower_than_msi_on_case_study() {
    // The case study is constructed so MSI pays two invalidation
    // round-trips that Tardis avoids (§V-B "the cycle saving of Tardis
    // mainly comes from the removal of invalidations").
    let w = litmus::case_study();
    let msi = run_logged(SystemConfig::small(2, ProtocolKind::Msi), &w).unwrap();
    let tardis = run_logged(SystemConfig::small(2, ProtocolKind::Tardis), &w).unwrap();
    assert!(
        tardis.stats.cycles <= msi.stats.cycles,
        "tardis {} vs msi {}",
        tardis.stats.cycles,
        msi.stats.cycles
    );
}

#[test]
fn tardis_assigns_paper_like_timestamps() {
    // Check the physiological signature: core 1's store to B jumps
    // ahead of core 0's lease on B (Listing 2 step: pts jumps to
    // rts + 1 = lease + 1), i.e., some store commits with ts > lease
    // while core 0's first load keeps ts 0.
    let w = litmus::case_study();
    let res = run_logged(SystemConfig::small(2, ProtocolKind::Tardis), &w).unwrap();
    let lease = SystemConfig::small(2, ProtocolKind::Tardis).tardis.lease;
    let first_load = res
        .log
        .records
        .iter()
        .find(|r| r.core == 0 && r.pc == 0)
        .expect("core 0 L(B)");
    // Initial timestamps start at mts = 1 (the paper initializes all
    // timestamps to 1), so the first load binds near the epoch.
    assert!(first_load.ts <= 2, "first load binds near ts 1, got {}", first_load.ts);
    let jumped = res
        .log
        .records
        .iter()
        .any(|r| r.value_written.is_some() && r.ts >= lease + 1);
    assert!(jumped, "some store should jump past the lease (rts + 1)");
}

#[test]
fn tardis_allows_time_travel_interleaving() {
    // Core 0's second L(B) (pc 3) may read B = 0 (the initial value)
    // even after core 1 stored B = 2 in physical time — it is ordered
    // before the stores in physiological time (paper Listing 4).  The
    // checker already proved the outcome SC; here we document which
    // interleaving happened and require the load to see either 0
    // (time travel) or a real stored value.
    let w = litmus::case_study();
    let res = run_logged(SystemConfig::small(2, ProtocolKind::Tardis), &w).unwrap();
    let l_b = res
        .log
        .records
        .iter()
        .find(|r| r.core == 0 && r.pc == 3 && r.value_read.is_some())
        .expect("core 0 second L(B)");
    let v = l_b.value_read.unwrap();
    assert!(
        v == 0 || v == 2 || v == 4,
        "L(B) must be one of the program's values, got {v}"
    );
}
