//! End-to-end: the experiment harness produces paper-shaped tables on
//! scaled-down sweeps — trace generation (PJRT or mirror) -> parallel
//! coordinator -> normalized tables.

use tardis_dsm::config::ProtocolKind;
use tardis_dsm::coordinator::experiments::{self, base_cfg, fig4_variants, EvalCtx};
use tardis_dsm::coordinator::{run_points, SimPoint};
use tardis_dsm::runtime::TraceRuntime;
use tardis_dsm::trace::synth_workload;
use tardis_dsm::workloads;

fn quick_ctx() -> EvalCtx {
    let mut ctx = EvalCtx::new(TraceRuntime::open_default().ok(), 0);
    ctx.scale_down = 8; // tiny traces for CI speed
    ctx
}

#[test]
fn fig4_table_has_twelve_workloads_and_average() {
    let mut ctx = quick_ctx();
    let t = experiments::fig4(&mut ctx).unwrap();
    assert_eq!(t.rows.len(), 13); // 12 workloads + AVG
    assert_eq!(t.rows[12][0], "AVG(geo)");
    // Throughput columns parse as positive ratios.
    for row in &t.rows {
        for cell in &row[1..] {
            let v: f64 = cell.parse().expect("numeric cell");
            assert!(v > 0.0, "non-positive ratio {cell}");
        }
    }
    // MSI normalized to itself is exactly 1.
    for row in &t.rows[..12] {
        assert_eq!(row[1], "1.000");
    }
}

#[test]
fn lease_matrix_covers_every_policy_consistency_and_core_count() {
    let mut ctx = quick_ctx();
    let t = experiments::lease_matrix(&mut ctx).unwrap();
    // Per core count: 12 workloads x 6 variants plus one AVG row per
    // variant; the matrix spans 16 / 64 / 256 cores.
    assert_eq!(t.rows.len(), 3 * (12 * 6 + 6));
    for cores in ["16", "64", "256"] {
        for v in [
            "static-sc",
            "static-tso",
            "dynamic-sc",
            "dynamic-tso",
            "predictive-sc",
            "predictive-tso",
        ] {
            assert!(
                t.rows.iter().any(|r| r[0] == cores && r[2] == v),
                "missing variant {v} at {cores} cores"
            );
        }
    }
    for row in t.rows.iter().filter(|r| r[1] != "AVG(geo)") {
        let thr: f64 = row[3].parse().expect("numeric throughput cell");
        assert!(thr > 0.0, "non-positive throughput in {row:?}");
    }
}

#[test]
fn table7_is_exactly_the_papers() {
    let t = experiments::table7();
    assert_eq!(t.rows[0], vec!["16", "16 bits", "16 bits", "40 bits"]);
    assert_eq!(t.rows[1], vec!["64", "64 bits", "24 bits", "40 bits"]);
    assert_eq!(t.rows[2], vec!["256", "256 bits", "64 bits", "40 bits"]);
}

#[test]
fn sweep_runs_all_points_in_parallel() {
    let mut ctx = quick_ctx();
    let stats = experiments::sweep(&mut ctx, 16, &fig4_variants(16)).unwrap();
    assert_eq!(stats.len(), 12 * 4);
    for ((w, v), s) in &stats {
        assert!(s.cycles > 0, "{w}/{v} empty run");
        assert!(s.memops > 0, "{w}/{v} no ops");
    }
}

#[test]
fn tardis_within_reasonable_band_of_msi() {
    // The paper's headline: Tardis ~ MSI.  On the scaled-down traces
    // we accept a generous band, but the geometric mean must be in the
    // same ballpark (> 0.5x) and traffic within 2x.
    let mut ctx = quick_ctx();
    let stats = experiments::sweep(&mut ctx, 16, &fig4_variants(16)).unwrap();
    let mut thr = Vec::new();
    let mut traf = Vec::new();
    for spec in workloads::all() {
        let msi = &stats[&(spec.name.to_string(), "msi".to_string())];
        let tar = &stats[&(spec.name.to_string(), "tardis".to_string())];
        thr.push(msi.cycles as f64 / tar.cycles as f64);
        traf.push(tar.traffic.total() as f64 / msi.traffic.total().max(1) as f64);
    }
    let geo = |xs: &[f64]| (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp();
    let g_thr = geo(&thr);
    let g_traf = geo(&traf);
    assert!(g_thr > 0.5, "tardis throughput collapsed: {g_thr:.3}");
    assert!(g_traf < 2.0, "tardis traffic exploded: {g_traf:.3}");
}

#[test]
fn coordinator_handles_mixed_configs() {
    use std::sync::Arc;
    let spec = workloads::by_name("fft").unwrap();
    let w = Arc::new(synth_workload(&spec.params, 16, 256));
    let mut points = Vec::new();
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        for lease in [5u64, 10] {
            let mut cfg = base_cfg(16, protocol);
            cfg.tardis.lease = lease;
            points.push(SimPoint {
                label: format!("{}-l{lease}", protocol.name()),
                cfg,
                workload: Arc::clone(&w),
            });
        }
    }
    let results = run_points(points, 3).unwrap();
    assert_eq!(results.len(), 6);
    // Lease only affects Tardis.
    let get = |label: &str| results.iter().find(|r| r.label == label).unwrap().stats.cycles;
    assert_eq!(get("msi-l5"), get("msi-l10"));
    assert_eq!(get("ackwise-l5"), get("ackwise-l10"));
}

#[test]
fn ooo_sweep_completes() {
    let mut ctx = quick_ctx();
    let t = experiments::fig6(&mut ctx).unwrap();
    assert_eq!(t.rows.len(), 13);
}
