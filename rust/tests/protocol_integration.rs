//! Protocol-level integration tests: Tardis mechanism observability
//! (renewals, jump-ahead, leases, self-increment, compression),
//! directory behaviour (invalidations, broadcasts), and cross-protocol
//! sanity on the synthetic workloads.

use tardis_dsm::config::{
    CoreModel, LeasePolicyKind, ProtocolKind, SystemConfig, DEFAULT_MAX_LEASE,
};
use tardis_dsm::coordinator::experiments::base_cfg;
use tardis_dsm::prog::{checker, load, lock, store, unlock, Program, Workload};
use tardis_dsm::proto::{Coherence, ackwise::Ackwise, msi::Msi, tardis::Tardis};
use tardis_dsm::api::SimBuilder;
use tardis_dsm::testutil::run_logged;
use tardis_dsm::trace::{synth_workload, TraceParams};
use tardis_dsm::types::SHARED_BASE;
use tardis_dsm::workloads;

fn small(protocol: ProtocolKind) -> SystemConfig {
    SystemConfig::small(4, protocol)
}

/// Re-reading an expired shared line triggers a renewal that succeeds
/// when nobody wrote (§III-F1 data renewal).
#[test]
fn tardis_renewals_mostly_succeed_on_read_shared_data() {
    // All cores read the same lines; one core's writes to OTHER lines
    // advance its pts so its cached copies expire.
    let mut progs = Vec::new();
    for c in 0..4u32 {
        let mut ops = vec![];
        for i in 0..200 {
            ops.push(load(SHARED_BASE + (i % 4)));
            if c == 0 {
                // Writer to a private-ish shared line: jumps its own pts.
                ops.push(store(SHARED_BASE + 100 + c as u64, i));
            }
        }
        progs.push(Program::new(ops));
    }
    let mut cfg = small(ProtocolKind::Tardis);
    // Let the writer's pts advance every store so reader leases expire.
    cfg.tardis.private_write_opt = false;
    let res = run_logged(cfg, &Workload::new(progs)).unwrap();
    let s = res.stats;
    assert!(s.renew_requests > 0, "expected renewals, got none");
    assert!(
        s.renew_success * 10 >= s.renew_requests * 9,
        "read-shared renewals should mostly succeed: {}/{}",
        s.renew_success,
        s.renew_requests
    );
    checker::check(&res.log).unwrap();
}

/// Writes to shared lines proceed without invalidations (§III-F1):
/// Tardis sends zero invalidation flits while MSI sends plenty.
#[test]
fn tardis_eliminates_invalidations() {
    let params = TraceParams { pct_shared: 500, pct_write_shared: 300, ..Default::default() };
    let w = synth_workload(&params, 4, 512);
    let tardis = run_logged(small(ProtocolKind::Tardis), &w).unwrap().stats;
    let msi = run_logged(small(ProtocolKind::Msi), &w).unwrap().stats;
    assert_eq!(tardis.invalidations_sent, 0, "Tardis must not invalidate");
    assert!(msi.invalidations_sent > 0, "MSI should invalidate under write sharing");
    assert!(msi.traffic.invalidation_flits > 0);
}

/// The private-write optimization (§IV-C) slows timestamp growth for
/// write-heavy private workloads.
#[test]
fn private_write_opt_slows_pts_growth() {
    let params = TraceParams {
        pct_shared: 50,
        pct_write_priv: 700,
        priv_lines: 8, // hot private lines, rewritten constantly
        ..Default::default()
    };
    let w = synth_workload(&params, 4, 512);
    let mut on = small(ProtocolKind::Tardis);
    on.tardis.private_write_opt = true;
    let mut off = small(ProtocolKind::Tardis);
    off.tardis.private_write_opt = false;
    let s_on = run_logged(on, &w).unwrap().stats;
    let s_off = run_logged(off, &w).unwrap().stats;
    assert!(
        s_on.ts.pts_increase_total < s_off.ts.pts_increase_total,
        "opt on: {} vs off: {}",
        s_on.ts.pts_increase_total,
        s_off.ts.pts_increase_total
    );
}

/// Self-increment drives expiration: disabling it (period = 0) must
/// not deadlock plain data workloads, and larger periods mean fewer
/// renewals (Fig. 7 mechanism).
#[test]
fn self_increment_period_controls_renewals() {
    let spec = workloads::by_name("volrend").unwrap();
    let w = synth_workload(&spec.params, 8, 1024);
    let mut renewals = Vec::new();
    for period in [10u64, 1000] {
        let mut cfg = SystemConfig::small(8, ProtocolKind::Tardis);
        cfg.tardis.self_inc_period = period;
        let s = run_logged(cfg, &w).unwrap().stats;
        renewals.push(s.renew_requests);
    }
    assert!(
        renewals[0] > renewals[1],
        "renewals should fall with a longer period: {renewals:?}"
    );
}

/// Lease sweep: longer leases reduce renewals (Fig. 10 mechanism).
#[test]
fn longer_lease_reduces_renewals() {
    let spec = workloads::by_name("volrend").unwrap();
    let w = synth_workload(&spec.params, 4, 512);
    let mut renewals = Vec::new();
    for lease in [5u64, 20, 80] {
        let mut cfg = small(ProtocolKind::Tardis);
        cfg.tardis.lease = lease;
        let s = run_logged(cfg, &w).unwrap().stats;
        renewals.push(s.renew_requests);
    }
    assert!(
        renewals[0] > renewals[2],
        "renewals should fall with lease: {renewals:?}"
    );
}

/// Small delta-timestamp widths trigger rebases (§IV-B); 64-bit never
/// rolls over (Fig. 9 mechanism).
#[test]
fn small_delta_width_triggers_rebases() {
    let spec = workloads::by_name("lu-nc").unwrap();
    let w = synth_workload(&spec.params, 4, 1024);
    let mut cfg = small(ProtocolKind::Tardis);
    cfg.tardis.delta_ts_bits = 8; // tiny: rolls over quickly
    let s_small = run_logged(cfg, &w).unwrap().stats;
    let mut cfg64 = small(ProtocolKind::Tardis);
    cfg64.tardis.delta_ts_bits = 64;
    let s_big = run_logged(cfg64, &w).unwrap().stats;
    assert!(s_small.ts.l1_rebases > 0, "8-bit deltas must rebase");
    assert_eq!(s_big.ts.l1_rebases, 0, "64-bit deltas never rebase");
    // Rebasing is modeled but must not break consistency.
}

/// Rebase-heavy runs still satisfy SC (rebase invalidations + clamps
/// are the §IV-B safety argument).
#[test]
fn rebase_preserves_sc() {
    let gen = tardis_dsm::testutil::ProgGen {
        n_cores: 4,
        ops_per_core: 80,
        store_pct: 50,
        ..Default::default()
    };
    tardis_dsm::testutil::prop_check(10, 0xBA5E, |seed, rng| {
        let w = gen.generate(rng);
        let mut cfg = small(ProtocolKind::Tardis);
        cfg.tardis.delta_ts_bits = 7;
        let res = run_logged(cfg, &w).unwrap();
        checker::check(&res.log).unwrap_or_else(|v| panic!("seed {seed:#x}: {v:?}"));
    });
}

/// Ackwise broadcasts once sharers exceed the pointer budget; full-map
/// MSI never broadcasts.
#[test]
fn ackwise_broadcasts_on_pointer_overflow() {
    // 8 cores all read one line, then one writes it.
    let mut progs = Vec::new();
    for c in 0..8u32 {
        let mut ops = vec![load(SHARED_BASE)];
        for i in 0..20 {
            ops.push(load(SHARED_BASE + 1 + (i + c as u64) % 4));
        }
        if c == 0 {
            ops.push(store(SHARED_BASE, 9));
        }
        progs.push(Program::new(ops));
    }
    let w = Workload::new(progs);
    let mut cfg = SystemConfig::small(8, ProtocolKind::Ackwise);
    cfg.ackwise.num_pointers = 2;
    let ack = run_logged(cfg, &w).unwrap().stats;
    let msi = run_logged(SystemConfig::small(8, ProtocolKind::Msi), &w).unwrap().stats;
    assert!(ack.broadcasts > 0, "expected a broadcast invalidation");
    assert_eq!(msi.broadcasts, 0);
}

/// Storage-overhead model matches the paper's Table VII.
#[test]
fn storage_bits_match_table7() {
    for (n, msi_bits, ack_bits) in [(16u32, 16u64, 16u64), (64, 64, 24), (256, 256, 64)] {
        let cfg = base_cfg(n, ProtocolKind::Msi);
        assert_eq!(Msi::new(&cfg).llc_storage_bits(n), msi_bits, "msi at {n}");
        assert_eq!(Ackwise::new(&cfg).llc_storage_bits(n), ack_bits, "ackwise at {n}");
        assert_eq!(Tardis::new(&cfg).llc_storage_bits(n), 40, "tardis at {n}");
    }
}

/// Locks serialize critical sections on every protocol (mutual
/// exclusion check is part of the SC checker).
#[test]
fn lock_mutual_exclusion_all_protocols() {
    use tardis_dsm::types::LOCK_BASE;
    let mut progs = Vec::new();
    for c in 0..4u32 {
        let mut ops = vec![];
        for i in 0..10 {
            ops.push(lock(LOCK_BASE));
            ops.push(load(SHARED_BASE + 50));
            ops.push(store(SHARED_BASE + 50, (c as u64) * 100 + i));
            ops.push(unlock(LOCK_BASE));
        }
        progs.push(Program::new(ops));
    }
    let w = Workload::new(progs);
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        let res = run_logged(small(protocol), &w).unwrap();
        assert_eq!(res.stats.locks_acquired, 40, "{protocol:?}");
        checker::check(&res.log).unwrap();
    }
}

/// The OoO window hides renewal latency: no-speculation OoO Tardis is
/// closer to MSI than no-speculation in-order (Fig. 6 observation).
#[test]
fn ooo_hides_renewal_latency_without_speculation() {
    // On a read-mostly workload (renewals succeed), speculation hides
    // renewal latency for the in-order core (paper §VI-B1: 7% gap).
    let spec = workloads::by_name("barnes").unwrap();
    let w = synth_workload(&spec.params, 8, 1024);
    let run = |model: CoreModel, spec_on: bool| {
        let mut cfg = SystemConfig::small(8, ProtocolKind::Tardis);
        cfg.core_model = model;
        cfg.tardis.speculation = spec_on;
        // Timing-only comparison: skip the SC log.
        SimBuilder::from_config(cfg).workload(&w).run().unwrap().stats.cycles
    };
    let inorder_nospec = run(CoreModel::InOrder, false) as f64;
    let inorder_spec = run(CoreModel::InOrder, true) as f64;
    assert!(
        inorder_spec <= inorder_nospec * 1.02,
        "speculation should not slow the in-order core materially: {inorder_spec} vs {inorder_nospec}"
    );
}

/// DRAM path: working sets beyond the LLC drive mts-mediated refetches
/// without breaking consistency.
#[test]
fn llc_eviction_and_mts_path() {
    let params = TraceParams {
        priv_lines: 4096, // exceeds the small test LLC
        pct_shared: 100,
        ..Default::default()
    };
    let w = synth_workload(&params, 2, 1024);
    let mut cfg = SystemConfig::small(2, ProtocolKind::Tardis);
    cfg.l2_sets = 16;
    cfg.l2_ways = 4;
    let res = run_logged(cfg, &w).unwrap();
    assert!(res.stats.dram_accesses > 100, "expected DRAM traffic");
    checker::check(&res.log).unwrap();
}

/// Every synthetic workload runs clean on every protocol at 8 cores
/// (the full matrix smoke — the heavy version of the dev loop).
#[test]
fn workload_matrix_smoke() {
    for spec in workloads::all() {
        let w = synth_workload(&spec.params, 8, 256);
        for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            let cfg = SystemConfig::small(8, protocol);
            let res = run_logged(cfg, &w)
                .unwrap_or_else(|e| panic!("{} {protocol:?}: {e}", spec.name));
            checker::check(&res.log)
                .unwrap_or_else(|v| panic!("{} {protocol:?}: {v:?}", spec.name));
        }
    }
}

/// §IV-D E-state extension: untouched lines are granted exclusively on
/// a shared request, so single-reader data never expires — renewals
/// drop versus baseline Tardis on private-heavy workloads.
#[test]
fn e_state_extension_reduces_renewals() {
    let spec = workloads::by_name("fft").unwrap();
    let w = synth_workload(&spec.params, 8, 1024);
    let base = {
        let cfg = SystemConfig::small(8, ProtocolKind::Tardis);
        run_logged(cfg, &w).unwrap().stats
    };
    let estate = {
        let mut cfg = SystemConfig::small(8, ProtocolKind::Tardis);
        cfg.tardis.exclusive_state = true;
        let res = run_logged(cfg, &w).unwrap();
        checker::check(&res.log).unwrap();
        res.stats
    };
    assert!(
        estate.renew_requests < base.renew_requests,
        "E state should cut renewals: {} vs {}",
        estate.renew_requests,
        base.renew_requests
    );
}

/// E-state runs must stay sequentially consistent even under write
/// sharing (the grant can race with other readers).
#[test]
fn e_state_extension_preserves_sc() {
    let gen = tardis_dsm::testutil::ProgGen {
        n_cores: 4,
        ops_per_core: 60,
        store_pct: 50,
        lock_pct: 10,
        ..Default::default()
    };
    tardis_dsm::testutil::prop_check(15, 0xE57A7E, |seed, rng| {
        let w = gen.generate(rng);
        let mut cfg = SystemConfig::small(4, ProtocolKind::Tardis);
        cfg.tardis.exclusive_state = true;
        let res = run_logged(cfg, &w).unwrap();
        checker::check(&res.log).unwrap_or_else(|v| panic!("seed {seed:#x}: {v:?}"));
    });
}

/// §VI-C5 dynamic leases: read-mostly lines earn exponentially longer
/// leases, cutting renewals versus the static lease, without breaking
/// SC.
#[test]
fn dynamic_lease_reduces_renewals() {
    let spec = workloads::by_name("volrend").unwrap();
    let w = synth_workload(&spec.params, 8, 1024);
    let stat = {
        let cfg = SystemConfig::small(8, ProtocolKind::Tardis);
        run_logged(cfg, &w).unwrap().stats
    };
    let dynamic = {
        let mut cfg = SystemConfig::small(8, ProtocolKind::Tardis);
        cfg.tardis.lease_policy = LeasePolicyKind::Dynamic { max_lease: DEFAULT_MAX_LEASE };
        let res = run_logged(cfg, &w).unwrap();
        checker::check(&res.log).unwrap();
        res.stats
    };
    assert!(
        dynamic.renew_requests < stat.renew_requests,
        "dynamic leases should cut renewals: {} vs {}",
        dynamic.renew_requests,
        stat.renew_requests
    );
}

// (The PR-4 `dynamic_lease` alias test retired with the alias itself:
// `LeasePolicyKind::Dynamic { max_lease }` is the one spelling now.)

/// Dynamic leases under write churn must reset (writes invalidate the
/// read-mostly assumption) and stay consistent.
#[test]
fn dynamic_lease_preserves_sc_under_writes() {
    let gen = tardis_dsm::testutil::ProgGen {
        n_cores: 4,
        ops_per_core: 60,
        store_pct: 60,
        n_shared: 3,
        ..Default::default()
    };
    tardis_dsm::testutil::prop_check(15, 0xD11A, |seed, rng| {
        let w = gen.generate(rng);
        let mut cfg = SystemConfig::small(4, ProtocolKind::Tardis);
        cfg.tardis.lease_policy = LeasePolicyKind::Dynamic { max_lease: DEFAULT_MAX_LEASE };
        let res = run_logged(cfg, &w).unwrap();
        checker::check(&res.log).unwrap_or_else(|v| panic!("seed {seed:#x}: {v:?}"));
    });
}

/// The spinning benchmark: cores hammer a small read-mostly working
/// set (spin-style re-reads) whose leases keep expiring through self
/// increment.  The Tardis-2.0-style predictive policy must grow those
/// lines' leases and cut renewal traffic versus the static lease —
/// the headline claim of the timestamp-policy layer.
#[test]
fn predictive_lease_cuts_renewals_on_spinning_reads() {
    // Every core re-reads the same 4 shared lines; short leases and a
    // fast self increment force continual renewals under Static.
    let mut progs = Vec::new();
    for _ in 0..4u32 {
        let mut ops = vec![];
        for i in 0..1500u64 {
            ops.push(load(SHARED_BASE + (i % 4)));
        }
        progs.push(Program::new(ops));
    }
    let w = Workload::new(progs);
    let run = |policy: LeasePolicyKind| {
        let mut cfg = SystemConfig::small(4, ProtocolKind::Tardis);
        cfg.tardis.lease = 5;
        cfg.tardis.self_inc_period = 5;
        cfg.tardis.lease_policy = policy;
        let res = run_logged(cfg, &w).unwrap();
        checker::check(&res.log).unwrap();
        res.stats
    };
    let stat = run(LeasePolicyKind::Static);
    let pred = run(LeasePolicyKind::Predictive { max_lease: DEFAULT_MAX_LEASE });
    assert!(stat.renew_requests > 0, "the benchmark must actually renew");
    assert!(
        pred.renew_requests * 2 < stat.renew_requests,
        "predictive leases should at least halve renewals on spinning reads: {} vs {}",
        pred.renew_requests,
        stat.renew_requests
    );
    assert!(
        pred.avg_lease() > stat.avg_lease(),
        "predictive must grant longer leases: {} vs {}",
        pred.avg_lease(),
        stat.avg_lease()
    );
}

/// Predictive leases under write churn self-tune *down* (the lease is
/// bounded by the observed write interval) and preserve SC.
#[test]
fn predictive_lease_preserves_sc_under_writes() {
    let gen = tardis_dsm::testutil::ProgGen {
        n_cores: 4,
        ops_per_core: 60,
        store_pct: 60,
        n_shared: 3,
        ..Default::default()
    };
    tardis_dsm::testutil::prop_check(15, 0x9D1C7, |seed, rng| {
        let w = gen.generate(rng);
        let mut cfg = SystemConfig::small(4, ProtocolKind::Tardis);
        cfg.tardis.lease_policy = LeasePolicyKind::Predictive { max_lease: DEFAULT_MAX_LEASE };
        let res = run_logged(cfg, &w).unwrap();
        checker::check(&res.log).unwrap_or_else(|v| panic!("seed {seed:#x}: {v:?}"));
    });
}

/// The livelock detector: a reader speculating through renewals on a
/// write-hot line keeps misspeculating; once its failure streak
/// crosses the threshold the line escalates to blocking demands
/// (counted in the stats) — and the run stays consistent.
#[test]
fn livelock_guard_escalates_starved_renewals() {
    let mut reader = vec![];
    let mut writer = vec![];
    for i in 0..600u64 {
        reader.push(load(SHARED_BASE));
        // Interleave reads of other lines so the reader's pts moves
        // and its copy of SHARED_BASE keeps expiring.
        reader.push(load(SHARED_BASE + 1 + (i % 3)));
        writer.push(store(SHARED_BASE, i + 1));
    }
    let w = Workload::new(vec![Program::new(reader), Program::new(writer)]);
    let mut cfg = SystemConfig::small(2, ProtocolKind::Tardis);
    cfg.tardis.self_inc_period = 5;
    cfg.tardis.livelock_threshold = 4;
    let res = run_logged(cfg, &w).unwrap();
    checker::check(&res.log).unwrap();
    assert!(
        res.stats.misspeculations > 0,
        "the write storm should defeat some speculations"
    );
    assert!(
        res.stats.ts.livelock_escalations > 0,
        "repeated renewal failures must escalate (misspecs: {})",
        res.stats.misspeculations
    );

    // With the guard disabled the same run never escalates.
    let mut off = SystemConfig::small(2, ProtocolKind::Tardis);
    off.tardis.self_inc_period = 5;
    off.tardis.livelock_threshold = 0;
    let res_off = run_logged(off, &w).unwrap();
    assert_eq!(res_off.stats.ts.livelock_escalations, 0);
}
