//! ccNUMA topology integration suite: the socket-split statistics,
//! flat-equivalence guarantees, and the paper's §VII acceptance claim
//! — Tardis's owner-free renewals keep inter-socket traffic growing
//! strictly slower than the MSI directory's invalidation multicasts
//! as the numa-ratio rises.

use tardis_dsm::api::{SimBuilder, SimReport};
use tardis_dsm::config::{
    ProtocolKind, SocketInterleave, SystemConfig, TopologyConfig,
};
use tardis_dsm::coordinator::experiments::{numa_variants, sweep, EvalCtx};
use tardis_dsm::prog::Workload;
use tardis_dsm::trace::synth_workload;
use tardis_dsm::workloads;

fn run(cfg: SystemConfig, w: &Workload) -> SimReport {
    SimBuilder::from_config(cfg)
        .record_accesses(true)
        .workload(w)
        .run()
        .unwrap()
}

fn small_workload(n_cores: u32) -> Workload {
    let spec = workloads::by_name("fft").unwrap();
    synth_workload(&spec.params, n_cores, 512)
}

/// The flat-vs-legacy equality check: a default (pre-topology shape)
/// run must be bit-for-bit identical to a run that explicitly routes
/// through the topology layer's flat path with every new knob set to
/// a non-default value that must be inert at 1 socket (numa-ratio,
/// Block interleave).  The subsystem cannot perturb flat results.
#[test]
fn flat_topology_is_bit_identical_to_legacy_flat_runs() {
    let w = small_workload(8);
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        let legacy = run(SystemConfig::small(8, protocol), &w);
        let mut cfg = SystemConfig::small(8, protocol);
        cfg.topology = TopologyConfig {
            sockets: 1,
            numa_ratio: 8,
            interleave: SocketInterleave::Block,
        };
        let topo = run(cfg, &w);
        assert_eq!(legacy.stats, topo.stats, "{protocol:?}: stats diverged");
        assert_eq!(legacy.log.records, topo.log.records, "{protocol:?}: logs diverged");
        assert_eq!(legacy.core_finish, topo.core_finish, "{protocol:?}");
        // Flat runs never cross a socket link.
        assert_eq!(topo.stats.socket.inter_msgs, 0);
        assert!(topo.stats.socket.intra_msgs > 0);
    }
}

/// Multi-socket runs complete correctly under every protocol and both
/// interleaves, split their traffic, and stay sequentially consistent.
#[test]
fn numa_runs_complete_and_split_traffic() {
    let w = small_workload(16);
    for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        for interleave in [SocketInterleave::Line, SocketInterleave::Block] {
            let mut cfg = SystemConfig::small(16, protocol);
            cfg.topology = TopologyConfig { sockets: 2, numa_ratio: 4, interleave };
            let res = run(cfg, &w);
            res.check_sc().unwrap_or_else(|v| {
                panic!("{protocol:?}/{interleave:?}: SC violation {v:?}")
            });
            let sk = &res.stats.socket;
            assert!(sk.inter_msgs > 0, "{protocol:?}/{interleave:?}: no cross-socket traffic");
            assert!(sk.intra_msgs > 0, "{protocol:?}/{interleave:?}: no local traffic");
            assert_eq!(sk.link_crossings, sk.inter_msgs, "one link per remote message");
            assert!(sk.inter_flits > 0);
            let f = sk.inter_fraction();
            assert!(f > 0.0 && f < 1.0, "{protocol:?}: inter fraction {f}");
        }
    }
}

/// Raising the inter-socket cost ratio slows completion (the links
/// really are on the critical path).
#[test]
fn numa_ratio_slows_completion() {
    let w = small_workload(16);
    let cycles = |ratio: u32| {
        let mut cfg = SystemConfig::small(16, ProtocolKind::Msi);
        cfg.topology = TopologyConfig { sockets: 2, numa_ratio: ratio, ..Default::default() };
        run(cfg, &w).stats.cycles
    };
    assert!(cycles(8) > cycles(1), "ratio-8 links must cost more than ratio-1");
}

/// An invalid socket split is rejected up front, not mid-run.
#[test]
fn builder_rejects_indivisible_socket_counts() {
    let w = small_workload(6);
    let err = SimBuilder::small(6, ProtocolKind::Tardis)
        .sockets(4)
        .workload(&w)
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("do not divide evenly"), "{err}");
}

/// The acceptance claim at 64 cores (paper §VII): going from cheap to
/// expensive inter-socket links (ratio 1 -> 8), Tardis's inter-socket
/// message count must grow strictly slower than the MSI directory's.
/// The mechanism: the NUMA-aware predictive policy stretches remote
/// leases with the ratio, converting recurring remote renewals into
/// long quiet leases, while the directory keeps multicasting
/// invalidations across the links at any price.
#[test]
fn tardis_inter_socket_traffic_grows_strictly_slower_than_msi() {
    let mut ctx = EvalCtx::new(None, 0);
    ctx.scale_down = 16; // 256-op traces: the full 12-workload grid stays fast
    let mut variants = Vec::new();
    for ratio in [1u32, 8] {
        variants.extend(
            numa_variants(64, 4, ratio)
                .into_iter()
                .filter(|v| {
                    v.label.starts_with("msi") || v.label.starts_with("tardis-predictive")
                }),
        );
    }
    let stats = sweep(&mut ctx, 64, &variants).unwrap();
    let total_inter = |variant: &str| -> i64 {
        workloads::all()
            .iter()
            .map(|s| stats[&(s.name.to_string(), variant.to_string())].socket.inter_msgs as i64)
            .sum()
    };
    let total_renews = |variant: &str| -> u64 {
        workloads::all()
            .iter()
            .map(|s| stats[&(s.name.to_string(), variant.to_string())].renew_requests)
            .sum()
    };
    let msi_growth = total_inter("msi-r8") - total_inter("msi-r1");
    let tardis_growth =
        total_inter("tardis-predictive-r8") - total_inter("tardis-predictive-r1");
    assert!(
        tardis_growth < msi_growth,
        "Tardis inter-socket messages must grow strictly slower than MSI's \
         as the numa-ratio rises: tardis {} -> {} (growth {tardis_growth}), \
         msi {} -> {} (growth {msi_growth})",
        total_inter("tardis-predictive-r1"),
        total_inter("tardis-predictive-r8"),
        total_inter("msi-r1"),
        total_inter("msi-r8"),
    );
    // The mechanism is visible too: stretched remote leases cut the
    // renewal stream as links get more expensive.
    assert!(
        total_renews("tardis-predictive-r8") < total_renews("tardis-predictive-r1"),
        "remote-lease stretching should reduce renewals at high ratios: {} vs {}",
        total_renews("tardis-predictive-r8"),
        total_renews("tardis-predictive-r1"),
    );
}
