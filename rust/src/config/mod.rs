//! System, protocol, and core-model configuration (paper Table V defaults).

use crate::types::Cycle;

/// Which coherence protocol backs the shared-memory system.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProtocolKind {
    /// Tardis timestamp coherence (the paper's contribution).
    Tardis,
    /// Full-map MSI directory (baseline).
    Msi,
    /// Ackwise-k limited-pointer directory with broadcast overflow.
    Ackwise,
}

impl ProtocolKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "tardis" => Some(Self::Tardis),
            "msi" => Some(Self::Msi),
            "ackwise" => Some(Self::Ackwise),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Tardis => "tardis",
            Self::Msi => "msi",
            Self::Ackwise => "ackwise",
        }
    }
}

/// Synchronization protocol for the sharded PDES engine
/// ([`crate::sim::pdes`], DESIGN.md §11.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PdesMode {
    /// PR-8 conservative windows: all shards advance in lockstep
    /// through two-barrier epochs sized by the global minimum
    /// lookahead.  Cheap per epoch, but a short lookahead anywhere
    /// rate-limits every shard.
    Epoch,
    /// Chandy-Misra-Bryant null messages: per-edge channel clocks let
    /// each shard advance independently to the min over its inbound
    /// bounds, so a quiet or distant shard no longer gates the fleet.
    NullMsg,
    /// Pick per run: NullMsg when the derived global lookahead is
    /// small relative to the per-edge windows (flat meshes), Epoch
    /// when the windows are uniform anyway.
    Auto,
}

impl PdesMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "epoch" => Some(Self::Epoch),
            "nullmsg" => Some(Self::NullMsg),
            "auto" => Some(Self::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Epoch => "epoch",
            Self::NullMsg => "nullmsg",
            Self::Auto => "auto",
        }
    }
}

/// Core microarchitecture model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoreModel {
    /// In-order, single-issue (paper Table V default).
    InOrder,
    /// Out-of-order: issue window + in-order commit with timestamp
    /// checking at commit (paper §III-D, §VI-C1).
    OutOfOrder,
}

impl CoreModel {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "inorder" => Some(Self::InOrder),
            "ooo" => Some(Self::OutOfOrder),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::InOrder => "inorder",
            Self::OutOfOrder => "ooo",
        }
    }
}

/// Memory consistency model the cores enforce (Tardis 2.0,
/// arXiv:1511.08774 §5: the physiological order supports relaxed
/// models directly).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Consistency {
    /// Sequential consistency: every memory operation completes before
    /// the next issues (stores block).
    Sc,
    /// Total store order: stores retire into a per-core FIFO store
    /// buffer with store-to-load forwarding; loads need not bump `pts`
    /// past buffered stores (the relaxed Tardis 2.0 `pts` rule).
    /// Store-load reordering becomes architecturally visible (the SB
    /// litmus outcome); all other orders are preserved.
    Tso,
}

impl Consistency {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sc" => Some(Self::Sc),
            "tso" => Some(Self::Tso),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Sc => "sc",
            Self::Tso => "tso",
        }
    }
}

/// Lease-assignment policy for the Tardis timestamp managers
/// ([`crate::proto::ts`] layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeasePolicyKind {
    /// The paper's fixed lease: every shared grant extends `rts` by the
    /// static `TardisConfig::lease`.
    Static,
    /// §VI-C5 dynamic leases: a line's lease doubles on each successful
    /// renewal (read-mostly data earns long leases) and resets on
    /// writes, capped at `max_lease`.
    Dynamic { max_lease: u64 },
    /// Tardis-2.0-style predictive leases: the manager tracks each
    /// line's read run (shared grants since the last write) and its
    /// write-to-write timestamp interval, growing the lease with the
    /// read run but never past the observed write interval (a lease
    /// outliving the next write only buys misspeculations).
    Predictive { max_lease: u64 },
}

impl LeasePolicyKind {
    /// Parse a policy name; `dynamic`/`predictive` use the default cap.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(Self::Static),
            "dynamic" => Some(Self::Dynamic { max_lease: DEFAULT_MAX_LEASE }),
            "predictive" => Some(Self::Predictive { max_lease: DEFAULT_MAX_LEASE }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Static => "static",
            Self::Dynamic { .. } => "dynamic",
            Self::Predictive { .. } => "predictive",
        }
    }
}

/// Default cap for adaptive lease policies.  Kept moderate: spinners
/// wait ~lease x self-inc-period cycles per recheck, so long leases on
/// synchronization lines collapse spin-heavy workloads (the paper's
/// Fig. 10 tension — "intelligent leasing" must avoid sync data).
pub const DEFAULT_MAX_LEASE: u64 = 80;

/// Address -> home-socket interleaving policy for the LLC slice
/// (timestamp-manager / directory) and memory-controller maps
/// ([`crate::mem::addr::SliceMap`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SocketInterleave {
    /// Global line interleave across all slices (`addr % n_slices`) —
    /// the flat single-chip mapping, distance-blind.
    Line,
    /// Block interleave: consecutive 8-line blocks share one home
    /// socket, and a line's LLC slice and memory controller both live
    /// on that socket (lines interleave across the socket's own
    /// slices/controllers).  On one socket this degenerates to `Line`.
    Block,
}

impl SocketInterleave {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "line" => Some(Self::Line),
            "block" => Some(Self::Block),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Line => "line",
            Self::Block => "block",
        }
    }
}

/// Fabric topology knobs ([`crate::net::Topology`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TopologyConfig {
    /// ccNUMA sockets; 1 = the flat single-chip mesh (today's
    /// behavior, bit-for-bit).  Must divide `n_cores` and `n_mcs`.
    pub sockets: u32,
    /// Remote-to-local cost multiplier on inter-socket links: link
    /// latency and serialization both scale by it (slower *and*
    /// narrower than on-chip wires).  Ignored when `sockets == 1`.
    pub numa_ratio: u32,
    /// Address -> home-socket interleaving for slice/MC maps.
    pub interleave: SocketInterleave,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        Self { sockets: 1, numa_ratio: 4, interleave: SocketInterleave::Line }
    }
}

impl TopologyConfig {
    pub fn is_flat(&self) -> bool {
        self.sockets <= 1
    }

    /// The topology name the bench schema records.
    pub fn name(&self) -> &'static str {
        if self.is_flat() {
            "flat"
        } else {
            "numa"
        }
    }
}

/// Tardis-specific knobs (paper Table V, §IV).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TardisConfig {
    /// Static lease added to `rts` on shared requests.
    pub lease: u64,
    /// `pts += 1` every this many L1 data accesses (§III-E). 0 disables.
    pub self_inc_period: u64,
    /// Speculate through expired-line loads instead of stalling (§IV-A).
    pub speculation: bool,
    /// Base-delta delta timestamp width in bits (§IV-B). 64 = uncompressed.
    pub delta_ts_bits: u32,
    /// Cycles an L1 is busy during a rebase (128 ns @ 1 GHz).
    pub l1_rebase_cycles: Cycle,
    /// Cycles an LLC slice is busy during a rebase (1024 ns @ 1 GHz).
    pub l2_rebase_cycles: Cycle,
    /// Private-write optimization: repeated stores to a modified line do
    /// not advance `pts` (§IV-C).
    pub private_write_opt: bool,
    /// E-state extension: grant exclusive on SH_REQ to untouched lines
    /// (§IV-D).  Off by default (paper evaluates MSI-equivalent Tardis).
    pub exclusive_state: bool,
    /// Lease-assignment policy ([`crate::proto::ts::LeasePolicy`]).
    pub lease_policy: LeasePolicyKind,
    /// Consecutive failed renewals on one line before the livelock
    /// detector escalates that core's next expired load to a blocking
    /// (non-speculative) demand, bounding rollback churn under write
    /// storms.  0 (the default) disables the detector — like the other
    /// beyond-the-paper extensions, it is opt-in so the evaluated
    /// protocol and the bench trajectory keep their semantics.
    ///
    /// (The PR-4 `dynamic_lease`/`max_lease` aliases served out their
    /// one-release deprecation window and are gone; set
    /// `lease_policy = LeasePolicyKind::Dynamic { max_lease }`.)
    pub livelock_threshold: u32,
}

impl Default for TardisConfig {
    fn default() -> Self {
        Self {
            lease: 10,
            self_inc_period: 100,
            speculation: true,
            delta_ts_bits: 20,
            l1_rebase_cycles: 128,
            l2_rebase_cycles: 1024,
            private_write_opt: true,
            exclusive_state: false,
            lease_policy: LeasePolicyKind::Static,
            livelock_threshold: 0,
        }
    }
}

/// Ackwise-specific knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AckwiseConfig {
    /// Number of precise sharer pointers before falling back to
    /// broadcast (paper Table VII: 4 at 16/64 cores, 8 at 256).
    pub num_pointers: u32,
}

impl Default for AckwiseConfig {
    fn default() -> Self {
        Self { num_pointers: 4 }
    }
}

/// Full system configuration (paper Table V).
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    pub n_cores: u32,
    pub core_model: CoreModel,
    /// Out-of-order issue-window depth (outstanding memory ops).
    pub ooo_window: u32,
    /// Memory consistency model the cores enforce (Sc default).
    pub consistency: Consistency,
    /// TSO store-buffer depth per core (ignored under Sc; 0 is
    /// treated as 1).
    pub sb_entries: u32,
    pub protocol: ProtocolKind,
    pub tardis: TardisConfig,
    pub ackwise: AckwiseConfig,

    /// L1 data cache geometry.
    pub l1_sets: u32,
    pub l1_ways: u32,
    /// Per-core shared-LLC slice geometry.
    pub l2_sets: u32,
    pub l2_ways: u32,
    /// LLC slice access latency (tag + data array), cycles.
    pub l2_latency: Cycle,

    /// DRAM access latency in cycles (100 ns @ 1 GHz).
    pub dram_latency: Cycle,
    /// Number of memory controllers.
    pub n_mcs: u32,
    /// Cycles one 64-B line occupies a controller (10 GB/s → 6.4 ns).
    pub dram_service_cycles: Cycle,

    /// Per-hop network latency (1 router + 1 link).
    pub hop_cycles: Cycle,
    /// Flit width in bits.
    pub flit_bits: u32,
    /// Fabric topology: flat single-chip mesh or multi-socket ccNUMA.
    pub topology: TopologyConfig,

    /// Misspeculation rollback cost added on a failed renewal (pipeline
    /// flush, like a branch mispredict).
    pub rollback_penalty: Cycle,
    /// Cycles between consecutive polls when a core spins on a cached,
    /// still-valid line (test-and-test-and-set backoff).
    pub spin_poll_cycles: Cycle,

    /// Hard cap on simulated cycles (deadlock guard).
    ///
    /// (Access-log recording moved off this struct: instrumentation is
    /// configured on [`crate::api::SimBuilder`] via `record_accesses`
    /// and the `Observer` plugins.)
    pub max_cycles: Cycle,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            n_cores: 64,
            core_model: CoreModel::InOrder,
            ooo_window: 16,
            consistency: Consistency::Sc,
            sb_entries: 8,
            protocol: ProtocolKind::Tardis,
            tardis: TardisConfig::default(),
            ackwise: AckwiseConfig::default(),
            // 32 KB, 4-way, 64-B lines -> 128 sets.
            l1_sets: 128,
            l1_ways: 4,
            // 256 KB slice, 8-way -> 512 sets.
            l2_sets: 512,
            l2_ways: 8,
            l2_latency: 8,
            dram_latency: 100,
            n_mcs: 8,
            dram_service_cycles: 7,
            hop_cycles: 2,
            flit_bits: 128,
            topology: TopologyConfig::default(),
            rollback_penalty: 8,
            spin_poll_cycles: 1,
            max_cycles: 2_000_000_000,
        }
    }
}

impl SystemConfig {
    /// Paper-default configuration for one sweep point: Table V
    /// defaults with the Ackwise pointer count scaled the way the
    /// paper's Table VII does (8 pointers at 256+ cores, 4 below).
    /// The single source of truth behind the CLI's `run`, the
    /// experiment harness's `base_cfg`, and the serve subsystem's
    /// per-point configs.
    pub fn for_point(n_cores: u32, protocol: ProtocolKind) -> Self {
        let mut cfg = Self { n_cores, protocol, ..Self::default() };
        cfg.ackwise.num_pointers = if n_cores >= 256 { 8 } else { 4 };
        cfg
    }

    /// Convenience: small test system.
    pub fn small(n_cores: u32, protocol: ProtocolKind) -> Self {
        Self {
            n_cores,
            protocol,
            l1_sets: 16,
            l1_ways: 4,
            l2_sets: 64,
            l2_ways: 8,
            max_cycles: 200_000_000,
            ..Self::default()
        }
    }

    /// Total L1 lines per core.
    pub fn l1_lines(&self) -> u32 {
        self.l1_sets * self.l1_ways
    }

    /// Total LLC lines per slice.
    pub fn l2_lines(&self) -> u32 {
        self.l2_sets * self.l2_ways
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_v_defaults() {
        let c = SystemConfig::default();
        assert_eq!(c.n_cores, 64);
        // 32 KB / 64 B / 4 ways = 128 sets
        assert_eq!(c.l1_sets * c.l1_ways * 64, 32 * 1024);
        // 256 KB / 64 B / 8 ways = 512 sets
        assert_eq!(c.l2_sets * c.l2_ways * 64, 256 * 1024);
        assert_eq!(c.tardis.lease, 10);
        assert_eq!(c.tardis.self_inc_period, 100);
        assert_eq!(c.tardis.delta_ts_bits, 20);
        assert_eq!(c.dram_latency, 100);
        assert_eq!(c.hop_cycles, 2);
        assert_eq!(c.flit_bits, 128);
    }

    #[test]
    fn protocol_parse_roundtrip() {
        for p in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            assert_eq!(ProtocolKind::parse(p.name()), Some(p));
        }
        assert_eq!(ProtocolKind::parse("mesi"), None);
    }

    #[test]
    fn consistency_parse_roundtrip() {
        for c in [Consistency::Sc, Consistency::Tso] {
            assert_eq!(Consistency::parse(c.name()), Some(c));
        }
        assert_eq!(Consistency::parse("rmo"), None);
        assert_eq!(SystemConfig::default().consistency, Consistency::Sc);
    }

    #[test]
    fn lease_policy_parse_roundtrip() {
        for k in [
            LeasePolicyKind::Static,
            LeasePolicyKind::Dynamic { max_lease: DEFAULT_MAX_LEASE },
            LeasePolicyKind::Predictive { max_lease: DEFAULT_MAX_LEASE },
        ] {
            assert_eq!(LeasePolicyKind::parse(k.name()), Some(k));
        }
        assert_eq!(LeasePolicyKind::parse("oracle"), None);
    }

    #[test]
    fn pdes_mode_parse_roundtrip() {
        for m in [PdesMode::Epoch, PdesMode::NullMsg, PdesMode::Auto] {
            assert_eq!(PdesMode::parse(m.name()), Some(m));
        }
        assert_eq!(PdesMode::parse("optimistic"), None);
    }

    #[test]
    fn core_model_parse_roundtrip() {
        for m in [CoreModel::InOrder, CoreModel::OutOfOrder] {
            assert_eq!(CoreModel::parse(m.name()), Some(m));
        }
        assert_eq!(CoreModel::parse("vliw"), None);
    }

    #[test]
    fn for_point_scales_ackwise_pointers() {
        assert_eq!(SystemConfig::for_point(64, ProtocolKind::Ackwise).ackwise.num_pointers, 4);
        assert_eq!(SystemConfig::for_point(256, ProtocolKind::Ackwise).ackwise.num_pointers, 8);
        assert_eq!(SystemConfig::for_point(16, ProtocolKind::Tardis).n_cores, 16);
    }

    #[test]
    fn topology_defaults_to_flat() {
        let t = SystemConfig::default().topology;
        assert!(t.is_flat());
        assert_eq!(t.name(), "flat");
        assert_eq!(t.interleave, SocketInterleave::Line);
        let numa = TopologyConfig { sockets: 4, ..t };
        assert!(!numa.is_flat());
        assert_eq!(numa.name(), "numa");
    }

    #[test]
    fn interleave_parse_roundtrip() {
        for i in [SocketInterleave::Line, SocketInterleave::Block] {
            assert_eq!(SocketInterleave::parse(i.name()), Some(i));
        }
        assert_eq!(SocketInterleave::parse("hash"), None);
    }
}
