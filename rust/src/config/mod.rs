//! System, protocol, and core-model configuration (paper Table V defaults).

use crate::types::Cycle;

/// Which coherence protocol backs the shared-memory system.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProtocolKind {
    /// Tardis timestamp coherence (the paper's contribution).
    Tardis,
    /// Full-map MSI directory (baseline).
    Msi,
    /// Ackwise-k limited-pointer directory with broadcast overflow.
    Ackwise,
}

impl ProtocolKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "tardis" => Some(Self::Tardis),
            "msi" => Some(Self::Msi),
            "ackwise" => Some(Self::Ackwise),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Tardis => "tardis",
            Self::Msi => "msi",
            Self::Ackwise => "ackwise",
        }
    }
}

/// Core microarchitecture model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoreModel {
    /// In-order, single-issue (paper Table V default).
    InOrder,
    /// Out-of-order: issue window + in-order commit with timestamp
    /// checking at commit (paper §III-D, §VI-C1).
    OutOfOrder,
}

/// Tardis-specific knobs (paper Table V, §IV).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TardisConfig {
    /// Static lease added to `rts` on shared requests.
    pub lease: u64,
    /// `pts += 1` every this many L1 data accesses (§III-E). 0 disables.
    pub self_inc_period: u64,
    /// Speculate through expired-line loads instead of stalling (§IV-A).
    pub speculation: bool,
    /// Base-delta delta timestamp width in bits (§IV-B). 64 = uncompressed.
    pub delta_ts_bits: u32,
    /// Cycles an L1 is busy during a rebase (128 ns @ 1 GHz).
    pub l1_rebase_cycles: Cycle,
    /// Cycles an LLC slice is busy during a rebase (1024 ns @ 1 GHz).
    pub l2_rebase_cycles: Cycle,
    /// Private-write optimization: repeated stores to a modified line do
    /// not advance `pts` (§IV-C).
    pub private_write_opt: bool,
    /// E-state extension: grant exclusive on SH_REQ to untouched lines
    /// (§IV-D).  Off by default (paper evaluates MSI-equivalent Tardis).
    pub exclusive_state: bool,
    /// Dynamic leases (paper §VI-C5 future work): per-line leases
    /// double on successful renewals (read-mostly data earns long
    /// leases) and reset on writes.  Off by default.
    pub dynamic_lease: bool,
    /// Cap for dynamic leases.  Kept moderate: spinners wait
    /// ~lease x self-inc-period cycles per recheck, so long leases on
    /// synchronization lines collapse spin-heavy workloads (the
    /// paper's Fig. 10 tension — "intelligent leasing" must avoid
    /// sync data).
    pub max_lease: u64,
}

impl Default for TardisConfig {
    fn default() -> Self {
        Self {
            lease: 10,
            self_inc_period: 100,
            speculation: true,
            delta_ts_bits: 20,
            l1_rebase_cycles: 128,
            l2_rebase_cycles: 1024,
            private_write_opt: true,
            exclusive_state: false,
            dynamic_lease: false,
            max_lease: 80,
        }
    }
}

/// Ackwise-specific knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AckwiseConfig {
    /// Number of precise sharer pointers before falling back to
    /// broadcast (paper Table VII: 4 at 16/64 cores, 8 at 256).
    pub num_pointers: u32,
}

impl Default for AckwiseConfig {
    fn default() -> Self {
        Self { num_pointers: 4 }
    }
}

/// Full system configuration (paper Table V).
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    pub n_cores: u32,
    pub core_model: CoreModel,
    /// Out-of-order issue-window depth (outstanding memory ops).
    pub ooo_window: u32,
    pub protocol: ProtocolKind,
    pub tardis: TardisConfig,
    pub ackwise: AckwiseConfig,

    /// L1 data cache geometry.
    pub l1_sets: u32,
    pub l1_ways: u32,
    /// Per-core shared-LLC slice geometry.
    pub l2_sets: u32,
    pub l2_ways: u32,
    /// LLC slice access latency (tag + data array), cycles.
    pub l2_latency: Cycle,

    /// DRAM access latency in cycles (100 ns @ 1 GHz).
    pub dram_latency: Cycle,
    /// Number of memory controllers.
    pub n_mcs: u32,
    /// Cycles one 64-B line occupies a controller (10 GB/s → 6.4 ns).
    pub dram_service_cycles: Cycle,

    /// Per-hop network latency (1 router + 1 link).
    pub hop_cycles: Cycle,
    /// Flit width in bits.
    pub flit_bits: u32,

    /// Misspeculation rollback cost added on a failed renewal (pipeline
    /// flush, like a branch mispredict).
    pub rollback_penalty: Cycle,
    /// Cycles between consecutive polls when a core spins on a cached,
    /// still-valid line (test-and-test-and-set backoff).
    pub spin_poll_cycles: Cycle,

    /// Hard cap on simulated cycles (deadlock guard).
    ///
    /// (Access-log recording moved off this struct: instrumentation is
    /// configured on [`crate::api::SimBuilder`] via `record_accesses`
    /// and the `Observer` plugins.)
    pub max_cycles: Cycle,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            n_cores: 64,
            core_model: CoreModel::InOrder,
            ooo_window: 16,
            protocol: ProtocolKind::Tardis,
            tardis: TardisConfig::default(),
            ackwise: AckwiseConfig::default(),
            // 32 KB, 4-way, 64-B lines -> 128 sets.
            l1_sets: 128,
            l1_ways: 4,
            // 256 KB slice, 8-way -> 512 sets.
            l2_sets: 512,
            l2_ways: 8,
            l2_latency: 8,
            dram_latency: 100,
            n_mcs: 8,
            dram_service_cycles: 7,
            hop_cycles: 2,
            flit_bits: 128,
            rollback_penalty: 8,
            spin_poll_cycles: 1,
            max_cycles: 2_000_000_000,
        }
    }
}

impl SystemConfig {
    /// Convenience: small test system.
    pub fn small(n_cores: u32, protocol: ProtocolKind) -> Self {
        Self {
            n_cores,
            protocol,
            l1_sets: 16,
            l1_ways: 4,
            l2_sets: 64,
            l2_ways: 8,
            max_cycles: 200_000_000,
            ..Self::default()
        }
    }

    /// Total L1 lines per core.
    pub fn l1_lines(&self) -> u32 {
        self.l1_sets * self.l1_ways
    }

    /// Total LLC lines per slice.
    pub fn l2_lines(&self) -> u32 {
        self.l2_sets * self.l2_ways
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_v_defaults() {
        let c = SystemConfig::default();
        assert_eq!(c.n_cores, 64);
        // 32 KB / 64 B / 4 ways = 128 sets
        assert_eq!(c.l1_sets * c.l1_ways * 64, 32 * 1024);
        // 256 KB / 64 B / 8 ways = 512 sets
        assert_eq!(c.l2_sets * c.l2_ways * 64, 256 * 1024);
        assert_eq!(c.tardis.lease, 10);
        assert_eq!(c.tardis.self_inc_period, 100);
        assert_eq!(c.tardis.delta_ts_bits, 20);
        assert_eq!(c.dram_latency, 100);
        assert_eq!(c.hop_cycles, 2);
        assert_eq!(c.flit_bits, 128);
    }

    #[test]
    fn protocol_parse_roundtrip() {
        for p in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            assert_eq!(ProtocolKind::parse(p.name()), Some(p));
        }
        assert_eq!(ProtocolKind::parse("mesi"), None);
    }
}
