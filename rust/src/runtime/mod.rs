//! PJRT runtime: load the AOT-compiled tracegen artifacts (HLO text
//! emitted by python/compile/aot.py) and execute them to materialize
//! workload traces.  Python never runs here — the artifacts are
//! compiled once by `make artifacts` and this module only loads and
//! executes them through the XLA PJRT C API (`xla` crate).
//!
//! The `xla` dependency is unavailable in offline registries, so the
//! real runtime sits behind the off-by-default `pjrt` cargo feature.
//! Without it, [`TraceRuntime`] is an API-compatible stub whose
//! constructors fail, and every consumer falls back to the bit-exact
//! rust mirror of the generator ([`crate::trace::synth`]) through
//! [`workload_or_synth`].

mod manifest;

pub use manifest::{parse_manifest, ManifestEntry};

#[cfg(feature = "pjrt")]
mod pjrt_runtime {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use anyhow::{anyhow, Context, Result};

    use super::manifest::{parse_manifest, ManifestEntry};

    /// Loads artifacts lazily and caches compiled executables per
    /// (n_cores, trace_len) configuration.
    pub struct TraceRuntime {
        client: xla::PjRtClient,
        dir: PathBuf,
        configs: Vec<ManifestEntry>,
        execs: HashMap<(u32, u32), xla::PjRtLoadedExecutable>,
    }

    impl TraceRuntime {
        /// Open the artifact directory (reads manifest.json).
        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref().to_path_buf();
            let manifest_path = dir.join("manifest.json");
            let text = std::fs::read_to_string(&manifest_path).with_context(|| {
                format!("reading {manifest_path:?} — run `make artifacts` first")
            })?;
            let configs = parse_manifest(&text)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
            Ok(Self { client, dir, configs, execs: HashMap::new() })
        }

        /// Default artifact directory (repo-root/artifacts),
        /// overridable via TARDIS_ARTIFACTS.
        pub fn open_default() -> Result<Self> {
            let dir = std::env::var("TARDIS_ARTIFACTS").unwrap_or_else(|_| {
                concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
            });
            Self::open(dir)
        }

        /// Available (n_cores, trace_len) configurations.
        pub fn configs(&self) -> Vec<(u32, u32)> {
            self.configs.iter().map(|c| (c.n_cores, c.trace_len)).collect()
        }

        /// Pick the artifact for `n_cores` (trace length is baked per
        /// config).
        pub fn config_for(&self, n_cores: u32) -> Option<(u32, u32)> {
            self.configs
                .iter()
                .find(|c| c.n_cores == n_cores)
                .map(|c| (c.n_cores, c.trace_len))
        }

        fn executable(
            &mut self,
            n_cores: u32,
            trace_len: u32,
        ) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.execs.contains_key(&(n_cores, trace_len)) {
                let entry = self
                    .configs
                    .iter()
                    .find(|c| c.n_cores == n_cores && c.trace_len == trace_len)
                    .ok_or_else(|| {
                        anyhow!("no artifact for n_cores={n_cores} trace_len={trace_len}")
                    })?;
                let path = self.dir.join(&entry.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
                self.execs.insert((n_cores, trace_len), exe);
            }
            Ok(&self.execs[&(n_cores, trace_len)])
        }

        /// Execute the tracegen artifact: params int32[16] -> flat
        /// int32[n_cores * trace_len * 3] trace tensor.
        pub fn generate_raw(
            &mut self,
            n_cores: u32,
            trace_len: u32,
            params: &[i32; 16],
        ) -> Result<Vec<i32>> {
            let exe = self.executable(n_cores, trace_len)?;
            let input = xla::Literal::vec1(params.as_slice());
            let result = exe
                .execute::<xla::Literal>(&[input])
                .map_err(|e| anyhow!("executing tracegen: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result: {e:?}"))?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = result.to_tuple1().map_err(|e| anyhow!("untupling: {e:?}"))?;
            let flat = out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            anyhow::ensure!(
                flat.len() == (n_cores * trace_len * 3) as usize,
                "artifact returned {} values, expected {}",
                flat.len(),
                n_cores * trace_len * 3
            );
            Ok(flat)
        }

        /// Execute + decode into a workload.
        pub fn generate_workload(
            &mut self,
            n_cores: u32,
            trace_len: u32,
            params: &crate::trace::TraceParams,
        ) -> Result<crate::prog::Workload> {
            let raw = self.generate_raw(n_cores, trace_len, &params.to_vec())?;
            Ok(crate::trace::decode_workload(&raw, n_cores, trace_len))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_runtime::TraceRuntime;

#[cfg(not(feature = "pjrt"))]
mod stub_runtime {
    use std::path::Path;

    use anyhow::{bail, Result};

    const UNAVAILABLE: &str =
        "PJRT trace runtime unavailable: tardis-dsm was built without the `pjrt` feature \
         (traces come from the rust synth mirror instead)";

    /// API-compatible stand-in for the PJRT runtime when the `pjrt`
    /// feature is off.  Constructors fail, so callers holding an
    /// `Option<TraceRuntime>` (the common pattern) transparently fall
    /// back to the synth mirror.
    pub struct TraceRuntime {
        _sealed: (),
    }

    impl TraceRuntime {
        pub fn open(_dir: impl AsRef<Path>) -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn open_default() -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn configs(&self) -> Vec<(u32, u32)> {
            Vec::new()
        }

        pub fn config_for(&self, _n_cores: u32) -> Option<(u32, u32)> {
            None
        }

        pub fn generate_raw(
            &mut self,
            _n_cores: u32,
            _trace_len: u32,
            _params: &[i32; 16],
        ) -> Result<Vec<i32>> {
            bail!(UNAVAILABLE)
        }

        pub fn generate_workload(
            &mut self,
            _n_cores: u32,
            _trace_len: u32,
            _params: &crate::trace::TraceParams,
        ) -> Result<crate::prog::Workload> {
            bail!(UNAVAILABLE)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_runtime::TraceRuntime;

/// Generate a workload from artifacts when available, falling back to
/// the bit-exact rust mirror (tests, artifact-less environments).
pub fn workload_or_synth(
    runtime: &mut Option<TraceRuntime>,
    n_cores: u32,
    trace_len: u32,
    params: &crate::trace::TraceParams,
) -> crate::prog::Workload {
    if let Some(rt) = runtime {
        if let Ok(w) = rt.generate_workload(n_cores, trace_len, params) {
            return w;
        }
    }
    crate::trace::synth_workload(params, n_cores, trace_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_or_synth_falls_back_without_runtime() {
        let mut rt: Option<TraceRuntime> = None;
        let params = crate::trace::TraceParams::default();
        let w = workload_or_synth(&mut rt, 2, 64, &params);
        assert_eq!(w.n_cores(), 2);
        assert_eq!(w.total_ops(), 2 * 64);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_refuses_to_open() {
        let err = TraceRuntime::open_default().err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
