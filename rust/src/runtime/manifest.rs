//! Minimal parser for artifacts/manifest.json (no serde in this
//! image's crate registry).  The format is fixed and produced by our
//! own aot.py, so a small field extractor is sufficient and strict.

use anyhow::{anyhow, Result};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    pub n_cores: u32,
    pub trace_len: u32,
    pub file: String,
}

/// Parse the manifest: extracts every `{"n_cores": N, "trace_len": L,
/// "file": "..."}` object from the configs array.
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut entries = Vec::new();
    let configs_at = text
        .find("\"configs\"")
        .ok_or_else(|| anyhow!("manifest missing \"configs\""))?;
    let body = &text[configs_at..];
    for obj in body.split('{').skip(1) {
        let obj = obj.split('}').next().unwrap_or("");
        let n_cores = extract_u32(obj, "n_cores");
        let trace_len = extract_u32(obj, "trace_len");
        let file = extract_str(obj, "file");
        if let (Some(n_cores), Some(trace_len), Some(file)) = (n_cores, trace_len, file) {
            entries.push(ManifestEntry { n_cores, trace_len, file });
        }
    }
    if entries.is_empty() {
        return Err(anyhow!("manifest has no artifact configs"));
    }
    Ok(entries)
}

fn extract_u32(obj: &str, key: &str) -> Option<u32> {
    let pat = format!("\"{key}\"");
    let at = obj.find(&pat)?;
    let rest = &obj[at + pat.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn extract_str(obj: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\"");
    let at = obj.find(&pat)?;
    let rest = &obj[at + pat.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let rest = rest.strip_prefix('"')?;
    Some(rest[..rest.find('"')?].to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "params_len": 16,
  "configs": [
    { "n_cores": 2, "trace_len": 256, "file": "tracegen_c2_l256.hlo.txt" },
    { "n_cores": 64, "trace_len": 4096, "file": "tracegen_c64_l4096.hlo.txt" }
  ]
}"#;

    #[test]
    fn parses_entries() {
        let e = parse_manifest(SAMPLE).unwrap();
        assert_eq!(e.len(), 2);
        assert_eq!(e[0], ManifestEntry {
            n_cores: 2,
            trace_len: 256,
            file: "tracegen_c2_l256.hlo.txt".into()
        });
        assert_eq!(e[1].n_cores, 64);
        assert_eq!(e[1].trace_len, 4096);
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_manifest("{}").is_err());
        assert!(parse_manifest("{\"configs\": []}").is_err());
    }

    #[test]
    fn tolerates_compact_json() {
        let compact = r#"{"configs":[{"n_cores":4,"trace_len":512,"file":"x.hlo.txt"}]}"#;
        let e = parse_manifest(compact).unwrap();
        assert_eq!(e[0].n_cores, 4);
        assert_eq!(e[0].file, "x.hlo.txt");
    }
}
