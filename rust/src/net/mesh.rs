//! 2-D mesh topology with XY routing (paper Table V): hop latency
//! 2 cycles (1 router + 1 link), 128-bit flits.  Latency is analytic
//! (no per-link contention queues — DESIGN.md substitution #1); flit
//! counts are exact and drive the traffic statistics.

use super::message::{Message, Node};
use super::topology::RouteInfo;
use crate::types::{Cycle, McId};

/// The on-chip interconnect.  Core `i` and LLC slice `i` share tile
/// `i`; memory controllers are spread evenly along the tile sequence.
#[derive(Debug, Clone)]
pub struct Mesh {
    /// Mesh side length (ceil(sqrt(n_tiles))).
    dim: u32,
    n_tiles: u32,
    n_mcs: u32,
    hop_cycles: Cycle,
    flit_bits: u32,
}

impl Mesh {
    pub fn new(n_tiles: u32, n_mcs: u32, hop_cycles: Cycle, flit_bits: u32) -> Self {
        let dim = (n_tiles as f64).sqrt().ceil() as u32;
        Self { dim, n_tiles, n_mcs, hop_cycles, flit_bits }
    }

    /// Tile index of a node.
    pub fn tile_of(&self, node: Node) -> u32 {
        match node {
            Node::Core(c) => c % self.n_tiles,
            Node::Slice(s) => s % self.n_tiles,
            Node::Mc(m) => self.mc_tile(m),
        }
    }

    /// Memory controller `m`'s tile: spread evenly across the tiles.
    /// Multiply before dividing — the old `m * (n_tiles / n_mcs)`
    /// truncated the stride first, clustering every controller into
    /// the low tiles whenever `n_tiles` was not divisible by `n_mcs`
    /// (and wrapping several controllers onto tile 0 for small
    /// meshes).
    pub fn mc_tile(&self, m: McId) -> u32 {
        ((m % self.n_mcs) as u64 * self.n_tiles as u64 / self.n_mcs as u64) as u32
    }

    /// (x, y) coordinates of a tile.
    pub fn coords(&self, tile: u32) -> (u32, u32) {
        (tile % self.dim, tile / self.dim)
    }

    /// XY-routed hop count between two nodes.
    pub fn hops(&self, a: Node, b: Node) -> u32 {
        let (ax, ay) = self.coords(self.tile_of(a));
        let (bx, by) = self.coords(self.tile_of(b));
        ax.abs_diff(bx) + ay.abs_diff(by)
    }

    /// End-to-end latency of a message: per-hop router+link latency
    /// plus payload serialization.  Same-tile messages skip the network
    /// (1-cycle controller hand-off).
    pub fn latency(&self, msg: &Message) -> Cycle {
        let hops = self.hops(msg.src, msg.dst);
        if hops == 0 {
            return 1;
        }
        self.hop_cycles * hops as Cycle + msg.kind.flits(self.flit_bits)
    }

    /// Flits this message contributes to network traffic.  Same-tile
    /// messages never enter the mesh and count zero.
    pub fn traffic_flits(&self, msg: &Message) -> u64 {
        if self.hops(msg.src, msg.dst) == 0 {
            0
        } else {
            msg.kind.flits(self.flit_bits)
        }
    }

    pub fn dim(&self) -> u32 {
        self.dim
    }

    /// Latency + traffic + hops in one pass (the [`super::Topology`]
    /// entry point — one hop computation instead of the separate
    /// [`Mesh::latency`] / [`Mesh::traffic_flits`] calls; identical
    /// arithmetic, asserted by `flat_route_matches_mesh_methods_*`).
    #[inline]
    pub fn route(&self, msg: &Message) -> RouteInfo {
        super::topology::mesh_segment(self.hops(msg.src, msg.dst), self.hop_cycles, || {
            msg.kind.flits(self.flit_bits)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::message::MsgKind;
    use crate::types::LineAddr;

    fn mesh64() -> Mesh {
        Mesh::new(64, 8, 2, 128)
    }

    fn msg(src: Node, dst: Node, kind: MsgKind) -> Message {
        Message { src, dst, addr: 0 as LineAddr, requester: 0, kind }
    }

    #[test]
    fn dim_is_sqrt() {
        assert_eq!(mesh64().dim(), 8);
        assert_eq!(Mesh::new(16, 8, 2, 128).dim(), 4);
        assert_eq!(Mesh::new(256, 8, 2, 128).dim(), 16);
        // Non-square counts round up.
        assert_eq!(Mesh::new(12, 4, 2, 128).dim(), 4);
    }

    #[test]
    fn xy_hops() {
        let m = mesh64();
        // tile 0 = (0,0), tile 63 = (7,7): 14 hops corner to corner.
        assert_eq!(m.hops(Node::Core(0), Node::Slice(63)), 14);
        // Core and slice on the same tile: 0 hops.
        assert_eq!(m.hops(Node::Core(5), Node::Slice(5)), 0);
        // Neighbors.
        assert_eq!(m.hops(Node::Core(0), Node::Slice(1)), 1);
        assert_eq!(m.hops(Node::Core(0), Node::Slice(8)), 1);
    }

    #[test]
    fn latency_control_vs_data() {
        let m = mesh64();
        let ctrl = msg(Node::Core(0), Node::Slice(1), MsgKind::GetS);
        let data = msg(Node::Slice(1), Node::Core(0), MsgKind::DataS { value: 0 });
        // 1 hop: 2 + 1 flit vs 2 + 5 flits.
        assert_eq!(m.latency(&ctrl), 3);
        assert_eq!(m.latency(&data), 7);
    }

    #[test]
    fn same_tile_is_fast_and_free() {
        let m = mesh64();
        let local = msg(Node::Core(3), Node::Slice(3), MsgKind::GetS);
        assert_eq!(m.latency(&local), 1);
        assert_eq!(m.traffic_flits(&local), 0);
    }

    #[test]
    fn traffic_counts_flits_for_remote() {
        let m = mesh64();
        let data = msg(Node::Slice(9), Node::Core(0), MsgKind::DataX { value: 0 });
        assert_eq!(m.traffic_flits(&data), 5);
    }

    #[test]
    fn mc_tiles_spread() {
        let m = mesh64();
        let tiles: Vec<u32> = (0..8).map(|i| m.mc_tile(i)).collect();
        assert_eq!(tiles, vec![0, 8, 16, 24, 32, 40, 48, 56]);
    }

    #[test]
    fn mc_tiles_distinct_and_spread_at_paper_scales() {
        // 4 controllers on the paper's 16/64/256-tile meshes: tiles
        // must be pairwise distinct and spread across the full range
        // (consecutive gaps of exactly n_tiles / n_mcs).
        for n_tiles in [16u32, 64, 256] {
            let mesh = Mesh::new(n_tiles, 4, 2, 128);
            let tiles: Vec<u32> = (0..4).map(|i| mesh.mc_tile(i)).collect();
            let expected_gap = n_tiles / 4;
            for (i, pair) in tiles.windows(2).enumerate() {
                assert!(
                    pair[1] > pair[0],
                    "{n_tiles} tiles: mc {} and {} collide or invert: {tiles:?}",
                    i,
                    i + 1
                );
                assert_eq!(
                    pair[1] - pair[0],
                    expected_gap,
                    "{n_tiles} tiles: uneven spread {tiles:?}"
                );
            }
            assert!(tiles.iter().all(|&t| t < n_tiles));
        }
    }

    #[test]
    fn mc_tiles_stay_distinct_when_not_divisible() {
        // 4 MCs on meshes whose tile count is NOT divisible by the
        // controller count: the old truncate-then-multiply formula
        // clustered these (e.g. 10 tiles -> 0, 2, 4, 6, all in the
        // low quarter); they must stay distinct and span the range.
        for n_tiles in [6u32, 10, 12, 18] {
            let mesh = Mesh::new(n_tiles, 4, 2, 128);
            let tiles: Vec<u32> = (0..4).map(|i| mesh.mc_tile(i)).collect();
            let mut sorted = tiles.clone();
            sorted.dedup();
            assert_eq!(sorted.len(), 4, "{n_tiles} tiles: collision in {tiles:?}");
            // The last controller sits in the top quarter, not the
            // low half.
            assert!(
                tiles[3] >= 3 * n_tiles / 4,
                "{n_tiles} tiles: clustered placement {tiles:?}"
            );
        }
    }

    #[test]
    fn mc_tiles_wrap_when_fewer_tiles_than_mcs() {
        // Degenerate small meshes (2 tiles, 8 MCs) still map into
        // range and use both tiles.
        let mesh = Mesh::new(2, 8, 2, 128);
        let tiles: Vec<u32> = (0..8).map(|i| mesh.mc_tile(i)).collect();
        assert!(tiles.iter().all(|&t| t < 2));
        assert!(tiles.contains(&0) && tiles.contains(&1));
    }
}
