//! Network message types for all three protocols (paper Table IV for
//! Tardis) plus DRAM transactions, with flit sizing and traffic-class
//! attribution.

use crate::types::{CoreId, LineAddr, McId, SliceId, Ts};

/// A network endpoint: a core's private-cache controller, an LLC slice
/// (timestamp manager / directory), or a memory controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Node {
    Core(CoreId),
    Slice(SliceId),
    Mc(McId),
}

/// Message payloads.  One unified enum keeps the engine protocol-
/// agnostic; each protocol only produces/consumes its own variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgKind {
    // ------ Tardis (paper Table IV) ------
    /// Shared (load) request; `renew` marks a lease-extension attempt
    /// (requester still holds data with matching `wts`).
    ShReq { pts: Ts, wts: Ts, renew: bool },
    /// Exclusive (store) request with the requester's cached `wts`.
    ExReq { wts: Ts },
    /// TM asks the owner to flush (invalidate + return data).
    FlushReq,
    /// TM asks the owner to write back (keep shared); carries the
    /// reservation end timestamp for the requester.
    WbReq { rts: Ts },
    /// Shared reply with data.
    ShRep { wts: Ts, rts: Ts, value: u64 },
    /// Exclusive reply with data.
    ExRep { wts: Ts, rts: Ts, value: u64 },
    /// Exclusive grant without data (requester's copy is current).
    UpgradeRep { rts: Ts },
    /// Lease renewed without data.
    RenewRep { rts: Ts },
    /// Owner returns + invalidates; `dirty` controls LLC writeback.
    FlushRep { wts: Ts, rts: Ts, value: u64, dirty: bool },
    /// Owner returns + downgrades to shared.
    WbRep { wts: Ts, rts: Ts, value: u64 },

    // ------ MSI / Ackwise directory ------
    /// Read miss.
    GetS,
    /// Write miss / upgrade.
    GetX,
    /// Clean eviction notification from an L1 (removes sharer).
    PutS,
    /// Dirty eviction with data from the owner.
    PutM { value: u64 },
    /// Directory invalidates an L1 copy.
    Inv,
    /// L1 acknowledges an invalidation.
    InvAck,
    /// Directory asks the owner to downgrade M -> S and return data.
    DownReq,
    DownRep { value: u64 },
    /// Directory asks the owner to flush M -> I and return data.
    DirFlushReq,
    DirFlushRep { value: u64 },
    /// Data replies to the requester.
    DataS { value: u64 },
    DataX { value: u64 },
    /// Exclusive grant without data (requester already had the line).
    GrantX,

    // ------ DRAM ------
    DramLdReq,
    DramLdRep { value: u64 },
    DramStReq { value: u64 },
}

/// Traffic class for the stats breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgClass {
    Request,
    Data,
    Control,
    Renew,
    Invalidation,
    Dram,
}

impl MsgKind {
    /// Does this message carry a 64-B data payload?
    pub fn carries_data(&self) -> bool {
        matches!(
            self,
            MsgKind::ShRep { .. }
                | MsgKind::ExRep { .. }
                | MsgKind::FlushRep { .. }
                | MsgKind::WbRep { .. }
                | MsgKind::PutM { .. }
                | MsgKind::DownRep { .. }
                | MsgKind::DirFlushRep { .. }
                | MsgKind::DataS { .. }
                | MsgKind::DataX { .. }
                | MsgKind::DramLdRep { .. }
                | MsgKind::DramStReq { .. }
        )
    }

    /// Message size in flits: control messages fit one 128-bit flit
    /// (address + up to two timestamps, paper §VI-B2: "a successful
    /// renewal only requires a single flit message"); data messages add
    /// a 64-B payload = 4 more flits.
    pub fn flits(&self, flit_bits: u32) -> u64 {
        let header = 1u64;
        if self.carries_data() {
            header + (crate::types::LINE_BYTES * 8).div_ceil(flit_bits as u64)
        } else {
            header
        }
    }

    /// Traffic class for the stats breakdown.
    pub fn class(&self) -> MsgClass {
        match self {
            MsgKind::ShReq { renew: true, .. } | MsgKind::RenewRep { .. } => MsgClass::Renew,
            MsgKind::ShReq { .. }
            | MsgKind::ExReq { .. }
            | MsgKind::GetS
            | MsgKind::GetX
            | MsgKind::FlushReq
            | MsgKind::WbReq { .. }
            | MsgKind::DownReq
            | MsgKind::DirFlushReq => MsgClass::Request,
            MsgKind::ShRep { .. }
            | MsgKind::ExRep { .. }
            | MsgKind::FlushRep { .. }
            | MsgKind::WbRep { .. }
            | MsgKind::PutM { .. }
            | MsgKind::DownRep { .. }
            | MsgKind::DirFlushRep { .. }
            | MsgKind::DataS { .. }
            | MsgKind::DataX { .. } => MsgClass::Data,
            MsgKind::Inv | MsgKind::InvAck | MsgKind::PutS => MsgClass::Invalidation,
            MsgKind::UpgradeRep { .. } | MsgKind::GrantX => MsgClass::Control,
            MsgKind::DramLdReq | MsgKind::DramLdRep { .. } | MsgKind::DramStReq { .. } => {
                MsgClass::Dram
            }
        }
    }
}

/// A message in flight.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Message {
    pub src: Node,
    pub dst: Node,
    pub addr: LineAddr,
    /// The core whose demand access ultimately caused this message
    /// (so the slice knows whom to serve / reply to).
    pub requester: CoreId,
    pub kind: MsgKind,
}

/// Free-list slab interning in-flight messages so the event queue
/// moves 4-byte indices instead of ~80-byte structs (§Perf).  Slots
/// are recycled LIFO; steady-state simulation keeps the slab at the
/// peak number of simultaneously in-flight messages.
#[derive(Debug, Default)]
pub struct MsgSlab {
    slots: Vec<Message>,
    free: Vec<u32>,
}

impl MsgSlab {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a message, returning its slot index.
    #[inline]
    pub fn insert(&mut self, m: Message) -> u32 {
        match self.free.pop() {
            Some(i) => {
                self.slots[i as usize] = m;
                i
            }
            None => {
                self.slots.push(m);
                (self.slots.len() - 1) as u32
            }
        }
    }

    /// Remove and return the message at `idx`, freeing the slot.
    /// `idx` must come from `insert` and not have been taken already.
    #[inline]
    pub fn take(&mut self, idx: u32) -> Message {
        debug_assert!((idx as usize) < self.slots.len(), "stale slab index {idx}");
        debug_assert!(!self.free.contains(&idx), "double take of slab slot {idx}");
        self.free.push(idx);
        self.slots[idx as usize]
    }

    /// Messages currently interned.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Allocated slots (high-water mark of in-flight messages).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_messages_are_one_flit() {
        assert_eq!(MsgKind::ShReq { pts: 0, wts: 0, renew: false }.flits(128), 1);
        assert_eq!(MsgKind::RenewRep { rts: 9 }.flits(128), 1);
        assert_eq!(MsgKind::Inv.flits(128), 1);
        assert_eq!(MsgKind::GrantX.flits(128), 1);
    }

    #[test]
    fn data_messages_are_five_flits() {
        // 64 B = 512 bits = 4 x 128-bit flits + 1 header.
        assert_eq!(MsgKind::ShRep { wts: 0, rts: 0, value: 1 }.flits(128), 5);
        assert_eq!(MsgKind::DataX { value: 3 }.flits(128), 5);
        assert_eq!(MsgKind::PutM { value: 3 }.flits(128), 5);
    }

    #[test]
    fn renewal_classified_as_renew_traffic() {
        assert_eq!(
            MsgKind::ShReq { pts: 1, wts: 1, renew: true }.class(),
            MsgClass::Renew
        );
        assert_eq!(MsgKind::RenewRep { rts: 1 }.class(), MsgClass::Renew);
        // A cold shared request is ordinary request traffic.
        assert_eq!(
            MsgKind::ShReq { pts: 1, wts: 0, renew: false }.class(),
            MsgClass::Request
        );
    }

    #[test]
    fn wider_flits_shrink_data_messages() {
        assert_eq!(MsgKind::DataS { value: 0 }.flits(256), 3);
        assert_eq!(MsgKind::DataS { value: 0 }.flits(512), 2);
    }

    #[test]
    fn slab_recycles_slots() {
        let msg = |addr| Message {
            src: Node::Core(0),
            dst: Node::Slice(0),
            addr,
            requester: 0,
            kind: MsgKind::GetS,
        };
        let mut slab = MsgSlab::new();
        let a = slab.insert(msg(1));
        let b = slab.insert(msg(2));
        assert_eq!(slab.len(), 2);
        assert_eq!(slab.take(a).addr, 1);
        // Freed slot is reused before the slab grows.
        let c = slab.insert(msg(3));
        assert_eq!(c, a);
        assert_eq!(slab.capacity(), 2);
        assert_eq!(slab.take(b).addr, 2);
        assert_eq!(slab.take(c).addr, 3);
        assert!(slab.is_empty());
    }
}
