//! Interconnect: message formats, the flat 2-D mesh timing/traffic
//! model, and the hierarchical ccNUMA topology layer above it.

pub mod mesh;
pub mod message;
pub mod topology;

pub use mesh::Mesh;
pub use message::{Message, MsgClass, MsgKind, MsgSlab, Node};
pub use topology::{NumaFabric, NumaView, RouteInfo, Topology};
