//! On-chip network: message formats and the 2-D mesh timing/traffic model.

pub mod mesh;
pub mod message;

pub use mesh::Mesh;
pub use message::{Message, MsgClass, MsgKind, MsgSlab, Node};
