//! Hierarchical ccNUMA network topology (paper §VII: Tardis in
//! *distributed* shared memory).
//!
//! The flat single-chip [`Mesh`] generalizes into a [`Topology`] enum
//! dispatched like [`crate::proto::ProtocolDispatch`]: [`Topology::Flat`]
//! wraps the unchanged `Mesh` (bit-for-bit the pre-topology behavior),
//! and [`Topology::Numa`] models N sockets, each an intra-socket mesh
//! of tiles with its own timestamp-manager / directory slices and
//! memory controllers, joined by point-to-point inter-socket links
//! that are both slower (`numa_ratio` x the per-hop latency) and
//! narrower (`numa_ratio` x the per-flit serialization) than on-chip
//! wires — the classic NUMA factor.
//!
//! Every message resolves to one [`RouteInfo`]: end-to-end latency,
//! flits entering the network, mesh hops traversed inside sockets, and
//! inter-socket links crossed.  The engine charges latency from it and
//! splits the traffic statistics into intra- vs inter-socket classes
//! ([`crate::stats::SocketStats`]), which the `numa` sweep reads off
//! to show Tardis's owner-free renewals beating directory multicasts
//! as the inter-socket cost grows.

use super::mesh::Mesh;
use super::message::{Message, MsgKind, Node};
use crate::config::SystemConfig;
use crate::types::{CoreId, Cycle, SliceId};

/// The resolved path of one message through the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteInfo {
    /// End-to-end delivery latency in cycles.
    pub latency: Cycle,
    /// Flits this message contributes to network traffic (0 when the
    /// endpoints share a tile and the message never enters the mesh).
    pub flits: u64,
    /// Mesh hops traversed inside sockets (both end segments of a
    /// cross-socket route).
    pub mesh_hops: u32,
    /// Inter-socket links crossed (0 = the route stayed on one socket).
    pub socket_hops: u32,
}

/// One on-chip mesh segment resolved to a [`RouteInfo`] — the single
/// source of the flat timing arithmetic, shared by [`Mesh::route`]
/// and the intra-socket arm of [`NumaFabric::route`] so the two can
/// never diverge.  A same-tile message is a 1-cycle controller
/// hand-off that never enters the network; `flits` is lazy so the
/// fast path skips the size computation.
pub(crate) fn mesh_segment(
    hops: u32,
    hop_cycles: Cycle,
    flits: impl FnOnce() -> u64,
) -> RouteInfo {
    if hops == 0 {
        return RouteInfo { latency: 1, flits: 0, mesh_hops: 0, socket_hops: 0 };
    }
    let flits = flits();
    RouteInfo {
        latency: hop_cycles * hops as Cycle + flits,
        flits,
        mesh_hops: hops,
        socket_hops: 0,
    }
}

/// The statically dispatched interconnect (the [`ProtocolDispatch`]
/// pattern): adding a fabric means adding an enum arm here — the
/// engine and protocols are untouched.
///
/// [`ProtocolDispatch`]: crate::proto::ProtocolDispatch
#[derive(Debug, Clone)]
pub enum Topology {
    /// Single-chip 2-D mesh (the pre-topology network, unchanged).
    Flat(Mesh),
    /// Multi-socket ccNUMA fabric.
    Numa(NumaFabric),
}

impl Topology {
    /// Instantiate the fabric selected by `cfg.topology` (1 socket =
    /// the flat mesh).
    pub fn new(cfg: &SystemConfig) -> Self {
        if cfg.topology.is_flat() {
            Self::Flat(Mesh::new(cfg.n_cores, cfg.n_mcs, cfg.hop_cycles, cfg.flit_bits))
        } else {
            Self::Numa(NumaFabric::new(
                cfg.n_cores,
                cfg.n_mcs,
                cfg.topology.sockets,
                cfg.topology.numa_ratio,
                cfg.hop_cycles,
                cfg.flit_bits,
            ))
        }
    }

    /// Resolve a message's route: latency, traffic flits, hop split.
    #[inline]
    pub fn route(&self, msg: &Message) -> RouteInfo {
        match self {
            Self::Flat(m) => m.route(msg),
            Self::Numa(f) => f.route(msg),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Flat(_) => "flat",
            Self::Numa(_) => "numa",
        }
    }

    /// Global tile index of a node — the same mapping both fabrics
    /// route by.  This is the anchor of the PDES ownership rule: the
    /// engine shards state by tile (DESIGN.md §11), so two nodes on
    /// different shards are guaranteed to sit on different tiles and
    /// every cross-shard message pays at least one mesh hop of
    /// latency — the conservative lookahead is never zero.
    pub fn tile_of(&self, node: Node) -> u32 {
        match self {
            Self::Flat(m) => m.tile_of(node),
            Self::Numa(f) => f.tile_of(node),
        }
    }

    /// Minimum delivery latency between two tiles: the smallest
    /// (1-flit control) message probed over the tiles' resident core
    /// pair.  Route timing depends only on the endpoint tiles and the
    /// flit count, so this is the tight per-edge bound the PDES
    /// lookahead table is built from — asymmetric on NUMA fabrics
    /// (intra-socket tile pairs are much closer than cross-socket
    /// ones), which is exactly what null-message mode exploits.
    pub fn probe_latency(&self, tile_a: u32, tile_b: u32) -> Cycle {
        let m = Message {
            src: Node::Core(tile_a),
            dst: Node::Core(tile_b),
            addr: 0,
            requester: 0,
            kind: MsgKind::GetS,
        };
        self.route(&m).latency
    }
}

/// A multi-socket ccNUMA fabric: `n_sockets` sockets, each owning a
/// contiguous block of `tiles_per_socket` tiles arranged as its own
/// 2-D XY-routed mesh, fully connected socket-to-socket (UPI-style
/// point-to-point links; one link crossing per remote message).
///
/// Tile numbering is global and socket-major: socket `s` owns tiles
/// `[s * tiles_per_socket, (s + 1) * tiles_per_socket)`.  Memory
/// controllers spread evenly over the global tile sequence (the
/// [`Mesh::mc_tile`] formula), which lands `n_mcs / n_sockets` of them
/// on each socket.
#[derive(Debug, Clone)]
pub struct NumaFabric {
    n_tiles: u32,
    n_mcs: u32,
    tiles_per_socket: u32,
    /// Per-socket mesh side length (ceil(sqrt(tiles_per_socket))).
    dim: u32,
    hop_cycles: Cycle,
    flit_bits: u32,
    numa_ratio: u32,
}

impl NumaFabric {
    pub fn new(
        n_tiles: u32,
        n_mcs: u32,
        n_sockets: u32,
        numa_ratio: u32,
        hop_cycles: Cycle,
        flit_bits: u32,
    ) -> Self {
        assert!(n_sockets >= 1, "a fabric needs at least one socket");
        assert_eq!(
            n_tiles % n_sockets,
            0,
            "tile count {n_tiles} must divide evenly into {n_sockets} sockets"
        );
        let tiles_per_socket = n_tiles / n_sockets;
        let dim = (tiles_per_socket as f64).sqrt().ceil() as u32;
        Self {
            n_tiles,
            n_mcs,
            tiles_per_socket,
            dim,
            hop_cycles,
            flit_bits,
            numa_ratio: numa_ratio.max(1),
        }
    }

    /// Global tile index of a node (same mapping as [`Mesh::tile_of`]).
    pub(crate) fn tile_of(&self, node: Node) -> u32 {
        match node {
            Node::Core(c) => c % self.n_tiles,
            Node::Slice(s) => s % self.n_tiles,
            // Spread controllers evenly over the global tile sequence
            // (multiply before dividing, like Mesh::mc_tile).
            Node::Mc(m) => {
                ((m % self.n_mcs) as u64 * self.n_tiles as u64 / self.n_mcs as u64) as u32
            }
        }
    }

    fn socket_of(&self, tile: u32) -> u32 {
        tile / self.tiles_per_socket
    }

    /// XY hop count between two tiles of the *same* socket.
    fn local_hops(&self, a: u32, b: u32) -> u32 {
        let (la, lb) = (a % self.tiles_per_socket, b % self.tiles_per_socket);
        let (ax, ay) = (la % self.dim, la / self.dim);
        let (bx, by) = (lb % self.dim, lb / self.dim);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }

    /// The socket's gateway tile (where its inter-socket link attaches):
    /// the socket's first tile, mesh coordinate (0, 0).
    fn gateway(&self, socket: u32) -> u32 {
        socket * self.tiles_per_socket
    }

    pub fn route(&self, msg: &Message) -> RouteInfo {
        let ta = self.tile_of(msg.src);
        let tb = self.tile_of(msg.dst);
        let (sa, sb) = (self.socket_of(ta), self.socket_of(tb));
        if sa == sb {
            // Intra-socket: the flat mesh arithmetic over the socket's
            // sub-mesh (1-socket fabrics reproduce Flat bit-for-bit —
            // see the equivalence test below).
            return mesh_segment(self.local_hops(ta, tb), self.hop_cycles, || {
                msg.kind.flits(self.flit_bits)
            });
        }
        // Cross-socket: mesh to the local gateway, one socket link
        // (numa_ratio x a mesh hop), mesh from the remote gateway —
        // and the payload serializes at the link's 1/numa_ratio
        // bandwidth instead of on-chip flit rate.
        let ratio = self.numa_ratio as u64;
        let mesh_hops = self.local_hops(ta, self.gateway(sa)) + self.local_hops(self.gateway(sb), tb);
        let flits = msg.kind.flits(self.flit_bits);
        RouteInfo {
            latency: self.hop_cycles * mesh_hops as Cycle + self.hop_cycles * ratio + flits * ratio,
            flits,
            mesh_hops,
            socket_hops: 1,
        }
    }
}

/// A compact, copyable view of the socket layout for protocol-side
/// NUMA awareness (the timestamp managers ask it how far a requester
/// sits so the lease policy can stretch leases on remote lines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NumaView {
    pub n_sockets: u32,
    pub tiles_per_socket: u32,
    pub numa_ratio: u32,
}

impl NumaView {
    pub fn from_config(cfg: &SystemConfig) -> Self {
        let n_sockets = cfg.topology.sockets.max(1);
        Self {
            n_sockets,
            tiles_per_socket: (cfg.n_cores / n_sockets).max(1),
            numa_ratio: cfg.topology.numa_ratio.max(1),
        }
    }

    /// Socket of a core's tile.
    pub fn socket_of_core(&self, core: CoreId) -> u32 {
        core / self.tiles_per_socket
    }

    /// Socket of an LLC slice's tile (core `i` and slice `i` share
    /// tile `i`).
    pub fn socket_of_slice(&self, slice: SliceId) -> u32 {
        slice / self.tiles_per_socket
    }

    /// Lease-stretch factor for a shared grant from `slice` to `core`:
    /// 1 on the local socket (and on flat systems), `numa_ratio` when
    /// the grant crosses a socket link — a remote renewal costs
    /// numa_ratio x as much, so a numa_ratio x longer lease amortizes
    /// it (Tardis 2.0's self-tuning argument applied to distance).
    pub fn lease_stretch(&self, slice: SliceId, core: CoreId) -> u64 {
        if self.n_sockets > 1 && self.socket_of_slice(slice) != self.socket_of_core(core) {
            self.numa_ratio as u64
        } else {
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyConfig;
    use crate::net::message::MsgKind;

    fn msg(src: Node, dst: Node, kind: MsgKind) -> Message {
        Message { src, dst, addr: 0, requester: 0, kind }
    }

    /// Every node of a 64-tile, 8-MC system.
    fn all_nodes() -> Vec<Node> {
        let mut v = Vec::new();
        for i in 0..64 {
            v.push(Node::Core(i));
            v.push(Node::Slice(i));
        }
        for m in 0..8 {
            v.push(Node::Mc(m));
        }
        v
    }

    /// `Topology::Flat` must reproduce the raw `Mesh` timing and
    /// traffic arithmetic exactly, for every endpoint pair and both
    /// message sizes (the flat-vs-legacy bit-for-bit guarantee).
    #[test]
    fn flat_route_matches_mesh_methods_exhaustively() {
        let mesh = Mesh::new(64, 8, 2, 128);
        let topo = Topology::Flat(mesh.clone());
        for &a in &all_nodes() {
            for &b in &all_nodes() {
                for kind in [MsgKind::GetS, MsgKind::DataS { value: 0 }] {
                    let m = msg(a, b, kind);
                    let info = topo.route(&m);
                    assert_eq!(info.latency, mesh.latency(&m), "{a:?}->{b:?}");
                    assert_eq!(info.flits, mesh.traffic_flits(&m), "{a:?}->{b:?}");
                    assert_eq!(info.socket_hops, 0);
                    assert_eq!(info.mesh_hops > 0, info.flits > 0);
                }
            }
        }
    }

    /// A 1-socket NumaFabric degenerates to the flat mesh: identical
    /// RouteInfo for every pair — the hierarchical code path cannot
    /// perturb flat results.
    #[test]
    fn single_socket_fabric_is_bit_identical_to_flat() {
        let flat = Topology::Flat(Mesh::new(64, 8, 2, 128));
        let numa = NumaFabric::new(64, 8, 1, 4, 2, 128);
        for &a in &all_nodes() {
            for &b in &all_nodes() {
                for kind in [MsgKind::GetS, MsgKind::DataX { value: 0 }] {
                    let m = msg(a, b, kind);
                    assert_eq!(numa.route(&m), flat.route(&m), "{a:?}->{b:?}");
                }
            }
        }
    }

    #[test]
    fn cross_socket_routes_pay_the_numa_factor() {
        // 64 tiles, 2 sockets of 32 (dim 6), ratio 4, hop 2.
        let f = NumaFabric::new(64, 8, 2, 4, 2, 128);
        // Core 0 (socket 0 gateway) -> slice 32 (socket 1 gateway):
        // 0 mesh hops, 1 link.  Control: 2*4 link + 1*4 flit = 12.
        let local_gw = msg(Node::Core(0), Node::Slice(32), MsgKind::GetS);
        let info = f.route(&local_gw);
        assert_eq!(info.socket_hops, 1);
        assert_eq!(info.mesh_hops, 0);
        assert_eq!(info.flits, 1);
        assert_eq!(info.latency, 2 * 4 + 4);
        // Data message: 5 flits serialize at 1/4 bandwidth.
        let data = msg(Node::Slice(32), Node::Core(0), MsgKind::DataS { value: 0 });
        assert_eq!(f.route(&data).latency, 2 * 4 + 5 * 4);
        // Same-socket messages never cross a link and match mesh
        // arithmetic: core 0 -> slice 1 is 1 hop.
        let local = msg(Node::Core(0), Node::Slice(1), MsgKind::GetS);
        assert_eq!(
            f.route(&local),
            RouteInfo { latency: 3, flits: 1, mesh_hops: 1, socket_hops: 0 }
        );
    }

    #[test]
    fn remote_latency_exceeds_local_and_grows_with_ratio() {
        let data = msg(Node::Core(1), Node::Slice(40), MsgKind::DataS { value: 0 });
        let mut last = 0;
        for ratio in [1, 2, 4, 8] {
            let f = NumaFabric::new(64, 8, 4, ratio, 2, 128);
            let lat = f.route(&data).latency;
            assert!(lat > last, "latency must grow with numa_ratio");
            last = lat;
        }
        // At ratio 1 a remote route still pays the link crossing but
        // at mesh cost (a 4-socket fabric is never faster than flat).
        let flat = Mesh::new(64, 8, 2, 128);
        let f1 = NumaFabric::new(64, 8, 4, 1, 2, 128);
        assert!(f1.route(&data).latency >= 1 + flat.traffic_flits(&data));
    }

    #[test]
    fn mcs_spread_across_sockets() {
        // 8 MCs over 64 tiles in 4 sockets: 2 controllers per socket.
        let f = NumaFabric::new(64, 8, 4, 4, 2, 128);
        let mut per_socket = [0u32; 4];
        for m in 0..8 {
            per_socket[f.socket_of(f.tile_of(Node::Mc(m))) as usize] += 1;
        }
        assert_eq!(per_socket, [2, 2, 2, 2]);
    }

    #[test]
    fn numa_view_distance_and_stretch() {
        let v = NumaView { n_sockets: 4, tiles_per_socket: 16, numa_ratio: 4 };
        assert_eq!(v.socket_of_core(0), 0);
        assert_eq!(v.socket_of_core(15), 0);
        assert_eq!(v.socket_of_core(16), 1);
        assert_eq!(v.socket_of_slice(63), 3);
        // Local grant: no stretch.  Remote: numa_ratio.
        assert_eq!(v.lease_stretch(3, 5), 1);
        assert_eq!(v.lease_stretch(3, 21), 4);
        // Flat systems never stretch, whatever the ratio says.
        let flat = NumaView { n_sockets: 1, tiles_per_socket: 64, numa_ratio: 4 };
        assert_eq!(flat.lease_stretch(0, 63), 1);
    }

    /// `probe_latency` is the 1-flit control-message bound, and on
    /// NUMA fabrics it is asymmetric across the socket boundary:
    /// intra-socket tile pairs are strictly closer than cross-socket
    /// pairs (the per-edge lookahead windows null-message mode uses).
    #[test]
    fn probe_latency_reflects_socket_distance() {
        let mut cfg = SystemConfig::default(); // 64 cores
        let flat = Topology::new(&cfg);
        assert_eq!(flat.probe_latency(0, 0), 1, "same tile: controller hand-off");
        assert_eq!(flat.probe_latency(0, 1), 2 + 1, "one hop + one flit");
        cfg.topology = TopologyConfig { sockets: 2, numa_ratio: 4, ..cfg.topology };
        let numa = Topology::new(&cfg);
        let intra = numa.probe_latency(0, 1);
        let cross = numa.probe_latency(0, 32);
        assert!(
            intra < cross,
            "intra-socket edge ({intra}) must be tighter than cross-socket ({cross})"
        );
    }

    #[test]
    fn topology_constructor_selects_by_socket_count() {
        let mut cfg = SystemConfig::default();
        assert_eq!(Topology::new(&cfg).name(), "flat");
        cfg.topology = TopologyConfig { sockets: 2, ..cfg.topology };
        assert_eq!(Topology::new(&cfg).name(), "numa");
    }
}
