//! Tardis timestamp-coherence protocol (paper §III–§IV).
//!
//! State lives in two halves mirroring the paper's Tables II and III:
//! per-core private caches ([`l1`]) and per-slice timestamp managers
//! ([`tm`]).  All timestamps are tracked exactly as u64; the base-delta
//! compression of §IV-B is *modeled*: per-cache base timestamps trigger
//! rebase events (with their stall cost and S-line invalidations)
//! whenever an assigned timestamp no longer fits in the configured
//! delta width.

mod l1;
mod tm;

use crate::config::{SystemConfig, TardisConfig};
use crate::hashing::FxHashMap;
use crate::mem::{SetAssoc, SliceMap};
use crate::net::{Message, MsgKind, Node, NumaView};
use crate::obs::EventKind;
use crate::proto::ts::{LeasePolicy, LineLease, LivelockGuard};
use crate::proto::{
    AccessOutcome, Coherence, Completion, CompletionKind, MemOp, ProtoCtx, SpinHint,
};
use crate::types::{CoreId, LineAddr, SliceId, Ts};

pub use tm::{Pending, PendingKind, Req, ReqKind};

/// Per-line state in a private L1 (paper Table II).
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash)]
pub struct L1Line {
    /// Exclusive (M-like) vs shared.
    pub excl: bool,
    pub wts: Ts,
    /// Shared: reservation (lease) end.  Exclusive: ts of last access.
    pub rts: Ts,
    pub value: u64,
    /// Written while exclusive (drives dirty write-back and the
    /// private-write optimization of §IV-C).
    pub modified: bool,
    /// An upgrade (ExReq from Shared) is outstanding: this copy is the
    /// data the UpgradeRep relies on — not evictable.
    pub pinned: bool,
}

/// A demand miss outstanding at an L1 (one per address).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Demand {
    pub op: MemOp,
    /// Extra same-address accesses parked behind this miss; they get a
    /// `Retry` completion once the line arrives.
    pub parked: u32,
}

/// An outstanding renewal (lease-extension) request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Renewal {
    /// Number of loads the core speculated through on this renewal
    /// (§IV-A); each gets a SpecOk/Misspec completion at resolution.
    pub spec_count: u32,
    /// A non-speculative demand load is blocked on this renewal.
    pub demand_waiting: bool,
}

/// Per-core private-cache controller state.
#[derive(Debug, Clone)]
pub struct L1 {
    pub cache: SetAssoc<L1Line>,
    /// Program timestamp: ts of the last committed operation.
    pub pts: Ts,
    /// Base timestamp for delta compression (§IV-B).
    pub bts: Ts,
    /// L1 data accesses since the last self increment.
    pub accesses_since_inc: u64,
    pub demand: FxHashMap<LineAddr, Demand>,
    pub renewals: FxHashMap<LineAddr, Renewal>,
    /// Line a spinning core is parked on (SpinWake on invalidate).
    pub watch: Option<LineAddr>,
}

/// Per-line state at a timestamp manager (paper Table III).  `owner`
/// Some = exclusive; the stored wts/rts are only meaningful while the
/// line is shared (the paper reuses those bits for the owner id).
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash)]
pub struct TmLine {
    pub owner: Option<CoreId>,
    /// Mid-transaction (owner round-trip in flight): not evictable.
    pub busy: bool,
    pub wts: Ts,
    pub rts: Ts,
    pub value: u64,
    pub dirty: bool,
    /// Any sharer since fill (E-state extension heuristic, §IV-D).
    pub touched: bool,
    /// Per-line lease-policy state ([`crate::proto::ts`]).
    pub lease: LineLease,
}

/// Per-slice timestamp-manager state.
#[derive(Debug, Clone)]
pub struct Tm {
    pub cache: SetAssoc<TmLine>,
    /// Memory timestamp for DRAM-resident lines (§III-C2).
    pub mts: Ts,
    pub bts: Ts,
    /// Running max of timestamps assigned in this slice (incremental —
    /// the rebase trigger must not scan the array per request).
    pub max_ts: Ts,
    pub pending: FxHashMap<LineAddr, Pending>,
}

/// The full protocol: all L1s + all timestamp managers.  `Clone`
/// exists for the `verif` model checker's snapshot/branch exploration.
#[derive(Debug, Clone)]
pub struct Tardis {
    pub(crate) cfg: TardisConfig,
    pub(crate) n_cores: u32,
    pub(crate) l1: Vec<L1>,
    pub(crate) tm: Vec<Tm>,
    /// Lease-assignment policy (timestamp-policy layer, proto/ts).
    pub(crate) lease_policy: LeasePolicy,
    /// Address -> home slice / memory-controller map (socket-aware).
    pub(crate) map: SliceMap,
    /// Socket layout view: lets the timestamp managers see how far a
    /// requester sits so the lease policy can stretch remote leases.
    pub(crate) numa: NumaView,
    /// Renewal-starvation detector (proto/ts).
    pub(crate) guard: LivelockGuard,
    /// 2^delta_ts_bits (saturating); timestamps must satisfy
    /// ts - bts < range or a rebase fires.
    pub(crate) ts_range: u64,
    /// Outstanding speculative renewals allowed per core.
    pub(crate) max_spec: usize,
}

impl Tardis {
    pub fn new(sys: &SystemConfig) -> Self {
        let cfg = sys.tardis;
        let ts_range = if cfg.delta_ts_bits >= 63 {
            u64::MAX
        } else {
            1u64 << cfg.delta_ts_bits
        };
        Self {
            lease_policy: LeasePolicy::new(&cfg),
            map: SliceMap::new(sys),
            numa: NumaView::from_config(sys),
            guard: LivelockGuard::new(cfg.livelock_threshold),
            cfg,
            n_cores: sys.n_cores,
            l1: (0..sys.n_cores)
                .map(|_| L1 {
                    cache: SetAssoc::new(sys.l1_sets, sys.l1_ways),
                    pts: 0,
                    bts: 0,
                    accesses_since_inc: 0,
                    demand: FxHashMap::default(),
                    renewals: FxHashMap::default(),
                    watch: None,
                })
                .collect(),
            tm: (0..sys.n_cores)
                .map(|_| Tm {
                    cache: SetAssoc::new(sys.l2_sets, sys.l2_ways),
                    // The paper initializes all timestamps to 1 (§III-C):
                    // wts = 0 in a request is then an unambiguous
                    // "requester holds no copy" sentinel for the
                    // RenewRep / UpgradeRep version checks.
                    mts: 1,
                    bts: 0,
                    max_ts: 1,
                    pending: FxHashMap::default(),
                })
                .collect(),
            ts_range,
            max_spec: 8,
        }
    }

    pub(crate) fn slice_of(&self, addr: LineAddr) -> SliceId {
        self.map.home_slice(addr)
    }

    /// Raise a core's pts, attributing the increase in the stats.
    pub(crate) fn raise_pts(&mut self, core: CoreId, new: Ts, self_inc: bool, ctx: &mut ProtoCtx) {
        let l1 = &mut self.l1[core as usize];
        if new > l1.pts {
            let delta = new - l1.pts;
            ctx.stats.ts.pts_increase_total += delta;
            if self_inc {
                ctx.stats.ts.pts_increase_self_inc += delta;
            }
            l1.pts = new;
            ctx.emit(EventKind::PtsJump, core, 0, delta);
        }
    }

    /// Count an L1 data access and apply the periodic self increment
    /// (§III-E).  Returns extra stall cycles (rebase).
    pub(crate) fn count_access(&mut self, core: CoreId, ctx: &mut ProtoCtx) -> u64 {
        let period = self.cfg.self_inc_period;
        if period == 0 {
            return 0;
        }
        let l1 = &mut self.l1[core as usize];
        l1.accesses_since_inc += 1;
        if l1.accesses_since_inc >= period {
            l1.accesses_since_inc = 0;
            let new = l1.pts + 1;
            self.raise_pts(core, new, true, ctx);
            return self.l1_check_rebase(core, new, ctx);
        }
        0
    }

    /// Current program timestamp of a core (diagnostics / tests).
    pub fn pts(&self, core: CoreId) -> Ts {
        self.l1[core as usize].pts
    }

    /// Snapshot tile `t`'s protocol state (L1 of core t, TM of slice
    /// t, livelock streaks of core t) for migration to another shard.
    /// The source copy is left in place — the losing shard never
    /// dispatches for this tile again.
    pub(crate) fn take_tile(&mut self, t: u32) -> TardisTile {
        TardisTile {
            l1: self.l1[t as usize].clone(),
            tm: self.tm[t as usize].clone(),
            streaks: self.guard.take_core_streaks(t),
        }
    }

    /// Overwrite tile `t`'s state with a snapshot from another shard.
    pub(crate) fn install_tile(&mut self, t: u32, tile: TardisTile) {
        self.l1[t as usize] = tile.l1;
        self.tm[t as usize] = tile.tm;
        self.guard.install_core_streaks(t, tile.streaks);
    }
}

/// Everything Tardis keeps per tile, packaged for shard migration.
#[derive(Debug, Clone)]
pub(crate) struct TardisTile {
    l1: L1,
    tm: Tm,
    streaks: Vec<(LineAddr, u32)>,
}

impl Coherence for Tardis {
    fn core_access(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        op: MemOp,
        spec_ok: bool,
        ctx: &mut ProtoCtx,
    ) -> AccessOutcome {
        self.l1_access(core, addr, op, spec_ok, ctx)
    }

    fn on_message(&mut self, msg: Message, ctx: &mut ProtoCtx) {
        match msg.dst {
            Node::Core(c) => self.l1_on_message(c, msg, ctx),
            Node::Slice(s) => self.tm_on_message(s, msg, ctx),
            Node::Mc(_) => unreachable!("MC messages are handled by the engine"),
        }
    }

    fn spin_hint(&mut self, core: CoreId, addr: LineAddr, ctx: &mut ProtoCtx) -> SpinHint {
        let period = self.cfg.self_inc_period;
        let (valid, excl, rts) = match self.l1[core as usize].cache.peek(addr) {
            None => return SpinHint::Retry,
            Some(line) => (
                line.excl || self.l1[core as usize].pts <= line.rts,
                line.excl,
                line.rts,
            ),
        };
        if !valid {
            return SpinHint::Retry;
        }
        if excl || period == 0 {
            // Exclusive lines only change via an external flush; with
            // self increment disabled a shared line never expires
            // (the §III-E livelock — the watchdog will flag it if the
            // update never comes).
            self.l1[core as usize].watch = Some(addr);
            return SpinHint::WaitInvalidate;
        }
        // Shared + valid: the spin loop's own accesses self-increment
        // pts past the lease.  Apply the bump now and tell the core
        // how many polls that costs.
        let l1 = &self.l1[core as usize];
        let need = rts - l1.pts + 1;
        let spins = need * period - l1.accesses_since_inc.min(period - 1);
        let new = rts + 1;
        self.raise_pts(core, new, true, ctx);
        let l1 = &mut self.l1[core as usize];
        l1.accesses_since_inc = 0;
        self.l1_check_rebase(core, new, ctx);
        SpinHint::ExpiresAfterSelfInc { spins_needed: spins.max(1) }
    }

    fn probe(&self, core: CoreId, addr: LineAddr) -> crate::proto::Probe {
        use crate::proto::Probe;
        let l1 = &self.l1[core as usize];
        match l1.cache.peek(addr) {
            None => Probe::Miss,
            Some(line) if line.excl || l1.pts <= line.rts => Probe::Hit,
            Some(_) if self.cfg.speculation => Probe::Spec,
            Some(_) => Probe::Miss,
        }
    }

    fn commit_check(&mut self, core: CoreId, addr: LineAddr, _early: bool, bound: u64) -> Option<Ts> {
        // OoO commit-time timestamp check (§III-D): the load commits at
        // ts = max(pts, wts) iff the line is still usable at that pts
        // (pts <= rts or exclusive) AND still holds the bound value
        // (it may have been renewed to a newer version since
        // execution); otherwise it re-executes.
        let l1 = &self.l1[core as usize];
        let (wts, excl, ok) = match l1.cache.peek(addr) {
            Some(line) => (
                line.wts,
                line.excl,
                (line.excl || l1.pts <= line.rts) && line.value == bound,
            ),
            None => return None, // line gone: re-execute
        };
        if !ok {
            return None;
        }
        let ts = self.l1[core as usize].pts.max(wts);
        self.l1[core as usize].pts = ts; // commit updates pts (Rule 1)
        if excl {
            // Full Table-II load semantics: an exclusive line's rts
            // tracks the last access so a later flush/write is ordered
            // after this read.
            let line = self.l1[core as usize].cache.peek_mut(addr).unwrap();
            line.rts = line.rts.max(ts);
        }
        Some(ts)
    }

    fn llc_storage_bits(&self, _n_cores: u32) -> u64 {
        // Two delta timestamps; owner id shares the same bits (§III-F2).
        2 * self.cfg.delta_ts_bits as u64
    }

    fn l1_storage_bits(&self) -> u64 {
        // wts + rts deltas + modified bit.
        2 * self.cfg.delta_ts_bits as u64 + 1
    }

    fn name(&self) -> &'static str {
        "tardis"
    }
}

/// Message constructor helpers shared by l1.rs / tm.rs.
pub(crate) fn to_slice(core: CoreId, slice: SliceId, addr: LineAddr, kind: MsgKind) -> Message {
    Message { src: Node::Core(core), dst: Node::Slice(slice), addr, requester: core, kind }
}

pub(crate) fn to_core(
    slice: SliceId,
    core: CoreId,
    addr: LineAddr,
    requester: CoreId,
    kind: MsgKind,
) -> Message {
    Message { src: Node::Slice(slice), dst: Node::Core(core), addr, requester, kind }
}

pub(crate) fn completion(
    core: CoreId,
    addr: LineAddr,
    kind: CompletionKind,
    value: u64,
    ts: Ts,
) -> Completion {
    Completion { core, addr, kind, value, ts }
}
