//! Tardis timestamp manager (shared-LLC slice) — paper Table III.

use std::collections::VecDeque;

use super::*;

/// A queued request at a TM line that is busy (DRAM fetch or owner
/// round-trip in flight).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Req {
    pub core: CoreId,
    pub kind: ReqKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReqKind {
    Sh { pts: Ts, wts: Ts, renew: bool },
    Ex { wts: Ts },
}

/// Why a line is busy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PendingKind {
    /// DRAM read in flight; the line is absent from the array.
    Fetch,
    /// Waiting for the owner's WB_REP (shared request to an exclusive
    /// line).
    AwaitWb,
    /// Waiting for the owner's FLUSH_REP (exclusive request to an
    /// exclusive line).
    AwaitFlush,
    /// LLC eviction of an exclusive line: flush the owner, then retry
    /// the fill stored in `Pending::fill`.
    EvictFlush,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Pending {
    pub kind: PendingKind,
    pub waiters: VecDeque<Req>,
    /// Deferred fill for `EvictFlush` (address + DRAM value).
    pub fill: Option<(LineAddr, u64)>,
}

impl Pending {
    fn new(kind: PendingKind) -> Self {
        Self { kind, waiters: VecDeque::new(), fill: None }
    }
}

impl Tardis {
    /// Network events at a timestamp manager.
    pub(crate) fn tm_on_message(&mut self, slice: SliceId, msg: Message, ctx: &mut ProtoCtx) {
        match msg.kind {
            MsgKind::ShReq { pts, wts, renew } => {
                ctx.stats.llc_accesses += 1;
                self.tm_request(
                    slice,
                    msg.addr,
                    Req { core: msg.requester, kind: ReqKind::Sh { pts, wts, renew } },
                    ctx,
                );
            }
            MsgKind::ExReq { wts } => {
                ctx.stats.llc_accesses += 1;
                self.tm_request(
                    slice,
                    msg.addr,
                    Req { core: msg.requester, kind: ReqKind::Ex { wts } },
                    ctx,
                );
            }
            MsgKind::WbRep { wts, rts, value } => {
                self.tm_owner_return(slice, msg.addr, wts, rts, value, true, ctx);
            }
            MsgKind::FlushRep { wts, rts, value, dirty } => {
                self.tm_owner_return(slice, msg.addr, wts, rts, value, dirty, ctx);
            }
            MsgKind::DramLdRep { value } => self.tm_install(slice, msg.addr, value, ctx),
            other => panic!("tardis TM got unexpected message {other:?}"),
        }
    }

    /// Entry point for SH/EX requests: queue if the line is busy,
    /// otherwise process.
    fn tm_request(&mut self, slice: SliceId, addr: LineAddr, req: Req, ctx: &mut ProtoCtx) {
        let s = slice as usize;
        if let Some(p) = self.tm[s].pending.get_mut(&addr) {
            p.waiters.push_back(req);
            return;
        }
        self.tm_process(slice, addr, req, ctx);
    }

    /// Process one request against a non-busy line (Table III columns
    /// 1 and 2).  May create a new pending entry.
    fn tm_process(&mut self, slice: SliceId, addr: LineAddr, req: Req, ctx: &mut ProtoCtx) {
        let s = slice as usize;
        let lease = self.cfg.lease;
        // The policy is Copy: take it by value so it can update the
        // line's lease state while the line borrows the cache array.
        let policy = self.lease_policy;
        // NUMA distance of the requester from this manager slice: a
        // remote grant's renewals cross a socket link, so the lease
        // policy may stretch the lease to amortize them (1 = local).
        let stretch = self.numa.lease_stretch(slice, req.core);
        let line = match self.tm[s].cache.get_mut(addr) {
            None => {
                // Invalid: load from DRAM (Table III column 1/2, row 1).
                let mut p = Pending::new(PendingKind::Fetch);
                p.waiters.push_back(req);
                self.tm[s].pending.insert(addr, p);
                ctx.stats.dram_accesses += 1;
                let mc = self.map.home_mc(addr);
                ctx.send(Message {
                    src: Node::Slice(slice),
                    dst: Node::Mc(mc),
                    addr,
                    requester: req.core,
                    kind: MsgKind::DramLdReq,
                });
                return;
            }
            Some(line) => line,
        };

        match (req.kind, line.owner) {
            // ---- Shared request, line shared ----
            (ReqKind::Sh { pts, wts, renew }, None) => {
                // E-state extension (§IV-D): a line nobody has touched
                // since its fill "seems private" — grant it exclusively
                // so it never expires (silent upgrades, no renewals).
                if self.cfg.exclusive_state && !line.touched {
                    let (l_wts, l_rts, l_val) = (line.wts, line.rts, line.value);
                    line.owner = Some(req.core);
                    line.touched = true;
                    ctx.send(to_core(
                        slice,
                        req.core,
                        addr,
                        req.core,
                        MsgKind::ExRep { wts: l_wts, rts: l_rts, value: l_val },
                    ));
                    return;
                }
                // Lease assignment is delegated to the timestamp-policy
                // layer (proto/ts): static, dynamic (§VI-C5), or
                // Tardis-2.0 predictive, all over the same per-line
                // `LineLease` state.
                let eff_lease = policy.shared_lease(
                    &mut line.lease,
                    crate::proto::ts::SharedReq {
                        renew,
                        version_match: wts == line.wts,
                        numa_stretch: stretch,
                    },
                );
                ctx.stats.ts.leases_granted += 1;
                ctx.stats.ts.lease_total += eff_lease;
                ctx.emit(EventKind::LeaseGrant, req.core, addr, eff_lease);
                line.rts = line.rts.max(line.wts + eff_lease).max(pts + eff_lease);
                line.touched = true;
                let (l_wts, l_rts, l_val) = (line.wts, line.rts, line.value);
                self.tm[s].max_ts = self.tm[s].max_ts.max(l_rts);
                // Seeded fault for the verif mutation smoke-check: the
                // grant promises the sharer a longer lease than the TM
                // records, breaking lease containment (sharer rts must
                // stay <= TM rts).  Compiled out of normal builds.
                let sent_rts = if cfg!(feature = "verif-mutate-over-lease") {
                    l_rts + 1000
                } else {
                    l_rts
                };
                if wts == l_wts {
                    // Requester's copy is current: renew without data.
                    ctx.send(to_core(
                        slice,
                        req.core,
                        addr,
                        req.core,
                        MsgKind::RenewRep { rts: sent_rts },
                    ));
                } else {
                    ctx.send(to_core(
                        slice,
                        req.core,
                        addr,
                        req.core,
                        MsgKind::ShRep { wts: l_wts, rts: sent_rts, value: l_val },
                    ));
                }
                self.tm_check_rebase(slice, ctx);
            }
            // ---- Exclusive request, line shared: jump ahead, no
            // invalidations (§III-C2) ----
            (ReqKind::Ex { wts }, None) => {
                let (l_wts, l_rts, l_val) = (line.wts, line.rts, line.value);
                line.owner = Some(req.core);
                line.touched = true;
                // A write is coming: the policy resets its read-run /
                // dynamic-exponent state (the write interval is learned
                // at the owner's return, when the new wts is known).
                policy.on_write(&mut line.lease, 0);
                if wts == l_wts {
                    ctx.send(to_core(slice, req.core, addr, req.core, MsgKind::UpgradeRep { rts: l_rts }));
                } else {
                    ctx.send(to_core(
                        slice,
                        req.core,
                        addr,
                        req.core,
                        MsgKind::ExRep { wts: l_wts, rts: l_rts, value: l_val },
                    ));
                }
            }
            // ---- Either request, line exclusively owned ----
            (kind, Some(owner)) => {
                line.busy = true;
                let (pk, msg_kind) = match kind {
                    ReqKind::Sh { pts, .. } => {
                        (PendingKind::AwaitWb, MsgKind::WbReq { rts: pts + lease })
                    }
                    ReqKind::Ex { .. } => (PendingKind::AwaitFlush, MsgKind::FlushReq),
                };
                let mut p = Pending::new(pk);
                p.waiters.push_back(req);
                self.tm[s].pending.insert(addr, p);
                ctx.send(to_core(slice, owner, addr, req.core, msg_kind));
            }
        }
    }

    /// WB_REP / FLUSH_REP from an owner — either solicited (resolves a
    /// pending owner round-trip) or an unsolicited eviction flush
    /// (Table III column 5: fill in data, state <- Shared).
    fn tm_owner_return(
        &mut self,
        slice: SliceId,
        addr: LineAddr,
        wts: Ts,
        rts: Ts,
        value: u64,
        dirty: bool,
        ctx: &mut ProtoCtx,
    ) {
        let s = slice as usize;
        let policy = self.lease_policy;
        match self.tm[s].cache.peek_mut(addr) {
            Some(line) => {
                if dirty {
                    // The owner wrote: feed the policy the observed
                    // write-to-write timestamp interval.
                    policy.on_write(&mut line.lease, wts.saturating_sub(line.wts));
                }
                line.owner = None;
                line.busy = false;
                line.wts = wts;
                line.rts = rts;
                line.value = value;
                line.dirty |= dirty;
                self.tm[s].max_ts = self.tm[s].max_ts.max(rts);
            }
            None => {
                // The line was dropped from the LLC while owned (bypass
                // grant): fold into mts and write back directly.
                self.tm[s].mts = self.tm[s].mts.max(rts);
                if dirty {
                    ctx.stats.dram_accesses += 1;
                    let mc = self.map.home_mc(addr);
                    ctx.send(Message {
                        src: Node::Slice(slice),
                        dst: Node::Mc(mc),
                        addr,
                        requester: 0,
                        kind: MsgKind::DramStReq { value },
                    });
                }
            }
        }
        let Some(mut p) = self.tm[s].pending.remove(&addr) else {
            return; // plain eviction flush, nothing queued
        };
        match p.kind {
            PendingKind::AwaitWb | PendingKind::AwaitFlush => {
                self.tm_drain(slice, addr, p.waiters, ctx);
            }
            PendingKind::EvictFlush => {
                // The line was being evicted: write it back, drop it,
                // then retry the deferred fill.
                if let Some(line) = self.tm[s].cache.invalidate(addr) {
                    self.tm_writeback(slice, addr, &line, ctx);
                }
                if let Some((fill_addr, fill_value)) = p.fill.take() {
                    self.tm_install(slice, fill_addr, fill_value, ctx);
                }
                // Requests that arrived for the victim restart cold.
                self.tm_drain(slice, addr, p.waiters, ctx);
            }
            PendingKind::Fetch => unreachable!("owner return while fetching"),
        }
    }

    /// Install a DRAM-fetched line with wts = rts = mts (§III-C2),
    /// evicting a victim if needed, then serve the waiters queued under
    /// the Fetch pending entry.
    fn tm_install(&mut self, slice: SliceId, addr: LineAddr, value: u64, ctx: &mut ProtoCtx) {
        let s = slice as usize;
        let mts = self.tm[s].mts;
        let new_line = TmLine {
            owner: None,
            busy: false,
            wts: mts,
            rts: mts,
            value,
            dirty: false,
            touched: false,
            lease: LineLease::default(),
        };

        // Preferred victims: unowned, non-busy lines (silent except for
        // the mts fold + dirty writeback).
        match self.tm[s].cache.insert_filtered(addr, new_line, |l| l.owner.is_none() && !l.busy) {
            Ok(evicted) => {
                if let Some((vaddr, v)) = evicted {
                    self.tm_writeback(slice, vaddr, &v, ctx);
                }
                if let Some(p) = self.tm[s].pending.remove(&addr) {
                    debug_assert_eq!(p.kind, PendingKind::Fetch);
                    self.tm_drain(slice, addr, p.waiters, ctx);
                }
            }
            Err(_) => {
                // Fall back to evicting an owned line: flush its owner
                // and park the fill on the victim (Table III column 3,
                // exclusive case).
                let victim = self.tm[s].cache.victim_for(addr, |l| l.owner.is_some() && !l.busy);
                match victim {
                    Some(vaddr) => {
                        let owner = {
                            let vline = self.tm[s].cache.peek_mut(vaddr).unwrap();
                            vline.busy = true;
                            vline.owner.unwrap()
                        };
                        let mut p = Pending::new(PendingKind::EvictFlush);
                        p.fill = Some((addr, value));
                        self.tm[s].pending.insert(vaddr, p);
                        ctx.send(to_core(slice, owner, vaddr, owner, MsgKind::FlushReq));
                    }
                    None => {
                        // Every way is mid-transaction (needs 8+
                        // concurrent owner round-trips in one set):
                        // retry the install after a cycle via a
                        // self-delivered DRAM reply.
                        ctx.send(Message {
                            src: Node::Slice(slice),
                            dst: Node::Slice(slice),
                            addr,
                            requester: 0,
                            kind: MsgKind::DramLdRep { value },
                        });
                    }
                }
            }
        }
    }

    /// Serve queued requests in order.  If one re-busies the line, the
    /// remaining waiters follow it into the new pending entry.
    fn tm_drain(
        &mut self,
        slice: SliceId,
        addr: LineAddr,
        mut waiters: VecDeque<Req>,
        ctx: &mut ProtoCtx,
    ) {
        let s = slice as usize;
        while let Some(req) = waiters.pop_front() {
            self.tm_process(slice, addr, req, ctx);
            if let Some(p) = self.tm[s].pending.get_mut(&addr) {
                p.waiters.extend(waiters.drain(..));
                return;
            }
        }
    }

    /// LLC eviction of a shared line (Table III column 3): fold its rts
    /// into mts; write data back to DRAM if dirty.  No invalidations —
    /// private copies stay readable until they expire (§III-F1).
    fn tm_writeback(&mut self, slice: SliceId, addr: LineAddr, line: &TmLine, ctx: &mut ProtoCtx) {
        let s = slice as usize;
        debug_assert!(line.owner.is_none(), "writeback of owned line");
        self.tm[s].mts = self.tm[s].mts.max(line.rts);
        if line.dirty {
            ctx.stats.dram_accesses += 1;
            let mc = self.map.home_mc(addr);
            ctx.send(Message {
                src: Node::Slice(slice),
                dst: Node::Mc(mc),
                addr,
                requester: 0,
                kind: MsgKind::DramStReq { value: line.value },
            });
        }
    }

    /// LLC-side base-delta rebase model (§IV-B): triggered when mts or
    /// a line timestamp outgrows the delta width; counted in stats (the
    /// slice-busy cost is recorded, not timed — see DESIGN.md §Perf).
    /// The trigger uses the incrementally-tracked slice max timestamp —
    /// scanning the array per request was the #1 hot spot (§Perf).
    pub(crate) fn tm_check_rebase(&mut self, slice: SliceId, ctx: &mut ProtoCtx) {
        if self.ts_range == u64::MAX {
            return;
        }
        let s = slice as usize;
        let max_ts = self.tm[s].max_ts.max(self.tm[s].mts);
        if max_ts.saturating_sub(self.tm[s].bts) < self.ts_range {
            return;
        }
        let half = self.ts_range / 2;
        let mut bts = self.tm[s].bts;
        while max_ts.saturating_sub(bts) >= self.ts_range {
            bts += half;
            ctx.stats.ts.l2_rebases += 1;
            ctx.stats.ts.rebase_stall_cycles += self.cfg.l2_rebase_cycles;
        }
        self.tm[s].bts = bts;
        // Clamp timestamps up to the new base (safe: a hypothetical
        // later read/write of the same data, §IV-B).
        self.tm[s].cache.retain_lines(|_, l| {
            if l.owner.is_none() {
                l.wts = l.wts.max(bts);
                l.rts = l.rts.max(bts);
            }
            true
        });
        self.tm[s].mts = self.tm[s].mts.max(bts);
    }
}
