//! Tardis private-cache (L1) controller — paper Table II.

use super::*;
use crate::proto::AccessDone;

impl Tardis {
    /// Core-side access (Table II, core-event columns).
    pub(crate) fn l1_access(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        op: MemOp,
        spec_ok: bool,
        ctx: &mut ProtoCtx,
    ) -> AccessOutcome {
        let mut extra = self.count_access(core, ctx);
        let c = core as usize;

        // A demand miss to this address is already outstanding: park.
        if self.l1[c].demand.contains_key(&addr) {
            self.l1[c].demand.get_mut(&addr).unwrap().parked += 1;
            return AccessOutcome::Pending;
        }

        // Single lookup on the hit path (the hottest code in the
        // simulator — §Perf): pull everything needed out of the line
        // in one probe and update in place.
        let pts0 = self.l1[c].pts;
        let line_state = self.l1[c].cache.get_mut(addr).map(|l| {
            let st = (l.excl, l.wts, l.rts, l.value);
            if matches!(op, MemOp::Load) {
                if l.excl {
                    let pts = pts0.max(l.wts);
                    l.rts = l.rts.max(pts);
                }
            }
            st
        });
        match (op, line_state) {
            // ---- Load, exclusive hit ----
            (MemOp::Load, Some((true, wts, rts, value))) => {
                let pts = pts0.max(wts);
                self.raise_pts(core, pts, false, ctx);
                extra += self.l1_check_rebase(core, rts.max(pts), ctx);
                ctx.stats.l1_hits += 1;
                AccessOutcome::Done(AccessDone { value, ts: pts, extra_cycles: extra })
            }
            // ---- Load, shared ----
            (MemOp::Load, Some((false, wts, rts, value))) => {
                if pts0 <= rts {
                    // Valid lease: plain hit.
                    let pts = pts0.max(wts);
                    self.raise_pts(core, pts, false, ctx);
                    extra += self.l1_check_rebase(core, pts, ctx);
                    ctx.stats.l1_hits += 1;
                    AccessOutcome::Done(AccessDone { value, ts: pts, extra_cycles: extra })
                } else {
                    // Expired: renew (and maybe speculate, §IV-A).
                    self.l1_expired_load(core, addr, wts, rts, spec_ok, extra, ctx)
                }
            }
            // ---- Store/atomic, exclusive hit ----
            (_, Some((true, _wts, rts, _value))) => {
                let old_pts = self.l1[c].pts;
                let modified = self.l1[c].cache.peek(addr).map(|l| l.modified).unwrap_or(false);
                // Private-write optimization (§IV-C): repeated stores to
                // an already-modified line need not jump past rts + 1.
                let ts = if self.cfg.private_write_opt && modified {
                    old_pts.max(rts)
                } else {
                    old_pts.max(rts + 1)
                };
                self.raise_pts(core, ts, false, ctx);
                let line = self.l1[c].cache.get_mut(addr).unwrap();
                let old = line.value;
                let new = op.write_value(old).expect("write op");
                line.value = new;
                line.wts = ts;
                line.rts = ts;
                line.modified = true;
                extra += self.l1_check_rebase(core, ts, ctx);
                ctx.stats.l1_hits += 1;
                let observed = if matches!(op, MemOp::Store { .. }) { new } else { old };
                AccessOutcome::Done(AccessDone { value: observed, ts, extra_cycles: extra })
            }
            // ---- Store/atomic, shared (upgrade) or miss ----
            (_, other) => {
                ctx.stats.l1_misses += 1;
                ctx.emit(EventKind::Demand, core, addr, op.is_write() as u64);
                let slice = self.slice_of(addr);
                let kind = if op.is_write() {
                    let wts = match other {
                        Some((false, wts, _, _)) => {
                            // Pin the shared copy: the TM may answer
                            // UpgradeRep, which assumes we keep the data.
                            self.l1[c].cache.peek_mut(addr).unwrap().pinned = true;
                            wts
                        }
                        _ => 0,
                    };
                    MsgKind::ExReq { wts }
                } else {
                    MsgKind::ShReq { pts: self.l1[c].pts, wts: 0, renew: false }
                };
                self.l1[c].demand.insert(addr, Demand { op, parked: 0 });
                ctx.send(to_slice(core, slice, addr, kind));
                AccessOutcome::Pending
            }
        }
    }

    /// Load to an expired shared line: send a renewal; speculate through
    /// it when allowed (§IV-A).  `rts` is the expired lease bound (the
    /// pts − rts gap is the flight recorder's expiry argument).
    #[allow(clippy::too_many_arguments)]
    fn l1_expired_load(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        wts: Ts,
        rts: Ts,
        spec_ok: bool,
        extra: u64,
        ctx: &mut ProtoCtx,
    ) -> AccessOutcome {
        let c = core as usize;
        let spec_outstanding: u32 =
            self.l1[c].renewals.values().map(|r| r.spec_count).sum();
        // The livelock guard (proto/ts) demotes speculation to blocking
        // demands on lines whose renewals keep failing for this core.
        let speculate = spec_ok
            && self.cfg.speculation
            && (spec_outstanding as usize) < self.max_spec
            && self.guard.allow_speculation(core, addr);

        if let Some(r) = self.l1[c].renewals.get_mut(&addr) {
            // Renewal already in flight.
            if speculate {
                r.spec_count += 1;
                let pts = self.l1[c].pts.max(wts);
                self.raise_pts(core, pts, false, ctx);
                let value = self.l1[c].cache.peek(addr).unwrap().value;
                return AccessOutcome::SpecDone(AccessDone { value, ts: pts, extra_cycles: extra });
            }
            r.demand_waiting = true;
            return AccessOutcome::Pending;
        }

        ctx.stats.renew_requests += 1;
        let pts0 = self.l1[c].pts;
        ctx.emit(EventKind::LeaseExpire, core, addr, pts0.saturating_sub(rts));
        let slice = self.slice_of(addr);
        ctx.send(to_slice(core, slice, addr, MsgKind::ShReq { pts: pts0, wts, renew: true }));
        if speculate {
            self.l1[c]
                .renewals
                .insert(addr, Renewal { spec_count: 1, demand_waiting: false });
            let pts = pts0.max(wts);
            self.raise_pts(core, pts, false, ctx);
            let value = self.l1[c].cache.peek(addr).unwrap().value;
            AccessOutcome::SpecDone(AccessDone { value, ts: pts, extra_cycles: extra })
        } else {
            self.l1[c]
                .renewals
                .insert(addr, Renewal { spec_count: 0, demand_waiting: true });
            ctx.stats.l1_misses += 1;
            AccessOutcome::Pending
        }
    }

    /// Network events at the private cache (Table II, right columns).
    pub(crate) fn l1_on_message(&mut self, core: CoreId, msg: Message, ctx: &mut ProtoCtx) {
        match msg.kind {
            MsgKind::ShRep { wts, rts, value } => self.l1_sh_rep(core, msg.addr, wts, rts, value, ctx),
            MsgKind::RenewRep { rts } => self.l1_renew_rep(core, msg.addr, rts, ctx),
            MsgKind::ExRep { wts, rts, value } => {
                self.l1_ex_rep(core, msg.addr, Some((wts, value)), rts, ctx)
            }
            MsgKind::UpgradeRep { rts } => self.l1_ex_rep(core, msg.addr, None, rts, ctx),
            MsgKind::FlushReq => self.l1_flush_req(core, msg, ctx),
            MsgKind::WbReq { rts } => self.l1_wb_req(core, msg, rts, ctx),
            other => panic!("tardis L1 got unexpected message {other:?}"),
        }
    }

    /// Fill a line into the L1, evicting as needed (Table II eviction
    /// column: shared victims drop silently; exclusive victims flush
    /// back to their timestamp manager).  Pinned lines (outstanding
    /// upgrades) are never evicted; if every way is pinned the fill is
    /// simply not cached (the completion already carries the value).
    fn l1_fill(&mut self, core: CoreId, addr: LineAddr, line: L1Line, ctx: &mut ProtoCtx) -> bool {
        let c = core as usize;
        let evicted = match self.l1[c].cache.insert_filtered(addr, line.clone(), |l| !l.pinned) {
            Ok(v) => v,
            Err(_) => {
                // All ways pinned: bypass the cache.  A shared line can
                // simply be dropped (Tardis keeps no sharer state), but
                // an exclusive grant must be returned to the TM at once
                // or the owner entry would dangle.
                if line.excl {
                    let slice = self.slice_of(addr);
                    ctx.send(to_slice(
                        core,
                        slice,
                        addr,
                        MsgKind::FlushRep {
                            wts: line.wts,
                            rts: line.rts,
                            value: line.value,
                            dirty: line.modified,
                        },
                    ));
                }
                return false;
            }
        };
        if let Some((vaddr, v)) = evicted {
            if v.excl {
                let slice = self.slice_of(vaddr);
                ctx.send(to_slice(
                    core,
                    slice,
                    vaddr,
                    MsgKind::FlushRep { wts: v.wts, rts: v.rts, value: v.value, dirty: v.modified },
                ));
            }
            // An evicted line may carry an outstanding renewal; the
            // reply handlers tolerate an absent line.
            debug_assert!(
                self.l1[c].watch != Some(vaddr),
                "evicted a watched line (spinning cores issue no fills)"
            );
        }
        true
    }

    fn l1_sh_rep(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        wts: Ts,
        rts: Ts,
        value: u64,
        ctx: &mut ProtoCtx,
    ) {
        let c = core as usize;
        // Renewal outcome: a ShRep for an outstanding renewal means the
        // lease could not be extended at the old version — new data.
        if let Some(renewal) = self.l1[c].renewals.remove(&addr) {
            ctx.emit(EventKind::RenewFail, core, addr, 0);
            if self.guard.on_renew_failed(core, addr) {
                ctx.stats.ts.livelock_escalations += 1;
                ctx.emit(EventKind::Livelock, core, addr, 0);
            }
            if let Some(line) = self.l1[c].cache.get_mut(addr) {
                line.excl = false;
                line.wts = wts;
                line.rts = rts;
                line.value = value;
                line.modified = false;
            }
            let pts = self.l1[c].pts.max(wts);
            self.raise_pts(core, pts, false, ctx);
            self.l1_check_rebase(core, pts.max(rts), ctx);
            if renewal.spec_count > 0 {
                ctx.stats.misspeculations += 1;
                for _ in 0..renewal.spec_count {
                    ctx.complete(completion(core, addr, CompletionKind::Misspec, value, pts));
                }
            }
            if renewal.demand_waiting {
                ctx.complete(completion(core, addr, CompletionKind::Demand, value, pts));
            }
            return;
        }
        // Plain demand fill.
        let Some(demand) = self.l1[c].demand.remove(&addr) else {
            return; // stale reply (e.g., line was rebase-invalidated)
        };
        debug_assert!(matches!(demand.op, MemOp::Load));
        let pts = self.l1[c].pts.max(wts);
        self.raise_pts(core, pts, false, ctx);
        let _ = self.l1_fill(
            core,
            addr,
            L1Line { excl: false, wts, rts, value, modified: false, pinned: false },
            ctx,
        );
        self.l1_check_rebase(core, pts.max(rts), ctx);
        ctx.complete(completion(core, addr, CompletionKind::Demand, value, pts));
        self.l1_release_parked(core, addr, demand.parked, ctx);
    }

    fn l1_renew_rep(&mut self, core: CoreId, addr: LineAddr, rts: Ts, ctx: &mut ProtoCtx) {
        let c = core as usize;
        ctx.stats.renew_success += 1;
        ctx.emit(EventKind::RenewOk, core, addr, 0);
        self.guard.on_renew_success(core, addr);
        let Some(renewal) = self.l1[c].renewals.remove(&addr) else {
            return;
        };
        match self.l1[c].cache.get_mut(addr) {
            Some(line) => {
                line.rts = line.rts.max(rts);
                let (value, wts) = (line.value, line.wts);
                let pts = self.l1[c].pts.max(wts);
                self.raise_pts(core, pts, false, ctx);
                self.l1_check_rebase(core, rts, ctx);
                if renewal.demand_waiting {
                    ctx.complete(completion(core, addr, CompletionKind::Demand, value, pts));
                }
                for _ in 0..renewal.spec_count {
                    // Speculative success: the core closes its window.
                    ctx.complete(completion(core, addr, CompletionKind::SpecOk, value, pts));
                }
            }
            None => {
                // The line vanished (rebase invalidation) while the
                // renewal was in flight.  A blocked demand must re-issue
                // as a cold miss; a speculative load is fine — the
                // renewal succeeded, so the value it used was current.
                for _ in 0..renewal.spec_count {
                    ctx.complete(completion(core, addr, CompletionKind::SpecOk, 0, 0));
                }
                if renewal.demand_waiting {
                    ctx.stats.l1_misses += 1;
                    ctx.emit(EventKind::Demand, core, addr, 0);
                    let slice = self.slice_of(addr);
                    let pts = self.l1[c].pts;
                    self.l1[c].demand.insert(addr, Demand { op: MemOp::Load, parked: 0 });
                    ctx.send(to_slice(core, slice, addr, MsgKind::ShReq { pts, wts: 0, renew: false }));
                }
            }
        }
    }

    /// Exclusive ownership granted: ExRep carries data; UpgradeRep
    /// relies on our cached (pinned) copy — its wts matched at the TM.
    fn l1_ex_rep(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        data: Option<(Ts, u64)>,
        rts: Ts,
        ctx: &mut ProtoCtx,
    ) {
        let c = core as usize;
        // Resolve any renewal that raced with this upgrade: an
        // UpgradeRep proves our copy was current (renewal would have
        // succeeded); an ExRep proves it was stale (misspeculation).
        if let Some(renewal) = self.l1[c].renewals.remove(&addr) {
            match data {
                None => {
                    ctx.stats.renew_success += 1;
                    ctx.emit(EventKind::RenewOk, core, addr, 0);
                    self.guard.on_renew_success(core, addr);
                    for _ in 0..renewal.spec_count {
                        ctx.complete(completion(core, addr, CompletionKind::SpecOk, 0, 0));
                    }
                }
                Some((new_wts, new_value)) => {
                    ctx.emit(EventKind::RenewFail, core, addr, 0);
                    if self.guard.on_renew_failed(core, addr) {
                        ctx.stats.ts.livelock_escalations += 1;
                        ctx.emit(EventKind::Livelock, core, addr, 0);
                    }
                    if renewal.spec_count > 0 {
                        ctx.stats.misspeculations += 1;
                        for _ in 0..renewal.spec_count {
                            ctx.complete(completion(
                                core,
                                addr,
                                CompletionKind::Misspec,
                                new_value,
                                new_wts,
                            ));
                        }
                    }
                    if renewal.demand_waiting {
                        ctx.complete(completion(
                            core,
                            addr,
                            CompletionKind::Demand,
                            new_value,
                            new_wts,
                        ));
                    }
                }
            }
        }
        let Some(demand) = self.l1[c].demand.remove(&addr) else {
            return;
        };

        let (wts, old_value) = match data {
            Some((wts, value)) => (wts, value),
            None => {
                let line = self.l1[c]
                    .cache
                    .peek_mut(addr)
                    .expect("UpgradeRep for a line we no longer hold (pin violated)");
                line.pinned = false;
                (line.wts, line.value)
            }
        };
        let (value_obs, new_line) = match demand.op {
            MemOp::Load => {
                // An exclusive reply can serve a load (E-state
                // extension, §IV-D): load-on-exclusive semantics.
                let ts = self.l1[c].pts.max(wts);
                self.raise_pts(core, ts, false, ctx);
                (
                    old_value,
                    L1Line {
                        excl: true,
                        wts,
                        rts: rts.max(ts),
                        value: old_value,
                        modified: false,
                        pinned: false,
                    },
                )
            }
            op => {
                // Store-hit semantics on the now-exclusive line
                // (Table II): ts = max(pts, rts + 1).
                let ts = self.l1[c].pts.max(rts + 1);
                self.raise_pts(core, ts, false, ctx);
                let new = op.write_value(old_value).expect("write op");
                let observed = if matches!(op, MemOp::Store { .. }) { new } else { old_value };
                // Seeded fault for the verif mutation smoke-check: keep
                // the stale wts on the freshly written line (the write
                // "time-travels" under the old version).  Compiled out
                // of normal builds.
                let line_wts = if cfg!(feature = "verif-mutate-wts-skip") { wts } else { ts };
                (
                    observed,
                    L1Line {
                        excl: true,
                        wts: line_wts,
                        rts: ts,
                        value: new,
                        modified: true,
                        pinned: false,
                    },
                )
            }
        };
        let ts_final = new_line.rts;
        if data.is_some() && self.l1[c].cache.peek(addr).is_none() {
            let _ = self.l1_fill(core, addr, new_line, ctx);
        } else {
            *self.l1[c].cache.get_mut(addr).unwrap() = new_line;
        }
        self.l1_check_rebase(core, ts_final, ctx);
        ctx.complete(completion(core, addr, CompletionKind::Demand, value_obs, ts_final));
        self.l1_release_parked(core, addr, demand.parked, ctx);
    }

    /// FLUSH_REQ from the TM: return data + timestamps and invalidate
    /// (Table II, last column).
    fn l1_flush_req(&mut self, core: CoreId, msg: Message, ctx: &mut ProtoCtx) {
        let c = core as usize;
        match self.l1[c].cache.peek(msg.addr) {
            Some(line) if line.excl => {}
            // Crossed with our own FlushRep (eviction): the TM will
            // treat that FlushRep as the response.
            _ => return,
        }
        let line = self.l1[c].cache.invalidate(msg.addr).unwrap();
        let slice = self.slice_of(msg.addr);
        ctx.send(to_slice(
            core,
            slice,
            msg.addr,
            MsgKind::FlushRep { wts: line.wts, rts: line.rts, value: line.value, dirty: line.modified },
        ));
        self.l1_wake_watcher(core, msg.addr, ctx);
    }

    /// WB_REQ from the TM: extend rts per Table II, return data, keep
    /// the line shared.
    fn l1_wb_req(&mut self, core: CoreId, msg: Message, req_rts: Ts, ctx: &mut ProtoCtx) {
        let c = core as usize;
        let lease = self.cfg.lease;
        let up_to;
        {
            let Some(line) = self.l1[c].cache.peek_mut(msg.addr) else {
                return; // crossed with eviction FlushRep
            };
            if !line.excl {
                return;
            }
            line.rts = line.rts.max(line.wts + lease).max(req_rts);
            line.excl = false;
            line.modified = false;
            up_to = (line.wts, line.rts, line.value);
        }
        let slice = self.slice_of(msg.addr);
        ctx.send(to_slice(
            core,
            slice,
            msg.addr,
            MsgKind::WbRep { wts: up_to.0, rts: up_to.1, value: up_to.2 },
        ));
        self.l1_check_rebase(core, up_to.1, ctx);
        // A core spin-parked on this (formerly exclusive) line was
        // waiting for a flush; after the downgrade the line is shared
        // and will never be invalidated — wake it so it re-enters the
        // lease-expiry spin path.
        self.l1_wake_watcher(core, msg.addr, ctx);
    }

    /// Wake a spinning core whose watched line was invalidated.
    pub(crate) fn l1_wake_watcher(&mut self, core: CoreId, addr: LineAddr, ctx: &mut ProtoCtx) {
        if self.l1[core as usize].watch == Some(addr) {
            self.l1[core as usize].watch = None;
            ctx.complete(completion(core, addr, CompletionKind::SpinWake, 0, 0));
        }
    }

    /// Re-issue accesses that were parked behind a demand miss.
    fn l1_release_parked(&mut self, core: CoreId, addr: LineAddr, parked: u32, ctx: &mut ProtoCtx) {
        for _ in 0..parked {
            ctx.complete(completion(core, addr, CompletionKind::SpinWake, 0, 0));
        }
    }

    /// Base-delta compression model (§IV-B): if `ts` no longer fits in
    /// the delta width relative to this L1's base timestamp, rebase —
    /// advance bts by half the range (repeatedly), drop shared lines
    /// whose rts fell behind the new base, clamp the rest up.  Returns
    /// stall cycles charged to the triggering access.
    pub(crate) fn l1_check_rebase(&mut self, core: CoreId, ts: Ts, ctx: &mut ProtoCtx) -> u64 {
        if self.ts_range == u64::MAX {
            return 0;
        }
        let c = core as usize;
        if ts.saturating_sub(self.l1[c].bts) < self.ts_range {
            return 0;
        }
        // Defer while an upgrade is pinned: rebase would invalidate the
        // copy an UpgradeRep relies on.  The upgrade resolves within a
        // round-trip and the rebase re-triggers on the next assignment.
        let mut pinned = false;
        self.l1[c].cache.for_each(|_, l| pinned |= l.pinned);
        if pinned {
            return 0;
        }
        let half = self.ts_range / 2;
        let mut bts = self.l1[c].bts;
        let mut stall = 0u64;
        while ts.saturating_sub(bts) >= self.ts_range {
            bts += half;
            ctx.stats.ts.l1_rebases += 1;
            stall += self.cfg.l1_rebase_cycles;
        }
        self.l1[c].bts = bts;
        let mut invalidated: Vec<LineAddr> = Vec::new();
        self.l1[c].cache.retain_lines(|addr, line| {
            if line.excl {
                // Exclusive lines may move both timestamps up freely.
                line.wts = line.wts.max(bts);
                line.rts = line.rts.max(bts);
                true
            } else if line.rts < bts {
                // delta_rts would go negative: invalidate (§IV-B).
                invalidated.push(addr);
                false
            } else {
                line.wts = line.wts.max(bts);
                true
            }
        });
        ctx.stats.ts.rebase_invalidations += invalidated.len() as u64;
        ctx.stats.ts.rebase_stall_cycles += stall;
        for addr in invalidated {
            self.l1_wake_watcher(core, addr, ctx);
            // Outstanding renewals to dropped lines resolve safely: the
            // reply handlers tolerate an absent line.
        }
        stall
    }
}
