//! The timestamp-policy layer: lease assignment and renewal-starvation
//! handling, factored out of the Tardis protocol controllers.
//!
//! The paper treats the lease as a single static constant (Table V:
//! 10); its follow-up (*Tardis 2.0*, arXiv:1511.08774) shows the
//! protocol's renewal traffic and misspeculation rate hinge on smarter
//! per-line lease assignment.  This module makes that a first-class,
//! sweepable subsystem:
//!
//! * [`LeasePolicy`] — enum-dispatched (the [`ProtocolDispatch`]
//!   pattern: no vtable on the per-request path) over
//!   [`StaticLease`], [`DynamicLease`] (the old `dynamic_lease` flag),
//!   and the Tardis-2.0-style [`PredictiveLease`];
//! * [`LineLease`] — the compact per-line state each policy reads and
//!   writes, embedded in every timestamp-manager line;
//! * [`LivelockGuard`](livelock::LivelockGuard) — escalates starved
//!   renewals (consecutive failures on one line) from speculative to
//!   blocking, bounding rollback churn under write storms.
//!
//! The protocol controllers only ever call [`LeasePolicy::shared_lease`]
//! on shared grants and [`LeasePolicy::on_write`] on exclusive grants /
//! dirty owner returns; everything else is policy-internal.
//!
//! [`ProtocolDispatch`]: crate::proto::ProtocolDispatch

pub mod livelock;

pub use livelock::LivelockGuard;

use crate::config::{LeasePolicyKind, TardisConfig};
use crate::types::Ts;

/// Per-line lease-policy state, embedded in each timestamp-manager
/// line.  One compact struct shared by all policies so switching
/// policies never changes the line layout (and the storage model).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct LineLease {
    /// Dynamic: lease multiplier exponent (`lease << exp`).
    pub exp: u8,
    /// Predictive: saturating count of shared grants since the last
    /// observed write (the read run).
    pub read_run: u8,
    /// Predictive: timestamp distance between the two most recent
    /// writes (0 = no interval observed yet), saturating.
    pub write_gap: u32,
}

/// What a policy learns about one shared request.
#[derive(Debug, Clone, Copy)]
pub struct SharedReq {
    /// The request is a renewal (lease-extension attempt).
    pub renew: bool,
    /// The requester's `wts` matches the line's (its copy is current).
    pub version_match: bool,
    /// NUMA cost factor of the requester's position: 1 = local socket
    /// (or a flat system), `numa_ratio` = the grant crosses a socket
    /// link ([`crate::net::NumaView::lease_stretch`]).  The paper's
    /// distance-blind policies (Static, Dynamic) ignore it and serve
    /// as the sweep's control; Predictive stretches remote leases by
    /// it, the Tardis-2.0 self-tuning argument applied to distance.
    pub numa_stretch: u64,
}

/// The paper's fixed lease.
#[derive(Debug, Clone, Copy)]
pub struct StaticLease {
    lease: u64,
}

impl StaticLease {
    #[inline]
    fn shared_lease(&self, _line: &mut LineLease, _req: SharedReq) -> u64 {
        self.lease
    }
}

/// §VI-C5 dynamic leases: double on successful renewals, reset on
/// writes (read-mostly data earns exponentially longer leases).
#[derive(Debug, Clone, Copy)]
pub struct DynamicLease {
    base: u64,
    max: u64,
    /// Largest exponent that keeps `base << exp` at or under `max`.
    max_exp: u8,
}

impl DynamicLease {
    #[inline]
    fn shared_lease(&self, line: &mut LineLease, req: SharedReq) -> u64 {
        let l = (self.base << line.exp.min(63)).min(self.max);
        if req.renew && req.version_match {
            line.exp = (line.exp + 1).min(self.max_exp);
        }
        l
    }

    #[inline]
    fn on_write(&self, line: &mut LineLease) {
        line.exp = 0;
    }
}

/// Tardis-2.0-style predictive leases: track each line's read run and
/// write-to-write timestamp interval, then lease proportionally to the
/// read run but never past the observed write interval — a lease that
/// outlives the next write only converts renewals into
/// misspeculations.
#[derive(Debug, Clone, Copy)]
pub struct PredictiveLease {
    base: u64,
    max: u64,
}

impl PredictiveLease {
    #[inline]
    fn shared_lease(&self, line: &mut LineLease, req: SharedReq) -> u64 {
        let run = line.read_run as u64;
        line.read_run = line.read_run.saturating_add(1);
        // A remote sharer's renewal crosses a socket link costing
        // `numa_stretch` x a local one, so its lease (and cap) stretch
        // by the same factor — the amortization that makes owner-free
        // renewal win in distributed shared memory (paper §VII).
        // stretch == 1 reproduces the flat behavior exactly.
        let stretch = req.numa_stretch.max(1);
        let mut lease = self
            .base
            .saturating_mul(1 + run)
            .saturating_mul(stretch)
            .min(self.max.saturating_mul(stretch));
        if line.write_gap > 0 {
            // Self-tune down to the observed write interval — it
            // outranks the distance stretch: over-leasing a
            // write-churned remote line only converts the renewals we
            // saved into misspeculations.
            lease = lease.min(line.write_gap as u64);
        }
        lease.max(1)
    }

    #[inline]
    fn on_write(&self, line: &mut LineLease, gap: Ts) {
        if gap > 0 {
            line.write_gap = gap.min(u32::MAX as u64) as u32;
        }
        line.read_run = 0;
    }
}

/// The statically dispatched union of the lease policies (mirror of
/// [`crate::proto::ProtocolDispatch`]): adding a policy means adding
/// an enum arm and a constructor case here — the protocol controllers
/// are untouched.
#[derive(Debug, Clone, Copy)]
pub enum LeasePolicy {
    Static(StaticLease),
    Dynamic(DynamicLease),
    Predictive(PredictiveLease),
}

impl LeasePolicy {
    /// Instantiate the policy selected by the Tardis configuration.
    pub fn new(cfg: &TardisConfig) -> Self {
        let base = cfg.lease;
        match cfg.lease_policy {
            LeasePolicyKind::Static => Self::Static(StaticLease { lease: base }),
            LeasePolicyKind::Dynamic { max_lease } => {
                let max = max_lease.max(base);
                let max_exp = (0u8..63)
                    .take_while(|&e| matches!(base.checked_shl(e as u32), Some(l) if l <= max))
                    .last()
                    .unwrap_or(0);
                Self::Dynamic(DynamicLease { base, max, max_exp })
            }
            LeasePolicyKind::Predictive { max_lease } => {
                Self::Predictive(PredictiveLease { base, max: max_lease.max(base) })
            }
        }
    }

    /// Which configured kind this policy implements.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Self::Static(_) => "static",
            Self::Dynamic(_) => "dynamic",
            Self::Predictive(_) => "predictive",
        }
    }

    /// Lease to grant a shared request on `line`, updating the line's
    /// policy state.
    #[inline]
    pub fn shared_lease(&self, line: &mut LineLease, req: SharedReq) -> u64 {
        match self {
            Self::Static(p) => p.shared_lease(line, req),
            Self::Dynamic(p) => p.shared_lease(line, req),
            Self::Predictive(p) => p.shared_lease(line, req),
        }
    }

    /// A write to the line was observed (exclusive grant, or a dirty
    /// owner return).  `gap` is the timestamp distance from the
    /// previous write when known, 0 otherwise.
    #[inline]
    pub fn on_write(&self, line: &mut LineLease, gap: Ts) {
        match self {
            Self::Static(_) => {}
            Self::Dynamic(p) => p.on_write(line),
            Self::Predictive(p) => p.on_write(line, gap),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DEFAULT_MAX_LEASE;

    fn cfg(kind: LeasePolicyKind) -> TardisConfig {
        TardisConfig { lease_policy: kind, ..TardisConfig::default() }
    }

    fn renew_hit() -> SharedReq {
        SharedReq { renew: true, version_match: true, numa_stretch: 1 }
    }

    fn cold_read() -> SharedReq {
        SharedReq { renew: false, version_match: false, numa_stretch: 1 }
    }

    fn remote_read(stretch: u64) -> SharedReq {
        SharedReq { renew: false, version_match: false, numa_stretch: stretch }
    }

    #[test]
    fn static_policy_is_constant() {
        let p = LeasePolicy::new(&cfg(LeasePolicyKind::Static));
        let mut line = LineLease::default();
        for _ in 0..5 {
            assert_eq!(p.shared_lease(&mut line, renew_hit()), 10);
        }
        assert_eq!(line, LineLease::default(), "static policy keeps no state");
    }

    #[test]
    fn dynamic_policy_doubles_on_renewals_and_resets_on_writes() {
        let p = LeasePolicy::new(&cfg(LeasePolicyKind::Dynamic { max_lease: 80 }));
        let mut line = LineLease::default();
        assert_eq!(p.shared_lease(&mut line, renew_hit()), 10);
        assert_eq!(p.shared_lease(&mut line, renew_hit()), 20);
        assert_eq!(p.shared_lease(&mut line, renew_hit()), 40);
        assert_eq!(p.shared_lease(&mut line, renew_hit()), 80);
        // Capped.
        assert_eq!(p.shared_lease(&mut line, renew_hit()), 80);
        // Non-renewal reads do not grow the lease.
        let exp = line.exp;
        p.shared_lease(&mut line, cold_read());
        assert_eq!(line.exp, exp);
        // A write resets.
        p.on_write(&mut line, 0);
        assert_eq!(p.shared_lease(&mut line, renew_hit()), 10);
    }

    #[test]
    fn predictive_policy_grows_with_read_run() {
        let p = LeasePolicy::new(&cfg(LeasePolicyKind::Predictive {
            max_lease: DEFAULT_MAX_LEASE,
        }));
        let mut line = LineLease::default();
        assert_eq!(p.shared_lease(&mut line, cold_read()), 10);
        assert_eq!(p.shared_lease(&mut line, cold_read()), 20);
        assert_eq!(p.shared_lease(&mut line, cold_read()), 30);
        for _ in 0..20 {
            p.shared_lease(&mut line, cold_read());
        }
        // Capped at max_lease.
        assert_eq!(p.shared_lease(&mut line, cold_read()), DEFAULT_MAX_LEASE);
    }

    #[test]
    fn predictive_policy_bounds_lease_by_write_interval() {
        let p = LeasePolicy::new(&cfg(LeasePolicyKind::Predictive {
            max_lease: DEFAULT_MAX_LEASE,
        }));
        let mut line = LineLease::default();
        for _ in 0..10 {
            p.shared_lease(&mut line, cold_read());
        }
        // Two writes 7 timestamps apart: the line is write-churned.
        p.on_write(&mut line, 0);
        p.on_write(&mut line, 7);
        assert_eq!(line.read_run, 0, "writes reset the read run");
        // Leases now never exceed the observed write interval.
        for _ in 0..20 {
            assert!(p.shared_lease(&mut line, cold_read()) <= 7);
        }
    }

    #[test]
    fn dynamic_exponent_never_overflows_the_cap() {
        // max_lease smaller than the base: the exponent stays 0.
        let p = LeasePolicy::new(&cfg(LeasePolicyKind::Dynamic { max_lease: 5 }));
        let mut line = LineLease::default();
        for _ in 0..100 {
            let l = p.shared_lease(&mut line, renew_hit());
            assert!(l <= 10, "lease {l} escaped the cap");
        }
    }

    #[test]
    fn predictive_policy_stretches_remote_leases_by_numa_distance() {
        let p = LeasePolicy::new(&cfg(LeasePolicyKind::Predictive {
            max_lease: DEFAULT_MAX_LEASE,
        }));
        // Same read-run position, different distances: the remote
        // grant is exactly stretch x the local one.
        let mut local = LineLease::default();
        let mut remote = LineLease::default();
        assert_eq!(p.shared_lease(&mut local, cold_read()), 10);
        assert_eq!(p.shared_lease(&mut remote, remote_read(4)), 40);
        // The cap stretches too: a long remote read run earns up to
        // stretch x max_lease.
        for _ in 0..30 {
            p.shared_lease(&mut remote, remote_read(4));
        }
        assert_eq!(
            p.shared_lease(&mut remote, remote_read(4)),
            4 * DEFAULT_MAX_LEASE
        );
    }

    #[test]
    fn write_interval_bound_outranks_the_numa_stretch() {
        let p = LeasePolicy::new(&cfg(LeasePolicyKind::Predictive {
            max_lease: DEFAULT_MAX_LEASE,
        }));
        let mut line = LineLease::default();
        p.on_write(&mut line, 0);
        p.on_write(&mut line, 7);
        // Even an 8x-stretched remote lease stays inside the observed
        // write interval — distance never buys misspeculations.
        for _ in 0..20 {
            assert!(p.shared_lease(&mut line, remote_read(8)) <= 7);
        }
    }

    #[test]
    fn paper_policies_are_distance_blind() {
        // Static and Dynamic ignore the stretch (the sweep's control
        // group): identical leases at any distance.
        let st = LeasePolicy::new(&cfg(LeasePolicyKind::Static));
        let mut line = LineLease::default();
        assert_eq!(st.shared_lease(&mut line, remote_read(8)), 10);
        let dy = LeasePolicy::new(&cfg(LeasePolicyKind::Dynamic { max_lease: 80 }));
        let mut a = LineLease::default();
        let mut b = LineLease::default();
        assert_eq!(
            dy.shared_lease(&mut a, renew_hit()),
            dy.shared_lease(&mut b, SharedReq { numa_stretch: 8, ..renew_hit() })
        );
    }
}
