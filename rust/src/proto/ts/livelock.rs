//! Renewal-starvation (livelock) detection.
//!
//! A core speculating through expired loads on a write-hot line can
//! starve: every renewal comes back as fresh data (a misspeculation),
//! the speculation window rolls back, and the core re-executes —
//! paying the rollback penalty in a loop while the writer races ahead
//! (the §III-E concern, generalized to speculation; see also the lazy
//! cache-coherence verification literature, arXiv:1705.08262, on why
//! liveness needs an explicit argument under lazy invalidation).
//!
//! [`LivelockGuard`] tracks consecutive failed renewals per
//! (core, line).  Once a streak crosses the configured threshold the
//! line is *escalated* for that core: subsequent expired loads issue
//! as blocking demands instead of speculating, so the core stalls one
//! round-trip, adopts the fresh value, and is guaranteed forward
//! progress.  A successful renewal clears the streak (the line is
//! read-mostly again).

use crate::hashing::FxHashMap;
use crate::types::{CoreId, LineAddr};

#[derive(Debug, Clone)]
pub struct LivelockGuard {
    /// Consecutive failed renewals before escalation; 0 disables.
    threshold: u32,
    /// Active failure streaks.  Entries exist only while a line is
    /// failing for a core (cleared on success), so the map stays tiny.
    streaks: FxHashMap<(CoreId, LineAddr), u32>,
}

impl LivelockGuard {
    pub fn new(threshold: u32) -> Self {
        Self { threshold, streaks: FxHashMap::default() }
    }

    /// Bound on tracked streaks: past this, sub-threshold entries are
    /// forgotten (their streaks restart from zero — safe, merely less
    /// eager) so the map can never grow with the address space the
    /// way the old per-channel clock map did (§Perf lesson).
    const MAX_TRACKED: usize = 1 << 16;

    /// A renewal failed (answered with fresh data).  Returns true when
    /// this failure crosses the threshold — the moment of escalation
    /// (counted once per streak in the stats).
    pub fn on_renew_failed(&mut self, core: CoreId, addr: LineAddr) -> bool {
        if self.threshold == 0 {
            return false;
        }
        if self.streaks.len() >= Self::MAX_TRACKED {
            let t = self.threshold;
            self.streaks.retain(|_, s| *s >= t);
        }
        let streak = self.streaks.entry((core, addr)).or_insert(0);
        *streak += 1;
        *streak == self.threshold
    }

    /// A renewal succeeded: the line is behaving read-mostly again.
    pub fn on_renew_success(&mut self, core: CoreId, addr: LineAddr) {
        self.streaks.remove(&(core, addr));
    }

    /// Remove and return this core's active streaks, sorted by line
    /// address (tile migration: the map iterates in hash order, so the
    /// extraction must impose a canonical order itself).
    pub(crate) fn take_core_streaks(&mut self, core: CoreId) -> Vec<(LineAddr, u32)> {
        let mut out: Vec<(LineAddr, u32)> = self
            .streaks
            .iter()
            .filter(|((c, _), _)| *c == core)
            .map(|((_, a), s)| (*a, *s))
            .collect();
        out.sort_unstable_by_key(|&(a, _)| a);
        self.streaks.retain(|(c, _), _| *c != core);
        out
    }

    /// Install streaks for a core arriving from another shard,
    /// replacing any stale local entries for it.
    pub(crate) fn install_core_streaks(&mut self, core: CoreId, v: Vec<(LineAddr, u32)>) {
        self.streaks.retain(|(c, _), _| *c != core);
        for (addr, s) in v {
            self.streaks.insert((core, addr), s);
        }
    }

    /// May this core still speculate through an expired load on
    /// `addr`, or has the line been escalated to blocking demands?
    pub fn allow_speculation(&self, core: CoreId, addr: LineAddr) -> bool {
        if self.threshold == 0 {
            return true;
        }
        match self.streaks.get(&(core, addr)) {
            Some(streak) => *streak < self.threshold,
            None => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escalates_exactly_at_the_threshold() {
        let mut g = LivelockGuard::new(3);
        assert!(g.allow_speculation(0, 7));
        assert!(!g.on_renew_failed(0, 7));
        assert!(!g.on_renew_failed(0, 7));
        assert!(g.allow_speculation(0, 7), "below threshold still speculates");
        assert!(g.on_renew_failed(0, 7), "third failure escalates");
        assert!(!g.allow_speculation(0, 7));
        // Further failures do not re-report the escalation.
        assert!(!g.on_renew_failed(0, 7));
    }

    #[test]
    fn success_clears_the_streak() {
        let mut g = LivelockGuard::new(2);
        g.on_renew_failed(0, 7);
        g.on_renew_success(0, 7);
        assert!(!g.on_renew_failed(0, 7), "streak restarted from zero");
        assert!(g.allow_speculation(0, 7));
    }

    #[test]
    fn streaks_are_per_core_and_per_line() {
        let mut g = LivelockGuard::new(1);
        assert!(g.on_renew_failed(0, 7));
        assert!(!g.allow_speculation(0, 7));
        assert!(g.allow_speculation(1, 7), "other cores unaffected");
        assert!(g.allow_speculation(0, 8), "other lines unaffected");
    }

    #[test]
    fn zero_threshold_disables_the_guard() {
        let mut g = LivelockGuard::new(0);
        for _ in 0..100 {
            assert!(!g.on_renew_failed(0, 7));
        }
        assert!(g.allow_speculation(0, 7));
    }
}
