//! Coherence-protocol abstraction.  The engine, cores, and NoC are
//! protocol-agnostic; Tardis, full-map MSI, and Ackwise all implement
//! [`Coherence`] and run on the identical substrate.

pub mod ackwise;
pub mod dispatch;
pub mod msi;
pub mod tardis;
pub mod ts;

pub use dispatch::ProtocolDispatch;
pub(crate) use dispatch::TileProtoState;

use crate::net::Message;
use crate::stats::SimStats;
use crate::types::{CoreId, Cycle, LineAddr, Ts};

/// A memory operation issued by a core.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemOp {
    Load,
    Store { value: u64 },
    /// Atomic test-and-set: writes 1, returns the old value.
    Tas,
    /// Atomic fetch-and-add: returns the old value.
    FetchAdd { delta: u64 },
}

impl MemOp {
    /// Does this op require exclusive ownership?
    pub fn is_write(&self) -> bool {
        !matches!(self, MemOp::Load)
    }

    /// Value written, given the old line value (None for loads).
    pub fn write_value(&self, old: u64) -> Option<u64> {
        match self {
            MemOp::Load => None,
            MemOp::Store { value } => Some(*value),
            MemOp::Tas => Some(1),
            MemOp::FetchAdd { delta } => Some(old.wrapping_add(*delta)),
        }
    }
}

/// A finished access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccessDone {
    /// Value observed: for loads the loaded value, for atomics the
    /// *old* value, for stores the value written.
    pub value: u64,
    /// Logical timestamp assigned to the operation (Tardis); 0 for
    /// directory protocols (they order by physical time).
    pub ts: Ts,
    /// Extra cycles beyond the 1-cycle L1 access (e.g., rebase stall).
    pub extra_cycles: Cycle,
}

/// Outcome of [`Coherence::core_access`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessOutcome {
    /// L1 hit: completed now.
    Done(AccessDone),
    /// Miss: the protocol sent messages and will push a [`Completion`]
    /// when the access finishes.
    Pending,
    /// Tardis speculation (§IV-A): the expired value is returned now
    /// and a renewal is in flight.  If the renewal fails, a
    /// [`Completion`] with `misspec = true` follows carrying the
    /// corrected value.
    SpecDone(AccessDone),
}

/// Pushed by the protocol into [`ProtoCtx`] when a pending access (or
/// speculation outcome) resolves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    pub core: CoreId,
    pub addr: LineAddr,
    pub kind: CompletionKind,
    /// Observed value (same convention as [`AccessDone::value`]).
    pub value: u64,
    pub ts: Ts,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompletionKind {
    /// A pending demand access finished.
    Demand,
    /// A speculated renewal failed: the core must roll back and adopt
    /// the corrected value.
    Misspec,
    /// A watched line was invalidated/updated — wake a spinning core.
    SpinWake,
    /// A speculative renewal succeeded: the value the core ran ahead
    /// with was current.
    SpecOk,
}

/// Non-mutating L1 probe (used by the in-order core to gate issue
/// while a speculation window is open).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Probe {
    /// Would hit in the L1.
    Hit,
    /// Expired shared line: a load would speculate through a renewal.
    Spec,
    /// Would miss (demand request).
    Miss,
}

/// What a spinning core should do after observing an unsatisfying
/// value (see `Coherence::spin_hint`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpinHint {
    /// Line not locally valid — re-issue the load after a poll interval.
    Retry,
    /// Line cached and valid indefinitely; the protocol will push a
    /// `SpinWake` completion when it is invalidated or flushed
    /// (directory protocols, or Tardis exclusive lines).
    WaitInvalidate,
    /// Tardis: the line is leased until `rts`; spinning loads count as
    /// L1 accesses, so the core's own self-increment advances `pts`
    /// past the lease after `spins_needed` polls (§III-E).  The
    /// protocol has already applied the pts bump + stats.
    ExpiresAfterSelfInc { spins_needed: u64 },
}

/// Side-effect sink handed to the protocol on every call.  The engine
/// drains `msgs` into the event queue (adding mesh latency + traffic
/// accounting) and dispatches `completions` to cores.  `trace` is the
/// flight recorder's per-shard buffer (DESIGN.md §12) — disabled (one
/// predictable branch per [`ProtoCtx::emit`]) unless the run asked for
/// a recording.
pub struct ProtoCtx<'a> {
    pub now: Cycle,
    pub msgs: &'a mut Vec<Message>,
    pub completions: &'a mut Vec<Completion>,
    pub stats: &'a mut SimStats,
    pub trace: &'a mut crate::obs::TraceBuf,
}

impl<'a> ProtoCtx<'a> {
    pub fn send(&mut self, msg: Message) {
        self.msgs.push(msg);
    }

    pub fn complete(&mut self, c: Completion) {
        self.completions.push(c);
    }

    /// Record one protocol event on the flight recorder (no-op for
    /// untraced runs).
    #[inline]
    pub fn emit(&mut self, kind: crate::obs::EventKind, core: CoreId, addr: LineAddr, arg: u64) {
        self.trace.push(crate::obs::TraceEvent { cycle: self.now, addr, arg, core, kind });
    }
}

/// A coherence protocol: the paired private-cache controllers and LLC
/// slice managers (timestamp manager or directory), owning all cache
/// state.
pub trait Coherence {
    /// A core issues a memory operation.  `spec_ok` permits Tardis to
    /// answer an expired load speculatively (spin loads and atomics
    /// pass false).
    fn core_access(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        op: MemOp,
        spec_ok: bool,
        ctx: &mut ProtoCtx,
    ) -> AccessOutcome;

    /// Deliver a network message to its destination controller.
    fn on_message(&mut self, msg: Message, ctx: &mut ProtoCtx);

    /// Ask how a core should wait while spinning on `addr` after an
    /// unsatisfying load.  May mutate protocol state (Tardis advances
    /// pts by the self-increments the spin loop would perform;
    /// directory protocols register an invalidation watcher).
    fn spin_hint(&mut self, core: CoreId, addr: LineAddr, ctx: &mut ProtoCtx) -> SpinHint;

    /// Non-mutating probe: how would a load to `addr` fare right now?
    fn probe(&self, core: CoreId, addr: LineAddr) -> Probe;

    /// Commit-time validation of a load (out-of-order cores, §III-D).
    /// `early` = the value was bound before the load reached the ROB
    /// head; `bound` = the value the load returned at execution.
    /// Returns the logical timestamp to commit at, or None if the load
    /// must re-execute.  Tardis re-derives ts = max(pts, wts) and
    /// checks the lease; both protocols additionally require the
    /// line's current value to match the bound value (value-based
    /// replay — the line may have been invalidated and refilled with
    /// newer data between execution and commit).  Head-bound values
    /// are safe in directory protocols: a conflicting store cannot
    /// complete before its invalidation round-trip.
    fn commit_check(&mut self, core: CoreId, addr: LineAddr, early: bool, bound: u64)
        -> Option<Ts>;

    /// Per-LLC-line coherence storage in bits (paper Table VII).
    fn llc_storage_bits(&self, n_cores: u32) -> u64;

    /// Per-L1-line coherence storage in bits beyond the baseline tag.
    fn l1_storage_bits(&self) -> u64;

    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memop_write_semantics() {
        assert!(!MemOp::Load.is_write());
        assert!(MemOp::Tas.is_write());
        assert_eq!(MemOp::Load.write_value(7), None);
        assert_eq!(MemOp::Store { value: 3 }.write_value(7), Some(3));
        assert_eq!(MemOp::Tas.write_value(0), Some(1));
        assert_eq!(MemOp::Tas.write_value(1), Some(1));
        assert_eq!(MemOp::FetchAdd { delta: 2 }.write_value(7), Some(9));
    }
}
