//! Ackwise-k (paper [11], §VI-A): the scalable directory baseline — a
//! limited-pointer directory that broadcasts invalidations once the
//! sharer count exceeds its pointer budget.  Implemented as the MSI
//! directory with `ptr_limit = Some(k)`; this module provides the
//! protocol-kind wrapper.

use crate::config::SystemConfig;
use crate::net::Message;
use crate::proto::{AccessOutcome, Coherence, MemOp, ProtoCtx, SpinHint};
use crate::types::{CoreId, LineAddr, Ts};

use super::msi::Msi;

/// Ackwise-k protocol.
pub struct Ackwise(Msi);

impl Ackwise {
    pub fn new(sys: &SystemConfig) -> Self {
        Self(Msi::with_limit(sys, Some(sys.ackwise.num_pointers)))
    }

    /// Tile-state migration delegates to the wrapped directory.
    pub(crate) fn inner_mut(&mut self) -> &mut Msi {
        &mut self.0
    }
}

impl Coherence for Ackwise {
    fn core_access(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        op: MemOp,
        spec_ok: bool,
        ctx: &mut ProtoCtx,
    ) -> AccessOutcome {
        self.0.core_access(core, addr, op, spec_ok, ctx)
    }

    fn on_message(&mut self, msg: Message, ctx: &mut ProtoCtx) {
        self.0.on_message(msg, ctx)
    }

    fn spin_hint(&mut self, core: CoreId, addr: LineAddr, ctx: &mut ProtoCtx) -> SpinHint {
        self.0.spin_hint(core, addr, ctx)
    }

    fn probe(&self, core: CoreId, addr: LineAddr) -> crate::proto::Probe {
        self.0.probe(core, addr)
    }

    fn commit_check(&mut self, core: CoreId, addr: LineAddr, early: bool, bound: u64) -> Option<Ts> {
        self.0.commit_check(core, addr, early, bound)
    }

    fn llc_storage_bits(&self, n_cores: u32) -> u64 {
        self.0.llc_storage_bits(n_cores)
    }

    fn l1_storage_bits(&self) -> u64 {
        self.0.l1_storage_bits()
    }

    fn name(&self) -> &'static str {
        "ackwise"
    }
}
