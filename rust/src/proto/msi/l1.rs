//! MSI private-cache (L1) controller.

use super::*;
use crate::proto::AccessDone;

impl Msi {
    /// Core-side access.
    pub(crate) fn l1_access(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        op: MemOp,
        ctx: &mut ProtoCtx,
    ) -> AccessOutcome {
        let c = core as usize;
        if self.l1[c].demand.contains_key(&addr) {
            self.l1[c].demand.get_mut(&addr).unwrap().parked += 1;
            return AccessOutcome::Pending;
        }
        let state = self.l1[c].cache.get_mut(addr).map(|l| l.m);
        match (op, state) {
            // Load hit (S or M).
            (MemOp::Load, Some(_)) => {
                ctx.stats.l1_hits += 1;
                let value = self.l1[c].cache.peek(addr).unwrap().value;
                AccessOutcome::Done(AccessDone { value, ts: 0, extra_cycles: 0 })
            }
            // Write hit (M).
            (_, Some(true)) => {
                ctx.stats.l1_hits += 1;
                let line = self.l1[c].cache.get_mut(addr).unwrap();
                let old = line.value;
                let new = op.write_value(old).expect("write op");
                line.value = new;
                let observed = if matches!(op, MemOp::Store { .. }) { new } else { old };
                AccessOutcome::Done(AccessDone { value: observed, ts: 0, extra_cycles: 0 })
            }
            // Write to S (upgrade) or any miss.
            (_, state) => {
                ctx.stats.l1_misses += 1;
                let kind = if op.is_write() {
                    if state == Some(false) {
                        self.l1[c].cache.peek_mut(addr).unwrap().pinned = true;
                    }
                    MsgKind::GetX
                } else {
                    MsgKind::GetS
                };
                self.l1[c].demand.insert(addr, Demand { op, parked: 0 });
                let slice = self.slice_of(addr);
                ctx.send(to_slice(core, slice, addr, kind));
                AccessOutcome::Pending
            }
        }
    }

    /// Network events at the private cache.
    pub(crate) fn l1_on_message(&mut self, core: CoreId, msg: Message, ctx: &mut ProtoCtx) {
        match msg.kind {
            MsgKind::DataS { value } => self.l1_data(core, msg.addr, value, false, true, ctx),
            MsgKind::DataX { value } => self.l1_data(core, msg.addr, value, true, true, ctx),
            MsgKind::GrantX => self.l1_data(core, msg.addr, 0, true, false, ctx),
            MsgKind::Inv => self.l1_inv(core, msg, ctx),
            MsgKind::DownReq => self.l1_down_req(core, msg, ctx),
            MsgKind::DirFlushReq => self.l1_flush_req(core, msg, ctx),
            other => panic!("msi L1 got unexpected message {other:?}"),
        }
    }

    /// Data (or data-less grant) response: fill, perform the blocked
    /// op, complete.
    fn l1_data(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        value: u64,
        exclusive: bool,
        carries_data: bool,
        ctx: &mut ProtoCtx,
    ) {
        let c = core as usize;
        let Some(demand) = self.l1[c].demand.remove(&addr) else {
            return; // stale
        };
        let old_value = if carries_data {
            value
        } else {
            let line = self.l1[c]
                .cache
                .peek_mut(addr)
                .expect("GrantX for a line we no longer hold (pin violated)");
            line.pinned = false;
            line.value
        };
        let (observed, line) = match demand.op {
            MemOp::Load => (
                old_value,
                MsiL1Line { m: exclusive, value: old_value, pinned: false },
            ),
            op => {
                debug_assert!(exclusive, "write demand answered without exclusivity");
                let new = op.write_value(old_value).expect("write op");
                let observed = if matches!(op, MemOp::Store { .. }) { new } else { old_value };
                (observed, MsiL1Line { m: true, value: new, pinned: false })
            }
        };
        if carries_data && self.l1[c].cache.peek(addr).is_none() {
            if !self.l1_fill(core, addr, line.clone(), ctx) {
                // Bypass (every way pinned): the directory believes we
                // hold this line — relinquish it immediately so its
                // sharer/owner state stays truthful.
                let slice = self.slice_of(addr);
                let kind = if line.m { MsgKind::PutM { value: line.value } } else { MsgKind::PutS };
                ctx.send(to_slice(core, slice, addr, kind));
            }
        } else {
            *self.l1[c].cache.get_mut(addr).unwrap() = line;
        }
        ctx.complete(completion(core, addr, CompletionKind::Demand, observed));
        for _ in 0..demand.parked {
            ctx.complete(completion(core, addr, CompletionKind::SpinWake, 0));
        }
    }

    /// Fill with eviction: S victims notify the directory (PutS — the
    /// traffic Tardis avoids, §III-F1); M victims write back (PutM).
    /// Returns false if the fill could not be cached (all ways pinned).
    fn l1_fill(&mut self, core: CoreId, addr: LineAddr, line: MsiL1Line, ctx: &mut ProtoCtx) -> bool {
        let c = core as usize;
        let evicted = match self.l1[c].cache.insert_filtered(addr, line, |l| !l.pinned) {
            Ok(v) => v,
            Err(_) => return false, // all ways pinned: bypass
        };
        if let Some((vaddr, v)) = evicted {
            let slice = self.slice_of(vaddr);
            let kind = if v.m { MsgKind::PutM { value: v.value } } else { MsgKind::PutS };
            ctx.send(to_slice(core, slice, vaddr, kind));
        }
        true
    }

    /// Directory invalidation: drop the line (any state), always ack.
    fn l1_inv(&mut self, core: CoreId, msg: Message, ctx: &mut ProtoCtx) {
        let c = core as usize;
        self.l1[c].cache.invalidate(msg.addr);
        let slice = self.slice_of(msg.addr);
        ctx.send(to_slice(core, slice, msg.addr, MsgKind::InvAck));
        if self.l1[c].watch == Some(msg.addr) {
            self.l1[c].watch = None;
            ctx.complete(completion(core, msg.addr, CompletionKind::SpinWake, 0));
        }
    }

    /// Downgrade request (GetS hit an M line): return data, keep S.
    fn l1_down_req(&mut self, core: CoreId, msg: Message, ctx: &mut ProtoCtx) {
        let c = core as usize;
        let Some(line) = self.l1[c].cache.peek_mut(msg.addr) else {
            return; // crossed with our PutM
        };
        if !line.m {
            return;
        }
        line.m = false;
        let value = line.value;
        let slice = self.slice_of(msg.addr);
        ctx.send(to_slice(core, slice, msg.addr, MsgKind::DownRep { value }));
    }

    /// Flush request (GetX hit an M line): return data, invalidate.
    fn l1_flush_req(&mut self, core: CoreId, msg: Message, ctx: &mut ProtoCtx) {
        let c = core as usize;
        match self.l1[c].cache.peek(msg.addr) {
            Some(line) if line.m => {}
            _ => return, // crossed with our PutM
        }
        let line = self.l1[c].cache.invalidate(msg.addr).unwrap();
        let slice = self.slice_of(msg.addr);
        ctx.send(to_slice(core, slice, msg.addr, MsgKind::DirFlushRep { value: line.value }));
        if self.l1[c].watch == Some(msg.addr) {
            self.l1[c].watch = None;
            ctx.complete(completion(core, msg.addr, CompletionKind::SpinWake, 0));
        }
    }
}
