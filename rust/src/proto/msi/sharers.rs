//! Sharer-set representations: full bit vector (MSI) and limited
//! pointers with broadcast overflow (Ackwise, paper §VII-B / [11]).

use crate::types::CoreId;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Sharers {
    /// Full-map bit vector.
    Map(Vec<u64>),
    /// Up to `limit` precise pointers.
    Ptrs { list: Vec<CoreId>, limit: u32 },
    /// Pointer overflow: only the population count is known;
    /// invalidation requires broadcast.
    Global { count: u32, limit: u32 },
}

impl Default for Sharers {
    fn default() -> Self {
        Sharers::Ptrs { list: Vec::new(), limit: 0 }
    }
}

/// Who must be invalidated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InvTargets {
    List(Vec<CoreId>),
    /// Every core in the system (Ackwise overflow).
    Broadcast,
}

impl Sharers {
    pub fn new_map(n_cores: u32) -> Self {
        Sharers::Map(vec![0; n_cores.div_ceil(64) as usize])
    }

    pub fn new_ptrs(limit: u32) -> Self {
        Sharers::Ptrs { list: Vec::new(), limit }
    }

    pub fn add(&mut self, core: CoreId) {
        match self {
            Sharers::Map(bits) => bits[core as usize / 64] |= 1 << (core % 64),
            Sharers::Ptrs { list, limit } => {
                if !list.contains(&core) {
                    if list.len() < *limit as usize {
                        list.push(core);
                    } else {
                        // Overflow: degrade to a count.
                        *self = Sharers::Global { count: list.len() as u32 + 1, limit: *limit };
                    }
                }
            }
            Sharers::Global { count, .. } => *count += 1,
        }
    }

    pub fn remove(&mut self, core: CoreId) {
        match self {
            Sharers::Map(bits) => bits[core as usize / 64] &= !(1 << (core % 64)),
            Sharers::Ptrs { list, .. } => list.retain(|&c| c != core),
            Sharers::Global { count, limit } => {
                *count = count.saturating_sub(1);
                if *count == 0 {
                    *self = Sharers::Ptrs { list: Vec::new(), limit: *limit };
                }
            }
        }
    }

    pub fn contains(&self, core: CoreId) -> bool {
        match self {
            Sharers::Map(bits) => bits[core as usize / 64] & (1 << (core % 64)) != 0,
            Sharers::Ptrs { list, .. } => list.contains(&core),
            // Conservative: unknown membership.
            Sharers::Global { .. } => true,
        }
    }

    /// Membership that is *certainly* true (Global mode cannot vouch
    /// for anyone — used for data-less GrantX decisions, which assume
    /// the requester still holds a copy).
    pub fn contains_certain(&self, core: CoreId) -> bool {
        match self {
            Sharers::Global { .. } => false,
            other => other.contains(core),
        }
    }

    pub fn is_empty(&self) -> bool {
        match self {
            Sharers::Map(bits) => bits.iter().all(|&b| b == 0),
            Sharers::Ptrs { list, .. } => list.is_empty(),
            Sharers::Global { count, .. } => *count == 0,
        }
    }

    pub fn count(&self) -> u32 {
        match self {
            Sharers::Map(bits) => bits.iter().map(|b| b.count_ones()).sum(),
            Sharers::Ptrs { list, .. } => list.len() as u32,
            Sharers::Global { count, .. } => *count,
        }
    }

    pub fn clear(&mut self) {
        match self {
            Sharers::Map(bits) => bits.fill(0),
            Sharers::Ptrs { list, .. } => list.clear(),
            Sharers::Global { count, limit } => {
                let limit = *limit;
                let _ = count;
                *self = Sharers::Ptrs { list: Vec::new(), limit };
            }
        }
    }

    /// Invalidation targets, excluding `except`.
    pub fn inv_targets(&self, except: Option<CoreId>) -> InvTargets {
        match self {
            Sharers::Map(bits) => {
                let mut v = Vec::new();
                for (w, &word) in bits.iter().enumerate() {
                    let mut word = word;
                    while word != 0 {
                        let b = word.trailing_zeros();
                        let core = (w as u32) * 64 + b;
                        if Some(core) != except {
                            v.push(core);
                        }
                        word &= word - 1;
                    }
                }
                InvTargets::List(v)
            }
            Sharers::Ptrs { list, .. } => {
                InvTargets::List(list.iter().copied().filter(|&c| Some(c) != except).collect())
            }
            Sharers::Global { .. } => InvTargets::Broadcast,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_add_remove_contains() {
        let mut s = Sharers::new_map(128);
        s.add(0);
        s.add(63);
        s.add(64);
        s.add(127);
        assert!(s.contains(0) && s.contains(63) && s.contains(64) && s.contains(127));
        assert!(!s.contains(1));
        assert_eq!(s.count(), 4);
        s.remove(63);
        assert!(!s.contains(63));
        assert_eq!(s.count(), 3);
    }

    #[test]
    fn map_inv_targets_excludes_requester() {
        let mut s = Sharers::new_map(64);
        s.add(3);
        s.add(7);
        s.add(11);
        assert_eq!(s.inv_targets(Some(7)), InvTargets::List(vec![3, 11]));
    }

    #[test]
    fn ptrs_overflow_to_global() {
        let mut s = Sharers::new_ptrs(2);
        s.add(1);
        s.add(2);
        assert!(matches!(s, Sharers::Ptrs { .. }));
        s.add(3);
        assert!(matches!(s, Sharers::Global { count: 3, .. }));
        assert_eq!(s.inv_targets(None), InvTargets::Broadcast);
    }

    #[test]
    fn ptrs_duplicate_add_is_noop() {
        let mut s = Sharers::new_ptrs(2);
        s.add(1);
        s.add(1);
        assert!(matches!(&s, Sharers::Ptrs { list, .. } if list.len() == 1));
    }

    #[test]
    fn global_drains_back_to_ptrs() {
        let mut s = Sharers::new_ptrs(1);
        s.add(1);
        s.add(2); // overflow
        s.remove(1);
        s.remove(2);
        assert!(s.is_empty());
        assert!(matches!(s, Sharers::Ptrs { .. }));
        // Precise again after draining.
        s.add(5);
        assert!(matches!(&s, Sharers::Ptrs { list, .. } if list == &vec![5]));
    }

    #[test]
    fn global_contains_is_conservative() {
        let mut s = Sharers::new_ptrs(1);
        s.add(1);
        s.add(2);
        assert!(s.contains(40)); // unknown -> conservative yes
    }
}
