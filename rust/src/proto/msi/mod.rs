//! Full-map MSI directory coherence (the paper's baseline), also
//! parameterizable as Ackwise-k (limited pointers + broadcast) — the
//! paper's second baseline.  Same substrate as Tardis: per-core L1
//! controllers + per-slice directory, exchanging [`MsgKind`] messages.

mod dir;
mod l1;
mod sharers;

use crate::config::SystemConfig;
use crate::hashing::FxHashMap;
use crate::mem::{SetAssoc, SliceMap};
use crate::net::{Message, MsgKind, Node};
use crate::proto::{
    AccessOutcome, Coherence, Completion, CompletionKind, MemOp, ProtoCtx, SpinHint,
};
use crate::types::{CoreId, LineAddr, SliceId, Ts};

pub use sharers::Sharers;

/// Per-line L1 state: present means S or M.
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash)]
pub struct MsiL1Line {
    /// Modified (exclusive + dirty) vs shared.
    pub m: bool,
    pub value: u64,
    /// Outstanding upgrade relies on this copy (not evictable).
    pub pinned: bool,
}

/// A demand miss outstanding at an L1.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Demand {
    pub op: MemOp,
    pub parked: u32,
}

#[derive(Debug, Clone)]
pub struct MsiL1 {
    pub cache: SetAssoc<MsiL1Line>,
    pub demand: FxHashMap<LineAddr, Demand>,
    pub watch: Option<LineAddr>,
}

/// Directory entry per LLC line.
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash)]
pub struct DirLine {
    pub sharers: Sharers,
    pub owner: Option<CoreId>,
    pub value: u64,
    pub dirty: bool,
    /// Mid-transaction: not evictable.
    pub busy: bool,
}

/// Why a directory line is busy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DirPendKind {
    /// DRAM fetch in flight (line absent).
    Fetch,
    /// Owner downgrade (GetS to an M line).
    AwaitDown,
    /// Owner flush (GetX to an M line).
    AwaitFlush,
    /// Invalidation acks outstanding for a GetX.
    AwaitInvAcks { left: u32 },
    /// LLC eviction: invalidation acks outstanding, then fill.
    EvictInvAcks { left: u32 },
    /// LLC eviction: owner flush outstanding, then fill.
    EvictFlush,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct DirPending {
    pub kind: DirPendKind,
    pub waiters: std::collections::VecDeque<DirReq>,
    pub fill: Option<(LineAddr, u64)>,
}

impl DirPending {
    fn new(kind: DirPendKind) -> Self {
        Self { kind, waiters: std::collections::VecDeque::new(), fill: None }
    }
}

/// A queued directory request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DirReq {
    pub core: CoreId,
    pub write: bool,
}

#[derive(Debug, Clone)]
pub struct DirSlice {
    pub cache: SetAssoc<DirLine>,
    pub pending: FxHashMap<LineAddr, DirPending>,
}

/// The directory protocol (MSI full map, or Ackwise-k when
/// `ptr_limit` is set).  `Clone` and the `pub(crate)` controller
/// fields exist for the `verif` model checker's snapshot/branch
/// exploration.
#[derive(Debug, Clone)]
pub struct Msi {
    n_cores: u32,
    /// None = full-map bit vector; Some(k) = Ackwise-k pointers.
    ptr_limit: Option<u32>,
    /// Address -> home slice / memory-controller map (socket-aware).
    map: SliceMap,
    pub(crate) l1: Vec<MsiL1>,
    pub(crate) dir: Vec<DirSlice>,
}

impl Msi {
    pub fn new(sys: &SystemConfig) -> Self {
        Self::with_limit(sys, None)
    }

    pub fn with_limit(sys: &SystemConfig, ptr_limit: Option<u32>) -> Self {
        Self {
            n_cores: sys.n_cores,
            ptr_limit,
            map: SliceMap::new(sys),
            l1: (0..sys.n_cores)
                .map(|_| MsiL1 {
                    cache: SetAssoc::new(sys.l1_sets, sys.l1_ways),
                    demand: FxHashMap::default(),
                    watch: None,
                })
                .collect(),
            dir: (0..sys.n_cores)
                .map(|_| DirSlice {
                    cache: SetAssoc::new(sys.l2_sets, sys.l2_ways),
                    pending: FxHashMap::default(),
                })
                .collect(),
        }
    }

    pub(crate) fn slice_of(&self, addr: LineAddr) -> SliceId {
        self.map.home_slice(addr)
    }

    pub(crate) fn new_sharers(&self) -> Sharers {
        match self.ptr_limit {
            None => Sharers::new_map(self.n_cores),
            Some(k) => Sharers::new_ptrs(k),
        }
    }

    /// Snapshot tile `t`'s protocol state (L1 of core t, directory
    /// slice t) for migration to another shard.
    pub(crate) fn take_tile(&mut self, t: u32) -> MsiTile {
        MsiTile { l1: self.l1[t as usize].clone(), dir: self.dir[t as usize].clone() }
    }

    /// Overwrite tile `t`'s state with a snapshot from another shard.
    pub(crate) fn install_tile(&mut self, t: u32, tile: MsiTile) {
        self.l1[t as usize] = tile.l1;
        self.dir[t as usize] = tile.dir;
    }
}

/// Everything the directory protocol keeps per tile, packaged for
/// shard migration.
#[derive(Debug, Clone)]
pub(crate) struct MsiTile {
    l1: MsiL1,
    dir: DirSlice,
}

impl Coherence for Msi {
    fn core_access(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        op: MemOp,
        _spec_ok: bool,
        ctx: &mut ProtoCtx,
    ) -> AccessOutcome {
        self.l1_access(core, addr, op, ctx)
    }

    fn on_message(&mut self, msg: Message, ctx: &mut ProtoCtx) {
        match msg.dst {
            Node::Core(c) => self.l1_on_message(c, msg, ctx),
            Node::Slice(s) => self.dir_on_message(s, msg, ctx),
            Node::Mc(_) => unreachable!("MC messages are handled by the engine"),
        }
    }

    fn spin_hint(&mut self, core: CoreId, addr: LineAddr, _ctx: &mut ProtoCtx) -> SpinHint {
        // A cached line's value can only change after an invalidation
        // (or flush) reaches this L1 — sleep until then.
        if self.l1[core as usize].cache.peek(addr).is_some() {
            self.l1[core as usize].watch = Some(addr);
            SpinHint::WaitInvalidate
        } else {
            SpinHint::Retry
        }
    }

    fn probe(&self, core: CoreId, addr: LineAddr) -> crate::proto::Probe {
        if self.l1[core as usize].cache.peek(addr).is_some() {
            crate::proto::Probe::Hit
        } else {
            crate::proto::Probe::Miss
        }
    }

    fn commit_check(&mut self, core: CoreId, addr: LineAddr, early: bool, bound: u64) -> Option<Ts> {
        // Invalidation / value-based replay (Gharachorloo et al.; Cain
        // & Lipasti): an early-bound load replays unless the line is
        // still present *with the bound value* (it may have been
        // invalidated and refilled with newer data).  A head-bound
        // value always commits: the conflicting store's invalidation
        // round-trip cannot have completed yet.
        if !early {
            return Some(0);
        }
        match self.l1[core as usize].cache.peek(addr) {
            Some(line) if line.value == bound => Some(0),
            _ => None,
        }
    }

    fn llc_storage_bits(&self, n_cores: u32) -> u64 {
        match self.ptr_limit {
            // Full sharer bit vector (paper Table VII).
            None => n_cores as u64,
            // k pointers of log2(N) bits each.
            Some(k) => k as u64 * (n_cores as f64).log2().ceil() as u64,
        }
    }

    fn l1_storage_bits(&self) -> u64 {
        1 // M bit
    }

    fn name(&self) -> &'static str {
        match self.ptr_limit {
            None => "msi",
            Some(_) => "ackwise",
        }
    }
}

pub(crate) fn to_slice(core: CoreId, slice: SliceId, addr: LineAddr, kind: MsgKind) -> Message {
    Message { src: Node::Core(core), dst: Node::Slice(slice), addr, requester: core, kind }
}

pub(crate) fn to_core(
    slice: SliceId,
    core: CoreId,
    addr: LineAddr,
    requester: CoreId,
    kind: MsgKind,
) -> Message {
    Message { src: Node::Slice(slice), dst: Node::Core(core), addr, requester, kind }
}

pub(crate) fn completion(
    core: CoreId,
    addr: LineAddr,
    kind: CompletionKind,
    value: u64,
) -> Completion {
    Completion { core, addr, kind, value, ts: 0 }
}
