//! MSI / Ackwise directory slice: sharer tracking, invalidation
//! collection, owner round-trips, DRAM fills, LLC evictions.

use std::collections::VecDeque;

use super::sharers::InvTargets;
use super::*;

impl Msi {
    pub(crate) fn dir_on_message(&mut self, slice: SliceId, msg: Message, ctx: &mut ProtoCtx) {
        match msg.kind {
            MsgKind::GetS => {
                ctx.stats.llc_accesses += 1;
                self.dir_request(slice, msg.addr, DirReq { core: msg.requester, write: false }, ctx);
            }
            MsgKind::GetX => {
                ctx.stats.llc_accesses += 1;
                self.dir_request(slice, msg.addr, DirReq { core: msg.requester, write: true }, ctx);
            }
            MsgKind::PutS => self.dir_put_s(slice, msg, ctx),
            MsgKind::PutM { value } => self.dir_owner_data(slice, msg.addr, msg.src, value, true, ctx),
            MsgKind::DownRep { value } => {
                self.dir_owner_data(slice, msg.addr, msg.src, value, false, ctx)
            }
            MsgKind::DirFlushRep { value } => {
                self.dir_owner_data(slice, msg.addr, msg.src, value, true, ctx)
            }
            MsgKind::InvAck => self.dir_inv_ack(slice, msg.addr, ctx),
            MsgKind::DramLdRep { value } => self.dir_install(slice, msg.addr, value, ctx),
            other => panic!("directory got unexpected message {other:?}"),
        }
    }

    fn dir_request(&mut self, slice: SliceId, addr: LineAddr, req: DirReq, ctx: &mut ProtoCtx) {
        let s = slice as usize;
        if let Some(p) = self.dir[s].pending.get_mut(&addr) {
            p.waiters.push_back(req);
            return;
        }
        self.dir_process(slice, addr, req, ctx);
    }

    fn dir_process(&mut self, slice: SliceId, addr: LineAddr, req: DirReq, ctx: &mut ProtoCtx) {
        let s = slice as usize;
        if self.dir[s].cache.peek(addr).is_none() {
            // Fetch from DRAM.
            let mut p = DirPending::new(DirPendKind::Fetch);
            p.waiters.push_back(req);
            self.dir[s].pending.insert(addr, p);
            ctx.stats.dram_accesses += 1;
            let mc = self.map.home_mc(addr);
            ctx.send(Message {
                src: Node::Slice(slice),
                dst: Node::Mc(mc),
                addr,
                requester: req.core,
                kind: MsgKind::DramLdReq,
            });
            return;
        }

        let (owner, was_sharer, others_empty) = {
            let line = self.dir[s].cache.get_mut(addr).unwrap();
            // GrantX (no data) requires *certain* knowledge that the
            // requester holds a copy; Ackwise Global mode cannot vouch.
            let was_sharer = line.sharers.contains_certain(req.core);
            let others_empty = match &line.sharers {
                Sharers::Global { .. } => false, // must broadcast
                s => {
                    let mut others = s.clone();
                    others.remove(req.core);
                    others.is_empty()
                }
            };
            (line.owner, was_sharer, others_empty)
        };

        match (req.write, owner) {
            // ---- Read, uncached or shared ----
            (false, None) => {
                let line = self.dir[s].cache.get_mut(addr).unwrap();
                line.sharers.add(req.core);
                let value = line.value;
                ctx.send(to_core(slice, req.core, addr, req.core, MsgKind::DataS { value }));
            }
            // ---- Read, owned: downgrade the owner ----
            (false, Some(owner)) => {
                let line = self.dir[s].cache.get_mut(addr).unwrap();
                line.busy = true;
                let mut p = DirPending::new(DirPendKind::AwaitDown);
                p.waiters.push_back(req);
                self.dir[s].pending.insert(addr, p);
                ctx.send(to_core(slice, owner, addr, req.core, MsgKind::DownReq));
            }
            // ---- Write, no owner ----
            (true, None) => {
                if others_empty {
                    // No other sharers: grant immediately.
                    let line = self.dir[s].cache.get_mut(addr).unwrap();
                    line.sharers.clear();
                    line.owner = Some(req.core);
                    let value = line.value;
                    if was_sharer {
                        ctx.send(to_core(slice, req.core, addr, req.core, MsgKind::GrantX));
                    } else {
                        ctx.send(to_core(slice, req.core, addr, req.core, MsgKind::DataX { value }));
                    }
                } else {
                    // Invalidate every other sharer, then grant.
                    self.dir_send_invs(slice, addr, Some(req.core), false, req, ctx);
                }
            }
            // ---- Write, owned: flush the owner ----
            (true, Some(owner)) => {
                let line = self.dir[s].cache.get_mut(addr).unwrap();
                line.busy = true;
                let mut p = DirPending::new(DirPendKind::AwaitFlush);
                p.waiters.push_back(req);
                self.dir[s].pending.insert(addr, p);
                ctx.send(to_core(slice, owner, addr, req.core, MsgKind::DirFlushReq));
            }
        }
    }

    /// Send invalidations to all sharers except `except`; create the
    /// ack-collection pending entry (for a GetX or an LLC eviction).
    fn dir_send_invs(
        &mut self,
        slice: SliceId,
        addr: LineAddr,
        except: Option<CoreId>,
        evicting: bool,
        req: DirReq,
        ctx: &mut ProtoCtx,
    ) {
        let s = slice as usize;
        let targets = {
            let line = self.dir[s].cache.get_mut(addr).unwrap();
            line.busy = true;
            line.sharers.inv_targets(except)
        };
        let (count, list): (u32, Vec<CoreId>) = match targets {
            InvTargets::List(list) => (list.len() as u32, list),
            InvTargets::Broadcast => {
                // Ackwise overflow: invalidate every core (except the
                // requester); all of them ack.
                ctx.stats.broadcasts += 1;
                let list: Vec<CoreId> =
                    (0..self.n_cores).filter(|&c| Some(c) != except).collect();
                (list.len() as u32, list)
            }
        };
        debug_assert!(count > 0, "inv fan-out of zero");
        ctx.stats.invalidations_sent += count as u64;
        for core in list {
            ctx.send(to_core(slice, core, addr, req.core, MsgKind::Inv));
        }
        let kind = if evicting {
            DirPendKind::EvictInvAcks { left: count }
        } else {
            DirPendKind::AwaitInvAcks { left: count }
        };
        let mut p = DirPending::new(kind);
        if !evicting {
            p.waiters.push_back(req);
        }
        self.dir[s].pending.insert(addr, p);
    }

    fn dir_inv_ack(&mut self, slice: SliceId, addr: LineAddr, ctx: &mut ProtoCtx) {
        let s = slice as usize;
        let Some(p) = self.dir[s].pending.get_mut(&addr) else {
            return; // stray ack (PutS crossed an Inv)
        };
        let done = match &mut p.kind {
            DirPendKind::AwaitInvAcks { left } | DirPendKind::EvictInvAcks { left } => {
                *left -= 1;
                *left == 0
            }
            _ => false,
        };
        if !done {
            return;
        }
        let mut p = self.dir[s].pending.remove(&addr).unwrap();
        match p.kind {
            DirPendKind::AwaitInvAcks { .. } => {
                // All copies gone: grant exclusivity to the head waiter.
                let req = p.waiters.pop_front().expect("GetX waiter");
                {
                    let line = self.dir[s].cache.get_mut(addr).unwrap();
                    line.busy = false;
                    let was_sharer = line.sharers.contains_certain(req.core);
                    line.sharers.clear();
                    line.owner = Some(req.core);
                    let value = line.value;
                    if was_sharer {
                        ctx.send(to_core(slice, req.core, addr, req.core, MsgKind::GrantX));
                    } else {
                        ctx.send(to_core(slice, req.core, addr, req.core, MsgKind::DataX { value }));
                    }
                }
                self.dir_drain(slice, addr, p.waiters, ctx);
            }
            DirPendKind::EvictInvAcks { .. } => {
                // Eviction complete: write back, drop, retry the fill.
                if let Some(line) = self.dir[s].cache.invalidate(addr) {
                    self.dir_writeback(slice, addr, &line, ctx);
                }
                if let Some((fill_addr, fill_value)) = p.fill.take() {
                    self.dir_install(slice, fill_addr, fill_value, ctx);
                }
                self.dir_drain(slice, addr, p.waiters, ctx);
            }
            _ => unreachable!(),
        }
    }

    /// Data returned by an owner (PutM / DownRep / DirFlushRep).
    fn dir_owner_data(
        &mut self,
        slice: SliceId,
        addr: LineAddr,
        src: Node,
        value: u64,
        owner_gone: bool,
        ctx: &mut ProtoCtx,
    ) {
        let s = slice as usize;
        let src_core = match src {
            Node::Core(c) => c,
            _ => panic!("owner data from non-core"),
        };
        {
            let Some(line) = self.dir[s].cache.peek_mut(addr) else {
                // Owned line fell out of the directory: write through.
                ctx.stats.dram_accesses += 1;
                let mc = self.map.home_mc(addr);
                ctx.send(Message {
                    src: Node::Slice(slice),
                    dst: Node::Mc(mc),
                    addr,
                    requester: 0,
                    kind: MsgKind::DramStReq { value },
                });
                return;
            };
            if line.owner != Some(src_core) {
                return; // stale (already transferred)
            }
            line.owner = None;
            line.busy = false;
            line.value = value;
            line.dirty = true;
            if !owner_gone {
                // Downgrade: the old owner remains a sharer.
                line.sharers.add(src_core);
            }
        }
        let Some(mut p) = self.dir[s].pending.remove(&addr) else {
            return; // unsolicited PutM
        };
        match p.kind {
            DirPendKind::AwaitDown | DirPendKind::AwaitFlush => {
                self.dir_drain(slice, addr, p.waiters, ctx);
            }
            DirPendKind::EvictFlush => {
                if let Some(line) = self.dir[s].cache.invalidate(addr) {
                    self.dir_writeback(slice, addr, &line, ctx);
                }
                if let Some((fill_addr, fill_value)) = p.fill.take() {
                    self.dir_install(slice, fill_addr, fill_value, ctx);
                }
                self.dir_drain(slice, addr, p.waiters, ctx);
            }
            _ => {
                // A PutM raced with invalidations/fetch: keep waiting.
                self.dir[s].pending.insert(addr, p);
            }
        }
    }

    /// Clean-eviction notification.
    fn dir_put_s(&mut self, slice: SliceId, msg: Message, ctx: &mut ProtoCtx) {
        let s = slice as usize;
        let Node::Core(core) = msg.src else { return };
        if let Some(line) = self.dir[s].cache.peek_mut(msg.addr) {
            line.sharers.remove(core);
        }
        let _ = ctx;
    }

    /// Install a DRAM fill, evicting if necessary.
    fn dir_install(&mut self, slice: SliceId, addr: LineAddr, value: u64, ctx: &mut ProtoCtx) {
        let s = slice as usize;
        let new_line = DirLine {
            sharers: self.new_sharers(),
            owner: None,
            value,
            dirty: false,
            busy: false,
        };
        // Preferred victims: no sharers, no owner, not busy.
        let res = self.dir[s].cache.insert_filtered(addr, new_line, |l| {
            l.owner.is_none() && l.sharers.is_empty() && !l.busy
        });
        match res {
            Ok(evicted) => {
                if let Some((vaddr, v)) = evicted {
                    self.dir_writeback(slice, vaddr, &v, ctx);
                }
                if let Some(p) = self.dir[s].pending.remove(&addr) {
                    debug_assert_eq!(p.kind, DirPendKind::Fetch);
                    self.dir_drain(slice, addr, p.waiters, ctx);
                }
            }
            Err(_) => {
                // Evict a line with sharers (invalidate them) or an
                // owner (flush it); park the fill.
                if let Some(vaddr) =
                    self.dir[s].cache.victim_for(addr, |l| l.owner.is_none() && !l.busy)
                {
                    self.dir_send_invs(
                        slice,
                        vaddr,
                        None,
                        true,
                        DirReq { core: 0, write: false },
                        ctx,
                    );
                    self.dir[s].pending.get_mut(&vaddr).unwrap().fill = Some((addr, value));
                } else if let Some(vaddr) =
                    self.dir[s].cache.victim_for(addr, |l| l.owner.is_some() && !l.busy)
                {
                    let owner = {
                        let line = self.dir[s].cache.peek_mut(vaddr).unwrap();
                        line.busy = true;
                        line.owner.unwrap()
                    };
                    let mut p = DirPending::new(DirPendKind::EvictFlush);
                    p.fill = Some((addr, value));
                    self.dir[s].pending.insert(vaddr, p);
                    ctx.send(to_core(slice, owner, vaddr, owner, MsgKind::DirFlushReq));
                } else {
                    // Whole set busy: retry shortly.
                    ctx.send(Message {
                        src: Node::Slice(slice),
                        dst: Node::Slice(slice),
                        addr,
                        requester: 0,
                        kind: MsgKind::DramLdRep { value },
                    });
                }
            }
        }
    }

    fn dir_drain(
        &mut self,
        slice: SliceId,
        addr: LineAddr,
        mut waiters: VecDeque<DirReq>,
        ctx: &mut ProtoCtx,
    ) {
        let s = slice as usize;
        while let Some(req) = waiters.pop_front() {
            self.dir_process(slice, addr, req, ctx);
            if let Some(p) = self.dir[s].pending.get_mut(&addr) {
                p.waiters.extend(waiters.drain(..));
                return;
            }
        }
    }

    fn dir_writeback(&mut self, slice: SliceId, addr: LineAddr, line: &DirLine, ctx: &mut ProtoCtx) {
        debug_assert!(line.owner.is_none() && line.sharers.is_empty());
        if line.dirty {
            ctx.stats.dram_accesses += 1;
            let mc = self.map.home_mc(addr);
            ctx.send(Message {
                src: Node::Slice(slice),
                dst: Node::Mc(mc),
                addr,
                requester: 0,
                kind: MsgKind::DramStReq { value: line.value },
            });
        }
    }
}
