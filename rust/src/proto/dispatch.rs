//! Monomorphized protocol dispatch.
//!
//! The engine used to hold a `Box<dyn Coherence>`, paying an indirect
//! vtable call on every event in the hot loop.  [`ProtocolDispatch`]
//! replaces it with a three-variant enum: every call site becomes a
//! match over concrete types, which the compiler can inline and the
//! branch predictor resolves in the common single-protocol run.
//! `benches/engine_hot.rs` compares both dispatch styles directly.

use crate::config::{ProtocolKind, SystemConfig};
use crate::net::Message;
use crate::types::{CoreId, LineAddr, Ts};

use super::ackwise::Ackwise;
use super::msi::{Msi, MsiTile};
use super::tardis::{Tardis, TardisTile};
use super::{AccessOutcome, Coherence, MemOp, Probe, ProtoCtx, SpinHint};

/// A tile's protocol-private state, opaque to the engine, carried
/// across shards when the PDES rebalancer migrates the tile.
#[derive(Debug, Clone)]
pub(crate) enum TileProtoState {
    Tardis(Box<TardisTile>),
    Msi(Box<MsiTile>),
}

/// The statically dispatched union of the coherence protocols.  Adding
/// a protocol variant (MESI, Tardis 2.0 leases) means adding an enum
/// arm here and a constructor case in [`ProtocolDispatch::new`] — the
/// engine, cores, and API are untouched.
pub enum ProtocolDispatch {
    Tardis(Tardis),
    Msi(Msi),
    Ackwise(Ackwise),
}

/// Expand `match self { variant(p) => body }` once per protocol.
macro_rules! for_each_protocol {
    ($self:expr, $p:ident => $body:expr) => {
        match $self {
            ProtocolDispatch::Tardis($p) => $body,
            ProtocolDispatch::Msi($p) => $body,
            ProtocolDispatch::Ackwise($p) => $body,
        }
    };
}

impl ProtocolDispatch {
    /// Instantiate the protocol selected by `cfg.protocol`.
    pub fn new(cfg: &SystemConfig) -> Self {
        match cfg.protocol {
            ProtocolKind::Tardis => Self::Tardis(Tardis::new(cfg)),
            ProtocolKind::Msi => Self::Msi(Msi::new(cfg)),
            ProtocolKind::Ackwise => Self::Ackwise(Ackwise::new(cfg)),
        }
    }

    /// Which protocol this dispatcher wraps.
    pub fn kind(&self) -> ProtocolKind {
        match self {
            Self::Tardis(_) => ProtocolKind::Tardis,
            Self::Msi(_) => ProtocolKind::Msi,
            Self::Ackwise(_) => ProtocolKind::Ackwise,
        }
    }

    /// Snapshot tile `t`'s protocol-private state for shard migration.
    pub(crate) fn take_tile(&mut self, t: u32) -> TileProtoState {
        match self {
            Self::Tardis(p) => TileProtoState::Tardis(Box::new(p.take_tile(t))),
            Self::Msi(p) => TileProtoState::Msi(Box::new(p.take_tile(t))),
            Self::Ackwise(p) => TileProtoState::Msi(Box::new(p.inner_mut().take_tile(t))),
        }
    }

    /// Install a migrated tile snapshot.  Panics on a protocol
    /// mismatch — every shard runs the same configured protocol.
    pub(crate) fn install_tile(&mut self, t: u32, tile: TileProtoState) {
        match (self, tile) {
            (Self::Tardis(p), TileProtoState::Tardis(s)) => p.install_tile(t, *s),
            (Self::Msi(p), TileProtoState::Msi(s)) => p.install_tile(t, *s),
            (Self::Ackwise(p), TileProtoState::Msi(s)) => p.inner_mut().install_tile(t, *s),
            _ => panic!("migrated tile state does not match the shard's protocol"),
        }
    }
}

impl Coherence for ProtocolDispatch {
    #[inline]
    fn core_access(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        op: MemOp,
        spec_ok: bool,
        ctx: &mut ProtoCtx,
    ) -> AccessOutcome {
        for_each_protocol!(self, p => p.core_access(core, addr, op, spec_ok, ctx))
    }

    #[inline]
    fn on_message(&mut self, msg: Message, ctx: &mut ProtoCtx) {
        for_each_protocol!(self, p => p.on_message(msg, ctx))
    }

    #[inline]
    fn spin_hint(&mut self, core: CoreId, addr: LineAddr, ctx: &mut ProtoCtx) -> SpinHint {
        for_each_protocol!(self, p => p.spin_hint(core, addr, ctx))
    }

    #[inline]
    fn probe(&self, core: CoreId, addr: LineAddr) -> Probe {
        for_each_protocol!(self, p => p.probe(core, addr))
    }

    #[inline]
    fn commit_check(
        &mut self,
        core: CoreId,
        addr: LineAddr,
        early: bool,
        bound: u64,
    ) -> Option<Ts> {
        for_each_protocol!(self, p => p.commit_check(core, addr, early, bound))
    }

    fn llc_storage_bits(&self, n_cores: u32) -> u64 {
        for_each_protocol!(self, p => p.llc_storage_bits(n_cores))
    }

    fn l1_storage_bits(&self) -> u64 {
        for_each_protocol!(self, p => p.l1_storage_bits())
    }

    fn name(&self) -> &'static str {
        for_each_protocol!(self, p => p.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructs_the_configured_protocol() {
        for kind in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            let cfg = SystemConfig { protocol: kind, ..SystemConfig::default() };
            let d = ProtocolDispatch::new(&cfg);
            assert_eq!(d.kind(), kind);
            assert_eq!(d.name(), kind.name());
        }
    }

    #[test]
    fn dispatch_matches_direct_protocol_calls() {
        let cfg = SystemConfig { protocol: ProtocolKind::Tardis, ..SystemConfig::default() };
        let enum_proto = ProtocolDispatch::new(&cfg);
        let direct = Tardis::new(&cfg);
        assert_eq!(enum_proto.llc_storage_bits(64), direct.llc_storage_bits(64));
        assert_eq!(enum_proto.l1_storage_bits(), direct.l1_storage_bits());
        assert_eq!(enum_proto.probe(0, 0), direct.probe(0, 0));
    }
}
