//! The 12 SPLASH-2-signature synthetic workloads (DESIGN.md
//! substitution #2).  Each parameter vector reproduces the
//! coherence-relevant behaviour the paper reports for that benchmark:
//! sharing degree and pattern, read/write mix, lock/barrier density,
//! spinning intensity, and L1-resident vs capacity-missing working
//! sets.  The paper's Table VI timestamp statistics guided the tuning:
//! e.g., FFT's pts growth is 88.5% self-increment (almost no shared
//! writes), while LU-NC's is 0.1% (constant fine-grained sharing).

use crate::trace::TraceParams;

/// A named workload.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    pub name: &'static str,
    pub params: TraceParams,
}

/// All 12 benchmarks, in the paper's figure order.
pub fn all() -> Vec<WorkloadSpec> {
    vec![
        // FMM: force computation on mostly-private bodies; lock-guarded
        // cell updates; spin-heavy synchronization (paper: perf drops
        // with large self-inc period).
        WorkloadSpec {
            name: "fmm",
            params: TraceParams {
                seed: 101,
                pattern: 0,
                priv_lines: 1024,
                shared_lines: 512,
                pct_shared: 150,
                pct_write_shared: 20,
                pct_write_priv: 300,
                sync_kind: 3,
                sync_period: 320,
                crit_len: 4,
                n_locks: 128,
                compute_gap_max: 6,
                barrier_period: 1024,
                ..TraceParams::default()
            },
        },
        // BARNES: tree walks — read-shared tree nodes, lock-guarded
        // updates of a smaller hot set.
        WorkloadSpec {
            name: "barnes",
            params: TraceParams {
                seed: 102,
                pattern: 0,
                priv_lines: 768,
                shared_lines: 1024,
                pct_shared: 300,
                pct_write_shared: 15,
                pct_write_priv: 300,
                sync_kind: 3,
                sync_period: 384,
                crit_len: 3,
                n_locks: 128,
                compute_gap_max: 4,
                barrier_period: 1024,
                ..TraceParams::default()
            },
        },
        // CHOLESKY: task-queue locks, frequent small critical sections,
        // heavy spinning (paper: period=1000 hurts badly).
        WorkloadSpec {
            name: "cholesky",
            params: TraceParams {
                seed: 103,
                pattern: 4,
                priv_lines: 512,
                shared_lines: 768,
                pct_shared: 250,
                pct_write_shared: 80,
                pct_write_priv: 300,
                sync_kind: 1,
                sync_period: 160,
                crit_len: 4,
                n_locks: 8,
                compute_gap_max: 3,
                ..TraceParams::default()
            },
        },
        // VOLREND: ray casting over a read-shared volume + task
        // stealing locks; the paper's renewal outlier (65.8% of LLC
        // requests are renewals).
        WorkloadSpec {
            name: "volrend",
            params: TraceParams {
                seed: 104,
                pattern: 4,
                priv_lines: 256,
                shared_lines: 2048,
                pct_shared: 450,
                pct_write_shared: 0,
                pct_write_priv: 250,
                sync_kind: 3,
                sync_period: 256,
                crit_len: 2,
                n_locks: 64,
                compute_gap_max: 2,
                barrier_period: 640,
                ..TraceParams::default()
            },
        },
        // OCEAN-CONTIGUOUS: grid stencil, barrier-phased, large working
        // set (capacity misses), little locking.
        WorkloadSpec {
            name: "ocean-c",
            params: TraceParams {
                seed: 105,
                pattern: 3,
                priv_lines: 2048,
                shared_lines: 4096,
                pct_shared: 350,
                pct_write_shared: 120,
                pct_write_priv: 400,
                sync_kind: 2,
                grid_dim: 64,
                compute_gap_max: 2,
                barrier_period: 256,
                ..TraceParams::default()
            },
        },
        // OCEAN-NON-CONTIGUOUS: same but worse locality (wider stencil
        // rows / more remote neighbors).
        WorkloadSpec {
            name: "ocean-nc",
            params: TraceParams {
                seed: 106,
                pattern: 3,
                priv_lines: 2048,
                shared_lines: 8192,
                pct_shared: 400,
                pct_write_shared: 140,
                pct_write_priv: 400,
                sync_kind: 2,
                grid_dim: 32,
                compute_gap_max: 2,
                barrier_period: 256,
                ..TraceParams::default()
            },
        },
        // FFT: all-to-all butterfly over strided addresses between
        // barrier phases; tiny shared-write rate (paper: 88.5% of pts
        // growth is self-increment).
        WorkloadSpec {
            name: "fft",
            params: TraceParams {
                seed: 107,
                pattern: 1,
                priv_lines: 1536,
                shared_lines: 4096,
                pct_shared: 200,
                pct_write_shared: 40,
                pct_write_priv: 350,
                sync_kind: 2,
                stride: 17,
                compute_gap_max: 5,
                barrier_period: 512,
                ..TraceParams::default()
            },
        },
        // RADIX: permutation writes to a shared array, barrier-phased
        // (paper: 59.3% self-increment).
        WorkloadSpec {
            name: "radix",
            params: TraceParams {
                seed: 108,
                pattern: 1,
                priv_lines: 1024,
                shared_lines: 4096,
                pct_shared: 250,
                pct_write_shared: 80,
                pct_write_priv: 300,
                sync_kind: 2,
                stride: 31,
                compute_gap_max: 3,
                barrier_period: 512,
                ..TraceParams::default()
            },
        },
        // LU-CONTIGUOUS: blocked factorization — each core writes its
        // own blocks, reads others'; few barriers.
        WorkloadSpec {
            name: "lu-c",
            params: TraceParams {
                seed: 109,
                pattern: 2,
                priv_lines: 1024,
                shared_lines: 2048,
                pct_shared: 300,
                pct_write_shared: 30,
                pct_write_priv: 350,
                sync_kind: 2,
                compute_gap_max: 4,
                barrier_period: 1024,
                ..TraceParams::default()
            },
        },
        // LU-NON-CONTIGUOUS: fine-grained interleaved sharing — lots of
        // read-write shared lines (paper: pts grows every 61 cycles,
        // 0.1% self-increment).
        WorkloadSpec {
            name: "lu-nc",
            params: TraceParams {
                seed: 110,
                pattern: 0,
                priv_lines: 512,
                shared_lines: 512,
                pct_shared: 550,
                pct_write_shared: 250,
                pct_write_priv: 300,
                sync_kind: 2,
                compute_gap_max: 2,
                barrier_period: 1024,
                ..TraceParams::default()
            },
        },
        // WATER-NSQUARED: O(n^2) pairwise forces, lock-guarded
        // accumulation into shared molecules.
        WorkloadSpec {
            name: "water-nsq",
            params: TraceParams {
                seed: 111,
                pattern: 0,
                priv_lines: 768,
                shared_lines: 1024,
                pct_shared: 350,
                pct_write_shared: 60,
                pct_write_priv: 300,
                sync_kind: 3,
                sync_period: 320,
                crit_len: 3,
                n_locks: 128,
                compute_gap_max: 4,
                barrier_period: 768,
                ..TraceParams::default()
            },
        },
        // WATER-SPATIAL: cell lists — tiny L1-resident working set and
        // very low miss rate (paper: Tardis 3x traffic on a tiny base).
        WorkloadSpec {
            name: "water-sp",
            params: TraceParams {
                seed: 112,
                pattern: 0,
                priv_lines: 96,
                shared_lines: 128,
                pct_shared: 120,
                pct_write_shared: 5,
                pct_write_priv: 250,
                sync_kind: 2,
                compute_gap_max: 6,
                barrier_period: 1024,
                ..TraceParams::default()
            },
        },
    ]
}

/// Look up a workload by name.
pub fn by_name(name: &str) -> Option<WorkloadSpec> {
    all().into_iter().find(|w| w.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_benchmarks_in_paper_order() {
        let names: Vec<&str> = all().iter().map(|w| w.name).collect();
        assert_eq!(
            names,
            vec![
                "fmm", "barnes", "cholesky", "volrend", "ocean-c", "ocean-nc", "fft", "radix",
                "lu-c", "lu-nc", "water-nsq", "water-sp"
            ]
        );
    }

    #[test]
    fn unique_seeds() {
        let mut seeds: Vec<u32> = all().iter().map(|w| w.params.seed).collect();
        seeds.sort();
        seeds.dedup();
        assert_eq!(seeds.len(), 12);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("fft").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn water_sp_fits_in_l1() {
        // The signature behind its paper-reported low miss rate.
        let w = by_name("water-sp").unwrap();
        assert!(w.params.priv_lines + w.params.shared_lines < 512);
    }

    #[test]
    fn spin_heavy_benchmarks_use_locks() {
        for name in ["fmm", "cholesky", "volrend", "water-nsq", "barnes"] {
            let w = by_name(name).unwrap();
            assert!(w.params.sync_kind & 1 != 0, "{name} should use locks");
        }
    }
}
