//! Simulated programs: the per-core operation streams the cores
//! execute, matching the trace format produced by the AOT tracegen
//! artifacts (python/compile/kernels/spec.py).

pub mod checker;
pub mod litmus;

use crate::types::{CoreId, LineAddr};

/// One program operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Load `addr` after `gap` compute cycles.
    Load { addr: LineAddr, gap: u32 },
    /// Store `value` to `addr` after `gap` compute cycles.  A value of
    /// `None` means "use the core's unique per-op value" (trace stores).
    Store { addr: LineAddr, value: Option<u64>, gap: u32 },
    /// Acquire the test-and-test-and-set spin lock at `addr`.
    Lock { addr: LineAddr },
    /// Release the spin lock at `addr`.
    Unlock { addr: LineAddr },
    /// Sense-reversing global barrier.
    Barrier,
}

impl Op {
    pub fn addr(&self) -> LineAddr {
        match *self {
            Op::Load { addr, .. }
            | Op::Store { addr, .. }
            | Op::Lock { addr }
            | Op::Unlock { addr } => addr,
            Op::Barrier => crate::types::BARRIER_BASE,
        }
    }
}

/// One core's instruction stream.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub ops: Vec<Op>,
}

impl Program {
    pub fn new(ops: Vec<Op>) -> Self {
        Self { ops }
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// A multi-core workload: one program per core.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub programs: Vec<Program>,
}

impl Workload {
    pub fn new(programs: Vec<Program>) -> Self {
        Self { programs }
    }

    pub fn n_cores(&self) -> u32 {
        self.programs.len() as u32
    }

    /// Total operation count across cores.
    pub fn total_ops(&self) -> usize {
        self.programs.iter().map(|p| p.len()).sum()
    }

    /// The unique value written by core `core`'s trace store at `pc`
    /// (distinguishable across all (core, pc) pairs — the SC checker
    /// relies on global uniqueness).
    pub fn store_value(core: CoreId, pc: usize) -> u64 {
        ((core as u64 + 1) << 32) | pc as u64
    }
}

/// Tiny builder DSL used by litmus tests and unit tests.
pub fn load(addr: LineAddr) -> Op {
    Op::Load { addr, gap: 0 }
}

pub fn store(addr: LineAddr, value: u64) -> Op {
    Op::Store { addr, value: Some(value), gap: 0 }
}

pub fn lock(addr: LineAddr) -> Op {
    Op::Lock { addr }
}

pub fn unlock(addr: LineAddr) -> Op {
    Op::Unlock { addr }
}

pub fn barrier() -> Op {
    Op::Barrier
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_values_globally_unique() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for core in 0..8u32 {
            for pc in 0..100usize {
                assert!(seen.insert(Workload::store_value(core, pc)));
            }
        }
    }

    #[test]
    fn op_addr_accessor() {
        assert_eq!(load(5).addr(), 5);
        assert_eq!(store(7, 1).addr(), 7);
        assert_eq!(lock(9).addr(), 9);
        assert_eq!(barrier().addr(), crate::types::BARRIER_BASE);
    }

    #[test]
    fn workload_totals() {
        let w = Workload::new(vec![
            Program::new(vec![load(1), store(2, 0)]),
            Program::new(vec![load(3)]),
        ]);
        assert_eq!(w.n_cores(), 2);
        assert_eq!(w.total_ops(), 3);
    }
}
