//! Sequential-consistency witness checker.
//!
//! The simulator logs every committed memory operation with its
//! physiological key — (logical timestamp, commit cycle, commit
//! sequence).  For Tardis, Definition 1 of the paper says the global
//! memory order *is* the physiological order; for directory protocols
//! (ts = 0 throughout) the key degenerates to physical commit order.
//! SC then reduces to two mechanically checkable rules:
//!
//! * **Rule 1**: each core's keys are non-decreasing in program order.
//! * **Rule 2**: per address, every load observes the value of the
//!   latest write preceding it in the key order.
//!
//! Plus two synchronization invariants: spin-lock acquire/release
//! alternation and balanced barrier episodes.

use std::collections::HashMap;

use crate::types::{CoreId, Cycle, LineAddr, Ts};

/// One committed memory operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LogRecord {
    pub core: CoreId,
    /// Program counter of the trace op this access implements (sync
    /// microcode reuses the surrounding op's pc).
    pub pc: u32,
    pub addr: LineAddr,
    /// Loaded / atomic-old value (None for plain stores).
    pub value_read: Option<u64>,
    /// Stored value (None for loads).
    pub value_written: Option<u64>,
    /// Logical timestamp (0 under directory protocols).
    pub ts: Ts,
    pub commit_cycle: Cycle,
    /// Global commit order (state-mutation order inside the engine).
    pub seq: u64,
    /// False for records squashed by a speculation rollback (the core
    /// re-executed them; checks skip squashed records).
    pub valid: bool,
}

impl LogRecord {
    /// Physiological key (Definition 1): logical time, tie-broken by
    /// physical time.
    pub fn key(&self) -> (Ts, Cycle, u64) {
        (self.ts, self.commit_cycle, self.seq)
    }
}

/// Growable access log, one per simulation when checking is enabled.
#[derive(Debug, Default)]
pub struct AccessLog {
    pub records: Vec<LogRecord>,
}

impl AccessLog {
    pub fn push(&mut self, r: LogRecord) -> usize {
        self.records.push(r);
        self.records.len() - 1
    }

    /// Rewrite a speculated load's outcome after a failed renewal (the
    /// core re-executes; the committed value is the corrected one).
    pub fn fix_speculation(&mut self, idx: usize, value: u64, ts: Ts, cycle: Cycle, seq: u64) {
        let r = &mut self.records[idx];
        r.value_read = Some(value);
        r.ts = ts;
        r.commit_cycle = cycle;
        r.seq = seq;
    }

    /// Squash a record: it belonged to a rolled-back speculation window
    /// and the core re-executed the operation.
    pub fn squash(&mut self, idx: usize) {
        self.records[idx].valid = false;
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

/// A detected consistency violation.
#[derive(Debug, Clone, PartialEq)]
pub enum Violation {
    /// Rule 1: a core's timestamps went backwards.
    ProgramOrder { core: CoreId, at_seq: u64 },
    /// Rule 2: a load saw a value other than the latest preceding
    /// write in the physiological order.
    StaleRead { core: CoreId, addr: LineAddr, expected: u64, got: u64, at_seq: u64 },
    /// Two successful lock acquires without an intervening release.
    LockOverlap { addr: LineAddr, first: CoreId, second: CoreId },
}

/// Summary of a clean check.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CheckReport {
    pub records: usize,
    pub addresses: usize,
    pub loads_checked: usize,
}

/// Run all checks over a log.  Lock alternation runs before value
/// order: overlapping lock acquires always also manifest as a stale
/// read of the lock word, and the more specific violation is the
/// useful diagnosis.
pub fn check(log: &AccessLog) -> Result<CheckReport, Violation> {
    check_program_order(log)?;
    check_lock_alternation(log)?;
    check_value_order(log)
}

/// Rule 1: per-core monotonic physiological keys in program order
/// (records are appended in commit order, which equals program order
/// per core).
fn check_program_order(log: &AccessLog) -> Result<(), Violation> {
    let mut last: HashMap<CoreId, (Ts, Cycle, u64)> = HashMap::new();
    for r in log.records.iter().filter(|r| r.valid) {
        let key = r.key();
        if let Some(prev) = last.get(&r.core) {
            if key < *prev {
                return Err(Violation::ProgramOrder { core: r.core, at_seq: r.seq });
            }
        }
        last.insert(r.core, key);
    }
    Ok(())
}

/// Rule 2: sort per address by physiological key; each read must see
/// the preceding write's value (memory starts zeroed).
fn check_value_order(log: &AccessLog) -> Result<CheckReport, Violation> {
    let mut by_addr: HashMap<LineAddr, Vec<&LogRecord>> = HashMap::new();
    for r in log.records.iter().filter(|r| r.valid) {
        by_addr.entry(r.addr).or_default().push(r);
    }
    let mut loads_checked = 0;
    for (addr, mut recs) in by_addr.iter_mut().map(|(a, v)| (*a, std::mem::take(v))) {
        recs.sort_by_key(|r| r.key());
        let mut current: u64 = 0;
        for r in recs {
            if let Some(read) = r.value_read {
                if read != current {
                    return Err(Violation::StaleRead {
                        core: r.core,
                        addr,
                        expected: current,
                        got: read,
                        at_seq: r.seq,
                    });
                }
                loads_checked += 1;
            }
            if let Some(written) = r.value_written {
                current = written;
            }
        }
    }
    Ok(CheckReport {
        records: log.records.len(),
        addresses: by_addr.len(),
        loads_checked,
    })
}

/// Mutual exclusion: per lock word, successful test-and-set acquires
/// (old 0 -> 1) and releases (store 0) must alternate in physical
/// commit order.
fn check_lock_alternation(log: &AccessLog) -> Result<(), Violation> {
    use crate::types::{region_of, Region};
    let mut holder: HashMap<LineAddr, CoreId> = HashMap::new();
    let mut recs: Vec<&LogRecord> = log
        .records
        .iter()
        .filter(|r| r.valid && region_of(r.addr) == Region::Lock)
        .collect();
    recs.sort_by_key(|r| (r.commit_cycle, r.seq));
    for r in recs {
        let acquired = r.value_read == Some(0) && r.value_written == Some(1);
        let released = r.value_read.is_none() && r.value_written == Some(0);
        if acquired {
            if let Some(&h) = holder.get(&r.addr) {
                return Err(Violation::LockOverlap { addr: r.addr, first: h, second: r.core });
            }
            holder.insert(r.addr, r.core);
        } else if released {
            holder.remove(&r.addr);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::LOCK_BASE;

    fn rec(core: CoreId, addr: LineAddr, rd: Option<u64>, wr: Option<u64>, ts: Ts, cyc: Cycle, seq: u64) -> LogRecord {
        LogRecord { core, pc: 0, addr, value_read: rd, value_written: wr, ts, commit_cycle: cyc, seq, valid: true }
    }

    #[test]
    fn clean_log_passes() {
        let mut log = AccessLog::default();
        log.push(rec(0, 1, None, Some(7), 1, 10, 1));
        log.push(rec(1, 1, Some(7), None, 2, 20, 2));
        let r = check(&log).unwrap();
        assert_eq!(r.loads_checked, 1);
    }

    #[test]
    fn initial_zero_read_ok() {
        let mut log = AccessLog::default();
        log.push(rec(0, 5, Some(0), None, 0, 1, 1));
        assert!(check(&log).is_ok());
    }

    #[test]
    fn stale_read_detected() {
        let mut log = AccessLog::default();
        log.push(rec(0, 1, None, Some(7), 1, 10, 1));
        // Load logically AFTER the store (ts 2) but saw the old value.
        log.push(rec(1, 1, Some(0), None, 2, 20, 2));
        assert!(matches!(check(&log), Err(Violation::StaleRead { .. })));
    }

    #[test]
    fn old_value_at_earlier_timestamp_is_legal() {
        // The Tardis signature: a load at a SMALLER logical time may
        // read the old value even if it commits later in physical time.
        let mut log = AccessLog::default();
        log.push(rec(0, 1, None, Some(7), 10, 5, 1));
        log.push(rec(1, 1, Some(0), None, 3, 50, 2)); // physically later, logically earlier
        assert!(check(&log).is_ok());
    }

    #[test]
    fn program_order_violation_detected() {
        let mut log = AccessLog::default();
        log.push(rec(0, 1, Some(0), None, 5, 10, 1));
        log.push(rec(0, 2, Some(0), None, 3, 11, 2)); // ts went backwards
        assert!(matches!(check(&log), Err(Violation::ProgramOrder { core: 0, .. })));
    }

    #[test]
    fn atomic_read_and_write_both_checked() {
        let mut log = AccessLog::default();
        log.push(rec(0, 1, None, Some(5), 1, 1, 1));
        log.push(rec(1, 1, Some(5), Some(6), 2, 2, 2)); // atomic sees 5, writes 6
        log.push(rec(0, 1, Some(6), None, 3, 3, 3));
        assert!(check(&log).is_ok());
    }

    #[test]
    fn lock_overlap_detected() {
        let l = LOCK_BASE + 1;
        let mut log = AccessLog::default();
        log.push(rec(0, l, Some(0), Some(1), 1, 1, 1)); // core 0 acquires
        log.push(rec(1, l, Some(0), Some(1), 2, 2, 2)); // core 1 also "acquires"
        assert!(matches!(check(&log), Err(Violation::LockOverlap { .. })));
    }

    #[test]
    fn lock_alternation_clean() {
        let l = LOCK_BASE;
        let mut log = AccessLog::default();
        log.push(rec(0, l, Some(0), Some(1), 1, 1, 1));
        log.push(rec(0, l, None, Some(0), 2, 2, 2)); // release
        log.push(rec(1, l, Some(0), Some(1), 3, 3, 3));
        assert!(check(&log).is_ok());
    }

    #[test]
    fn speculation_fixup_rewrites_record() {
        let mut log = AccessLog::default();
        let idx = log.push(rec(0, 1, Some(0), None, 1, 1, 1));
        log.push(rec(1, 1, None, Some(9), 2, 2, 2));
        log.fix_speculation(idx, 9, 3, 5, 3);
        assert!(check(&log).is_ok());
        assert_eq!(log.records[idx].value_read, Some(9));
    }
}
