//! Consistency witness checker (SC and TSO).
//!
//! The simulator logs every committed memory operation with its
//! physiological key — (logical timestamp, commit cycle, commit
//! sequence).  For Tardis, Definition 1 of the paper says the global
//! memory order *is* the physiological order; for directory protocols
//! (ts = 0 throughout) the key degenerates to physical commit order.
//! SC then reduces to two mechanically checkable rules:
//!
//! * **Rule 1**: each core's keys are non-decreasing in program order.
//! * **Rule 2**: per address, every load observes the value of the
//!   latest write preceding it in the key order.
//!
//! Plus two synchronization invariants: spin-lock acquire/release
//! alternation and balanced barrier episodes.
//!
//! Under [`Consistency::Tso`] the rules relax exactly where TSO does
//! (Tardis 2.0 §5; cf. the lazy-coherence-vs-weak-memory verification
//! of arXiv:1705.08262 — the checker must evolve with the model):
//!
//! * Rule 1 splits per access type: load→load and store→store order
//!   are preserved (each type's keys are non-decreasing in commit
//!   order, which equals its program order), a store's key must
//!   dominate every *program-order-earlier* load (load→store), and
//!   atomics fence everything — but a load may carry a key *smaller*
//!   than a program-order-earlier store's (the store-buffer
//!   reordering TSO permits).
//! * Loads served by store-to-load forwarding (`forwarded`) are
//!   exempt from the global key order; instead each must observe its
//!   own core's latest program-order-earlier store to that address.

use std::collections::HashMap;

use crate::config::Consistency;
use crate::types::{CoreId, Cycle, LineAddr, Ts};

/// One committed memory operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LogRecord {
    pub core: CoreId,
    /// Program counter of the trace op this access implements (sync
    /// microcode reuses the surrounding op's pc).
    pub pc: u32,
    pub addr: LineAddr,
    /// Loaded / atomic-old value (None for plain stores).
    pub value_read: Option<u64>,
    /// Stored value (None for loads).
    pub value_written: Option<u64>,
    /// Logical timestamp (0 under directory protocols).
    pub ts: Ts,
    pub commit_cycle: Cycle,
    /// Global commit order (state-mutation order inside the engine).
    pub seq: u64,
    /// False for records squashed by a speculation rollback (the core
    /// re-executed them; checks skip squashed records).
    pub valid: bool,
    /// The load was served by store-to-load forwarding from the core's
    /// own store buffer (TSO): its value never touched the coherence
    /// substrate, so it is checked against program order instead of
    /// the global key order.  Always false under SC.
    pub forwarded: bool,
}

impl LogRecord {
    /// Physiological key (Definition 1): logical time, tie-broken by
    /// physical time.
    pub fn key(&self) -> (Ts, Cycle, u64) {
        (self.ts, self.commit_cycle, self.seq)
    }
}

/// Growable access log, one per simulation when checking is enabled.
/// `Clone` exists for the `verif` model checker, which forks a log per
/// explored interleaving.
#[derive(Debug, Clone, Default)]
pub struct AccessLog {
    pub records: Vec<LogRecord>,
}

impl AccessLog {
    pub fn push(&mut self, r: LogRecord) -> usize {
        self.records.push(r);
        self.records.len() - 1
    }

    /// Rewrite a speculated load's outcome after a failed renewal (the
    /// core re-executes; the committed value is the corrected one).
    pub fn fix_speculation(&mut self, idx: usize, value: u64, ts: Ts, cycle: Cycle, seq: u64) {
        let r = &mut self.records[idx];
        r.value_read = Some(value);
        r.ts = ts;
        r.commit_cycle = cycle;
        r.seq = seq;
    }

    /// Squash a record: it belonged to a rolled-back speculation window
    /// and the core re-executed the operation.
    pub fn squash(&mut self, idx: usize) {
        self.records[idx].valid = false;
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

/// A detected consistency violation.  Each variant carries its
/// witness — the pc / physiological-key pair and the forbidden edge —
/// so a model-checker counterexample is actionable without re-running
/// the log by hand.
#[derive(Debug, Clone, PartialEq)]
pub enum Violation {
    /// Rule 1: a core's keys went backwards along `edge` — the
    /// offending record (`pc`, `key`) sits below the `prev_key` it had
    /// to dominate.
    ProgramOrder {
        core: CoreId,
        at_seq: u64,
        pc: u32,
        key: (Ts, Cycle, u64),
        prev_key: (Ts, Cycle, u64),
        /// Which preserved order broke: "program-order" (SC),
        /// "load-load", "store-store", "load-store", or
        /// "atomic-fence" (TSO).
        edge: &'static str,
    },
    /// Rule 2: a load saw a value other than the latest preceding
    /// write in the physiological order.
    StaleRead {
        core: CoreId,
        addr: LineAddr,
        expected: u64,
        got: u64,
        at_seq: u64,
        pc: u32,
        /// The load's physiological key — where in the global order it
        /// observed the stale value.
        key: (Ts, Cycle, u64),
    },
    /// Two successful lock acquires without an intervening release.
    LockOverlap {
        addr: LineAddr,
        first: CoreId,
        second: CoreId,
        /// Commit cycle of the overlapping (second) acquire.
        at_cycle: Cycle,
        at_seq: u64,
    },
    /// TSO: a forwarded load did not observe its own core's latest
    /// program-order-earlier store to that address.
    BadForward {
        core: CoreId,
        addr: LineAddr,
        got: u64,
        expected: Option<u64>,
        at_seq: u64,
        pc: u32,
    },
}

/// Summary of a clean check.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CheckReport {
    pub records: usize,
    pub addresses: usize,
    pub loads_checked: usize,
}

/// Run all checks over a log.  Lock alternation runs before value
/// order: overlapping lock acquires always also manifest as a stale
/// read of the lock word, and the more specific violation is the
/// useful diagnosis.
pub fn check(log: &AccessLog) -> Result<CheckReport, Violation> {
    check_program_order(log)?;
    check_lock_alternation(log)?;
    check_value_order(log)
}

/// Run the checks appropriate to the consistency model the run was
/// configured with (module docs describe the TSO relaxations).
pub fn check_model(log: &AccessLog, model: Consistency) -> Result<CheckReport, Violation> {
    match model {
        Consistency::Sc => check(log),
        Consistency::Tso => {
            check_tso_program_order(log)?;
            check_tso_forwarding(log)?;
            check_lock_alternation(log)?;
            check_value_order(log)
        }
    }
}

/// TSO Rule 1: per core, load keys and store keys are each
/// non-decreasing in commit order (loads execute / stores drain in
/// program order, so commit order per type *is* program order); every
/// store's key dominates all program-order-earlier loads (found via
/// the records' pc); atomics fence everything before them.  The one
/// order deliberately *not* required is store→load — that is the
/// store-buffer relaxation.  Forwarded loads are exempt (validated by
/// [`check_tso_forwarding`]).
fn check_tso_program_order(log: &AccessLog) -> Result<(), Violation> {
    #[derive(Default)]
    struct CoreState {
        last_load: (Ts, Cycle, u64),
        last_store: (Ts, Cycle, u64),
        /// (pc, running max load key) in arrival order; pc
        /// non-decreasing, so the prefix max for "loads earlier than
        /// pc" is a binary search away.
        loads: Vec<(u32, (Ts, Cycle, u64))>,
        max_key: (Ts, Cycle, u64),
    }
    let mut cores: HashMap<CoreId, CoreState> = HashMap::new();
    for r in log.records.iter().filter(|r| r.valid && !r.forwarded) {
        let key = r.key();
        let st = cores.entry(r.core).or_default();
        let is_load = r.value_read.is_some();
        let is_store = r.value_written.is_some();
        let fail = |prev_key: (Ts, Cycle, u64), edge: &'static str| Violation::ProgramOrder {
            core: r.core,
            at_seq: r.seq,
            pc: r.pc,
            key,
            prev_key,
            edge,
        };
        match (is_load, is_store) {
            // Atomic: a full fence — nothing may pass it either way.
            (true, true) => {
                if key < st.max_key {
                    return Err(fail(st.max_key, "atomic-fence"));
                }
                st.last_load = key;
                st.last_store = key;
                push_load(&mut st.loads, r.pc, key);
            }
            (true, false) => {
                if key < st.last_load {
                    return Err(fail(st.last_load, "load-load"));
                }
                st.last_load = key;
                push_load(&mut st.loads, r.pc, key);
            }
            (false, true) => {
                if key < st.last_store {
                    return Err(fail(st.last_store, "store-store"));
                }
                // Load→store order: the store may not slip under any
                // load that precedes it in *program* order.
                let earlier = st.loads.partition_point(|&(pc, _)| pc < r.pc);
                if earlier > 0 && key < st.loads[earlier - 1].1 {
                    return Err(fail(st.loads[earlier - 1].1, "load-store"));
                }
                st.last_store = key;
            }
            (false, false) => {} // no observable value: nothing to order
        }
        st.max_key = st.max_key.max(key);
    }
    Ok(())
}

/// Append a load to the per-core (pc, prefix-max key) index.  pcs are
/// clamped monotone so `partition_point` stays valid even if a
/// rollback replays an earlier pc.
fn push_load(loads: &mut Vec<(u32, (Ts, Cycle, u64))>, pc: u32, key: (Ts, Cycle, u64)) {
    let (last_pc, last_max) = loads.last().copied().unwrap_or((0, (0, 0, 0)));
    loads.push((pc.max(last_pc), key.max(last_max)));
}

/// TSO forwarding rule: walking each core's records in program order
/// (pc, tie-broken by commit sequence), every forwarded load observes
/// the latest value its own core wrote to that address.
fn check_tso_forwarding(log: &AccessLog) -> Result<(), Violation> {
    let mut by_core: HashMap<CoreId, Vec<&LogRecord>> = HashMap::new();
    for r in log.records.iter().filter(|r| r.valid) {
        by_core.entry(r.core).or_default().push(r);
    }
    for (core, mut recs) in by_core {
        recs.sort_by_key(|r| (r.pc, r.seq));
        let mut written: HashMap<LineAddr, u64> = HashMap::new();
        for r in recs {
            if r.forwarded {
                let got = r.value_read.unwrap_or(0);
                let expected = written.get(&r.addr).copied();
                if expected != Some(got) {
                    return Err(Violation::BadForward {
                        core,
                        addr: r.addr,
                        got,
                        expected,
                        at_seq: r.seq,
                        pc: r.pc,
                    });
                }
            }
            if let Some(w) = r.value_written {
                written.insert(r.addr, w);
            }
        }
    }
    Ok(())
}

/// Rule 1: per-core monotonic physiological keys in program order
/// (records are appended in commit order, which equals program order
/// per core).
fn check_program_order(log: &AccessLog) -> Result<(), Violation> {
    let mut last: HashMap<CoreId, (Ts, Cycle, u64)> = HashMap::new();
    for r in log.records.iter().filter(|r| r.valid) {
        let key = r.key();
        if let Some(prev) = last.get(&r.core) {
            if key < *prev {
                return Err(Violation::ProgramOrder {
                    core: r.core,
                    at_seq: r.seq,
                    pc: r.pc,
                    key,
                    prev_key: *prev,
                    edge: "program-order",
                });
            }
        }
        last.insert(r.core, key);
    }
    Ok(())
}

/// Rule 2: sort per address by physiological key; each read must see
/// the preceding write's value (memory starts zeroed).
fn check_value_order(log: &AccessLog) -> Result<CheckReport, Violation> {
    let mut by_addr: HashMap<LineAddr, Vec<&LogRecord>> = HashMap::new();
    for r in log.records.iter().filter(|r| r.valid) {
        by_addr.entry(r.addr).or_default().push(r);
    }
    let mut loads_checked = 0;
    for (addr, mut recs) in by_addr.iter_mut().map(|(a, v)| (*a, std::mem::take(v))) {
        recs.sort_by_key(|r| r.key());
        let mut current: u64 = 0;
        for r in recs {
            // Forwarded loads never touched the coherence substrate;
            // they are validated against program order instead.
            if let Some(read) = r.value_read.filter(|_| !r.forwarded) {
                if read != current {
                    return Err(Violation::StaleRead {
                        core: r.core,
                        addr,
                        expected: current,
                        got: read,
                        at_seq: r.seq,
                        pc: r.pc,
                        key: r.key(),
                    });
                }
                loads_checked += 1;
            }
            if let Some(written) = r.value_written {
                current = written;
            }
        }
    }
    Ok(CheckReport {
        records: log.records.len(),
        addresses: by_addr.len(),
        loads_checked,
    })
}

/// Mutual exclusion: per lock word, successful test-and-set acquires
/// (old 0 -> 1) and releases (store 0) must alternate in physical
/// commit order.
fn check_lock_alternation(log: &AccessLog) -> Result<(), Violation> {
    use crate::types::{region_of, Region};
    let mut holder: HashMap<LineAddr, CoreId> = HashMap::new();
    let mut recs: Vec<&LogRecord> = log
        .records
        .iter()
        .filter(|r| r.valid && region_of(r.addr) == Region::Lock)
        .collect();
    recs.sort_by_key(|r| (r.commit_cycle, r.seq));
    for r in recs {
        let acquired = r.value_read == Some(0) && r.value_written == Some(1);
        let released = r.value_read.is_none() && r.value_written == Some(0);
        if acquired {
            if let Some(&h) = holder.get(&r.addr) {
                return Err(Violation::LockOverlap {
                    addr: r.addr,
                    first: h,
                    second: r.core,
                    at_cycle: r.commit_cycle,
                    at_seq: r.seq,
                });
            }
            holder.insert(r.addr, r.core);
        } else if released {
            holder.remove(&r.addr);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::LOCK_BASE;

    fn rec(core: CoreId, addr: LineAddr, rd: Option<u64>, wr: Option<u64>, ts: Ts, cyc: Cycle, seq: u64) -> LogRecord {
        LogRecord {
            core,
            pc: seq as u32,
            addr,
            value_read: rd,
            value_written: wr,
            ts,
            commit_cycle: cyc,
            seq,
            valid: true,
            forwarded: false,
        }
    }

    /// Same, with an explicit program counter (the TSO checks order by
    /// pc, not arrival).
    fn rec_pc(
        core: CoreId,
        pc: u32,
        addr: LineAddr,
        rd: Option<u64>,
        wr: Option<u64>,
        ts: Ts,
        cyc: Cycle,
        seq: u64,
    ) -> LogRecord {
        LogRecord { pc, ..rec(core, addr, rd, wr, ts, cyc, seq) }
    }

    #[test]
    fn clean_log_passes() {
        let mut log = AccessLog::default();
        log.push(rec(0, 1, None, Some(7), 1, 10, 1));
        log.push(rec(1, 1, Some(7), None, 2, 20, 2));
        let r = check(&log).unwrap();
        assert_eq!(r.loads_checked, 1);
    }

    #[test]
    fn initial_zero_read_ok() {
        let mut log = AccessLog::default();
        log.push(rec(0, 5, Some(0), None, 0, 1, 1));
        assert!(check(&log).is_ok());
    }

    #[test]
    fn stale_read_detected() {
        let mut log = AccessLog::default();
        log.push(rec(0, 1, None, Some(7), 1, 10, 1));
        // Load logically AFTER the store (ts 2) but saw the old value.
        log.push(rec(1, 1, Some(0), None, 2, 20, 2));
        assert!(matches!(check(&log), Err(Violation::StaleRead { .. })));
    }

    #[test]
    fn old_value_at_earlier_timestamp_is_legal() {
        // The Tardis signature: a load at a SMALLER logical time may
        // read the old value even if it commits later in physical time.
        let mut log = AccessLog::default();
        log.push(rec(0, 1, None, Some(7), 10, 5, 1));
        log.push(rec(1, 1, Some(0), None, 3, 50, 2)); // physically later, logically earlier
        assert!(check(&log).is_ok());
    }

    #[test]
    fn program_order_violation_detected() {
        let mut log = AccessLog::default();
        log.push(rec(0, 1, Some(0), None, 5, 10, 1));
        log.push(rec(0, 2, Some(0), None, 3, 11, 2)); // ts went backwards
        assert!(matches!(check(&log), Err(Violation::ProgramOrder { core: 0, .. })));
    }

    #[test]
    fn atomic_read_and_write_both_checked() {
        let mut log = AccessLog::default();
        log.push(rec(0, 1, None, Some(5), 1, 1, 1));
        log.push(rec(1, 1, Some(5), Some(6), 2, 2, 2)); // atomic sees 5, writes 6
        log.push(rec(0, 1, Some(6), None, 3, 3, 3));
        assert!(check(&log).is_ok());
    }

    #[test]
    fn lock_overlap_detected() {
        let l = LOCK_BASE + 1;
        let mut log = AccessLog::default();
        log.push(rec(0, l, Some(0), Some(1), 1, 1, 1)); // core 0 acquires
        log.push(rec(1, l, Some(0), Some(1), 2, 2, 2)); // core 1 also "acquires"
        assert!(matches!(check(&log), Err(Violation::LockOverlap { .. })));
    }

    #[test]
    fn lock_alternation_clean() {
        let l = LOCK_BASE;
        let mut log = AccessLog::default();
        log.push(rec(0, l, Some(0), Some(1), 1, 1, 1));
        log.push(rec(0, l, None, Some(0), 2, 2, 2)); // release
        log.push(rec(1, l, Some(0), Some(1), 3, 3, 3));
        assert!(check(&log).is_ok());
    }

    #[test]
    fn speculation_fixup_rewrites_record() {
        let mut log = AccessLog::default();
        let idx = log.push(rec(0, 1, Some(0), None, 1, 1, 1));
        log.push(rec(1, 1, None, Some(9), 2, 2, 2));
        log.fix_speculation(idx, 9, 3, 5, 3);
        assert!(check(&log).is_ok());
        assert_eq!(log.records[idx].value_read, Some(9));
    }

    // ------------------------------------------------------ TSO rules

    /// The store-buffering execution: each core's store drains *after*
    /// its program-order-later load committed.  SC must reject it once
    /// program order is visible; TSO must accept it.
    fn sb_relaxed_log() -> AccessLog {
        let (a, b) = (1u64, 2u64);
        let mut log = AccessLog::default();
        // Core 0: st A (pc 0) drains late; ld B (pc 1) reads 0 early.
        log.push(rec_pc(0, 1, b, Some(0), None, 1, 5, 1));
        log.push(rec_pc(1, 1, a, Some(0), None, 1, 6, 2));
        log.push(rec_pc(0, 0, a, None, Some(1), 3, 20, 3));
        log.push(rec_pc(1, 0, b, None, Some(1), 3, 21, 4));
        log
    }

    #[test]
    fn tso_accepts_the_store_buffering_relaxation() {
        let log = sb_relaxed_log();
        assert!(check_model(&log, Consistency::Tso).is_ok());
    }

    #[test]
    fn tso_still_requires_store_store_order() {
        let mut log = AccessLog::default();
        log.push(rec_pc(0, 0, 1, None, Some(1), 9, 9, 1));
        // Program-order-later store drains with a smaller key.
        log.push(rec_pc(0, 1, 2, None, Some(1), 3, 10, 2));
        assert!(matches!(
            check_model(&log, Consistency::Tso),
            Err(Violation::ProgramOrder { core: 0, .. })
        ));
    }

    #[test]
    fn tso_still_requires_load_load_order() {
        let mut log = AccessLog::default();
        log.push(rec_pc(0, 0, 1, Some(0), None, 9, 9, 1));
        log.push(rec_pc(0, 1, 2, Some(0), None, 3, 10, 2));
        assert!(matches!(
            check_model(&log, Consistency::Tso),
            Err(Violation::ProgramOrder { core: 0, .. })
        ));
    }

    #[test]
    fn tso_still_requires_load_to_store_order() {
        let mut log = AccessLog::default();
        // Load at pc 0, then a store at pc 1 whose key is *earlier*.
        log.push(rec_pc(0, 0, 1, Some(0), None, 9, 9, 1));
        log.push(rec_pc(0, 1, 2, None, Some(1), 3, 10, 2));
        assert!(matches!(
            check_model(&log, Consistency::Tso),
            Err(Violation::ProgramOrder { core: 0, .. })
        ));
    }

    #[test]
    fn tso_atomics_fence_everything() {
        let mut log = AccessLog::default();
        log.push(rec_pc(0, 0, 1, None, Some(1), 9, 9, 1));
        // An atomic (read + write) with a smaller key than the store.
        log.push(rec_pc(0, 1, 2, Some(0), Some(1), 3, 10, 2));
        assert!(matches!(
            check_model(&log, Consistency::Tso),
            Err(Violation::ProgramOrder { core: 0, .. })
        ));
    }

    #[test]
    fn forwarded_load_must_match_own_store() {
        let mut log = AccessLog::default();
        let mut fwd = rec_pc(0, 1, 1, Some(7), None, 0, 2, 1);
        fwd.forwarded = true;
        log.push(fwd);
        // The store it forwarded from drains later but sits earlier in
        // program order (pc 0).
        log.push(rec_pc(0, 0, 1, None, Some(7), 5, 9, 2));
        assert!(check_model(&log, Consistency::Tso).is_ok());

        // A forwarded value with no matching earlier store is flagged.
        let mut bad = AccessLog::default();
        let mut fwd = rec_pc(0, 1, 1, Some(7), None, 0, 2, 1);
        fwd.forwarded = true;
        bad.push(fwd);
        assert!(matches!(
            check_model(&bad, Consistency::Tso),
            Err(Violation::BadForward { core: 0, got: 7, expected: None, .. })
        ));
    }

    #[test]
    fn forwarded_loads_are_exempt_from_global_value_order() {
        let mut log = AccessLog::default();
        // Another core owns the line's global history...
        log.push(rec_pc(1, 0, 1, None, Some(99), 1, 1, 1));
        // ...while core 0 forwards its own (not yet drained) store.
        let mut fwd = rec_pc(0, 1, 1, Some(7), None, 2, 2, 2);
        fwd.forwarded = true;
        log.push(fwd);
        log.push(rec_pc(0, 0, 1, None, Some(7), 5, 9, 3));
        assert!(check_model(&log, Consistency::Tso).is_ok());
    }

    #[test]
    fn violations_carry_their_witness() {
        // SC program order: both keys, the pc, and the edge name.
        let mut log = AccessLog::default();
        log.push(rec(0, 1, Some(0), None, 5, 10, 1));
        log.push(rec(0, 2, Some(0), None, 3, 11, 2));
        match check(&log) {
            Err(Violation::ProgramOrder { key, prev_key, edge, pc, .. }) => {
                assert_eq!(prev_key, (5, 10, 1));
                assert_eq!(key, (3, 11, 2));
                assert_eq!(edge, "program-order");
                assert_eq!(pc, 2);
            }
            other => panic!("expected ProgramOrder, got {other:?}"),
        }
        // TSO names the specific forbidden edge.
        let mut log = AccessLog::default();
        log.push(rec_pc(0, 0, 1, None, Some(1), 9, 9, 1));
        log.push(rec_pc(0, 1, 2, None, Some(1), 3, 10, 2));
        match check_model(&log, Consistency::Tso) {
            Err(Violation::ProgramOrder { edge, prev_key, .. }) => {
                assert_eq!(edge, "store-store");
                assert_eq!(prev_key, (9, 9, 1));
            }
            other => panic!("expected ProgramOrder, got {other:?}"),
        }
        // Stale reads carry the observing load's key.
        let mut log = AccessLog::default();
        log.push(rec(0, 1, None, Some(7), 1, 10, 1));
        log.push(rec(1, 1, Some(0), None, 2, 20, 2));
        match check(&log) {
            Err(Violation::StaleRead { key, expected, got, .. }) => {
                assert_eq!(key, (2, 20, 2));
                assert_eq!((expected, got), (7, 0));
            }
            other => panic!("expected StaleRead, got {other:?}"),
        }
    }

    #[test]
    fn check_model_sc_matches_plain_check() {
        let mut log = AccessLog::default();
        log.push(rec(0, 1, None, Some(7), 1, 10, 1));
        log.push(rec(1, 1, Some(7), None, 2, 20, 2));
        assert_eq!(check(&log), check_model(&log, Consistency::Sc));
    }
}
