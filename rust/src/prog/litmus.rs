//! Litmus tests: small multi-core programs with enumerated
//! SC-allowed outcomes.  Includes the paper's Listing 1 (store
//! buffering — the A=B=0 outcome Tardis must forbid, §III-C3/§III-D2)
//! and the §V case-study program (Listing 2).
//!
//! Each test also carries its TSO verdict (`allowed_tso`).  The two
//! models split exactly once in this suite: **SB** admits the relaxed
//! r0 = r1 = 0 outcome under TSO (store buffers delay the stores past
//! the loads) while SC forbids it.  Everything else — MP, LB, CO, and
//! notably **IRIW** — keeps its SC verdict: TSO is multi-copy atomic,
//! so two readers may never disagree on the order of independent
//! writes even though each writer's own store buffer reorders
//! store→load.

use super::{load, store, Op, Program, Workload};
use crate::config::Consistency;
use crate::types::{LineAddr, SHARED_BASE};

/// Addresses used by the litmus programs (distinct shared lines).
pub const A: LineAddr = SHARED_BASE + 0x10;
pub const B: LineAddr = SHARED_BASE + 0x21;
pub const F: LineAddr = SHARED_BASE + 0x32;

/// A named litmus test: programs plus per-model predicates over the
/// observed load values (keyed by (core, pc)) deciding whether an
/// outcome is legal.
pub struct Litmus {
    pub name: &'static str,
    pub workload: Workload,
    /// The (core, pc) pairs whose loaded values form the outcome tuple.
    pub observed: Vec<(u32, u32)>,
    /// SC-legality of an outcome tuple (same order as `observed`).
    pub allowed: fn(&[u64]) -> bool,
    /// TSO-legality of an outcome tuple.
    pub allowed_tso: fn(&[u64]) -> bool,
}

impl Litmus {
    /// A test whose verdict is the same under SC and TSO (everything
    /// here except SB — TSO relaxes only store→load order).
    fn model_independent(
        name: &'static str,
        workload: Workload,
        observed: Vec<(u32, u32)>,
        allowed: fn(&[u64]) -> bool,
    ) -> Self {
        Self { name, workload, observed, allowed, allowed_tso: allowed }
    }

    /// The predicate for a consistency model.
    pub fn allowed_under(&self, model: Consistency) -> fn(&[u64]) -> bool {
        match model {
            Consistency::Sc => self.allowed,
            Consistency::Tso => self.allowed_tso,
        }
    }
}

/// Store buffering (paper Listing 1):
///   C0: A = 1; r0 = B          C1: B = 1; r1 = A
/// SC forbids r0 = r1 = 0; TSO admits it (each store waits in its
/// core's buffer while the other core's load reads the old value).
pub fn store_buffering() -> Litmus {
    Litmus {
        name: "SB",
        workload: Workload::new(vec![
            Program::new(vec![store(A, 1), load(B)]),
            Program::new(vec![store(B, 1), load(A)]),
        ]),
        observed: vec![(0, 1), (1, 1)],
        allowed: |v| !(v[0] == 0 && v[1] == 0),
        allowed_tso: |_| true,
    }
}

/// Message passing:
///   C0: A = 1; F = 1           C1: r0 = F; r1 = A
/// SC forbids r0 = 1 && r1 = 0.
pub fn message_passing() -> Litmus {
    Litmus::model_independent(
        "MP",
        Workload::new(vec![
            Program::new(vec![store(A, 1), store(F, 1)]),
            Program::new(vec![load(F), load(A)]),
        ]),
        vec![(1, 0), (1, 1)],
        |v| !(v[0] == 1 && v[1] == 0),
    )
}

/// Load buffering:
///   C0: r0 = A; B = 1          C1: r1 = B; A = 1
/// SC forbids r0 = r1 = 1.
pub fn load_buffering() -> Litmus {
    Litmus::model_independent(
        "LB",
        Workload::new(vec![
            Program::new(vec![load(A), store(B, 1)]),
            Program::new(vec![load(B), store(A, 1)]),
        ]),
        vec![(0, 0), (1, 0)],
        |v| !(v[0] == 1 && v[1] == 1),
    )
}

/// Independent reads of independent writes (4 cores).
/// SC forbids the two readers disagreeing on the write order:
/// r0=1,r1=0 together with r2=1,r3=0.
pub fn iriw() -> Litmus {
    // TSO is multi-copy atomic: the readers (which never write) still
    // may not disagree on the independent-write order, so the verdict
    // is model-independent.
    Litmus::model_independent(
        "IRIW",
        Workload::new(vec![
            Program::new(vec![store(A, 1)]),
            Program::new(vec![store(B, 1)]),
            Program::new(vec![load(A), load(B)]),
            Program::new(vec![load(B), load(A)]),
        ]),
        vec![(2, 0), (2, 1), (3, 0), (3, 1)],
        // v = [rA@c2, rB@c2, rB@c3, rA@c3]
        |v| !(v[0] == 1 && v[1] == 0 && v[2] == 1 && v[3] == 0),
    )
}

/// Coherence (same-location) test: both readers of one location must
/// agree with some single write order — reading 2-then-1 on one core
/// and 1-then-2 on another is forbidden.
pub fn coherence_co() -> Litmus {
    // Same-location coherence is untouched by store buffering.
    Litmus::model_independent(
        "CO",
        Workload::new(vec![
            Program::new(vec![store(A, 1)]),
            Program::new(vec![store(A, 2)]),
            Program::new(vec![load(A), load(A)]),
            Program::new(vec![load(A), load(A)]),
        ]),
        vec![(2, 0), (2, 1), (3, 0), (3, 1)],
        |v| {
            let fwd = |x: u64, y: u64| !(x == 2 && y == 1);
            let rev = |x: u64, y: u64| !(x == 1 && y == 2);
            // Both readers must be consistent with a single order.
            (fwd(v[0], v[1]) && fwd(v[2], v[3])) || (rev(v[0], v[1]) && rev(v[2], v[3]))
        },
    )
}

/// The §V case-study program (Listing 2):
///   C0: L(B); A=1; L(A); L(B); A=3     C1: nop; B=2; L(A); B=4
/// (the nop is modeled as a 1-cycle gap before B=2).
pub fn case_study() -> Workload {
    Workload::new(vec![
        Program::new(vec![
            load(B),
            store(A, 1),
            load(A),
            load(B),
            store(A, 3),
        ]),
        Program::new(vec![
            Op::Store { addr: B, value: Some(2), gap: 1 },
            load(A),
            store(B, 4),
        ]),
    ])
}

/// All outcome-checked litmus tests.
pub fn all() -> Vec<Litmus> {
    vec![store_buffering(), message_passing(), load_buffering(), iriw(), coherence_co()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sb_forbids_zero_zero() {
        let l = store_buffering();
        assert!(!(l.allowed)(&[0, 0]));
        assert!((l.allowed)(&[1, 0]));
        assert!((l.allowed)(&[0, 1]));
        assert!((l.allowed)(&[1, 1]));
    }

    #[test]
    fn mp_forbids_flag_without_data() {
        let l = message_passing();
        assert!(!(l.allowed)(&[1, 0]));
        assert!((l.allowed)(&[0, 0]));
        assert!((l.allowed)(&[1, 1]));
    }

    #[test]
    fn co_rejects_disagreeing_readers() {
        let l = coherence_co();
        assert!(!(l.allowed)(&[2, 1, 1, 2]));
        assert!((l.allowed)(&[1, 2, 1, 2]));
        assert!((l.allowed)(&[2, 2, 1, 2])); // reader saw 2 then 2: fine
    }

    #[test]
    fn distinct_addresses() {
        assert_ne!(A, B);
        assert_ne!(B, F);
        assert_ne!(A, F);
    }

    #[test]
    fn tso_relaxes_exactly_store_buffering() {
        // SB: the relaxed outcome flips from forbidden to allowed.
        let sb = store_buffering();
        assert!(!(sb.allowed)(&[0, 0]));
        assert!((sb.allowed_tso)(&[0, 0]));
        assert!(sb.allowed_under(Consistency::Tso)(&[0, 0]));
        assert!(!sb.allowed_under(Consistency::Sc)(&[0, 0]));
        // Every other test keeps its SC verdict on its signature
        // outcome (TSO preserves L→L, S→S, L→S, and store atomicity).
        for (lt, forbidden) in [
            (message_passing(), vec![1, 0]),
            (load_buffering(), vec![1, 1]),
            (iriw(), vec![1, 0, 1, 0]),
            (coherence_co(), vec![2, 1, 1, 2]),
        ] {
            assert!(!(lt.allowed)(&forbidden), "{} SC", lt.name);
            assert!(!(lt.allowed_tso)(&forbidden), "{} TSO", lt.name);
        }
    }
}
