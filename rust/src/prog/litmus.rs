//! Litmus tests: small multi-core programs with enumerated
//! SC-allowed outcomes.  Includes the paper's Listing 1 (store
//! buffering — the A=B=0 outcome Tardis must forbid, §III-C3/§III-D2)
//! and the §V case-study program (Listing 2).

use super::{load, store, Op, Program, Workload};
use crate::types::{LineAddr, SHARED_BASE};

/// Addresses used by the litmus programs (distinct shared lines).
pub const A: LineAddr = SHARED_BASE + 0x10;
pub const B: LineAddr = SHARED_BASE + 0x21;
pub const F: LineAddr = SHARED_BASE + 0x32;

/// A named litmus test: programs plus a predicate over the observed
/// load values (keyed by (core, pc)) deciding whether an outcome is
/// SC-legal.
pub struct Litmus {
    pub name: &'static str,
    pub workload: Workload,
    /// The (core, pc) pairs whose loaded values form the outcome tuple.
    pub observed: Vec<(u32, u32)>,
    /// SC-legality of an outcome tuple (same order as `observed`).
    pub allowed: fn(&[u64]) -> bool,
}

/// Store buffering (paper Listing 1):
///   C0: A = 1; r0 = B          C1: B = 1; r1 = A
/// SC forbids r0 = r1 = 0.
pub fn store_buffering() -> Litmus {
    Litmus {
        name: "SB",
        workload: Workload::new(vec![
            Program::new(vec![store(A, 1), load(B)]),
            Program::new(vec![store(B, 1), load(A)]),
        ]),
        observed: vec![(0, 1), (1, 1)],
        allowed: |v| !(v[0] == 0 && v[1] == 0),
    }
}

/// Message passing:
///   C0: A = 1; F = 1           C1: r0 = F; r1 = A
/// SC forbids r0 = 1 && r1 = 0.
pub fn message_passing() -> Litmus {
    Litmus {
        name: "MP",
        workload: Workload::new(vec![
            Program::new(vec![store(A, 1), store(F, 1)]),
            Program::new(vec![load(F), load(A)]),
        ]),
        observed: vec![(1, 0), (1, 1)],
        allowed: |v| !(v[0] == 1 && v[1] == 0),
    }
}

/// Load buffering:
///   C0: r0 = A; B = 1          C1: r1 = B; A = 1
/// SC forbids r0 = r1 = 1.
pub fn load_buffering() -> Litmus {
    Litmus {
        name: "LB",
        workload: Workload::new(vec![
            Program::new(vec![load(A), store(B, 1)]),
            Program::new(vec![load(B), store(A, 1)]),
        ]),
        observed: vec![(0, 0), (1, 0)],
        allowed: |v| !(v[0] == 1 && v[1] == 1),
    }
}

/// Independent reads of independent writes (4 cores).
/// SC forbids the two readers disagreeing on the write order:
/// r0=1,r1=0 together with r2=1,r3=0.
pub fn iriw() -> Litmus {
    Litmus {
        name: "IRIW",
        workload: Workload::new(vec![
            Program::new(vec![store(A, 1)]),
            Program::new(vec![store(B, 1)]),
            Program::new(vec![load(A), load(B)]),
            Program::new(vec![load(B), load(A)]),
        ]),
        observed: vec![(2, 0), (2, 1), (3, 0), (3, 1)],
        allowed: |v| {
            // v = [rA@c2, rB@c2, rB@c3, rA@c3]
            !(v[0] == 1 && v[1] == 0 && v[2] == 1 && v[3] == 0)
        },
    }
}

/// Coherence (same-location) test: both readers of one location must
/// agree with some single write order — reading 2-then-1 on one core
/// and 1-then-2 on another is forbidden.
pub fn coherence_co() -> Litmus {
    Litmus {
        name: "CO",
        workload: Workload::new(vec![
            Program::new(vec![store(A, 1)]),
            Program::new(vec![store(A, 2)]),
            Program::new(vec![load(A), load(A)]),
            Program::new(vec![load(A), load(A)]),
        ]),
        observed: vec![(2, 0), (2, 1), (3, 0), (3, 1)],
        allowed: |v| {
            let fwd = |x: u64, y: u64| !(x == 2 && y == 1);
            let rev = |x: u64, y: u64| !(x == 1 && y == 2);
            // Both readers must be consistent with a single order.
            (fwd(v[0], v[1]) && fwd(v[2], v[3])) || (rev(v[0], v[1]) && rev(v[2], v[3]))
        },
    }
}

/// The §V case-study program (Listing 2):
///   C0: L(B); A=1; L(A); L(B); A=3     C1: nop; B=2; L(A); B=4
/// (the nop is modeled as a 1-cycle gap before B=2).
pub fn case_study() -> Workload {
    Workload::new(vec![
        Program::new(vec![
            load(B),
            store(A, 1),
            load(A),
            load(B),
            store(A, 3),
        ]),
        Program::new(vec![
            Op::Store { addr: B, value: Some(2), gap: 1 },
            load(A),
            store(B, 4),
        ]),
    ])
}

/// All outcome-checked litmus tests.
pub fn all() -> Vec<Litmus> {
    vec![store_buffering(), message_passing(), load_buffering(), iriw(), coherence_co()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sb_forbids_zero_zero() {
        let l = store_buffering();
        assert!(!(l.allowed)(&[0, 0]));
        assert!((l.allowed)(&[1, 0]));
        assert!((l.allowed)(&[0, 1]));
        assert!((l.allowed)(&[1, 1]));
    }

    #[test]
    fn mp_forbids_flag_without_data() {
        let l = message_passing();
        assert!(!(l.allowed)(&[1, 0]));
        assert!((l.allowed)(&[0, 0]));
        assert!((l.allowed)(&[1, 1]));
    }

    #[test]
    fn co_rejects_disagreeing_readers() {
        let l = coherence_co();
        assert!(!(l.allowed)(&[2, 1, 1, 2]));
        assert!((l.allowed)(&[1, 2, 1, 2]));
        assert!((l.allowed)(&[2, 2, 1, 2])); // reader saw 2 then 2: fine
    }

    #[test]
    fn distinct_addresses() {
        assert_ne!(A, B);
        assert_ne!(B, F);
        assert_ne!(A, F);
    }
}
