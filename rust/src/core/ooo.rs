//! Out-of-order core model (paper §III-D, §VI-C1).
//!
//! A window of up to `ooo_window` in-flight memory operations: loads
//! issue eagerly (possibly many outstanding), stores/atomics execute
//! only at the ROB head (sequential consistency — no store buffer),
//! and everything commits in order.  At commit, loads re-validate
//! against the protocol (`commit_check`): under Tardis this is the
//! timestamp check — `pts <= rts` or exclusive — and a failure
//! re-executes the load (the renewal path); under directory protocols
//! it models invalidation-triggered replay.
//!
//! Synchronization ops (lock/unlock/barrier) serialize: the window
//! drains, then the same TTAS / sense-reversing-barrier microcode as
//! the in-order core runs, one access at a time.
//!
//! Under [`Consistency::Tso`] stores no longer execute at the ROB
//! head: they retire into a FIFO store buffer (committing
//! immediately) and drain to the protocol in the background, while
//! loads forward from older in-flight stores (ROB or buffer) — the
//! store-queue forwarding real TSO machines do.  Forwarded loads skip
//! the commit-time timestamp check (their value never touched the
//! coherence substrate) and, per the relaxed Tardis 2.0 `pts` rule,
//! advance no timestamp.
//!
//! [`Consistency::Tso`]: crate::config::Consistency::Tso

use std::collections::VecDeque;

use super::{barrier, sb_cap, CoreAction, CoreEnv, SbEntry, StoreBuffer};
use crate::config::Consistency;
use crate::prog::{Op, Program, Workload};
use crate::proto::{AccessDone, AccessOutcome, Coherence, Completion, CompletionKind, MemOp};
use crate::types::{CoreId, Cycle, LineAddr, BARRIER_COUNTER_LINE, BARRIER_SENSE_LINE};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Status {
    /// Waiting to issue (stores below head; loads being retried).
    NotIssued,
    /// Access outstanding at the protocol.
    Issued,
    /// Value available, waiting for in-order commit.
    Ready(AccessDone),
}

#[derive(Debug, Clone, Copy)]
struct RobEntry {
    pc: usize,
    addr: LineAddr,
    mem: MemOp,
    status: Status,
    /// Completed speculatively via Tardis SpecDone (renewal pending).
    speculative: bool,
    /// Value bound before this entry reached the ROB head.
    early: bool,
    /// Load served by store-to-load forwarding (TSO): commits without
    /// a timestamp check.
    forwarded: bool,
}

/// Sync microcode state (mirrors the in-order core's spin machinery).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SyncState {
    Idle,
    WaitTas { lock: LineAddr },
    WaitBarrierAdd,
    SpinPoll { addr: LineAddr, target_zero: bool, target: u64 },
    SpinPark { addr: LineAddr, target_zero: bool, target: u64 },
    WaitSpinLoad { addr: LineAddr, target_zero: bool, target: u64 },
    WaitCounterReset,
    WaitSenseStore,
    WaitUnlock,
}

pub struct OooCore {
    pub id: CoreId,
    program: Program,
    /// Next op index to enter the ROB.
    fetch_pc: usize,
    rob: VecDeque<RobEntry>,
    sync: SyncState,
    barrier_count: u64,
    penalty: Cycle,
    spin_since: Option<Cycle>,
    /// Replay safeguard: after repeated commit-check failures at the
    /// same head, stop issuing and fetching until the head commits
    /// (freezes pts, guarantees forward progress under contention).
    drain_mode: bool,
    /// Consecutive commit-check failures at the current head.
    head_retries: u32,
    /// TSO store buffer (empty under Sc).
    sb: StoreBuffer,
    /// The current head-store stall episode was already counted in
    /// `sb_full_stalls` (one count per episode, like the in-order
    /// core).
    sb_stall_counted: bool,
    pub next_wake: Option<Cycle>,
    pub finished_at: Option<Cycle>,
    pub committed_ops: u64,
}

impl OooCore {
    pub fn new(id: CoreId, workload: &Workload) -> Self {
        Self {
            id,
            program: workload.programs[id as usize].clone(),
            fetch_pc: 0,
            rob: VecDeque::new(),
            sync: SyncState::Idle,
            barrier_count: 0,
            penalty: 0,
            spin_since: None,
            drain_mode: false,
            head_retries: 0,
            sb: StoreBuffer::default(),
            sb_stall_counted: false,
            next_wake: None,
            finished_at: None,
            committed_ops: 0,
        }
    }

    pub fn step(&mut self, now: Cycle, env: &mut CoreEnv) -> CoreAction {
        self.next_wake = None;
        if self.finished_at.is_some() {
            return CoreAction::Park;
        }
        if self.penalty > 0 {
            let p = self.penalty;
            self.penalty = 0;
            env.pctx.stats.rollback_cycles += p;
            return self.wake_at(now + p);
        }
        // Sync microcode in progress?
        match self.sync {
            SyncState::Idle => {}
            SyncState::SpinPoll { addr, target_zero, target } => {
                return self.spin_poll(now, addr, target_zero, target, env);
            }
            // Parked / waiting states progress via completions.
            _ => return CoreAction::Park,
        }
        self.pipeline_step(now, env)
    }

    /// One cycle of the load/store pipeline: commit the head if ready,
    /// issue what can issue, fetch into the window.
    fn pipeline_step(&mut self, now: Cycle, env: &mut CoreEnv) -> CoreAction {
        // 0. Keep the store buffer draining in the background (TSO).
        self.pump_sb(now, env);

        let mut progressed = false;

        // 1a. TSO: a store at the ROB head retires into the store
        // buffer — it commits now and becomes globally visible at its
        // drain.  (Stores never carry Ready status under TSO.)
        if env.consistency == Consistency::Tso {
            if let Some(head) = self.rob.front() {
                if let MemOp::Store { value } = head.mem {
                    if self.sb.len() < sb_cap(env) {
                        let head = self.rob.pop_front().unwrap();
                        self.sb.push(SbEntry {
                            addr: head.addr,
                            value,
                            pc: head.pc as u32,
                        });
                        env.pctx.stats.sb_stores += 1;
                        self.committed_ops += 1;
                        self.sb_stall_counted = false;
                        self.pump_sb(now, env);
                        progressed = true;
                    } else {
                        // Wait for a drain completion to free a slot;
                        // count the episode once.
                        if !self.sb_stall_counted {
                            env.pctx.stats.sb_full_stalls += 1;
                            env.pctx.emit(crate::obs::EventKind::SbStall, self.id, head.addr, 0);
                            self.sb_stall_counted = true;
                        }
                    }
                }
            }
        }

        // 1b. Commit the head if ready (one per cycle).  Speculative
        // heads wait for their renewal to resolve (SpecOk / Misspec).
        if let Some(head) = self.rob.front().copied() {
            if let Status::Ready(mut d) = head.status {
                if !head.speculative && !progressed {
                    let decision = if head.forwarded {
                        // Forwarded loads carry their own store's value;
                        // there is no protocol state to re-validate.
                        Some(d.ts)
                    } else {
                        match head.mem {
                            MemOp::Load => {
                                env.proto.commit_check(self.id, head.addr, head.early, d.value)
                            }
                            _ => Some(d.ts),
                        }
                    };
                    match decision {
                        Some(ts) => {
                            d.ts = ts;
                            self.commit_head(now, d, env);
                            self.drain_mode = false;
                            self.head_retries = 0;
                            progressed = true;
                        }
                        None => {
                            // Commit check failed (§III-D): re-execute.
                            // Drain (freeze the window so pts stops
                            // moving) only after repeated failures —
                            // the forward-progress safeguard, not the
                            // common case.
                            env.pctx.stats.rollback_cycles += env.rollback_penalty;
                            let head = self.rob.front_mut().unwrap();
                            head.status = Status::NotIssued;
                            head.speculative = false;
                            self.penalty += env.rollback_penalty;
                            self.head_retries += 1;
                            if self.head_retries >= 3 {
                                self.drain_mode = true;
                            }
                            progressed = true;
                        }
                    }
                }
            }
        }

        // 2. Issue: loads anywhere in the window; writes only at head
        // (SC) or never from the ROB (TSO — they retire into the store
        // buffer instead).  In drain mode only the head may issue
        // (replay safeguard).
        let tso = env.consistency == Consistency::Tso;
        let mut issued = false;
        for i in 0..self.rob.len() {
            let e = self.rob[i];
            if e.status != Status::NotIssued {
                continue;
            }
            let is_head = i == 0;
            if self.drain_mode && !is_head {
                break;
            }
            if tso && e.mem.is_write() {
                continue; // retires via the store buffer at the head
            }
            // TSO store-to-load forwarding: the youngest older store
            // to the same address — in the ROB first (younger than
            // anything buffered), then the store buffer — satisfies
            // the load locally.
            if tso && e.mem == MemOp::Load {
                let fwd = self
                    .rob
                    .iter()
                    .take(i)
                    .rev()
                    .find_map(|p| match p.mem {
                        MemOp::Store { value } if p.addr == e.addr => Some(value),
                        _ => None,
                    })
                    .or_else(|| self.sb.forward(e.addr));
                if let Some(value) = fwd {
                    let entry = &mut self.rob[i];
                    entry.status =
                        Status::Ready(AccessDone { value, ts: 0, extra_cycles: 0 });
                    entry.forwarded = true;
                    env.pctx.stats.sb_forwards += 1;
                    issued = true;
                    break;
                }
            }
            // One outstanding access per line across the whole window
            // (and the store-buffer drain): protocol completions are
            // matched by address, so a second in-flight access to the
            // same line would steal the first one's completion (worst
            // case: a store adopting a load's fill without
            // exclusivity).
            let line_busy = self
                .rob
                .iter()
                .enumerate()
                .any(|(j, p)| j != i && p.addr == e.addr && p.status == Status::Issued)
                || self.sb.inflight_addr() == Some(e.addr);
            // SC: a load must not bypass an older, not-yet-committed
            // write to the same address (no forwarding).
            let older_write = self
                .rob
                .iter()
                .take(i)
                .any(|p| p.addr == e.addr && p.mem.is_write());
            let can_issue = !line_busy
                && match e.mem {
                    MemOp::Load => tso || !older_write,
                    _ => is_head,
                };
            if !can_issue {
                continue;
            }
            let outcome = env.proto.core_access(self.id, e.addr, e.mem, true, env.pctx);
            let entry = &mut self.rob[i];
            entry.early = !is_head;
            match outcome {
                AccessOutcome::Done(d) => entry.status = Status::Ready(d),
                AccessOutcome::SpecDone(d) => {
                    entry.status = Status::Ready(d);
                    entry.speculative = true;
                }
                AccessOutcome::Pending => entry.status = Status::Issued,
            }
            issued = true;
            break; // one issue per cycle
        }

        // 3. Fetch the next op into the window.
        let mut fetched = false;
        if !self.drain_mode && self.rob.len() < env.ooo_window as usize {
            match self.program.ops.get(self.fetch_pc).copied() {
                Some(Op::Load { addr, .. }) => {
                    self.rob.push_back(RobEntry {
                        pc: self.fetch_pc,
                        addr,
                        mem: MemOp::Load,
                        status: Status::NotIssued,
                        speculative: false,
                        early: false,
                        forwarded: false,
                    });
                    self.fetch_pc += 1;
                    fetched = true;
                }
                Some(Op::Store { addr, value, .. }) => {
                    let v = value.unwrap_or_else(|| Workload::store_value(self.id, self.fetch_pc));
                    self.rob.push_back(RobEntry {
                        pc: self.fetch_pc,
                        addr,
                        mem: MemOp::Store { value: v },
                        status: Status::NotIssued,
                        speculative: false,
                        early: false,
                        forwarded: false,
                    });
                    self.fetch_pc += 1;
                    fetched = true;
                }
                Some(sync_op) if self.rob.is_empty() && self.sb.is_empty() => {
                    // Serialize: start the sync microcode (a fence —
                    // the window and the store buffer are both empty).
                    return self.start_sync(now, sync_op, env);
                }
                Some(_) => {} // sync op waits for the window + buffer to drain
                None => {
                    if self.rob.is_empty() && self.sb.is_empty() {
                        self.finished_at = Some(now);
                        return CoreAction::Finished;
                    }
                }
            }
        }

        if progressed || issued || fetched {
            self.wake_at(now + 1)
        } else {
            CoreAction::Park // completions (or spec resolutions) wake us
        }
    }

    fn commit_head(&mut self, now: Cycle, d: AccessDone, env: &mut CoreEnv) {
        let head = self.rob.pop_front().unwrap();
        if head.forwarded {
            env.log_forwarded_load(self.id, head.pc as u32, head.addr, d.value, now);
            env.pctx.stats.memops += 1;
            env.pctx.stats.loads += 1;
            self.committed_ops += 1;
            return;
        }
        let (read, written) = match head.mem {
            MemOp::Load => (Some(d.value), None),
            MemOp::Store { value } => (None, Some(value)),
            MemOp::Tas => (Some(d.value), Some(1)),
            MemOp::FetchAdd { delta } => (Some(d.value), Some(d.value.wrapping_add(delta))),
        };
        env.log_access(self.id, head.pc as u32, head.addr, read, written, d.ts, now);
        env.pctx.stats.memops += 1;
        match head.mem {
            MemOp::Load => env.pctx.stats.loads += 1,
            MemOp::Store { .. } => env.pctx.stats.stores += 1,
            _ => env.pctx.stats.atomics += 1,
        }
        self.committed_ops += 1;
    }

    /// Drain the store buffer: issue the oldest buffered store unless
    /// an in-flight ROB access to the same line would collide (its
    /// completion re-steps the pipeline and the pump retries).
    /// Postcondition otherwise: buffer empty or head in flight.
    fn pump_sb(&mut self, now: Cycle, env: &mut CoreEnv) {
        while !self.sb.inflight() {
            let Some(e) = self.sb.head() else { return };
            if self
                .rob
                .iter()
                .any(|p| p.addr == e.addr && p.status == Status::Issued)
            {
                return;
            }
            let mem = MemOp::Store { value: e.value };
            match env.proto.core_access(self.id, e.addr, mem, false, env.pctx) {
                AccessOutcome::Done(d) => {
                    self.log_drained(now, e, d.ts, env);
                    self.sb.pop_head();
                }
                AccessOutcome::Pending => self.sb.set_inflight(),
                AccessOutcome::SpecDone(_) => unreachable!("stores never speculate"),
            }
        }
    }

    /// A buffered store became globally visible: log it at its drain
    /// point.
    fn log_drained(&mut self, now: Cycle, e: SbEntry, ts: crate::types::Ts, env: &mut CoreEnv) {
        env.log_access(self.id, e.pc, e.addr, None, Some(e.value), ts, now);
        env.pctx.stats.memops += 1;
        env.pctx.stats.stores += 1;
    }

    // ------------------------------------------------ sync microcode

    fn start_sync(&mut self, now: Cycle, op: Op, env: &mut CoreEnv) -> CoreAction {
        match op {
            Op::Lock { addr } => {
                self.sync = SyncState::WaitTas { lock: addr };
                let outcome = env.proto.core_access(self.id, addr, MemOp::Tas, false, env.pctx);
                match outcome {
                    AccessOutcome::Done(d) => self.sync_tas_result(now, addr, d, env),
                    AccessOutcome::Pending => CoreAction::Park,
                    AccessOutcome::SpecDone(_) => unreachable!("atomics never speculate"),
                }
            }
            Op::Unlock { addr } => {
                self.sync = SyncState::WaitUnlock;
                let mem = MemOp::Store { value: 0 };
                let outcome = env.proto.core_access(self.id, addr, mem, false, env.pctx);
                match outcome {
                    AccessOutcome::Done(d) => self.sync_unlock_done(now, addr, d, env),
                    AccessOutcome::Pending => CoreAction::Park,
                    AccessOutcome::SpecDone(_) => unreachable!(),
                }
            }
            Op::Barrier => {
                self.sync = SyncState::WaitBarrierAdd;
                let mem = MemOp::FetchAdd { delta: 1 };
                let outcome =
                    env.proto.core_access(self.id, BARRIER_COUNTER_LINE, mem, false, env.pctx);
                match outcome {
                    AccessOutcome::Done(d) => self.sync_barrier_arrived(now, d, env),
                    AccessOutcome::Pending => CoreAction::Park,
                    AccessOutcome::SpecDone(_) => unreachable!(),
                }
            }
            _ => unreachable!("start_sync on non-sync op"),
        }
    }

    fn sync_tas_result(&mut self, now: Cycle, lock: LineAddr, d: AccessDone, env: &mut CoreEnv) -> CoreAction {
        env.log_access(self.id, self.fetch_pc as u32, lock, Some(d.value), Some(1), d.ts, now);
        env.pctx.stats.memops += 1;
        env.pctx.stats.atomics += 1;
        if d.value == 0 {
            env.pctx.stats.locks_acquired += 1;
            self.sync_done(now)
        } else {
            if self.spin_since.is_none() {
                self.spin_since = Some(now);
            }
            self.spin_continue(now, lock, true, 0, env)
        }
    }

    fn sync_unlock_done(&mut self, now: Cycle, addr: LineAddr, d: AccessDone, env: &mut CoreEnv) -> CoreAction {
        env.log_access(self.id, self.fetch_pc as u32, addr, None, Some(0), d.ts, now);
        env.pctx.stats.memops += 1;
        env.pctx.stats.stores += 1;
        self.sync_done(now)
    }

    fn sync_barrier_arrived(&mut self, now: Cycle, d: AccessDone, env: &mut CoreEnv) -> CoreAction {
        env.log_access(
            self.id,
            self.fetch_pc as u32,
            BARRIER_COUNTER_LINE,
            Some(d.value),
            Some(d.value + 1),
            d.ts,
            now,
        );
        env.pctx.stats.memops += 1;
        env.pctx.stats.atomics += 1;
        let target = barrier::target_sense(self.barrier_count);
        if d.value == env.n_cores as u64 - 1 {
            self.sync = SyncState::WaitCounterReset;
            let mem = MemOp::Store { value: 0 };
            let outcome =
                env.proto.core_access(self.id, BARRIER_COUNTER_LINE, mem, false, env.pctx);
            match outcome {
                AccessOutcome::Done(d2) => self.sync_counter_reset(now, d2, env),
                AccessOutcome::Pending => CoreAction::Park,
                AccessOutcome::SpecDone(_) => unreachable!(),
            }
        } else {
            if self.spin_since.is_none() {
                self.spin_since = Some(now);
            }
            self.spin_continue(now, BARRIER_SENSE_LINE, false, target, env)
        }
    }

    fn sync_counter_reset(&mut self, now: Cycle, d: AccessDone, env: &mut CoreEnv) -> CoreAction {
        env.log_access(self.id, self.fetch_pc as u32, BARRIER_COUNTER_LINE, None, Some(0), d.ts, now);
        env.pctx.stats.memops += 1;
        env.pctx.stats.stores += 1;
        self.sync = SyncState::WaitSenseStore;
        let target = barrier::target_sense(self.barrier_count);
        let mem = MemOp::Store { value: target };
        let outcome = env.proto.core_access(self.id, BARRIER_SENSE_LINE, mem, false, env.pctx);
        match outcome {
            AccessOutcome::Done(d2) => self.sync_sense_stored(now, d2, env),
            AccessOutcome::Pending => CoreAction::Park,
            AccessOutcome::SpecDone(_) => unreachable!(),
        }
    }

    fn sync_sense_stored(&mut self, now: Cycle, d: AccessDone, env: &mut CoreEnv) -> CoreAction {
        let target = barrier::target_sense(self.barrier_count);
        env.log_access(self.id, self.fetch_pc as u32, BARRIER_SENSE_LINE, None, Some(target), d.ts, now);
        env.pctx.stats.memops += 1;
        env.pctx.stats.stores += 1;
        self.barrier_count += 1;
        env.pctx.stats.barriers_passed += 1;
        self.sync_done(now)
    }

    fn spin_continue(
        &mut self,
        now: Cycle,
        addr: LineAddr,
        target_zero: bool,
        target: u64,
        env: &mut CoreEnv,
    ) -> CoreAction {
        use crate::proto::SpinHint;
        match env.proto.spin_hint(self.id, addr, env.pctx) {
            SpinHint::Retry => {
                self.sync = SyncState::SpinPoll { addr, target_zero, target };
                self.wake_at(now + env.spin_poll)
            }
            SpinHint::WaitInvalidate => {
                self.sync = SyncState::SpinPark { addr, target_zero, target };
                CoreAction::Park
            }
            SpinHint::ExpiresAfterSelfInc { spins_needed } => {
                self.sync = SyncState::SpinPoll { addr, target_zero, target };
                self.wake_at(now + spins_needed.max(1) * env.spin_poll)
            }
        }
    }

    fn spin_poll(
        &mut self,
        now: Cycle,
        addr: LineAddr,
        target_zero: bool,
        target: u64,
        env: &mut CoreEnv,
    ) -> CoreAction {
        let outcome = env.proto.core_access(self.id, addr, MemOp::Load, false, env.pctx);
        match outcome {
            AccessOutcome::Done(d) => self.spin_value(now, addr, target_zero, target, d, env),
            AccessOutcome::Pending => {
                self.sync = SyncState::WaitSpinLoad { addr, target_zero, target };
                CoreAction::Park
            }
            AccessOutcome::SpecDone(_) => unreachable!("spin loads never speculate"),
        }
    }

    fn spin_value(
        &mut self,
        now: Cycle,
        addr: LineAddr,
        target_zero: bool,
        target: u64,
        d: AccessDone,
        env: &mut CoreEnv,
    ) -> CoreAction {
        env.log_access(self.id, self.fetch_pc as u32, addr, Some(d.value), None, d.ts, now);
        env.pctx.stats.memops += 1;
        env.pctx.stats.loads += 1;
        let satisfied = if target_zero { d.value == 0 } else { d.value == target };
        if satisfied {
            if let Some(start) = self.spin_since.take() {
                env.pctx.stats.spin_cycles += now - start;
            }
            if target_zero {
                // Lock free: retry the Tas.
                self.sync = SyncState::WaitTas { lock: addr };
                let outcome = env.proto.core_access(self.id, addr, MemOp::Tas, false, env.pctx);
                match outcome {
                    AccessOutcome::Done(d2) => self.sync_tas_result(now, addr, d2, env),
                    AccessOutcome::Pending => CoreAction::Park,
                    AccessOutcome::SpecDone(_) => unreachable!(),
                }
            } else {
                // Barrier sense reached.
                self.barrier_count += 1;
                env.pctx.stats.barriers_passed += 1;
                self.sync_done(now)
            }
        } else {
            self.spin_continue(now, addr, target_zero, target, env)
        }
    }

    fn sync_done(&mut self, now: Cycle) -> CoreAction {
        self.sync = SyncState::Idle;
        self.fetch_pc += 1;
        self.committed_ops += 1;
        self.wake_at(now + 1)
    }

    // ------------------------------------------------ completions

    pub fn on_completion(&mut self, c: &Completion, now: Cycle, env: &mut CoreEnv) -> CoreAction {
        // TSO drain completion, matched by address against the
        // in-flight buffered store.  Never ambiguous with a ROB or
        // sync access: loads to buffered addresses forward, the pump
        // refuses to chase an issued ROB access to the same line, and
        // sync microcode runs with the buffer empty.
        if c.kind == CompletionKind::Demand && self.sb.owns_completion(c.addr) {
            let e = self.sb.pop_head();
            self.log_drained(now, e, c.ts, env);
            self.pump_sb(now, env);
            return self.wake_at_if_parked(now + 1);
        }
        match c.kind {
            CompletionKind::SpecOk => {
                // Renewal succeeded: the ROB entry's value was current;
                // commit_check will pass once the head reaches it.
                for e in self.rob.iter_mut() {
                    if e.addr == c.addr && e.speculative {
                        e.speculative = false;
                    }
                }
                self.wake_at_if_parked(now + 1)
            }
            CompletionKind::Misspec => {
                // The speculative renewal failed; the ROB entry (if not
                // yet committed) adopts the corrected value and will be
                // re-checked at commit.
                for e in self.rob.iter_mut() {
                    if e.addr == c.addr && e.speculative {
                        e.status = Status::Ready(AccessDone {
                            value: c.value,
                            ts: c.ts,
                            extra_cycles: 0,
                        });
                        e.speculative = false;
                    }
                }
                self.penalty += env.rollback_penalty;
                self.wake_at_if_parked(now + 1)
            }
            CompletionKind::SpinWake => match self.sync {
                SyncState::SpinPark { addr, target_zero, target } if addr == c.addr => {
                    self.sync = SyncState::SpinPoll { addr, target_zero, target };
                    self.wake_at(now + 1)
                }
                _ => {
                    // Retry wake for a parked duplicate access: put any
                    // still-Issued entries for this line back to
                    // NotIssued so they re-execute (their original
                    // completion may have been matched to an earlier
                    // entry of the same address).
                    for e in self.rob.iter_mut() {
                        if e.addr == c.addr && e.status == Status::Issued {
                            e.status = Status::NotIssued;
                        }
                    }
                    self.wake_at_if_parked(now + 1)
                }
            },
            CompletionKind::Demand => {
                match self.sync {
                    SyncState::WaitBarrierAdd if c.addr == BARRIER_COUNTER_LINE => {
                        return self.sync_barrier_arrived(
                            now,
                            AccessDone { value: c.value, ts: c.ts, extra_cycles: 0 },
                            env,
                        );
                    }
                    SyncState::WaitTas { lock } if lock == c.addr => {
                        return self.sync_tas_result(
                            now,
                            lock,
                            AccessDone { value: c.value, ts: c.ts, extra_cycles: 0 },
                            env,
                        );
                    }
                    SyncState::WaitUnlock => {
                        return self.sync_unlock_done(
                            now,
                            c.addr,
                            AccessDone { value: c.value, ts: c.ts, extra_cycles: 0 },
                            env,
                        );
                    }
                    SyncState::WaitCounterReset => {
                        return self.sync_counter_reset(
                            now,
                            AccessDone { value: c.value, ts: c.ts, extra_cycles: 0 },
                            env,
                        );
                    }
                    SyncState::WaitSenseStore => {
                        return self.sync_sense_stored(
                            now,
                            AccessDone { value: c.value, ts: c.ts, extra_cycles: 0 },
                            env,
                        );
                    }
                    SyncState::WaitSpinLoad { addr, target_zero, target } if addr == c.addr => {
                        return self.spin_value(
                            now,
                            addr,
                            target_zero,
                            target,
                            AccessDone { value: c.value, ts: c.ts, extra_cycles: 0 },
                            env,
                        );
                    }
                    _ => {}
                }
                // Pipeline completion: mark matching issued entry ready.
                for (i, e) in self.rob.iter_mut().enumerate() {
                    if e.addr == c.addr && e.status == Status::Issued {
                        e.status =
                            Status::Ready(AccessDone { value: c.value, ts: c.ts, extra_cycles: 0 });
                        e.early = i > 0;
                        break;
                    }
                }
                self.wake_at(now + 1)
            }
        }
    }

    /// Diagnostic snapshot for deadlock reports.
    pub fn state_string(&self) -> String {
        let rob: Vec<String> = self
            .rob
            .iter()
            .map(|e| format!("pc{} {:#x} {:?} spec={} early={}", e.pc, e.addr, e.status, e.speculative, e.early))
            .collect();
        format!(
            "core {} fetch_pc {}/{} sync {:?} drain {} sb {} next_wake {:?} rob [{}]",
            self.id,
            self.fetch_pc,
            self.program.len(),
            self.sync,
            self.drain_mode,
            self.sb.len(),
            self.next_wake,
            rob.join("; ")
        )
    }

    fn wake_at_if_parked(&mut self, t: Cycle) -> CoreAction {
        if self.next_wake.is_none() {
            self.wake_at(t)
        } else {
            CoreAction::Park
        }
    }

    fn wake_at(&mut self, t: Cycle) -> CoreAction {
        self.next_wake = Some(t);
        CoreAction::WakeAt(t)
    }
}
