//! Core models: in-order single-issue (paper Table V) and out-of-order
//! with commit-time timestamp checking (§III-D).  Cores interpret the
//! trace programs, expanding Lock/Unlock/Barrier into test-and-test-
//! and-set and sense-reversing-barrier microcode over ordinary memory
//! operations, so all synchronization traffic flows through the
//! coherence protocol under test.

pub mod inorder;
pub mod ooo;

use crate::api::observer::Observers;
use crate::config::Consistency;
use crate::prog::checker::LogRecord;
use crate::proto::{Completion, ProtoCtx, ProtocolDispatch};
use crate::types::{CoreId, Cycle, LineAddr, Ts};

/// What the engine should do with a core after a step/completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreAction {
    /// Schedule a wake at this cycle.
    WakeAt(Cycle),
    /// The core is blocked; a completion will wake it.
    Park,
    /// The core finished its program.
    Finished,
}

/// Everything a core needs while stepping: the (statically
/// dispatched) protocol, the protocol side-effect context, and the
/// observer registry.
pub struct CoreEnv<'a, 'b> {
    pub proto: &'a mut ProtocolDispatch,
    pub pctx: &'a mut ProtoCtx<'b>,
    /// Instrumentation plugins + optional SC log.
    pub obs: &'a mut Observers,
    /// Global commit sequence (state-mutation order).
    pub seq: &'a mut u64,
    pub n_cores: u32,
    pub spin_poll: Cycle,
    pub rollback_penalty: Cycle,
    pub ooo_window: u32,
    /// Memory consistency model (Sc = no store buffer).
    pub consistency: Consistency,
    /// TSO store-buffer depth.
    pub sb_entries: u32,
}

impl<'a, 'b> CoreEnv<'a, 'b> {
    /// Report a committed access to the observers; returns an opaque
    /// squash handle to pass back to `obs.squash` (usize::MAX means
    /// nothing observes and no squash is needed).  The handle is NOT
    /// guaranteed to be an SC-log index — see [`Observers::commit`].
    #[allow(clippy::too_many_arguments)]
    pub fn log_access(
        &mut self,
        core: CoreId,
        pc: u32,
        addr: LineAddr,
        value_read: Option<u64>,
        value_written: Option<u64>,
        ts: Ts,
        cycle: Cycle,
    ) -> usize {
        self.log_access_inner(core, pc, addr, value_read, value_written, ts, cycle, false)
    }

    /// [`Self::log_access`] for a load served by TSO store-to-load
    /// forwarding (the checker validates it against program order
    /// instead of the global key order).
    #[allow(clippy::too_many_arguments)]
    pub fn log_forwarded_load(
        &mut self,
        core: CoreId,
        pc: u32,
        addr: LineAddr,
        value: u64,
        cycle: Cycle,
    ) -> usize {
        self.log_access_inner(core, pc, addr, Some(value), None, 0, cycle, true)
    }

    #[allow(clippy::too_many_arguments)]
    fn log_access_inner(
        &mut self,
        core: CoreId,
        pc: u32,
        addr: LineAddr,
        value_read: Option<u64>,
        value_written: Option<u64>,
        ts: Ts,
        cycle: Cycle,
        forwarded: bool,
    ) -> usize {
        *self.seq += 1;
        self.obs.commit(LogRecord {
            core,
            pc,
            addr,
            value_read,
            value_written,
            ts,
            commit_cycle: cycle,
            seq: *self.seq,
            valid: true,
            forwarded,
        })
    }
}

/// Effective TSO store-buffer capacity (0 is treated as 1 so a
/// misconfigured depth can never wedge the drain state machines).
pub(crate) fn sb_cap(env: &CoreEnv) -> usize {
    env.sb_entries.max(1) as usize
}

/// One retired store awaiting global visibility (TSO).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct SbEntry {
    pub addr: LineAddr,
    pub value: u64,
    /// Program counter of the trace store (checker program order).
    pub pc: u32,
}

/// The per-core TSO store buffer: a FIFO of retired stores draining
/// to the protocol in the background, with store-to-load forwarding.
/// Under `Consistency::Sc` it stays empty and costs one branch.
///
/// Invariant maintained by the cores: after any `pump`, either the
/// buffer is empty or its head is in flight at the protocol — drains
/// never silently stall.
#[derive(Debug, Default)]
pub(crate) struct StoreBuffer {
    entries: std::collections::VecDeque<SbEntry>,
    /// The head entry has been issued to the protocol; its Demand
    /// completion (matched by address) pops it.
    inflight: bool,
}

impl StoreBuffer {
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn push(&mut self, e: SbEntry) {
        self.entries.push_back(e);
    }

    pub fn head(&self) -> Option<SbEntry> {
        self.entries.front().copied()
    }

    pub fn inflight(&self) -> bool {
        self.inflight
    }

    pub fn set_inflight(&mut self) {
        self.inflight = true;
    }

    /// Address of the in-flight drain, if any.
    pub fn inflight_addr(&self) -> Option<LineAddr> {
        if self.inflight {
            self.entries.front().map(|e| e.addr)
        } else {
            None
        }
    }

    /// Does this Demand completion belong to the in-flight drain?
    pub fn owns_completion(&self, addr: LineAddr) -> bool {
        self.inflight_addr() == Some(addr)
    }

    /// Pop the drained head (clears the in-flight mark).
    pub fn pop_head(&mut self) -> SbEntry {
        self.inflight = false;
        self.entries.pop_front().expect("pop on empty store buffer")
    }

    /// Store-to-load forwarding: the youngest buffered value for
    /// `addr` (in-flight head included — it is still not globally
    /// visible until its completion).
    pub fn forward(&self, addr: LineAddr) -> Option<u64> {
        self.entries.iter().rev().find(|e| e.addr == addr).map(|e| e.value)
    }
}

/// Either core model, enum-dispatched (no trait objects on the hot
/// path).
pub enum CoreUnit {
    InOrder(inorder::InOrderCore),
    Ooo(ooo::OooCore),
}

impl CoreUnit {
    pub fn step(&mut self, now: Cycle, env: &mut CoreEnv) -> CoreAction {
        match self {
            CoreUnit::InOrder(c) => c.step(now, env),
            CoreUnit::Ooo(c) => c.step(now, env),
        }
    }

    pub fn on_completion(&mut self, c: &Completion, now: Cycle, env: &mut CoreEnv) -> CoreAction {
        match self {
            CoreUnit::InOrder(core) => core.on_completion(c, now, env),
            CoreUnit::Ooo(core) => core.on_completion(c, now, env),
        }
    }

    /// Prime the wake-dedup token (engine start-up).
    pub fn set_next_wake(&mut self, t: Cycle) {
        match self {
            CoreUnit::InOrder(c) => c.next_wake = Some(t),
            CoreUnit::Ooo(c) => c.next_wake = Some(t),
        }
    }

    pub fn next_wake(&self) -> Option<Cycle> {
        match self {
            CoreUnit::InOrder(c) => c.next_wake,
            CoreUnit::Ooo(c) => c.next_wake,
        }
    }

    /// Diagnostic snapshot for deadlock reports.
    pub fn state_string(&self) -> String {
        match self {
            CoreUnit::InOrder(c) => c.state_string(),
            CoreUnit::Ooo(c) => c.state_string(),
        }
    }

    pub fn finished_at(&self) -> Option<Cycle> {
        match self {
            CoreUnit::InOrder(c) => c.finished_at,
            CoreUnit::Ooo(c) => c.finished_at,
        }
    }

    pub fn committed_ops(&self) -> u64 {
        match self {
            CoreUnit::InOrder(c) => c.committed_ops,
            CoreUnit::Ooo(c) => c.committed_ops,
        }
    }
}

/// Sense-reversing barrier helpers shared by both core models.
pub(crate) mod barrier {
    /// Target sense value for the k-th barrier episode (0-indexed);
    /// the shared sense line starts at 0 and flips every episode.
    pub fn target_sense(episode: u64) -> u64 {
        1 - (episode % 2)
    }

    #[test]
    fn sense_alternates_starting_at_one() {
        assert_eq!(target_sense(0), 1);
        assert_eq!(target_sense(1), 0);
        assert_eq!(target_sense(2), 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(addr: LineAddr, value: u64, pc: u32) -> SbEntry {
        SbEntry { addr, value, pc }
    }

    /// Forwarding picks the *newest* buffered store per address, even
    /// with several stores to one line interleaved with other lines —
    /// and the in-flight head still forwards (it is not globally
    /// visible until its completion).
    #[test]
    fn forward_returns_newest_store_per_address() {
        let mut sb = StoreBuffer::default();
        sb.push(entry(10, 1, 0));
        sb.push(entry(20, 9, 1));
        sb.push(entry(10, 2, 2));
        sb.push(entry(10, 3, 3));
        assert_eq!(sb.forward(10), Some(3), "newest of three buffered stores");
        assert_eq!(sb.forward(20), Some(9));
        assert_eq!(sb.forward(30), None);
        // Head in flight: still forwards.
        sb.set_inflight();
        assert_eq!(sb.forward(10), Some(3));
        assert_eq!(sb.inflight_addr(), Some(10));
    }

    /// The drain is strictly FIFO: heads pop in push order regardless
    /// of address, and popping clears the in-flight mark so the next
    /// head can issue (retirement ordering under back-pressure).
    #[test]
    fn drain_pops_heads_in_retirement_order() {
        let mut sb = StoreBuffer::default();
        for (i, addr) in [30u64, 10, 20, 10].iter().enumerate() {
            sb.push(entry(*addr, i as u64, i as u32));
        }
        let mut drained = Vec::new();
        while !sb.is_empty() {
            sb.set_inflight();
            assert!(sb.owns_completion(sb.inflight_addr().unwrap()));
            let e = sb.pop_head();
            assert!(!sb.inflight(), "pop must clear the in-flight mark");
            drained.push((e.addr, e.value));
        }
        assert_eq!(drained, vec![(30, 0), (10, 1), (20, 2), (10, 3)]);
    }

    /// Completion ownership is precise: only the in-flight head's
    /// address claims a Demand completion — an identical address
    /// deeper in the buffer (or no in-flight drain at all) does not.
    #[test]
    fn completion_ownership_tracks_only_the_inflight_head() {
        let mut sb = StoreBuffer::default();
        sb.push(entry(10, 1, 0));
        sb.push(entry(20, 2, 1));
        assert!(!sb.owns_completion(10), "nothing in flight yet");
        sb.set_inflight();
        assert!(sb.owns_completion(10));
        assert!(!sb.owns_completion(20), "only the head drains");
        sb.pop_head();
        assert!(!sb.owns_completion(20), "pop cleared the in-flight mark");
    }
}
