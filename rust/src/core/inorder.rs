//! In-order, single-issue core (paper Table V): one memory operation
//! per cycle, blocking on demand misses, with Tardis speculation
//! continuing through expired-load renewals (§IV-A).
//!
//! Under [`Consistency::Tso`] plain stores retire into a FIFO store
//! buffer and drain to the protocol in the background; loads forward
//! from the buffer and — per the relaxed Tardis 2.0 `pts` rule — need
//! not bump their timestamp past buffered stores, making store→load
//! reordering architecturally visible.  Synchronization (locks,
//! barriers, atomics, spins) fences: the buffer drains first.
//!
//! [`Consistency::Tso`]: crate::config::Consistency::Tso

use super::{barrier, sb_cap, CoreAction, CoreEnv, SbEntry, StoreBuffer};
use crate::config::Consistency;
use crate::hashing::FxHashMap;
use crate::prog::{Op, Program, Workload};
use crate::proto::{AccessDone, AccessOutcome, Coherence, Completion, CompletionKind, MemOp};
use crate::types::{
    CoreId, Cycle, LineAddr, BARRIER_COUNTER_LINE, BARRIER_SENSE_LINE,
};

/// What the core resumes once a blocked access completes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Cont {
    /// Plain trace load/store: advance pc.
    Plain,
    /// Lock test-and-set: acquired if old == 0, else spin.
    LockTas { lock: LineAddr },
    /// Spin-loop poll load; exit when `pred` is satisfied.
    SpinLoad,
    /// Barrier fetch-and-increment of the counter line.
    BarrierArrive,
    /// Last arrival resets the counter, then flips the sense.
    BarrierResetCounter,
    BarrierSetSense,
}

/// Why the core is spinning and what to do on exit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SpinGoal {
    /// Waiting for the lock word to read 0, then retry the Tas.
    LockFree { lock: LineAddr },
    /// Waiting for the barrier sense line to reach `target`.
    Sense { target: u64 },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    /// Issue the op at `pc` when next woken.
    Ready,
    /// Serving the pre-access compute gap.
    Gap,
    /// Demand access outstanding at the protocol.
    WaitDemand(Cont),
    /// Spinning: next wake re-polls `addr`.
    SpinPoll { addr: LineAddr, goal: SpinGoal },
    /// Spinning but parked (protocol will push SpinWake).
    SpinPark { addr: LineAddr, goal: SpinGoal },
    /// Waiting for outstanding speculative renewals to resolve (and,
    /// under TSO, the store buffer to drain) before issuing a
    /// non-re-executable op (store/atomic/sync/miss) or retiring.
    WaitDrain,
    Done,
}

pub struct InOrderCore {
    pub id: CoreId,
    program: Program,
    pc: usize,
    state: State,
    /// Completed barrier episodes (drives the local sense).
    barrier_count: u64,
    /// Accumulated rollback penalty to charge before the next issue.
    penalty: Cycle,
    /// Unresolved speculative renewals per address (window gate).
    spec_unresolved: FxHashMap<LineAddr, u32>,
    /// Speculation window: (pc, log idx) of every op executed since the
    /// first unresolved speculative load — all re-executable (hit or
    /// spec loads only).  Squashed + re-executed on misspeculation.
    window: Vec<(usize, usize)>,
    window_start: Option<usize>,
    /// Cycle the current spin started (for spin_cycles accounting).
    spin_since: Option<Cycle>,
    /// Spin context preserved across a Pending spin load.
    pending_spin: Option<(LineAddr, SpinGoal)>,
    /// TSO store buffer (empty under Sc).
    sb: StoreBuffer,
    /// Stalled on a full store buffer (WaitDrain resumes as soon as
    /// one slot frees, not on full drain).
    sb_stalled: bool,
    /// Dedup token for CoreWake events.
    pub next_wake: Option<Cycle>,
    pub finished_at: Option<Cycle>,
    pub committed_ops: u64,
}

impl InOrderCore {
    pub fn new(id: CoreId, workload: &Workload) -> Self {
        Self {
            id,
            program: workload.programs[id as usize].clone(),
            pc: 0,
            state: State::Ready,
            barrier_count: 0,
            penalty: 0,
            spec_unresolved: FxHashMap::default(),
            window: Vec::new(),
            window_start: None,
            spin_since: None,
            pending_spin: None,
            sb: StoreBuffer::default(),
            sb_stalled: false,
            next_wake: None,
            finished_at: None,
            committed_ops: 0,
        }
    }

    /// Engine entry: the core was woken at `now`.
    pub fn step(&mut self, now: Cycle, env: &mut CoreEnv) -> CoreAction {
        self.next_wake = None;
        match self.state {
            State::Done => CoreAction::Park,
            State::WaitDemand(_) | State::SpinPark { .. } => CoreAction::Park, // spurious
            State::WaitDrain => {
                if self.drain_satisfied(env) {
                    self.sb_stalled = false;
                    // WaitDrain is only ever entered after the op's
                    // compute gap was served; resume as Gap so the gap
                    // is not charged a second time.
                    self.state = State::Gap;
                    self.issue_current(now, env)
                } else {
                    CoreAction::Park
                }
            }
            State::Ready | State::Gap => self.issue_current(now, env),
            State::SpinPoll { addr, goal } => self.spin_poll(now, addr, goal, env),
        }
    }

    /// Issue (or finish gapping for) the op at pc.
    fn issue_current(&mut self, now: Cycle, env: &mut CoreEnv) -> CoreAction {
        if self.penalty > 0 {
            let p = self.penalty;
            self.penalty = 0;
            env.pctx.stats.rollback_cycles += p;
            return self.wake_at(now + p);
        }
        // Keep the store buffer draining in the background.
        self.pump_sb(now, env);
        let Some(&op) = self.program.ops.get(self.pc) else {
            // The final instruction cannot retire under an open
            // speculation window (a failure rolls the window back and
            // re-executes) or with undrained buffered stores.
            if !self.spec_unresolved.is_empty() || !self.sb.is_empty() {
                self.state = State::WaitDrain;
                return CoreAction::Park;
            }
            self.state = State::Done;
            self.finished_at = Some(now);
            return CoreAction::Finished;
        };
        // Serve the compute gap once per op.
        if self.state == State::Ready {
            let gap = match op {
                Op::Load { gap, .. } | Op::Store { gap, .. } => gap as Cycle,
                _ => 0,
            };
            if gap > 0 {
                self.state = State::Gap;
                return self.wake_at(now + gap);
            }
        }
        // While speculative renewals are unresolved, only re-executable
        // ops may issue (hit / speculative loads); everything else
        // drains the window first — stores and atomics must not commit
        // under an open speculation (like buffered stores behind a
        // branch).
        if !self.spec_unresolved.is_empty() {
            use crate::proto::Probe;
            // Bound the window like a ROB: past the cap, stall until
            // outstanding renewals resolve (keeps rollback re-execution
            // cost bounded, like a branch-mispredict flush).
            const WINDOW_CAP: usize = 16;
            let drain = self.window.len() >= WINDOW_CAP
                || match op {
                    // A store-buffer hit is re-executable (forwarding
                    // repeats or the drained value is re-read).
                    Op::Load { addr, .. } => {
                        self.sb.forward(addr).is_none()
                            && env.proto.probe(self.id, addr) == Probe::Miss
                    }
                    // TSO: a plain store retires into the buffer
                    // without touching protocol state, but it is not
                    // re-executable — a rollback past it would replay
                    // the (already retired) store.  Drain first.
                    _ => true,
                };
            if drain {
                self.state = State::WaitDrain;
                return CoreAction::Park;
            }
        }
        // TSO: synchronization is a fence — the store buffer drains
        // before lock/unlock/barrier microcode touches the protocol.
        if matches!(op, Op::Lock { .. } | Op::Unlock { .. } | Op::Barrier) && !self.sb.is_empty()
        {
            self.state = State::WaitDrain;
            return CoreAction::Park;
        }
        self.state = State::Ready;
        match op {
            Op::Load { addr, .. } => {
                // TSO store-to-load forwarding: the youngest buffered
                // store wins; the load completes locally and — per the
                // relaxed Tardis 2.0 pts rule — advances no timestamp.
                if env.consistency == Consistency::Tso {
                    if let Some(v) = self.sb.forward(addr) {
                        return self.finish_forwarded_load(now, addr, v, env);
                    }
                }
                let outcome = env.proto.core_access(self.id, addr, MemOp::Load, true, env.pctx);
                self.resolve_access(now, addr, MemOp::Load, Cont::Plain, outcome, env)
            }
            Op::Store { addr, value, .. } => {
                let v = value.unwrap_or_else(|| unique_store_value(self.id, self.pc));
                if env.consistency == Consistency::Tso {
                    return self.retire_store_to_sb(now, addr, v, env);
                }
                let mem = MemOp::Store { value: v };
                let outcome = env.proto.core_access(self.id, addr, mem, true, env.pctx);
                self.resolve_access(now, addr, mem, Cont::Plain, outcome, env)
            }
            Op::Lock { addr } => {
                let outcome = env.proto.core_access(self.id, addr, MemOp::Tas, false, env.pctx);
                self.resolve_access(now, addr, MemOp::Tas, Cont::LockTas { lock: addr }, outcome, env)
            }
            Op::Unlock { addr } => {
                let mem = MemOp::Store { value: 0 };
                let outcome = env.proto.core_access(self.id, addr, mem, false, env.pctx);
                self.resolve_access(now, addr, mem, Cont::Plain, outcome, env)
            }
            Op::Barrier => {
                let mem = MemOp::FetchAdd { delta: 1 };
                let outcome =
                    env.proto.core_access(self.id, BARRIER_COUNTER_LINE, mem, false, env.pctx);
                self.resolve_access(now, BARRIER_COUNTER_LINE, mem, Cont::BarrierArrive, outcome, env)
            }
        }
    }

    /// TSO: retire a plain store into the store buffer (or stall on a
    /// full buffer until one slot frees).
    fn retire_store_to_sb(
        &mut self,
        now: Cycle,
        addr: LineAddr,
        value: u64,
        env: &mut CoreEnv,
    ) -> CoreAction {
        if self.sb.len() >= sb_cap(env) {
            // Full: wait for the drain (pump_sb already left the head
            // in flight); the next drain completion frees a slot and
            // resumes this store.
            env.pctx.stats.sb_full_stalls += 1;
            env.pctx.emit(crate::obs::EventKind::SbStall, self.id, addr, 0);
            self.sb_stalled = true;
            self.state = State::WaitDrain;
            return CoreAction::Park;
        }
        self.sb.push(SbEntry { addr, value, pc: self.pc as u32 });
        env.pctx.stats.sb_stores += 1;
        self.committed_ops += 1;
        self.pc += 1;
        self.state = State::Ready;
        self.pump_sb(now, env);
        self.wake_at(now + 1)
    }

    /// TSO: complete a load from the store buffer (no protocol access,
    /// no timestamp movement — the relaxed Tardis 2.0 pts rule).
    fn finish_forwarded_load(
        &mut self,
        now: Cycle,
        addr: LineAddr,
        value: u64,
        env: &mut CoreEnv,
    ) -> CoreAction {
        env.pctx.stats.sb_forwards += 1;
        let idx = env.log_forwarded_load(self.id, self.pc as u32, addr, value, now);
        if self.window_start.is_some() {
            self.window.push((self.pc, idx));
        }
        env.pctx.stats.memops += 1;
        env.pctx.stats.loads += 1;
        self.committed_ops += 1;
        self.pc += 1;
        self.state = State::Ready;
        self.wake_at(now + 1)
    }

    /// Drain the store buffer: issue the oldest store and keep going
    /// while stores complete synchronously.  Postcondition: the buffer
    /// is empty or its head is in flight (drains never silently
    /// stall).
    fn pump_sb(&mut self, now: Cycle, env: &mut CoreEnv) {
        while !self.sb.inflight() {
            let Some(e) = self.sb.head() else { return };
            let mem = MemOp::Store { value: e.value };
            match env.proto.core_access(self.id, e.addr, mem, false, env.pctx) {
                AccessOutcome::Done(d) => {
                    self.log_drained(now, e, d.ts, env);
                    self.sb.pop_head();
                }
                AccessOutcome::Pending => self.sb.set_inflight(),
                AccessOutcome::SpecDone(_) => unreachable!("stores never speculate"),
            }
        }
    }

    /// A buffered store became globally visible: log it at its drain
    /// point (its position in the global memory order).
    fn log_drained(&mut self, now: Cycle, e: SbEntry, ts: crate::types::Ts, env: &mut CoreEnv) {
        env.log_access(self.id, e.pc, e.addr, None, Some(e.value), ts, now);
        env.pctx.stats.memops += 1;
        env.pctx.stats.stores += 1;
    }

    /// Handle the outcome of an access issued with continuation `cont`.
    fn resolve_access(
        &mut self,
        now: Cycle,
        addr: LineAddr,
        mem: MemOp,
        cont: Cont,
        outcome: AccessOutcome,
        env: &mut CoreEnv,
    ) -> CoreAction {
        match outcome {
            AccessOutcome::Done(d) => self.finish_access(now, addr, mem, cont, d, env),
            AccessOutcome::SpecDone(d) => {
                // Speculated load: open (or extend) the window.
                let idx = env.log_access(self.id, self.pc as u32, addr, Some(d.value), None, d.ts, now);
                if self.window_start.is_none() {
                    self.window_start = Some(self.pc);
                }
                self.window.push((self.pc, idx));
                *self.spec_unresolved.entry(addr).or_insert(0) += 1;
                self.committed_ops += 1;
                env.pctx.stats.memops += 1;
                env.pctx.stats.loads += 1;
                self.pc += 1;
                self.state = State::Ready;
                self.wake_at(now + 1 + d.extra_cycles)
            }
            AccessOutcome::Pending => {
                self.state = State::WaitDemand(cont);
                CoreAction::Park
            }
        }
    }

    /// An access finished with value `d`: log it and run the
    /// continuation.
    fn finish_access(
        &mut self,
        now: Cycle,
        addr: LineAddr,
        mem: MemOp,
        cont: Cont,
        d: AccessDone,
        env: &mut CoreEnv,
    ) -> CoreAction {
        let (read, written) = match mem {
            MemOp::Load => (Some(d.value), None),
            MemOp::Store { value } => (None, Some(value)),
            MemOp::Tas => (Some(d.value), Some(1)),
            MemOp::FetchAdd { delta } => (Some(d.value), Some(d.value.wrapping_add(delta))),
        };
        let idx = env.log_access(self.id, self.pc as u32, addr, read, written, d.ts, now);
        if self.window_start.is_some() {
            self.window.push((self.pc, idx));
        }
        env.pctx.stats.memops += 1;
        match mem {
            MemOp::Load => env.pctx.stats.loads += 1,
            MemOp::Store { .. } => env.pctx.stats.stores += 1,
            _ => env.pctx.stats.atomics += 1,
        }
        let next = now + 1 + d.extra_cycles;
        match cont {
            Cont::Plain => {
                self.committed_ops += 1;
                self.pc += 1;
                self.state = State::Ready;
                self.wake_at(next)
            }
            Cont::LockTas { lock } => {
                if d.value == 0 {
                    // Acquired.
                    env.pctx.stats.locks_acquired += 1;
                    self.committed_ops += 1;
                    self.pc += 1;
                    self.state = State::Ready;
                    self.wake_at(next)
                } else {
                    self.enter_spin(now, lock, SpinGoal::LockFree { lock }, env)
                }
            }
            Cont::SpinLoad => {
                let (State::SpinPoll { addr: saddr, goal } | State::SpinPark { addr: saddr, goal }) =
                    self.state
                else {
                    unreachable!("SpinLoad outside spin state");
                };
                debug_assert_eq!(saddr, addr);
                if self.spin_satisfied(goal, d.value) {
                    self.exit_spin(now, goal, env)
                } else {
                    self.continue_spin(now, addr, goal, env)
                }
            }
            Cont::BarrierArrive => {
                let old = d.value;
                let target = barrier::target_sense(self.barrier_count);
                if old == env.n_cores as u64 - 1 {
                    // Last arrival: reset the counter, then flip sense.
                    let mem = MemOp::Store { value: 0 };
                    let outcome = env.proto.core_access(
                        self.id,
                        BARRIER_COUNTER_LINE,
                        mem,
                        false,
                        env.pctx,
                    );
                    self.resolve_access(now, BARRIER_COUNTER_LINE, mem, Cont::BarrierResetCounter, outcome, env)
                } else {
                    self.enter_spin(now, BARRIER_SENSE_LINE, SpinGoal::Sense { target }, env)
                }
            }
            Cont::BarrierResetCounter => {
                let target = barrier::target_sense(self.barrier_count);
                let mem = MemOp::Store { value: target };
                let outcome =
                    env.proto.core_access(self.id, BARRIER_SENSE_LINE, mem, false, env.pctx);
                self.resolve_access(now, BARRIER_SENSE_LINE, mem, Cont::BarrierSetSense, outcome, env)
            }
            Cont::BarrierSetSense => {
                self.barrier_count += 1;
                env.pctx.stats.barriers_passed += 1;
                self.committed_ops += 1;
                self.pc += 1;
                self.state = State::Ready;
                self.wake_at(next)
            }
        }
    }

    fn spin_satisfied(&self, goal: SpinGoal, value: u64) -> bool {
        match goal {
            SpinGoal::LockFree { .. } => value == 0,
            SpinGoal::Sense { target } => value == target,
        }
    }

    /// Begin (or continue) spinning after an unsatisfying poll.
    fn enter_spin(&mut self, now: Cycle, addr: LineAddr, goal: SpinGoal, env: &mut CoreEnv) -> CoreAction {
        if self.spin_since.is_none() {
            self.spin_since = Some(now);
        }
        self.continue_spin(now, addr, goal, env)
    }

    fn continue_spin(&mut self, now: Cycle, addr: LineAddr, goal: SpinGoal, env: &mut CoreEnv) -> CoreAction {
        use crate::proto::SpinHint;
        match env.proto.spin_hint(self.id, addr, env.pctx) {
            SpinHint::Retry => {
                self.state = State::SpinPoll { addr, goal };
                self.wake_at(now + env.spin_poll)
            }
            SpinHint::WaitInvalidate => {
                self.state = State::SpinPark { addr, goal };
                CoreAction::Park
            }
            SpinHint::ExpiresAfterSelfInc { spins_needed } => {
                self.state = State::SpinPoll { addr, goal };
                self.wake_at(now + spins_needed.max(1) * env.spin_poll)
            }
        }
    }

    /// A poll is due: issue the spin load.
    fn spin_poll(&mut self, now: Cycle, addr: LineAddr, goal: SpinGoal, env: &mut CoreEnv) -> CoreAction {
        let outcome = env.proto.core_access(self.id, addr, MemOp::Load, false, env.pctx);
        match outcome {
            AccessOutcome::Done(d) => self.finish_spin_value(now, addr, goal, d, env),
            AccessOutcome::Pending => {
                // Preserve the spin context for the completion path.
                self.state = State::WaitDemand(Cont::SpinLoad);
                self.pending_spin = Some((addr, goal));
                CoreAction::Park
            }
            AccessOutcome::SpecDone(_) => unreachable!("spin loads never speculate"),
        }
    }

    fn finish_spin_value(
        &mut self,
        now: Cycle,
        addr: LineAddr,
        goal: SpinGoal,
        d: AccessDone,
        env: &mut CoreEnv,
    ) -> CoreAction {
        env.log_access(self.id, self.pc as u32, addr, Some(d.value), None, d.ts, now);
        env.pctx.stats.memops += 1;
        env.pctx.stats.loads += 1;
        if self.spin_satisfied(goal, d.value) {
            self.exit_spin(now, goal, env)
        } else {
            self.state = State::SpinPoll { addr, goal };
            self.continue_spin(now, addr, goal, env)
        }
    }

    /// The spin predicate finally holds.
    fn exit_spin(&mut self, now: Cycle, goal: SpinGoal, env: &mut CoreEnv) -> CoreAction {
        if let Some(start) = self.spin_since.take() {
            env.pctx.stats.spin_cycles += now - start;
        }
        match goal {
            SpinGoal::LockFree { lock } => {
                // Retry the Tas next cycle.
                let outcome = env.proto.core_access(self.id, lock, MemOp::Tas, false, env.pctx);
                self.resolve_access(now, lock, MemOp::Tas, Cont::LockTas { lock }, outcome, env)
            }
            SpinGoal::Sense { .. } => {
                self.barrier_count += 1;
                env.pctx.stats.barriers_passed += 1;
                self.committed_ops += 1;
                self.pc += 1;
                self.state = State::Ready;
                self.wake_at(now + 1)
            }
        }
    }

    /// Protocol completion for this core.
    pub fn on_completion(&mut self, c: &Completion, now: Cycle, env: &mut CoreEnv) -> CoreAction {
        // TSO drain completion, matched by address against the
        // in-flight buffered store.  Never ambiguous with a blocking
        // demand: a load to a buffered address forwards instead of
        // issuing, and sync microcode runs with the buffer empty.
        if c.kind == CompletionKind::Demand && self.sb.owns_completion(c.addr) {
            let e = self.sb.pop_head();
            self.log_drained(now, e, c.ts, env);
            self.pump_sb(now, env);
            return self.maybe_resume_drain(now, env);
        }
        match c.kind {
            CompletionKind::Misspec => {
                // Failed renewal: roll the speculation window back —
                // squash everything executed since the first unresolved
                // speculative load and re-execute from there (branch-
                // mispredict analogy, §IV-A).
                self.spec_resolve(c.addr);
                if let Some(start) = self.window_start.take() {
                    self.penalty += env.rollback_penalty;
                    for &(_, idx) in &self.window {
                        if idx != usize::MAX {
                            env.obs.squash(idx);
                        }
                    }
                    // Re-executed ops do not recount toward memops.
                    let n = self.window.len() as u64;
                    self.committed_ops = self.committed_ops.saturating_sub(n);
                    env.pctx.stats.memops = env.pctx.stats.memops.saturating_sub(n);
                    self.window.clear();
                    self.pc = start;
                    self.state = State::Ready;
                    self.wake_at(now + 1)
                } else {
                    // Already rolled back by an earlier failure.
                    self.maybe_resume_drain(now, env)
                }
            }
            CompletionKind::SpecOk => {
                self.spec_resolve(c.addr);
                if self.spec_unresolved.is_empty() {
                    // Window commits.
                    self.window.clear();
                    self.window_start = None;
                }
                self.maybe_resume_drain(now, env)
            }
            CompletionKind::SpinWake => match self.state {
                State::SpinPark { addr, goal } if addr == c.addr => {
                    self.state = State::SpinPoll { addr, goal };
                    self.wake_at(now + 1)
                }
                _ => CoreAction::Park, // stale wake
            },
            CompletionKind::Demand => {
                let State::WaitDemand(cont) = self.state else {
                    return CoreAction::Park; // stale (e.g., already rolled back)
                };
                match cont {
                    Cont::SpinLoad => {
                        let (addr, goal) = self.pending_spin.take().expect("spin context");
                        debug_assert_eq!(addr, c.addr);
                        self.state = State::SpinPoll { addr, goal };
                        self.finish_spin_value(
                            now,
                            addr,
                            goal,
                            AccessDone { value: c.value, ts: c.ts, extra_cycles: 0 },
                            env,
                        )
                    }
                    cont => {
                        let mem = self.current_memop(cont);
                        self.state = State::Ready;
                        self.finish_access(
                            now,
                            c.addr,
                            mem,
                            cont,
                            AccessDone { value: c.value, ts: c.ts, extra_cycles: 0 },
                            env,
                        )
                    }
                }
            }
        }
    }

    /// Reconstruct the MemOp a continuation was issued with (for
    /// logging at completion time).
    fn current_memop(&self, cont: Cont) -> MemOp {
        match cont {
            Cont::Plain => match self.program.ops[self.pc] {
                Op::Load { .. } => MemOp::Load,
                Op::Store { addr: _, value, .. } => MemOp::Store {
                    value: value.unwrap_or_else(|| unique_store_value(self.id, self.pc)),
                },
                Op::Unlock { .. } => MemOp::Store { value: 0 },
                _ => unreachable!(),
            },
            Cont::LockTas { .. } => MemOp::Tas,
            Cont::SpinLoad => MemOp::Load,
            Cont::BarrierArrive => MemOp::FetchAdd { delta: 1 },
            Cont::BarrierResetCounter => MemOp::Store { value: 0 },
            Cont::BarrierSetSense => {
                MemOp::Store { value: barrier::target_sense(self.barrier_count) }
            }
        }
    }

    fn wake_at(&mut self, t: Cycle) -> CoreAction {
        self.next_wake = Some(t);
        CoreAction::WakeAt(t)
    }

    /// Diagnostic snapshot for deadlock reports.
    pub fn state_string(&self) -> String {
        format!(
            "core {} pc {}/{} state {:?} specs {:?} sb {} next_wake {:?}",
            self.id,
            self.pc,
            self.program.len(),
            self.state,
            self.spec_unresolved,
            self.sb.len(),
            self.next_wake
        )
    }

    /// Mark one speculative renewal for `addr` resolved.
    fn spec_resolve(&mut self, addr: LineAddr) {
        if let Some(n) = self.spec_unresolved.get_mut(&addr) {
            *n -= 1;
            if *n == 0 {
                self.spec_unresolved.remove(&addr);
            }
        }
    }

    /// Is the condition WaitDrain is parked on satisfied?  Fences and
    /// retirement need the speculation window and the buffer fully
    /// drained; a full-buffer stall only needs one free slot.
    fn drain_satisfied(&self, env: &CoreEnv) -> bool {
        self.spec_unresolved.is_empty()
            && if self.sb_stalled {
                self.sb.len() < sb_cap(env)
            } else {
                self.sb.is_empty()
            }
    }

    /// Wake the core if it was draining and its drain condition just
    /// became satisfied.  (`sb_stalled` is cleared by the WaitDrain
    /// step arm, which re-evaluates the same condition at the wake —
    /// clearing it here would demote a one-slot stall back to a
    /// full-drain wait.)
    fn maybe_resume_drain(&mut self, now: Cycle, env: &CoreEnv) -> CoreAction {
        if self.state == State::WaitDrain && self.drain_satisfied(env) {
            self.wake_at(now + 1)
        } else {
            CoreAction::Park
        }
    }
}

/// Unique per-(core, pc) store value (trace stores carry no payload).
fn unique_store_value(core: CoreId, pc: usize) -> u64 {
    crate::prog::Workload::store_value(core, pc)
}
