//! Memory-hierarchy substrates: address mapping, set-associative cache
//! arrays, and the DRAM timing model.

pub mod addr;
pub mod cache;
pub mod dram;

pub use addr::SliceMap;
pub use cache::SetAssoc;
pub use dram::Dram;
