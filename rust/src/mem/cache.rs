//! Generic set-associative cache array with LRU replacement.  Both the
//! private L1s and the LLC slices instantiate this with their own
//! per-line metadata type.

use crate::types::LineAddr;

#[derive(Debug, Clone)]
struct Entry<T> {
    /// Full line address (index hashing makes set+tag reconstruction
    /// non-trivial, so the whole address is kept).
    tag: u64,
    valid: bool,
    lru: u64,
    data: T,
}

/// A set-associative array of `sets * ways` lines indexed by line
/// address.  `T` is the protocol's per-line state.
///
/// Probing is on the engine's hot path (§Perf): the set index uses a
/// precomputed mask when `sets` is a power of two (every paper
/// geometry), and the lookup family is `#[inline]` so the tag loop
/// unrolls to `ways` compares at the call site.
#[derive(Debug, Clone)]
pub struct SetAssoc<T> {
    sets: u32,
    ways: u32,
    /// `sets - 1` when `sets` is a power of two; `u64::MAX` sentinel
    /// selects the generic modulo path otherwise.
    set_mask: u64,
    tick: u64,
    entries: Vec<Entry<T>>,
}

impl<T> SetAssoc<T> {
    pub fn new(sets: u32, ways: u32) -> Self
    where
        T: Default + Clone,
    {
        assert!(sets > 0 && ways > 0);
        Self {
            sets,
            ways,
            set_mask: if sets.is_power_of_two() { sets as u64 - 1 } else { u64::MAX },
            tick: 0,
            entries: vec![
                Entry { tag: 0, valid: false, lru: 0, data: T::default() };
                (sets * ways) as usize
            ],
        }
    }

    /// Set index with hashing: regular address strides (e.g., the
    /// trace format's 64 KiB private regions) would otherwise collide
    /// whole working sets into a handful of sets; real LLCs hash the
    /// index for the same reason.
    #[inline(always)]
    fn set_of(&self, addr: LineAddr) -> u32 {
        let mut x = addr;
        x ^= x >> 33;
        x = x.wrapping_mul(0xFF51AFD7ED558CCD);
        x ^= x >> 33;
        if self.set_mask != u64::MAX {
            (x & self.set_mask) as u32
        } else {
            (x % self.sets as u64) as u32
        }
    }

    #[inline(always)]
    fn tag_of(&self, addr: LineAddr) -> u64 {
        addr
    }

    #[inline(always)]
    fn set_range(&self, set: u32) -> std::ops::Range<usize> {
        let base = (set * self.ways) as usize;
        base..base + self.ways as usize
    }

    /// Line address of an entry index.
    fn addr_of(&self, idx: usize) -> LineAddr {
        self.entries[idx].tag
    }

    /// Look up a line, updating LRU on hit.
    #[inline]
    pub fn get_mut(&mut self, addr: LineAddr) -> Option<&mut T> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        self.tick += 1;
        let tick = self.tick;
        let range = self.set_range(set);
        self.entries[range]
            .iter_mut()
            .find(|e| e.valid && e.tag == tag)
            .map(|e| {
                e.lru = tick;
                &mut e.data
            })
    }

    /// Look up without touching LRU (for snoops / external requests).
    #[inline]
    pub fn peek_mut(&mut self, addr: LineAddr) -> Option<&mut T> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        let range = self.set_range(set);
        self.entries[range]
            .iter_mut()
            .find(|e| e.valid && e.tag == tag)
            .map(|e| &mut e.data)
    }

    #[inline]
    pub fn peek(&self, addr: LineAddr) -> Option<&T> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        self.entries[self.set_range(set)]
            .iter()
            .find(|e| e.valid && e.tag == tag)
            .map(|e| &e.data)
    }

    /// Insert a line, evicting the LRU entry among those `evictable`
    /// admits.  Returns `Ok(Some((victim_addr, victim_state)))` if a
    /// valid line was displaced, `Ok(None)` if a free way was used, and
    /// `Err(data)` if every way is pinned (caller must retry later).
    pub fn insert_filtered(
        &mut self,
        addr: LineAddr,
        data: T,
        evictable: impl Fn(&T) -> bool,
    ) -> Result<Option<(LineAddr, T)>, T> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        self.tick += 1;
        let tick = self.tick;
        let range = self.set_range(set);

        debug_assert!(
            !self.entries[range.clone()].iter().any(|e| e.valid && e.tag == tag),
            "insert over existing line"
        );

        // Prefer a free way.
        if let Some(idx) = range.clone().find(|&i| !self.entries[i].valid) {
            self.entries[idx] = Entry { tag, valid: true, lru: tick, data };
            return Ok(None);
        }
        // Otherwise evict the LRU admissible line.
        let victim = range
            .filter(|&i| evictable(&self.entries[i].data))
            .min_by_key(|&i| self.entries[i].lru);
        match victim {
            Some(idx) => {
                let vaddr = self.addr_of(idx);
                let old = std::mem::replace(
                    &mut self.entries[idx],
                    Entry { tag, valid: true, lru: tick, data },
                );
                Ok(Some((vaddr, old.data)))
            }
            None => Err(data),
        }
    }

    /// Pick the LRU admissible victim in `addr`'s set without
    /// inserting anything.  Returns the victim's line address, or None
    /// if the set has a free way or no admissible victim.
    pub fn victim_for(&self, addr: LineAddr, admissible: impl Fn(&T) -> bool) -> Option<LineAddr> {
        let set = self.set_of(addr);
        let range = self.set_range(set);
        if range.clone().any(|i| !self.entries[i].valid) {
            return None;
        }
        range
            .filter(|&i| admissible(&self.entries[i].data))
            .min_by_key(|&i| self.entries[i].lru)
            .map(|i| self.addr_of(i))
    }

    /// Insert with every line evictable.
    pub fn insert(&mut self, addr: LineAddr, data: T) -> Option<(LineAddr, T)> {
        match self.insert_filtered(addr, data, |_| true) {
            Ok(v) => v,
            Err(_) => unreachable!("unfiltered insert cannot fail"),
        }
    }

    /// Remove a line, returning its state.
    pub fn invalidate(&mut self, addr: LineAddr) -> Option<T>
    where
        T: Default,
    {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        let range = self.set_range(set);
        for i in range {
            if self.entries[i].valid && self.entries[i].tag == tag {
                self.entries[i].valid = false;
                return Some(std::mem::take(&mut self.entries[i].data));
            }
        }
        None
    }

    /// Visit every valid line (rebase scans, checkers).  The callback
    /// returns `false` to invalidate the line in place.
    pub fn retain_lines(&mut self, mut f: impl FnMut(LineAddr, &mut T) -> bool) {
        for i in 0..self.entries.len() {
            if self.entries[i].valid {
                let addr = self.addr_of(i);
                if !f(addr, &mut self.entries[i].data) {
                    self.entries[i].valid = false;
                }
            }
        }
    }

    /// Iterate all valid lines immutably.
    pub fn for_each(&self, mut f: impl FnMut(LineAddr, &T)) {
        for i in 0..self.entries.len() {
            if self.entries[i].valid {
                f(self.addr_of(i), &self.entries[i].data);
            }
        }
    }

    pub fn occupancy(&self) -> usize {
        self.entries.iter().filter(|e| e.valid).count()
    }

    pub fn capacity(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(sets: u32, ways: u32) -> SetAssoc<u64> {
        SetAssoc::new(sets, ways)
    }

    #[test]
    fn hit_after_insert() {
        let mut c = cache(4, 2);
        assert!(c.insert(13, 99).is_none());
        assert_eq!(c.get_mut(13), Some(&mut 99));
        assert_eq!(c.peek(13), Some(&99));
        assert!(c.get_mut(14).is_none());
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = cache(1, 2);
        c.insert(0, 100);
        c.insert(1, 101);
        // Touch 0 so 1 becomes LRU.
        c.get_mut(0);
        let evicted = c.insert(2, 102);
        assert_eq!(evicted, Some((1, 101)));
        assert!(c.peek(0).is_some());
        assert!(c.peek(2).is_some());
    }

    #[test]
    fn victim_address_reconstruction() {
        let mut c = cache(8, 1);
        c.insert(3, 1); // set 3, tag 0
        let evicted = c.insert(11, 2); // set 3, tag 1
        assert_eq!(evicted, Some((3, 1)));
        let evicted = c.insert(19, 3); // set 3, tag 2
        assert_eq!(evicted, Some((11, 2)));
    }

    #[test]
    fn filtered_insert_respects_pins() {
        let mut c = cache(1, 2);
        c.insert(0, 100);
        c.insert(1, 101);
        // Only value 101 is evictable.
        let r = c.insert_filtered(2, 102, |v| *v == 101);
        assert_eq!(r, Ok(Some((1, 101))));
        // Now 100 and 102 are pinned: insertion fails.
        let r = c.insert_filtered(3, 103, |_| false);
        assert_eq!(r, Err(103));
    }

    #[test]
    fn invalidate_removes() {
        let mut c = cache(4, 2);
        c.insert(5, 50);
        assert_eq!(c.invalidate(5), Some(50));
        assert!(c.peek(5).is_none());
        assert_eq!(c.invalidate(5), None);
    }

    #[test]
    fn retain_lines_scan_and_drop() {
        let mut c = cache(4, 4);
        for a in 0..12u64 {
            c.insert(a, a * 10);
        }
        assert_eq!(c.occupancy(), 12);
        // Drop odd addresses.
        c.retain_lines(|addr, _| addr % 2 == 0);
        assert_eq!(c.occupancy(), 6);
        assert!(c.peek(4).is_some());
        assert!(c.peek(5).is_none());
    }

    #[test]
    fn non_power_of_two_sets_still_probe_correctly() {
        // Exercises the modulo fallback behind the pow2 mask path.
        // Two inserts cannot evict from a 2-way cache, so both lines
        // must be retrievable wherever they hash.
        let mut c: SetAssoc<u64> = SetAssoc::new(3, 2);
        c.insert(1_000, 1);
        c.insert(2_000, 2);
        assert_eq!(c.peek(1_000), Some(&1));
        assert_eq!(c.get_mut(2_000), Some(&mut 2));
        assert_eq!(c.peek(3_000), None);
    }

    #[test]
    fn peek_does_not_disturb_lru() {
        let mut c = cache(1, 2);
        c.insert(0, 100);
        c.insert(1, 101);
        // peek 0, then insert: LRU should still evict 0.
        c.peek_mut(0);
        let evicted = c.insert(2, 102);
        assert_eq!(evicted, Some((0, 100)));
    }
}
