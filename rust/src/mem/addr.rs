//! Address-to-home mapping: LLC slices and memory controllers are
//! line-interleaved across the chip.

use crate::types::{LineAddr, McId, SliceId};

/// Home LLC slice (timestamp-manager / directory slice) of a line.
pub fn home_slice(addr: LineAddr, n_slices: u32) -> SliceId {
    (addr % n_slices as u64) as SliceId
}

/// Memory controller serving a line.
pub fn home_mc(addr: LineAddr, n_mcs: u32) -> McId {
    ((addr / 8) % n_mcs as u64) as McId
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_interleave_covers_all() {
        let mut seen = vec![false; 16];
        for a in 0..64u64 {
            seen[home_slice(a, 16) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mc_interleave_is_block_wise() {
        // 8-line blocks map to the same MC, consecutive blocks rotate.
        assert_eq!(home_mc(0, 8), home_mc(7, 8));
        assert_ne!(home_mc(0, 8), home_mc(8, 8));
    }
}
