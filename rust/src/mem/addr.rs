//! Address-to-home mapping: LLC slices and memory controllers are
//! line-interleaved across the chip — or, on a multi-socket system,
//! interleaved with a socket-aware policy ([`SliceMap`]).

use crate::config::{SocketInterleave, SystemConfig};
use crate::types::{LineAddr, McId, SliceId};

/// Lines per home block: the granularity at which both the MC
/// interleave and the `Block` socket interleave rotate homes.
pub const HOME_BLOCK_LINES: u64 = 8;

/// Home LLC slice (timestamp-manager / directory slice) of a line
/// under the flat global line interleave.
pub fn home_slice(addr: LineAddr, n_slices: u32) -> SliceId {
    (addr % n_slices as u64) as SliceId
}

/// Memory controller serving a line under the flat block interleave.
pub fn home_mc(addr: LineAddr, n_mcs: u32) -> McId {
    ((addr / HOME_BLOCK_LINES) % n_mcs as u64) as McId
}

/// The address -> (LLC slice, memory controller) map a protocol homes
/// requests through, configured once from [`SystemConfig`] (the
/// protocols used to hard-code `home_mc(addr, 8)`).
///
/// With `SocketInterleave::Line` — or on any single-socket system —
/// it is bit-for-bit the flat [`home_slice`]/[`home_mc`] maps.  With
/// `Block` on a multi-socket system, consecutive 8-line blocks rotate
/// across sockets and a line's slice *and* controller both live on
/// its home socket, so a block's coherence and DRAM traffic stay
/// socket-local.
#[derive(Debug, Clone, Copy)]
pub struct SliceMap {
    n_slices: u32,
    n_mcs: u32,
    n_sockets: u32,
    slices_per_socket: u32,
    mcs_per_socket: u32,
    interleave: SocketInterleave,
}

impl SliceMap {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n_sockets = cfg.topology.sockets.max(1);
        Self {
            n_slices: cfg.n_cores,
            n_mcs: cfg.n_mcs,
            n_sockets,
            slices_per_socket: (cfg.n_cores / n_sockets).max(1),
            mcs_per_socket: (cfg.n_mcs / n_sockets).max(1),
            interleave: cfg.topology.interleave,
        }
    }

    /// Home socket of a line under `Block` interleave (its only
    /// caller; `Line` homing does not rotate by block — a Line-homed
    /// line's socket is wherever `addr % n_slices` happens to land).
    fn home_socket(&self, addr: LineAddr) -> u64 {
        (addr / HOME_BLOCK_LINES) % self.n_sockets as u64
    }

    /// Index of a line's block within its home socket's block
    /// sequence.  Local slice/MC indices must derive from this — not
    /// from raw address bits, which are correlated with the socket
    /// selector and would leave a gcd-dependent subset of each
    /// socket's slices/controllers permanently unhomed.
    fn socket_block(&self, addr: LineAddr) -> u64 {
        (addr / HOME_BLOCK_LINES) / self.n_sockets as u64
    }

    #[inline]
    pub fn home_slice(&self, addr: LineAddr) -> SliceId {
        match self.interleave {
            SocketInterleave::Line => home_slice(addr, self.n_slices),
            SocketInterleave::Block => {
                let socket = self.home_socket(addr);
                // The line's position in the socket's concatenated
                // block sequence, line-interleaved over its slices
                // (degenerates to the flat map at one socket).
                let line_in_socket =
                    self.socket_block(addr) * HOME_BLOCK_LINES + addr % HOME_BLOCK_LINES;
                let local = line_in_socket % self.slices_per_socket as u64;
                (socket * self.slices_per_socket as u64 + local) as SliceId
            }
        }
    }

    #[inline]
    pub fn home_mc(&self, addr: LineAddr) -> McId {
        match self.interleave {
            SocketInterleave::Line => home_mc(addr, self.n_mcs),
            SocketInterleave::Block => {
                let socket = self.home_socket(addr);
                let local = self.socket_block(addr) % self.mcs_per_socket as u64;
                (socket * self.mcs_per_socket as u64 + local) as McId
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyConfig;

    fn map(n_cores: u32, n_mcs: u32, sockets: u32, interleave: SocketInterleave) -> SliceMap {
        let cfg = SystemConfig {
            n_cores,
            n_mcs,
            topology: TopologyConfig { sockets, interleave, ..TopologyConfig::default() },
            ..SystemConfig::default()
        };
        SliceMap::new(&cfg)
    }

    #[test]
    fn slice_interleave_covers_all() {
        let mut seen = vec![false; 16];
        for a in 0..64u64 {
            seen[home_slice(a, 16) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mc_interleave_is_block_wise() {
        // 8-line blocks map to the same MC, consecutive blocks rotate.
        assert_eq!(home_mc(0, 8), home_mc(7, 8));
        assert_ne!(home_mc(0, 8), home_mc(8, 8));
    }

    #[test]
    fn line_map_matches_flat_functions_exactly() {
        // The default map is bit-for-bit the flat interleave, however
        // many sockets the fabric has.
        for sockets in [1u32, 2, 4] {
            let m = map(64, 8, sockets, SocketInterleave::Line);
            for addr in 0..512u64 {
                assert_eq!(m.home_slice(addr), home_slice(addr, 64));
                assert_eq!(m.home_mc(addr), home_mc(addr, 8));
            }
        }
    }

    #[test]
    fn block_map_on_one_socket_degenerates_to_line() {
        let m = map(64, 8, 1, SocketInterleave::Block);
        for addr in 0..512u64 {
            assert_eq!(m.home_slice(addr), home_slice(addr, 64));
            assert_eq!(m.home_mc(addr), home_mc(addr, 8));
        }
    }

    #[test]
    fn block_map_keeps_slice_and_mc_on_the_home_socket() {
        // 64 slices / 8 MCs over 4 sockets: 16 slices + 2 MCs each.
        let m = map(64, 8, 4, SocketInterleave::Block);
        for addr in 0..4096u64 {
            let slice_socket = m.home_slice(addr) / 16;
            let mc_socket = m.home_mc(addr) / 2;
            assert_eq!(slice_socket, mc_socket, "addr {addr} split across sockets");
            // An 8-line block never straddles sockets.
            assert_eq!(slice_socket as u64, (addr / 8) % 4);
        }
        // All slices and controllers are reachable.
        let slices: std::collections::BTreeSet<u32> =
            (0..4096u64).map(|a| m.home_slice(a)).collect();
        assert_eq!(slices.len(), 64);
        let mcs: std::collections::BTreeSet<u32> = (0..4096u64).map(|a| m.home_mc(a)).collect();
        assert_eq!(mcs.len(), 8);
    }
}
