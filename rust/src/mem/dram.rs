//! DRAM timing model: fixed access latency plus per-controller
//! bandwidth occupancy (paper Table V: 8 MCs, 10 GB/s each, 100 ns).

use crate::types::{Cycle, McId};

/// Per-controller queue model: each access occupies its controller for
/// `service_cycles` (64 B / 10 GB/s = 6.4 ns ≈ 7 cycles) and completes
/// `latency` cycles after it starts service.
#[derive(Debug, Clone)]
pub struct Dram {
    latency: Cycle,
    service_cycles: Cycle,
    next_free: Vec<Cycle>,
    pub accesses: u64,
    pub stall_cycles: u64,
}

impl Dram {
    pub fn new(n_mcs: u32, latency: Cycle, service_cycles: Cycle) -> Self {
        Self {
            latency,
            service_cycles,
            next_free: vec![0; n_mcs as usize],
            accesses: 0,
            stall_cycles: 0,
        }
    }

    /// Schedule an access arriving at controller `mc` at `now`; returns
    /// the completion cycle.
    pub fn access(&mut self, mc: McId, now: Cycle) -> Cycle {
        let idx = mc as usize % self.next_free.len();
        let slot = &mut self.next_free[idx];
        let start = now.max(*slot);
        self.stall_cycles += start - now;
        *slot = start + self.service_cycles;
        self.accesses += 1;
        start + self.latency
    }

    /// Next-free cycle of controller `mc`'s service slot (tile
    /// migration: the slot travels with the controller's tile).
    pub(crate) fn slot(&self, mc: McId) -> Cycle {
        self.next_free[mc as usize % self.next_free.len()]
    }

    pub(crate) fn set_slot(&mut self, mc: McId, t: Cycle) {
        let idx = mc as usize % self.next_free.len();
        self.next_free[idx] = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncontended_access_takes_latency() {
        let mut d = Dram::new(8, 100, 7);
        assert_eq!(d.access(0, 1000), 1100);
        assert_eq!(d.accesses, 1);
        assert_eq!(d.stall_cycles, 0);
    }

    #[test]
    fn back_to_back_accesses_queue() {
        let mut d = Dram::new(1, 100, 7);
        assert_eq!(d.access(0, 0), 100);
        // Second access at the same cycle waits for the service slot.
        assert_eq!(d.access(0, 0), 107);
        assert_eq!(d.access(0, 0), 114);
        assert_eq!(d.stall_cycles, 7 + 14);
    }

    #[test]
    fn controllers_are_independent() {
        let mut d = Dram::new(2, 100, 7);
        assert_eq!(d.access(0, 0), 100);
        assert_eq!(d.access(1, 0), 100);
    }

    #[test]
    fn idle_gap_resets_queue() {
        let mut d = Dram::new(1, 100, 7);
        d.access(0, 0);
        // Long after the service window, no queueing.
        assert_eq!(d.access(0, 1000), 1100);
    }
}
