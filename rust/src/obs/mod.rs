//! The coherence flight recorder (DESIGN.md §12).
//!
//! Records protocol-level events — demand misses, lease expiries,
//! renewal outcomes, lease grants, pts jumps, livelock escalations,
//! store-buffer stalls — into a compact per-shard ring buffer as the
//! simulation runs, and replays one recording as three views:
//!
//! 1. a `tardis-trace-v1` Chrome trace-event JSON export
//!    ([`export_chrome`], loadable in Perfetto) where protocol events
//!    live on the *sim-time* clock (pid 1, `ts` = cycle) and PDES
//!    execution spans live on an explicitly tagged *host-time* process
//!    (pid 2, `cat: "host"`);
//! 2. an interval metrics timeline ([`timeline`]: renewal counts, avg
//!    lease, a log2 pts-gap histogram per window of N cycles), plus
//!    the [`MetricsWindow`] delta helper that surfaces the same
//!    interval metrics through `Observer::on_sample`, serve progress
//!    frames, and the bench per-point summary;
//! 3. top-K hot-line / hot-core attribution tables ([`hot_lines`],
//!    [`hot_cores`]) printed by `tardis trace` and embedded in the
//!    export's `otherData`.
//!
//! Determinism contract: trace events are *simulated* quantities, like
//! stats.  Each shard appends into its own [`TraceBuf`] in dispatch
//! order; the PDES driver merges per-dispatch event groups in the same
//! canonical `(cycle, PushKey)` order the SC log already uses, so the
//! merged event sequence — and therefore the default export — is
//! bit-for-bit identical across serial, epoch, null-message, and any
//! thread count.  Host-time spans ([`ExecEvent`], per-shard busy/wait)
//! are execution-strategy telemetry, excluded from the default export
//! and only emitted behind `--host-spans`.
//!
//! Recording is zero-cost when disabled: [`TraceBuf::default`] is a
//! disabled buffer whose `push` is one predictable branch, and no
//! engine behavior depends on the recorder's state.

use std::fmt::Write as _;

use crate::hashing::FxHashMap;
use crate::stats::{ParallelStats, SimStats};
use crate::types::{Cycle, LineAddr};

/// Per-shard ring-buffer capacity (events).  Chosen so a worst-case
/// 64-shard run stays well under a gigabyte while typical sweeps never
/// drop anything; the per-shard cap composes with the post-merge
/// global truncation to the same constant (see [`TraceBuf`] docs for
/// the determinism argument).
pub const TRACE_CAP: usize = 1 << 20;

/// Histogram buckets in the per-window pts-gap histogram (log2 of the
/// `pts - rts` gap at lease expiry; bucket 0 is gap 0, the top bucket
/// collects everything >= 2^14).
pub const PTS_GAP_BUCKETS: usize = 16;

/// Protocol-level event kinds the recorder captures.  The wire name
/// ([`EventKind::name`]) is the `name` field of the exported Chrome
/// trace event and part of the `tardis-trace-v1` schema
/// (tools/validate_trace.py mirrors the list).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EventKind {
    /// Demand miss: a load/store that missed (or needed an upgrade)
    /// and issued a request to the home slice.  arg = 1 for writes.
    Demand,
    /// An expired-lease load: the line was present but `rts < pts`, so
    /// a renewal was issued.  arg = the pts − rts gap at expiry (the
    /// quantity the pts-gap histogram bins).
    LeaseExpire,
    /// A renewal resolved with an unchanged wts (the paper's cheap
    /// flit-level renewal).  arg = 0.
    RenewOk,
    /// A renewal came back with new data (the line had been written):
    /// the speculation window squashes or re-executes.  arg = 0.
    RenewFail,
    /// The home slice granted a shared lease.  arg = the effective
    /// lease length; exported as a sim-time span of that duration.
    LeaseGrant,
    /// A core's pts advanced.  arg = the delta.  addr = 0 (pts is
    /// per-core state, not per-line).
    PtsJump,
    /// The livelock guard escalated a starved renewal to a blocking
    /// demand.  arg = 0.
    Livelock,
    /// A TSO store buffer filled and stalled retirement.  arg = 0.
    SbStall,
}

impl EventKind {
    /// Every kind, in export order (the schema vocabulary).
    pub const ALL: [EventKind; 8] = [
        EventKind::Demand,
        EventKind::LeaseExpire,
        EventKind::RenewOk,
        EventKind::RenewFail,
        EventKind::LeaseGrant,
        EventKind::PtsJump,
        EventKind::Livelock,
        EventKind::SbStall,
    ];

    /// Stable wire name (the exported `name` field).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Demand => "demand",
            EventKind::LeaseExpire => "lease_expire",
            EventKind::RenewOk => "renew_ok",
            EventKind::RenewFail => "renew_fail",
            EventKind::LeaseGrant => "lease_grant",
            EventKind::PtsJump => "pts_jump",
            EventKind::Livelock => "livelock",
            EventKind::SbStall => "sb_stall",
        }
    }
}

/// One recorded protocol event.  24 bytes + kind; everything needed to
/// reconstruct the three views without re-running the simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Simulated cycle the handling dispatch ran at.
    pub cycle: Cycle,
    /// Line address (0 for per-core events like pts jumps).
    pub addr: LineAddr,
    /// Kind-specific argument (lease length, pts delta, gap...).
    pub arg: u64,
    /// Core the event is attributed to (the export's `tid`).
    pub core: u32,
    pub kind: EventKind,
}

/// Per-shard append buffer with a hard capacity.
///
/// Determinism under capping: each shard's buffer is appended in
/// dispatch order, which the PDES merge re-sorts into the canonical
/// `(cycle, PushKey)` order.  Because the merge preserves each shard's
/// relative order, the events a shard contributes to the global first
/// `TRACE_CAP` are a *prefix* of that shard's local sequence — so a
/// per-shard cap of the same constant can never evict an event the
/// global truncation would have kept, and merged-then-truncated equals
/// the serial recording bit for bit.  `emitted` keeps counting past
/// the cap so the dropped total is exact (and itself deterministic).
#[derive(Debug, Default)]
pub struct TraceBuf {
    enabled: bool,
    cap: usize,
    emitted: u64,
    events: Vec<TraceEvent>,
}

impl TraceBuf {
    /// An enabled buffer at the standard capacity.
    pub fn recording() -> Self {
        Self { enabled: true, cap: TRACE_CAP, emitted: 0, events: Vec::new() }
    }

    /// An enabled buffer with an explicit capacity (tests).
    pub fn with_cap(cap: usize) -> Self {
        Self { enabled: true, cap, emitted: 0, events: Vec::new() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Record one event; a single branch when disabled (the zero-cost
    /// contract every untraced run relies on).
    #[inline]
    pub fn push(&mut self, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        self.emitted += 1;
        if self.events.len() < self.cap {
            self.events.push(ev);
        }
    }

    /// Total events offered, including any past the cap.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Finish a serial recording: the append order *is* the canonical
    /// order when there is only one shard.
    pub fn into_recording(self) -> TraceRecording {
        let dropped = self.emitted - self.events.len() as u64;
        TraceRecording { enabled: self.enabled, events: self.events, dropped, exec: Vec::new() }
    }

    /// Decompose into raw parts for the PDES merge.
    pub fn into_parts(self) -> (Vec<TraceEvent>, u64) {
        (self.events, self.emitted)
    }
}

/// Host-side execution event kinds (PDES telemetry, never part of the
/// deterministic export).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecKind {
    /// A count-driven shard repartition ran.  arg = migrated events.
    Rebalance,
    /// A synchronization window / epoch boundary.  arg = epoch index.
    Window,
}

impl ExecKind {
    pub fn name(self) -> &'static str {
        match self {
            ExecKind::Rebalance => "rebalance",
            ExecKind::Window => "window",
        }
    }
}

/// One host-side execution event (shard-attributed; cycle is the
/// *simulated* time the boundary corresponded to, exported with an
/// explicit `"clock": "sim"` tag on the host process).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecEvent {
    pub kind: ExecKind,
    pub cycle: Cycle,
    pub shard: u32,
    pub arg: u64,
}

/// A finished recording: the canonically ordered protocol events plus
/// host-side execution telemetry.
#[derive(Debug, Default)]
pub struct TraceRecording {
    /// False for untraced runs (the export of a disabled recording is
    /// an error at the CLI layer, not here).
    pub enabled: bool,
    /// Protocol events in canonical `(cycle, PushKey)` order.
    pub events: Vec<TraceEvent>,
    /// Events past the (deterministic) capacity.
    pub dropped: u64,
    /// Host-side PDES events (empty on serial runs).
    pub exec: Vec<ExecEvent>,
}

// ---- view 2: interval metrics timeline -----------------------------

/// Aggregated protocol activity over one window of cycles.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TimelineBin {
    /// First cycle of the window.
    pub start: Cycle,
    pub demand: u64,
    pub expiries: u64,
    pub renew_ok: u64,
    pub renew_fail: u64,
    pub leases: u64,
    /// Sum of granted lease lengths (avg = lease_total / leases).
    pub lease_total: u64,
    /// Sum of pts deltas.
    pub pts_total: u64,
    pub sb_stalls: u64,
    pub livelocks: u64,
    /// log2 histogram of the pts − rts gap at each lease expiry.
    pub pts_gap_hist: [u64; PTS_GAP_BUCKETS],
}

impl TimelineBin {
    /// Fraction of resolved renewals that succeeded, in [0, 1].
    pub fn renewal_success_rate(&self) -> f64 {
        let n = self.renew_ok + self.renew_fail;
        if n == 0 {
            0.0
        } else {
            self.renew_ok as f64 / n as f64
        }
    }

    /// Mean granted lease length over the window.
    pub fn avg_lease(&self) -> f64 {
        if self.leases == 0 {
            0.0
        } else {
            self.lease_total as f64 / self.leases as f64
        }
    }
}

/// log2 bucket for a pts-gap value (bucket 0 = gap 0; top bucket
/// collects the tail).
pub fn pts_gap_bucket(gap: u64) -> usize {
    if gap == 0 {
        0
    } else {
        ((64 - gap.leading_zeros()) as usize).min(PTS_GAP_BUCKETS - 1)
    }
}

/// Fold canonically ordered events into contiguous windows of
/// `window` cycles ([start, start + window)); empty leading/interior
/// windows are kept so bin index == window index.
pub fn timeline(events: &[TraceEvent], window: Cycle) -> Vec<TimelineBin> {
    let window = window.max(1);
    let mut bins: Vec<TimelineBin> = Vec::new();
    for ev in events {
        let idx = (ev.cycle / window) as usize;
        while bins.len() <= idx {
            bins.push(TimelineBin {
                start: bins.len() as Cycle * window,
                ..TimelineBin::default()
            });
        }
        let bin = &mut bins[idx];
        match ev.kind {
            EventKind::Demand => bin.demand += 1,
            EventKind::LeaseExpire => {
                bin.expiries += 1;
                bin.pts_gap_hist[pts_gap_bucket(ev.arg)] += 1;
            }
            EventKind::RenewOk => bin.renew_ok += 1,
            EventKind::RenewFail => bin.renew_fail += 1,
            EventKind::LeaseGrant => {
                bin.leases += 1;
                bin.lease_total += ev.arg;
            }
            EventKind::PtsJump => bin.pts_total += ev.arg,
            EventKind::Livelock => bin.livelocks += 1,
            EventKind::SbStall => bin.sb_stalls += 1,
        }
    }
    bins
}

// ---- view 3: hot-line / hot-core attribution -----------------------

/// Per-key (line address or core id) protocol activity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct HotStat {
    pub key: u64,
    pub demand: u64,
    pub expiries: u64,
    pub renew_ok: u64,
    pub renew_fail: u64,
}

impl HotStat {
    /// Ranking metric: coherence *traffic pressure* — demand misses
    /// plus renewal-triggering expiries.
    pub fn total(&self) -> u64 {
        self.demand + self.expiries
    }
}

fn hot_by(
    events: &[TraceEvent],
    k: usize,
    key_of: impl Fn(&TraceEvent) -> Option<u64>,
) -> Vec<HotStat> {
    let mut map: FxHashMap<u64, HotStat> = FxHashMap::default();
    for ev in events {
        let Some(key) = key_of(ev) else { continue };
        let s = map.entry(key).or_insert(HotStat { key, ..HotStat::default() });
        match ev.kind {
            EventKind::Demand => s.demand += 1,
            EventKind::LeaseExpire => s.expiries += 1,
            EventKind::RenewOk => s.renew_ok += 1,
            EventKind::RenewFail => s.renew_fail += 1,
            _ => {}
        }
    }
    let mut out: Vec<HotStat> = map.into_values().collect();
    // Deterministic ranking: pressure desc, key asc on ties.
    out.sort_unstable_by(|a, b| b.total().cmp(&a.total()).then(a.key.cmp(&b.key)));
    out.truncate(k);
    out
}

/// Top-K line addresses by coherence pressure.  Only line-attributed
/// kinds count (pts jumps and SB stalls carry no meaningful address).
pub fn hot_lines(events: &[TraceEvent], k: usize) -> Vec<HotStat> {
    hot_by(events, k, |ev| match ev.kind {
        EventKind::Demand | EventKind::LeaseExpire | EventKind::RenewOk | EventKind::RenewFail => {
            Some(ev.addr)
        }
        _ => None,
    })
}

/// Top-K cores by coherence pressure.
pub fn hot_cores(events: &[TraceEvent], k: usize) -> Vec<HotStat> {
    hot_by(events, k, |ev| match ev.kind {
        EventKind::Demand | EventKind::LeaseExpire | EventKind::RenewOk | EventKind::RenewFail => {
            Some(ev.core as u64)
        }
        _ => None,
    })
}

/// Render a hot table for the CLI / report (aligned plain text).
pub fn format_hot_table(title: &str, key_name: &str, hex_keys: bool, rows: &[HotStat]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{title}");
    let _ = writeln!(
        s,
        "  {:<4} {:>14} {:>8} {:>9} {:>9} {:>10} {:>9}",
        "rank", key_name, "demand", "expiries", "renew_ok", "renew_fail", "pressure"
    );
    for (i, r) in rows.iter().enumerate() {
        let key = if hex_keys { format!("{:#x}", r.key) } else { r.key.to_string() };
        let _ = writeln!(
            s,
            "  {:<4} {:>14} {:>8} {:>9} {:>9} {:>10} {:>9}",
            i + 1,
            key,
            r.demand,
            r.expiries,
            r.renew_ok,
            r.renew_fail,
            r.total()
        );
    }
    s
}

// ---- interval metrics from stats snapshots -------------------------

/// Interval metrics between two [`SimStats`] snapshots: the live
/// counterpart of the trace timeline, cheap enough for every
/// `Observer::on_sample` / serve progress frame.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct IntervalMetrics {
    /// Renewals per LLC access over the interval, in [0, 1].
    pub renew_rate: f64,
    /// Mean granted lease length over the interval.
    pub avg_lease: f64,
}

/// Stateful delta tracker over successive cumulative [`SimStats`]
/// snapshots.
#[derive(Debug, Default, Clone)]
pub struct MetricsWindow {
    renew_requests: u64,
    llc_accesses: u64,
    lease_total: u64,
    leases_granted: u64,
}

impl MetricsWindow {
    /// Interval metrics since the previous call (or since zero).
    pub fn tick(&mut self, stats: &SimStats) -> IntervalMetrics {
        let d_renew = stats.renew_requests - self.renew_requests;
        let d_llc = stats.llc_accesses - self.llc_accesses;
        let d_lease = stats.ts.lease_total - self.lease_total;
        let d_grants = stats.ts.leases_granted - self.leases_granted;
        self.renew_requests = stats.renew_requests;
        self.llc_accesses = stats.llc_accesses;
        self.lease_total = stats.ts.lease_total;
        self.leases_granted = stats.ts.leases_granted;
        IntervalMetrics {
            renew_rate: if d_llc == 0 { 0.0 } else { d_renew as f64 / d_llc as f64 },
            avg_lease: if d_grants == 0 { 0.0 } else { d_lease as f64 / d_grants as f64 },
        }
    }
}

// ---- view 1: the tardis-trace-v1 Chrome trace-event export ---------

/// Schema identifier stamped into every export.
pub const TRACE_SCHEMA: &str = "tardis-trace-v1";

/// Export options.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExportOpts {
    /// Include the host-time PDES process (pid 2): per-shard busy/wait
    /// spans and rebalance/window markers.  Host telemetry is
    /// nondeterministic by nature, so the default export excludes it —
    /// that is what makes serial-vs-parallel exports byte-diffable.
    pub host_spans: bool,
}

/// Hot-table depth embedded in the export's `otherData`.
const EXPORT_TOP_K: usize = 8;

fn push_hot_json(j: &mut String, rows: &[HotStat], hex_keys: bool) {
    j.push('[');
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            j.push_str(", ");
        }
        let key = if hex_keys { format!("\"{:#x}\"", r.key) } else { r.key.to_string() };
        let _ = write!(
            j,
            "{{\"key\": {key}, \"demand\": {}, \"expiries\": {}, \"renew_ok\": {}, \
             \"renew_fail\": {}, \"pressure\": {}}}",
            r.demand,
            r.expiries,
            r.renew_ok,
            r.renew_fail,
            r.total()
        );
    }
    j.push(']');
}

/// Serialize a recording to the `tardis-trace-v1` Chrome trace-event
/// JSON document (tools/validate_trace.py validates it; Perfetto and
/// `chrome://tracing` load it).
///
/// Layout: one event object per line inside `traceEvents`, so two
/// exports diff line-by-line.  Sim-time protocol events are pid 1
/// (`cat: "proto"`, `ts` = cycle, `tid` = core); lease grants are `X`
/// spans of their lease length, everything else an instant.  Host-time
/// events are pid 2 (`cat: "host"`), opt-in via
/// [`ExportOpts::host_spans`].
pub fn export_chrome(rec: &TraceRecording, parallel: &ParallelStats, opts: &ExportOpts) -> String {
    let mut j = String::with_capacity(128 * rec.events.len() + 4096);
    j.push_str("{\n\"displayTimeUnit\": \"ns\",\n");
    let _ = write!(
        j,
        "\"otherData\": {{\"schema\": \"{TRACE_SCHEMA}\", \"events\": {}, \"dropped\": {}, \
         \"hot_lines\": ",
        rec.events.len(),
        rec.dropped
    );
    push_hot_json(&mut j, &hot_lines(&rec.events, EXPORT_TOP_K), true);
    j.push_str(", \"hot_cores\": ");
    push_hot_json(&mut j, &hot_cores(&rec.events, EXPORT_TOP_K), false);
    j.push_str("},\n\"traceEvents\": [\n");

    let mut first = true;
    let mut sep = |j: &mut String| {
        if first {
            first = false;
        } else {
            j.push_str(",\n");
        }
    };

    // Process metadata, then one thread_name per core present (derived
    // from the deterministic event sequence, so itself deterministic).
    sep(&mut j);
    j.push_str(
        "{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": 1, \"tid\": 0, \
         \"args\": {\"name\": \"sim (protocol, ts=cycles)\"}}",
    );
    let mut cores: Vec<u32> = rec.events.iter().map(|e| e.core).collect();
    cores.sort_unstable();
    cores.dedup();
    for c in &cores {
        sep(&mut j);
        let _ = write!(
            j,
            "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": 1, \"tid\": {c}, \
             \"args\": {{\"name\": \"core {c}\"}}}}"
        );
    }

    for ev in &rec.events {
        sep(&mut j);
        match ev.kind {
            EventKind::LeaseGrant => {
                let _ = write!(
                    j,
                    "{{\"name\": \"lease_grant\", \"cat\": \"proto\", \"ph\": \"X\", \
                     \"pid\": 1, \"tid\": {}, \"ts\": {}, \"dur\": {}, \
                     \"args\": {{\"addr\": \"{:#x}\", \"v\": {}}}}}",
                    ev.core, ev.cycle, ev.arg.max(1), ev.addr, ev.arg
                );
            }
            kind => {
                let _ = write!(
                    j,
                    "{{\"name\": \"{}\", \"cat\": \"proto\", \"ph\": \"i\", \"s\": \"t\", \
                     \"pid\": 1, \"tid\": {}, \"ts\": {}, \
                     \"args\": {{\"addr\": \"{:#x}\", \"v\": {}}}}}",
                    kind.name(),
                    ev.core,
                    ev.cycle,
                    ev.addr,
                    ev.arg
                );
            }
        }
    }

    if opts.host_spans {
        sep(&mut j);
        j.push_str(
            "{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": 2, \"tid\": 0, \
             \"args\": {\"name\": \"host (PDES execution, ts=us)\"}}",
        );
        // Per-shard busy then wait spans laid end to end: ts is host
        // microseconds, which Chrome treats natively.
        for s in &parallel.shards {
            let busy_us = s.busy_ns / 1_000;
            let wait_us = s.wait_ns / 1_000;
            sep(&mut j);
            let _ = write!(
                j,
                "{{\"name\": \"shard_busy\", \"cat\": \"host\", \"ph\": \"X\", \"pid\": 2, \
                 \"tid\": {}, \"ts\": 0, \"dur\": {}, \
                 \"args\": {{\"clock\": \"host_us\", \"events\": {}}}}}",
                s.shard, busy_us.max(1), s.events
            );
            sep(&mut j);
            let _ = write!(
                j,
                "{{\"name\": \"shard_wait\", \"cat\": \"host\", \"ph\": \"X\", \"pid\": 2, \
                 \"tid\": {}, \"ts\": {}, \"dur\": {}, \
                 \"args\": {{\"clock\": \"host_us\"}}}}",
                s.shard,
                busy_us.max(1),
                wait_us.max(1)
            );
        }
        // Window/rebalance markers: simulated boundary cycles shown on
        // the host process, tagged so tooling never conflates clocks.
        for ex in &rec.exec {
            sep(&mut j);
            let _ = write!(
                j,
                "{{\"name\": \"{}\", \"cat\": \"host\", \"ph\": \"i\", \"s\": \"p\", \
                 \"pid\": 2, \"tid\": {}, \"ts\": {}, \
                 \"args\": {{\"clock\": \"sim\", \"v\": {}}}}}",
                ex.kind.name(),
                ex.shard,
                ex.cycle,
                ex.arg
            );
        }
    }

    j.push_str("\n]\n}\n");
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cycle: Cycle, kind: EventKind, core: u32, addr: LineAddr, arg: u64) -> TraceEvent {
        TraceEvent { cycle, addr, arg, core, kind }
    }

    #[test]
    fn disabled_buffer_records_nothing() {
        let mut b = TraceBuf::default();
        assert!(!b.enabled());
        b.push(ev(1, EventKind::Demand, 0, 0x10, 0));
        assert!(b.is_empty());
        assert_eq!(b.emitted(), 0);
        let rec = b.into_recording();
        assert!(!rec.enabled && rec.events.is_empty() && rec.dropped == 0);
    }

    #[test]
    fn capped_buffer_keeps_the_prefix_and_counts_drops() {
        let mut b = TraceBuf::with_cap(3);
        for i in 0..5u64 {
            b.push(ev(i, EventKind::Demand, 0, i, 0));
        }
        assert_eq!(b.len(), 3);
        assert_eq!(b.emitted(), 5);
        let rec = b.into_recording();
        assert_eq!(rec.dropped, 2);
        assert_eq!(rec.events.iter().map(|e| e.cycle).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    /// The determinism-under-capping argument, in miniature: two
    /// shards each keep their first K events; the canonical-order
    /// merge truncated to K equals the serial first K.
    #[test]
    fn per_shard_caps_compose_with_global_truncation() {
        const K: usize = 4;
        // Serial order: interleaved by cycle across two "shards".
        let all: Vec<TraceEvent> =
            (0..10u64).map(|i| ev(i, EventKind::Demand, (i % 2) as u32, i, 0)).collect();
        let mut serial = TraceBuf::with_cap(K);
        for &e in &all {
            serial.push(e);
        }
        let serial = serial.into_recording();

        let mut sh: [TraceBuf; 2] = [TraceBuf::with_cap(K), TraceBuf::with_cap(K)];
        for &e in &all {
            sh[e.core as usize].push(e);
        }
        let (ev0, em0) = std::mem::take(&mut sh[0]).into_parts();
        let (ev1, em1) = std::mem::take(&mut sh[1]).into_parts();
        let mut merged: Vec<TraceEvent> = ev0.into_iter().chain(ev1).collect();
        merged.sort_unstable_by_key(|e| e.cycle); // stand-in for (cycle, PushKey)
        let emitted = em0 + em1;
        merged.truncate(K);
        let dropped = emitted - merged.len() as u64;
        assert_eq!(merged, serial.events);
        assert_eq!(dropped, serial.dropped);
    }

    #[test]
    fn timeline_bins_and_histogram() {
        let events = vec![
            ev(0, EventKind::Demand, 0, 0x10, 0),
            ev(5, EventKind::LeaseGrant, 0, 0x10, 8),
            ev(12, EventKind::LeaseExpire, 1, 0x10, 0),
            ev(13, EventKind::LeaseExpire, 1, 0x10, 9),
            ev(14, EventKind::RenewOk, 1, 0x10, 0),
            ev(25, EventKind::RenewFail, 0, 0x20, 0),
            ev(25, EventKind::PtsJump, 0, 0, 7),
            ev(26, EventKind::SbStall, 2, 0x30, 0),
            ev(27, EventKind::Livelock, 2, 0x30, 0),
        ];
        let bins = timeline(&events, 10);
        assert_eq!(bins.len(), 3);
        assert_eq!(bins[0].start, 0);
        assert_eq!(bins[0].demand, 1);
        assert_eq!(bins[0].leases, 1);
        assert_eq!(bins[0].lease_total, 8);
        assert_eq!(bins[0].avg_lease(), 8.0);
        assert_eq!(bins[1].expiries, 2);
        // gap 0 -> bucket 0; gap 9 -> bucket 4 ([8, 15]).
        assert_eq!(bins[1].pts_gap_hist[0], 1);
        assert_eq!(bins[1].pts_gap_hist[4], 1);
        assert_eq!(bins[1].renew_ok, 1);
        assert_eq!(bins[1].renewal_success_rate(), 1.0);
        assert_eq!(bins[2].renew_fail, 1);
        assert_eq!(bins[2].pts_total, 7);
        assert_eq!(bins[2].sb_stalls, 1);
        assert_eq!(bins[2].livelocks, 1);
    }

    #[test]
    fn pts_gap_buckets_are_log2() {
        assert_eq!(pts_gap_bucket(0), 0);
        assert_eq!(pts_gap_bucket(1), 1);
        assert_eq!(pts_gap_bucket(2), 2);
        assert_eq!(pts_gap_bucket(3), 2);
        assert_eq!(pts_gap_bucket(4), 3);
        assert_eq!(pts_gap_bucket(1 << 13), 14);
        assert_eq!(pts_gap_bucket(u64::MAX), PTS_GAP_BUCKETS - 1);
    }

    #[test]
    fn hot_lines_rank_by_pressure_with_key_tiebreak() {
        let mut events = Vec::new();
        for _ in 0..5 {
            events.push(ev(1, EventKind::LeaseExpire, 0, 0xAA, 1));
        }
        for _ in 0..2 {
            events.push(ev(2, EventKind::Demand, 1, 0xBB, 0));
        }
        // 0x10 and 0x20 tie at pressure 1: key order must decide.
        events.push(ev(3, EventKind::Demand, 0, 0x20, 0));
        events.push(ev(3, EventKind::Demand, 0, 0x10, 0));
        events.push(ev(4, EventKind::PtsJump, 0, 0xDEAD, 3)); // no address attribution
        let hot = hot_lines(&events, 10);
        assert_eq!(hot[0].key, 0xAA);
        assert_eq!(hot[0].expiries, 5);
        assert_eq!(hot[1].key, 0xBB);
        assert_eq!(hot[2].key, 0x10);
        assert_eq!(hot[3].key, 0x20);
        assert!(hot.iter().all(|h| h.key != 0xDEAD));
        let cores = hot_cores(&events, 2);
        assert_eq!(cores[0].key, 0); // core 0: 5 expiries + 2 demands
        assert_eq!(cores[0].total(), 7);
    }

    #[test]
    fn metrics_window_computes_interval_deltas() {
        let mut w = MetricsWindow::default();
        let mut s = SimStats::default();
        s.renew_requests = 10;
        s.llc_accesses = 100;
        s.ts.leases_granted = 5;
        s.ts.lease_total = 50;
        let m = w.tick(&s);
        assert_eq!(m.renew_rate, 0.1);
        assert_eq!(m.avg_lease, 10.0);
        // Second window: only the delta counts.
        s.renew_requests = 10; // no new renewals
        s.llc_accesses = 200;
        s.ts.leases_granted = 7;
        s.ts.lease_total = 90;
        let m = w.tick(&s);
        assert_eq!(m.renew_rate, 0.0);
        assert_eq!(m.avg_lease, 20.0);
        // Empty interval yields zeros, not NaN.
        let m = w.tick(&s);
        assert_eq!(m.renew_rate, 0.0);
        assert_eq!(m.avg_lease, 0.0);
    }

    fn sample_recording() -> TraceRecording {
        TraceRecording {
            enabled: true,
            events: vec![
                ev(3, EventKind::Demand, 1, 0x10, 1),
                ev(7, EventKind::LeaseGrant, 1, 0x10, 12),
                ev(30, EventKind::LeaseExpire, 2, 0x10, 4),
                ev(31, EventKind::RenewOk, 2, 0x10, 0),
            ],
            dropped: 0,
            exec: vec![ExecEvent { kind: ExecKind::Window, cycle: 64, shard: 0, arg: 1 }],
        }
    }

    #[test]
    fn export_is_deterministic_and_host_free_by_default() {
        let rec = sample_recording();
        let par = ParallelStats::default();
        let a = export_chrome(&rec, &par, &ExportOpts::default());
        let b = export_chrome(&rec, &par, &ExportOpts::default());
        assert_eq!(a, b);
        assert!(a.contains("\"schema\": \"tardis-trace-v1\""));
        assert!(a.contains("\"events\": 4"));
        assert!(a.contains("\"name\": \"lease_grant\""));
        assert!(a.contains("\"dur\": 12"));
        assert!(a.contains("\"hot_lines\": [{\"key\": \"0x10\""));
        assert!(!a.contains("\"pid\": 2"), "default export must exclude host spans");
        assert!(!a.contains("\"cat\": \"host\""));
        // Every traceEvents line is exactly one event object.
        let body = a.split("\"traceEvents\": [\n").nth(1).unwrap();
        for line in body.lines().take_while(|l| l.starts_with('{')) {
            assert!(line.trim_end_matches(',').ends_with('}'), "one object per line: {line}");
        }
    }

    #[test]
    fn host_spans_are_opt_in_and_tagged() {
        use crate::stats::ShardLoad;
        let rec = sample_recording();
        let par = ParallelStats {
            threads: 2,
            shards: vec![
                ShardLoad { shard: 0, events: 10, busy_ns: 5_000, wait_ns: 2_000 },
                ShardLoad { shard: 1, events: 12, busy_ns: 6_000, wait_ns: 1_000 },
            ],
            ..ParallelStats::default()
        };
        let j = export_chrome(&rec, &par, &ExportOpts { host_spans: true });
        assert!(j.contains("\"name\": \"shard_busy\""));
        assert!(j.contains("\"name\": \"shard_wait\""));
        assert!(j.contains("\"name\": \"window\""));
        assert!(j.contains("\"clock\": \"sim\""));
        // Every pid-2 line carries the host tag (the validator's rule).
        for line in j.lines().filter(|l| l.contains("\"pid\": 2")) {
            assert!(
                line.contains("\"cat\": \"host\"") || line.contains("\"ph\": \"M\""),
                "untagged host event: {line}"
            );
        }
    }

    #[test]
    fn hot_table_renders_ranked_rows() {
        let rows = vec![
            HotStat { key: 0x10, demand: 3, expiries: 9, renew_ok: 8, renew_fail: 1 },
            HotStat { key: 0x20, demand: 2, expiries: 0, renew_ok: 0, renew_fail: 0 },
        ];
        let t = format_hot_table("hot lines", "addr", true, &rows);
        assert!(t.contains("hot lines"));
        assert!(t.contains("0x10"));
        assert!(t.contains("12")); // pressure = 3 + 9
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4); // title + header + 2 rows
    }
}
