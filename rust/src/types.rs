//! Fundamental scalar types and trace-format constants shared across the
//! simulator.  The trace constants mirror `python/compile/kernels/spec.py`
//! — the contract between the AOT tracegen artifacts and this crate.

/// Simulated clock cycle (1 GHz: 1 cycle == 1 ns).
pub type Cycle = u64;

/// Logical (physiological) timestamp — Tardis `pts`/`wts`/`rts`.
pub type Ts = u64;

/// Cacheline index (64-byte granularity).  The trace format uses i32
/// line addresses; we widen to u64 internally.
pub type LineAddr = u64;

/// Core identifier.
pub type CoreId = u32;

/// LLC slice (timestamp manager / directory slice) identifier.
pub type SliceId = u32;

/// Memory-controller identifier.
pub type McId = u32;

/// Cacheline size in bytes.
pub const LINE_BYTES: u64 = 64;

// --- Trace opcode encoding (kernels/spec.py) ---------------------------
pub const OP_LOAD: i32 = 0;
pub const OP_STORE: i32 = 1;
pub const OP_LOCK: i32 = 2;
pub const OP_UNLOCK: i32 = 3;
pub const OP_BARRIER: i32 = 4;

// --- Trace address-region bases (kernels/spec.py) ----------------------
pub const PRIV_STRIDE: u64 = 1 << 16;
pub const PRIV_BASE: u64 = 0;
pub const LOCK_DATA_BASE: u64 = 1 << 26;
pub const SHARED_BASE: u64 = 1 << 27;
pub const LOCK_BASE: u64 = 1 << 28;
pub const BARRIER_BASE: u64 = 1 << 29;

/// Lines of protected data per lock (kernels/spec.py LOCK_DATA_SPAN).
pub const LOCK_DATA_SPAN: u64 = 64;

/// Barrier implementation lines (derived from BARRIER_BASE):
/// counter line and sense line used by the sense-reversing barrier.
pub const BARRIER_COUNTER_LINE: u64 = BARRIER_BASE + 1;
pub const BARRIER_SENSE_LINE: u64 = BARRIER_BASE + 2;

/// Classification of a line address into its trace region, mainly for
/// diagnostics and traffic breakdowns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    Private,
    LockData,
    Shared,
    Lock,
    Barrier,
}

/// Classify a line address into its generator region.
pub fn region_of(addr: LineAddr) -> Region {
    if addr >= BARRIER_BASE {
        Region::Barrier
    } else if addr >= LOCK_BASE {
        Region::Lock
    } else if addr >= SHARED_BASE {
        Region::Shared
    } else if addr >= LOCK_DATA_BASE {
        Region::LockData
    } else {
        Region::Private
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_classification() {
        assert_eq!(region_of(0), Region::Private);
        assert_eq!(region_of(3 * PRIV_STRIDE + 5), Region::Private);
        assert_eq!(region_of(LOCK_DATA_BASE), Region::LockData);
        assert_eq!(region_of(SHARED_BASE), Region::Shared);
        assert_eq!(region_of(LOCK_BASE + 7), Region::Lock);
        assert_eq!(region_of(BARRIER_SENSE_LINE), Region::Barrier);
    }

    #[test]
    fn region_bases_ordered_and_disjoint() {
        assert!(PRIV_BASE < LOCK_DATA_BASE);
        assert!(LOCK_DATA_BASE < SHARED_BASE);
        assert!(SHARED_BASE < LOCK_BASE);
        assert!(LOCK_BASE < BARRIER_BASE);
    }
}
