//! Deterministic fast hashing for simulator-internal maps (§Perf).
//!
//! `std::collections::HashMap`'s default SipHash is keyed per map
//! instance and hardened against adversarial keys — properties a
//! deterministic simulator hashing its own line addresses pays for
//! without needing.  This is the classic Fx multiply-rotate hasher
//! (rustc's internal hasher; external crates are unavailable in this
//! image's offline registry, and it is ~10 lines): 2-4x faster on the
//! small integer keys that dominate the engine's hot maps, and with a
//! fixed seed, so iteration order is reproducible across runs.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// `HashMap` keyed by the Fx hasher; drop-in for `HashMap::new()` via
/// `FxHashMap::default()`.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Fixed odd multiplier (the 64-bit golden-ratio constant).
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Debug, Clone, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::{BuildHasher, Hash};

    fn hash_of<T: Hash>(v: &T) -> u64 {
        FxBuildHasher::default().hash_one(v)
    }

    #[test]
    fn deterministic_across_instances() {
        assert_eq!(hash_of(&42u64), hash_of(&42u64));
        assert_eq!(hash_of(&(1u32, 2u32)), hash_of(&(1u32, 2u32)));
    }

    #[test]
    fn distinguishes_nearby_keys() {
        // Line addresses differ in low bits; the hash must spread them.
        let a = hash_of(&0x0800_0000u64);
        let b = hash_of(&0x0800_0001u64);
        assert_ne!(a, b);
        assert_ne!(a ^ b, 1, "low bits must avalanche");
    }

    #[test]
    fn map_round_trips() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i * 64, i);
        }
        for i in 0..1000u64 {
            assert_eq!(m.get(&(i * 64)), Some(&i));
        }
        let mut s: FxHashSet<u32> = FxHashSet::default();
        s.insert(7);
        assert!(s.contains(&7));
    }
}
