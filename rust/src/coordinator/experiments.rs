//! Paper experiments: one function per evaluation table/figure
//! (DESIGN.md §5 experiment index).  Each builds the sweep points,
//! runs them through the parallel coordinator, and renders a
//! paper-shaped [`Table`] (throughput bars normalized to full-map MSI,
//! traffic dots, rates, timestamp statistics, storage).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use super::report::{geomean, pct, r3, Table};
use super::{run_points, SimPoint, SimPointResult};
use crate::config::{
    Consistency, CoreModel, LeasePolicyKind, ProtocolKind, SocketInterleave, SystemConfig,
    TopologyConfig, DEFAULT_MAX_LEASE,
};
use crate::prog::Workload;
use crate::runtime::TraceRuntime;
use crate::stats::SimStats;
use crate::workloads::{all as all_workloads, WorkloadSpec};

/// Evaluation context: trace source + sweep parameters.
pub struct EvalCtx {
    /// PJRT trace runtime; None falls back to the rust synth mirror.
    pub runtime: Option<TraceRuntime>,
    pub threads: usize,
    /// Divide trace lengths by this factor (quick benches/tests).
    pub scale_down: u32,
    /// Cache of generated workloads keyed by (workload, n_cores).
    cache: HashMap<(String, u32), Arc<Workload>>,
}

impl EvalCtx {
    pub fn new(runtime: Option<TraceRuntime>, threads: usize) -> Self {
        Self { runtime, threads, scale_down: 1, cache: HashMap::new() }
    }

    /// Default trace length per core count (matches aot.py CONFIGS),
    /// divided by the sweep's scale-down factor.
    pub fn trace_len(&self, n_cores: u32) -> u32 {
        crate::api::scaled_trace_len(n_cores, self.scale_down)
    }

    /// Generate (and cache) the trace for a workload at a core count.
    pub fn workload(&mut self, spec: &WorkloadSpec, n_cores: u32) -> Arc<Workload> {
        let key = (spec.name.to_string(), n_cores);
        if let Some(w) = self.cache.get(&key) {
            return Arc::clone(w);
        }
        let trace_len = self.trace_len(n_cores);
        let w = Arc::new(crate::runtime::workload_or_synth(
            &mut self.runtime,
            n_cores,
            trace_len,
            &spec.params,
        ));
        self.cache.insert(key, Arc::clone(&w));
        w
    }
}

/// A protocol variant in a sweep.
#[derive(Clone)]
pub struct Variant {
    pub label: String,
    pub cfg: SystemConfig,
}

/// Base config at a core count (Table V defaults + Ackwise pointer
/// scaling: 4 at 16/64 cores, 8 at 256 — paper Table VII).  Thin
/// alias of [`SystemConfig::for_point`], which the CLI and the serve
/// subsystem share via [`crate::api::SimSpec`].
pub fn base_cfg(n_cores: u32, protocol: ProtocolKind) -> SystemConfig {
    SystemConfig::for_point(n_cores, protocol)
}

/// Standard Fig-4 variant set: MSI baseline, Ackwise, Tardis,
/// Tardis without speculation.
pub fn fig4_variants(n_cores: u32) -> Vec<Variant> {
    let mut tardis_nospec = base_cfg(n_cores, ProtocolKind::Tardis);
    tardis_nospec.tardis.speculation = false;
    vec![
        Variant { label: "msi".into(), cfg: base_cfg(n_cores, ProtocolKind::Msi) },
        Variant { label: "ackwise".into(), cfg: base_cfg(n_cores, ProtocolKind::Ackwise) },
        Variant { label: "tardis".into(), cfg: base_cfg(n_cores, ProtocolKind::Tardis) },
        Variant { label: "tardis-nospec".into(), cfg: tardis_nospec },
    ]
}

/// Run `variants` x all 12 workloads; returns stats indexed by
/// (workload, variant label).
pub fn sweep(
    ctx: &mut EvalCtx,
    n_cores: u32,
    variants: &[Variant],
) -> Result<HashMap<(String, String), SimStats>> {
    let specs = all_workloads();
    let mut points = Vec::new();
    for spec in &specs {
        let w = ctx.workload(spec, n_cores);
        for v in variants {
            points.push(SimPoint {
                label: format!("{}|{}", spec.name, v.label),
                cfg: v.cfg.clone(),
                workload: Arc::clone(&w),
            });
        }
    }
    let results = run_points(points, ctx.threads)?;
    Ok(index_results(results))
}

fn index_results(results: Vec<SimPointResult>) -> HashMap<(String, String), SimStats> {
    results
        .into_iter()
        .map(|r| {
            let (w, v) = r.label.split_once('|').expect("label format");
            ((w.to_string(), v.to_string()), r.stats)
        })
        .collect()
}

/// Normalized-to-MSI throughput + traffic table (the Fig. 4 / 6 / 8
/// shape).  Throughput ratio = msi_cycles / variant_cycles.
pub fn normalized_table(
    title: &str,
    stats: &HashMap<(String, String), SimStats>,
    variants: &[&str],
    baseline: &str,
) -> Table {
    let mut cols: Vec<String> = vec!["workload".into()];
    for v in variants {
        cols.push(format!("{v} thr"));
        cols.push(format!("{v} traf"));
    }
    let mut table =
        Table::new(title, &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut thr_acc: HashMap<&str, Vec<f64>> = HashMap::new();
    let mut traf_acc: HashMap<&str, Vec<f64>> = HashMap::new();
    for spec in all_workloads() {
        let base = &stats[&(spec.name.to_string(), baseline.to_string())];
        let mut row = vec![spec.name.to_string()];
        for v in variants {
            let s = &stats[&(spec.name.to_string(), v.to_string())];
            let thr = base.cycles as f64 / s.cycles as f64;
            let traf = s.traffic.total() as f64 / base.traffic.total().max(1) as f64;
            thr_acc.entry(v).or_default().push(thr);
            traf_acc.entry(v).or_default().push(traf);
            row.push(r3(thr));
            row.push(r3(traf));
        }
        table.row(row);
    }
    let mut avg = vec!["AVG(geo)".to_string()];
    for v in variants {
        avg.push(r3(geomean(&thr_acc[v])));
        avg.push(r3(geomean(&traf_acc[v])));
    }
    table.row(avg);
    table
}

// ------------------------------------------------------------------
// The experiments.
// ------------------------------------------------------------------

/// Fig. 4: 64-core in-order throughput + network traffic.
pub fn fig4(ctx: &mut EvalCtx) -> Result<Table> {
    let stats = sweep(ctx, 64, &fig4_variants(64))?;
    Ok(normalized_table(
        "Fig. 4 — 64-core throughput (vs MSI) and network traffic",
        &stats,
        &["msi", "ackwise", "tardis", "tardis-nospec"],
        "msi",
    ))
}

/// Fig. 5: renewal and misspeculation rates (of LLC accesses), Tardis.
pub fn fig5(ctx: &mut EvalCtx) -> Result<Table> {
    let variants = vec![Variant {
        label: "tardis".into(),
        cfg: base_cfg(64, ProtocolKind::Tardis),
    }];
    let stats = sweep(ctx, 64, &variants)?;
    let mut t = Table::new(
        "Fig. 5 — Tardis renew / misspeculation rate (64 cores, % of LLC accesses)",
        &["workload", "renew rate", "misspec rate", "renew success"],
    );
    for spec in all_workloads() {
        let s = &stats[&(spec.name.to_string(), "tardis".to_string())];
        let succ = if s.renew_requests == 0 {
            1.0
        } else {
            s.renew_success as f64 / s.renew_requests as f64
        };
        t.row(vec![
            spec.name.into(),
            pct(s.renew_rate()),
            pct(s.misspeculation_rate()),
            pct(succ),
        ]);
    }
    Ok(t)
}

/// Table VI: timestamp increase rate + self-increment share.
pub fn table6(ctx: &mut EvalCtx) -> Result<Table> {
    let variants =
        vec![Variant { label: "tardis".into(), cfg: base_cfg(64, ProtocolKind::Tardis) }];
    let stats = sweep(ctx, 64, &variants)?;
    let mut t = Table::new(
        "Table VI — timestamp statistics (64 cores)",
        &["workload", "ts incr rate (cyc/ts)", "self incr %"],
    );
    let mut rates = Vec::new();
    let mut selfs = Vec::new();
    for spec in all_workloads() {
        let s = &stats[&(spec.name.to_string(), "tardis".to_string())];
        let rate = s.ts_incr_rate();
        rates.push(rate);
        selfs.push(s.self_inc_fraction());
        t.row(vec![spec.name.into(), format!("{rate:.0}"), pct(s.self_inc_fraction())]);
    }
    t.row(vec![
        "AVG".into(),
        format!("{:.0}", rates.iter().sum::<f64>() / rates.len() as f64),
        pct(selfs.iter().sum::<f64>() / selfs.len() as f64),
    ]);
    Ok(t)
}

/// Fig. 6: out-of-order cores.
pub fn fig6(ctx: &mut EvalCtx) -> Result<Table> {
    let mut variants = fig4_variants(64);
    for v in &mut variants {
        v.cfg.core_model = CoreModel::OutOfOrder;
    }
    let stats = sweep(ctx, 64, &variants)?;
    Ok(normalized_table(
        "Fig. 6 — 64 out-of-order cores: throughput (vs MSI) and traffic",
        &stats,
        &["msi", "ackwise", "tardis", "tardis-nospec"],
        "msi",
    ))
}

/// Fig. 7: self-increment period sweep {10, 100, 1000}.
pub fn fig7(ctx: &mut EvalCtx) -> Result<Table> {
    let mut variants =
        vec![Variant { label: "msi".into(), cfg: base_cfg(64, ProtocolKind::Msi) }];
    for period in [10u64, 100, 1000] {
        let mut cfg = base_cfg(64, ProtocolKind::Tardis);
        cfg.tardis.self_inc_period = period;
        variants.push(Variant { label: format!("tardis-p{period}"), cfg });
    }
    let stats = sweep(ctx, 64, &variants)?;
    Ok(normalized_table(
        "Fig. 7 — Tardis self-increment period sweep (64 cores)",
        &stats,
        &["tardis-p10", "tardis-p100", "tardis-p1000"],
        "msi",
    ))
}

/// Fig. 8: scalability at 16 and 256 cores (256 with periods 10/100).
pub fn fig8(ctx: &mut EvalCtx) -> Result<(Table, Table)> {
    let stats16 = sweep(ctx, 16, &fig4_variants(16))?;
    let t16 = normalized_table(
        "Fig. 8a — 16-core throughput (vs MSI) and traffic",
        &stats16,
        &["msi", "ackwise", "tardis"],
        "msi",
    );
    let mut variants256 =
        vec![Variant { label: "msi".into(), cfg: base_cfg(256, ProtocolKind::Msi) }];
    for period in [10u64, 100] {
        let mut cfg = base_cfg(256, ProtocolKind::Tardis);
        cfg.tardis.self_inc_period = period;
        variants256.push(Variant { label: format!("tardis-p{period}"), cfg });
    }
    let stats256 = sweep(ctx, 256, &variants256)?;
    let t256 = normalized_table(
        "Fig. 8b — 256-core throughput (vs MSI) and traffic",
        &stats256,
        &["tardis-p10", "tardis-p100"],
        "msi",
    );
    Ok((t16, t256))
}

/// Table VII: per-LLC-line coherence storage.
pub fn table7() -> Table {
    use crate::proto::{ackwise::Ackwise, msi::Msi, tardis::Tardis, Coherence};
    let mut t = Table::new(
        "Table VII — storage overhead (bits per LLC cacheline)",
        &["# cores", "full-map MSI", "Ackwise", "Tardis"],
    );
    for n in [16u32, 64, 256] {
        let cfg = base_cfg(n, ProtocolKind::Msi);
        let msi = Msi::new(&cfg);
        let ack = Ackwise::new(&cfg);
        let tardis = Tardis::new(&cfg);
        t.row(vec![
            n.to_string(),
            format!("{} bits", msi.llc_storage_bits(n)),
            format!("{} bits", ack.llc_storage_bits(n)),
            format!("{} bits", tardis.llc_storage_bits(n)),
        ]);
    }
    t
}

/// Fig. 9: delta-timestamp size sweep.  The paper sweeps {14, 18, 20,
/// 64} bits over 280M-cycle runs; our traces finish in ~1M cycles with
/// pts reaching only ~10^4, so the sweep is shifted down to widths
/// that actually roll over at this scale ({10, 12, 14} bits) plus the
/// paper's default 20 and rollover-free 64.
pub fn fig9(ctx: &mut EvalCtx) -> Result<Table> {
    let mut variants =
        vec![Variant { label: "msi".into(), cfg: base_cfg(64, ProtocolKind::Msi) }];
    for bits in [10u32, 12, 14, 20, 64] {
        let mut cfg = base_cfg(64, ProtocolKind::Tardis);
        cfg.tardis.delta_ts_bits = bits;
        variants.push(Variant { label: format!("tardis-{bits}b"), cfg });
    }
    let stats = sweep(ctx, 64, &variants)?;
    Ok(normalized_table(
        "Fig. 9 — Tardis delta-timestamp size sweep (64 cores)",
        &stats,
        &["tardis-10b", "tardis-12b", "tardis-14b", "tardis-20b", "tardis-64b"],
        "msi",
    ))
}

/// Core counts the lease matrix (and its BENCH_5 trajectory) crosses
/// (ROADMAP: extend the 64-core matrix across 16/256).
pub const LEASE_MATRIX_CORES: [u32; 3] = [16, 64, 256];

/// The lease-policy grid shared by the matrix and the bench suite.
pub fn lease_policies() -> [(&'static str, LeasePolicyKind); 3] {
    [
        ("static", LeasePolicyKind::Static),
        ("dynamic", LeasePolicyKind::Dynamic { max_lease: DEFAULT_MAX_LEASE }),
        ("predictive", LeasePolicyKind::Predictive { max_lease: DEFAULT_MAX_LEASE }),
    ]
}

/// The Tardis lease-policy x consistency variant grid at one core
/// count (labels `{policy}-{model}`) — the single construction shared
/// by [`lease_matrix`] and the bench lease suite, so the sweep table
/// and the BENCH trajectory can never desynchronize.
pub fn tardis_lease_variants(n_cores: u32) -> Vec<Variant> {
    let mut variants = Vec::new();
    for (pname, policy) in lease_policies() {
        for model in [Consistency::Sc, Consistency::Tso] {
            let mut cfg = base_cfg(n_cores, ProtocolKind::Tardis);
            cfg.tardis.lease_policy = policy;
            cfg.consistency = model;
            variants.push(Variant { label: format!("{pname}-{}", model.name()), cfg });
        }
    }
    variants
}

/// Tardis 2.0 design space: every lease policy crossed with both
/// consistency models at 16 / 64 / 256 cores, normalized to the
/// MSI/SC baseline at the same core count.  One table reads off both
/// follow-up claims at every scale — smarter leases cut renewal
/// traffic, and TSO's store buffers buy throughput on top.
pub fn lease_matrix(ctx: &mut EvalCtx) -> Result<Table> {
    // Flat layout: one row per (cores, workload, variant) — six
    // variants x five metrics would not fit a readable wide table.
    let mut table = Table::new(
        "Lease policy x consistency x core count (throughput vs MSI/SC at equal cores)",
        &["cores", "workload", "variant", "thr", "renew%", "misspec%", "avg lease", "sb fwd"],
    );
    for &n_cores in &LEASE_MATRIX_CORES {
        let tardis_variants = tardis_lease_variants(n_cores);
        // Labels taken from the variants themselves so the two can
        // never drift apart.
        let labels: Vec<String> = tardis_variants.iter().map(|v| v.label.clone()).collect();
        let mut variants =
            vec![Variant { label: "msi".into(), cfg: base_cfg(n_cores, ProtocolKind::Msi) }];
        variants.extend(tardis_variants);
        let stats = sweep(ctx, n_cores, &variants)?;
        let mut thr_acc: HashMap<&str, Vec<f64>> = HashMap::new();
        for spec in all_workloads() {
            let base = &stats[&(spec.name.to_string(), "msi".to_string())];
            for v in &labels {
                let s = &stats[&(spec.name.to_string(), v.clone())];
                let thr = base.cycles as f64 / s.cycles as f64;
                thr_acc.entry(v.as_str()).or_default().push(thr);
                table.row(vec![
                    n_cores.to_string(),
                    spec.name.to_string(),
                    v.clone(),
                    r3(thr),
                    pct(s.renew_rate()),
                    pct(s.misspeculation_rate()),
                    format!("{:.1}", s.avg_lease()),
                    s.sb_forwards.to_string(),
                ]);
            }
        }
        for v in &labels {
            table.row(vec![
                n_cores.to_string(),
                "AVG(geo)".into(),
                v.clone(),
                r3(geomean(&thr_acc[v.as_str()])),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
            ]);
        }
    }
    Ok(table)
}

// ------------------------------------------------------------------
// The ccNUMA sweep (paper §VII: Tardis in distributed shared memory).
// ------------------------------------------------------------------

/// Inter-socket cost ratios the numa sweep crosses.
pub const NUMA_RATIOS: [u32; 4] = [1, 2, 4, 8];

/// Socket count of the headline numa sweep (64 cores -> 16 per
/// socket).
pub const NUMA_SOCKETS: u32 = 4;

/// The four protocol variants at one numa-ratio point: the directory
/// baselines, distance-blind Tardis, and NUMA-aware predictive
/// Tardis.
pub fn numa_variants(n_cores: u32, sockets: u32, ratio: u32) -> Vec<Variant> {
    let mk = |protocol| {
        let mut cfg = base_cfg(n_cores, protocol);
        cfg.topology = TopologyConfig {
            sockets,
            numa_ratio: ratio,
            interleave: SocketInterleave::Line,
        };
        cfg
    };
    let mut tardis_pred = mk(ProtocolKind::Tardis);
    tardis_pred.tardis.lease_policy =
        LeasePolicyKind::Predictive { max_lease: DEFAULT_MAX_LEASE };
    vec![
        Variant { label: format!("msi-r{ratio}"), cfg: mk(ProtocolKind::Msi) },
        Variant { label: format!("ackwise-r{ratio}"), cfg: mk(ProtocolKind::Ackwise) },
        Variant { label: format!("tardis-static-r{ratio}"), cfg: mk(ProtocolKind::Tardis) },
        Variant { label: format!("tardis-predictive-r{ratio}"), cfg: tardis_pred },
    ]
}

/// Run the numa grid (`ratios` x the four variants x all workloads)
/// at one (core count, socket count); stats indexed by
/// (workload, variant label).
pub fn numa_sweep_stats(
    ctx: &mut EvalCtx,
    n_cores: u32,
    sockets: u32,
    ratios: &[u32],
) -> Result<HashMap<(String, String), SimStats>> {
    let mut variants = Vec::new();
    for &r in ratios {
        variants.extend(numa_variants(n_cores, sockets, r));
    }
    sweep(ctx, n_cores, &variants)
}

/// The ccNUMA sweep: Tardis vs the directory baselines as the
/// inter-socket cost grows (64 cores, 4 sockets).  The §VII claim to
/// read off: directory invalidation multicasts keep paying the socket
/// links at every ratio, while Tardis renews owner-free — and the
/// NUMA-aware predictive policy stretches remote leases with the
/// ratio, so its inter-socket message count *falls* as links get more
/// expensive.
pub fn numa_sweep(ctx: &mut EvalCtx) -> Result<Table> {
    let stats = numa_sweep_stats(ctx, 64, NUMA_SOCKETS, &NUMA_RATIOS)?;
    let mut table = Table::new(
        "ccNUMA sweep — 64 cores, 4 sockets (throughput vs MSI at equal ratio; \
         messages summed over all workloads)",
        &["ratio", "variant", "thr", "inter msgs", "intra msgs", "inter%", "renew%"],
    );
    for &ratio in &NUMA_RATIOS {
        let baseline = format!("msi-r{ratio}");
        for variant in ["msi", "ackwise", "tardis-static", "tardis-predictive"] {
            let label = format!("{variant}-r{ratio}");
            let mut thr = Vec::new();
            let (mut inter, mut intra, mut renew, mut llc) = (0u64, 0u64, 0u64, 0u64);
            for spec in all_workloads() {
                let base = &stats[&(spec.name.to_string(), baseline.clone())];
                let s = &stats[&(spec.name.to_string(), label.clone())];
                thr.push(base.cycles as f64 / s.cycles as f64);
                inter += s.socket.inter_msgs;
                intra += s.socket.intra_msgs;
                renew += s.renew_requests;
                llc += s.llc_accesses;
            }
            let total = (inter + intra).max(1);
            table.row(vec![
                ratio.to_string(),
                variant.to_string(),
                r3(geomean(&thr)),
                inter.to_string(),
                intra.to_string(),
                pct(inter as f64 / total as f64),
                pct(renew as f64 / llc.max(1) as f64),
            ]);
        }
    }
    Ok(table)
}

/// Fig. 10: lease sweep {5, 10, 20, 40, 80}.
pub fn fig10(ctx: &mut EvalCtx) -> Result<Table> {
    let mut variants =
        vec![Variant { label: "msi".into(), cfg: base_cfg(64, ProtocolKind::Msi) }];
    for lease in [5u64, 10, 20, 40, 80] {
        let mut cfg = base_cfg(64, ProtocolKind::Tardis);
        cfg.tardis.lease = lease;
        variants.push(Variant { label: format!("tardis-l{lease}"), cfg });
    }
    let stats = sweep(ctx, 64, &variants)?;
    Ok(normalized_table(
        "Fig. 10 — Tardis lease sweep (64 cores)",
        &stats,
        &["tardis-l5", "tardis-l10", "tardis-l20", "tardis-l40", "tardis-l80"],
        "msi",
    ))
}
