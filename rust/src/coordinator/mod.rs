//! Experiment coordinator: the leader/worker layer that fans a set of
//! simulation points out over a thread pool, gathers their statistics,
//! and renders the paper's tables and figures.
//!
//! One simulation is single-threaded and deterministic; sweeps (12
//! workloads x protocols x configs) parallelize across points.  The
//! leader generates all traces up front through the PJRT runtime
//! (executables are not Sync), then workers pull points off a shared
//! queue.

pub mod bench;
pub mod experiments;
pub mod report;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::api::SimBuilder;
use crate::config::SystemConfig;
use crate::prog::Workload;
use crate::stats::SimStats;

/// One simulation to run.
pub struct SimPoint {
    /// Label, e.g. "fig4/volrend/tardis".
    pub label: String,
    pub cfg: SystemConfig,
    pub workload: Arc<Workload>,
}

/// A completed point.
pub struct SimPointResult {
    pub label: String,
    pub stats: SimStats,
}

/// Run all points on `threads` worker threads (0 = available
/// parallelism), preserving input order in the result.
pub fn run_points(points: Vec<SimPoint>, threads: usize) -> Result<Vec<SimPointResult>> {
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    };
    let n = points.len();
    let points = Arc::new(points);
    let next = Arc::new(AtomicUsize::new(0));
    let results: Arc<Mutex<Vec<Option<SimPointResult>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    let errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

    std::thread::scope(|scope| {
        for _ in 0..threads.min(n.max(1)) {
            let points = Arc::clone(&points);
            let next = Arc::clone(&next);
            let results = Arc::clone(&results);
            let errors = Arc::clone(&errors);
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let p = &points[i];
                let run = SimBuilder::from_config(p.cfg.clone())
                    .workload_arc(Arc::clone(&p.workload))
                    .run();
                match run {
                    Ok(res) => {
                        results.lock().unwrap()[i] =
                            Some(SimPointResult { label: p.label.clone(), stats: res.stats });
                    }
                    Err(e) => {
                        errors.lock().unwrap().push(format!("{}: {e}", p.label));
                    }
                }
            });
        }
    });

    let errors = match Arc::try_unwrap(errors) {
        Ok(m) => m.into_inner().unwrap(),
        Err(_) => unreachable!("workers joined"),
    };
    if !errors.is_empty() {
        anyhow::bail!("{} simulation(s) failed:\n{}", errors.len(), errors.join("\n"));
    }
    let results = match Arc::try_unwrap(results) {
        Ok(m) => m.into_inner().unwrap(),
        Err(_) => unreachable!("workers joined"),
    };
    Ok(results.into_iter().map(|r| r.unwrap()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ProtocolKind;
    use crate::prog::{load, store, Program};

    fn tiny_workload() -> Arc<Workload> {
        Arc::new(Workload::new(vec![
            Program::new(vec![store(crate::types::SHARED_BASE, 1), load(crate::types::SHARED_BASE)]),
            Program::new(vec![load(crate::types::SHARED_BASE)]),
        ]))
    }

    #[test]
    fn parallel_sweep_preserves_order() {
        let w = tiny_workload();
        let points: Vec<SimPoint> = (0..8)
            .map(|i| SimPoint {
                label: format!("p{i}"),
                cfg: SystemConfig::small(2, ProtocolKind::Tardis),
                workload: Arc::clone(&w),
            })
            .collect();
        let results = run_points(points, 4).unwrap();
        assert_eq!(results.len(), 8);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.label, format!("p{i}"));
            assert!(r.stats.cycles > 0);
        }
    }

    #[test]
    fn identical_points_are_deterministic() {
        let w = tiny_workload();
        let mk = || SimPoint {
            label: "x".into(),
            cfg: SystemConfig::small(2, ProtocolKind::Msi),
            workload: Arc::clone(&w),
        };
        let r = run_points(vec![mk(), mk()], 2).unwrap();
        assert_eq!(r[0].stats.cycles, r[1].stats.cycles);
        assert_eq!(r[0].stats.traffic.total(), r[1].stats.traffic.total());
    }
}
