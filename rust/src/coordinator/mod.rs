//! Experiment coordinator: the leader/worker layer that fans a set of
//! simulation points out over a thread pool, gathers their statistics,
//! and renders the paper's tables and figures.
//!
//! One simulation is single-threaded and deterministic; sweeps (12
//! workloads x protocols x configs) parallelize across points.  The
//! leader generates all traces up front through the PJRT runtime
//! (executables are not Sync), then workers pull points off a shared
//! queue.

pub mod bench;
pub mod experiments;
pub mod report;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::Result;

use crate::api::SimBuilder;
use crate::config::SystemConfig;
use crate::prog::Workload;
use crate::stats::SimStats;

/// One simulation to run.
pub struct SimPoint {
    /// Label, e.g. "fig4/volrend/tardis".
    pub label: String,
    pub cfg: SystemConfig,
    pub workload: Arc<Workload>,
}

/// A completed point.
pub struct SimPointResult {
    pub label: String,
    pub stats: SimStats,
}

/// A boxed unit of work for the [`WorkerPool`].
type Job = Box<dyn FnOnce() + Send + 'static>;

/// A long-lived pool of worker threads draining a shared job queue —
/// the execution substrate of the serve subsystem (`crate::serve`,
/// DESIGN.md §10) and the plumbing groundwork for the PDES shard
/// engine (ROADMAP item 1).  Unlike [`run_points`], which spawns
/// scoped workers per sweep and joins them before returning, a
/// `WorkerPool` outlives any one batch: sessions from many concurrent
/// clients interleave on the same threads.
///
/// Shutdown is graceful by construction: [`WorkerPool::shutdown`]
/// closes the queue and joins the workers, which keep draining every
/// job already submitted — in-flight sessions always finish.
pub struct WorkerPool {
    tx: Mutex<Option<mpsc::Sender<Job>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Jobs submitted but not yet picked up by a worker.
    queued: Arc<AtomicUsize>,
    /// High-water mark of `queued` (per-batch queue-depth stats).
    peak_queued: Arc<AtomicUsize>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn `workers` threads (0 = available parallelism).
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            workers
        };
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let handles = (0..workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::spawn(move || loop {
                    // Holding the lock across recv serializes dequeue
                    // only — the job itself runs after the guard drops.
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => {
                            queued.fetch_sub(1, Ordering::Relaxed);
                            job();
                        }
                        // Queue closed and drained: worker retires.
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Self {
            tx: Mutex::new(Some(tx)),
            handles: Mutex::new(handles),
            queued,
            peak_queued: Arc::new(AtomicUsize::new(0)),
            workers,
        }
    }

    /// Enqueue a job; returns the queue depth right after enqueue
    /// (jobs waiting for a worker, this one included).  Fails once
    /// [`WorkerPool::shutdown`] has closed the queue.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> Result<usize> {
        let guard = self.tx.lock().unwrap();
        let tx = guard.as_ref().ok_or_else(|| anyhow::anyhow!("worker pool is shut down"))?;
        let depth = self.queued.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_queued.fetch_max(depth, Ordering::Relaxed);
        tx.send(job).map_err(|_| anyhow::anyhow!("worker pool is shut down"))?;
        Ok(depth)
    }

    /// Jobs submitted but not yet started.
    pub fn queue_depth(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// High-water mark of [`WorkerPool::queue_depth`] over the pool's
    /// lifetime.
    pub fn peak_queue_depth(&self) -> usize {
        self.peak_queued.load(Ordering::Relaxed)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Close the queue and join every worker.  Already submitted jobs
    /// are drained first (graceful); new submissions fail.
    pub fn shutdown(&self) {
        self.tx.lock().unwrap().take();
        let handles: Vec<_> = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Run all points on `threads` worker threads (0 = available
/// parallelism), preserving input order in the result.
pub fn run_points(points: Vec<SimPoint>, threads: usize) -> Result<Vec<SimPointResult>> {
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    };
    let n = points.len();
    let points = Arc::new(points);
    let next = Arc::new(AtomicUsize::new(0));
    let results: Arc<Mutex<Vec<Option<SimPointResult>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    let errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

    std::thread::scope(|scope| {
        for _ in 0..threads.min(n.max(1)) {
            let points = Arc::clone(&points);
            let next = Arc::clone(&next);
            let results = Arc::clone(&results);
            let errors = Arc::clone(&errors);
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let p = &points[i];
                let run = SimBuilder::from_config(p.cfg.clone())
                    .workload_arc(Arc::clone(&p.workload))
                    .run();
                match run {
                    Ok(res) => {
                        results.lock().unwrap()[i] =
                            Some(SimPointResult { label: p.label.clone(), stats: res.stats });
                    }
                    Err(e) => {
                        errors.lock().unwrap().push(format!("{}: {e}", p.label));
                    }
                }
            });
        }
    });

    let errors = match Arc::try_unwrap(errors) {
        Ok(m) => m.into_inner().unwrap(),
        Err(_) => unreachable!("workers joined"),
    };
    if !errors.is_empty() {
        anyhow::bail!("{} simulation(s) failed:\n{}", errors.len(), errors.join("\n"));
    }
    let results = match Arc::try_unwrap(results) {
        Ok(m) => m.into_inner().unwrap(),
        Err(_) => unreachable!("workers joined"),
    };
    Ok(results.into_iter().map(|r| r.unwrap()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ProtocolKind;
    use crate::prog::{load, store, Program};

    fn tiny_workload() -> Arc<Workload> {
        Arc::new(Workload::new(vec![
            Program::new(vec![store(crate::types::SHARED_BASE, 1), load(crate::types::SHARED_BASE)]),
            Program::new(vec![load(crate::types::SHARED_BASE)]),
        ]))
    }

    #[test]
    fn parallel_sweep_preserves_order() {
        let w = tiny_workload();
        let points: Vec<SimPoint> = (0..8)
            .map(|i| SimPoint {
                label: format!("p{i}"),
                cfg: SystemConfig::small(2, ProtocolKind::Tardis),
                workload: Arc::clone(&w),
            })
            .collect();
        let results = run_points(points, 4).unwrap();
        assert_eq!(results.len(), 8);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.label, format!("p{i}"));
            assert!(r.stats.cycles > 0);
        }
    }

    #[test]
    fn worker_pool_runs_jobs_and_drains_on_shutdown() {
        use std::sync::atomic::AtomicU64;
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 1..=100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            })
            .unwrap();
        }
        // Graceful shutdown drains every queued job before joining.
        pool.shutdown();
        assert_eq!(sum.load(Ordering::Relaxed), 5050);
        assert_eq!(pool.queue_depth(), 0);
        assert!(pool.peak_queue_depth() >= 1);
        assert!(pool.submit(|| {}).is_err(), "closed pool must reject jobs");
    }

    #[test]
    fn worker_pool_zero_means_available_parallelism() {
        let pool = WorkerPool::new(0);
        assert!(pool.workers() >= 1);
    }

    #[test]
    fn identical_points_are_deterministic() {
        let w = tiny_workload();
        let mk = || SimPoint {
            label: "x".into(),
            cfg: SystemConfig::small(2, ProtocolKind::Msi),
            workload: Arc::clone(&w),
        };
        let r = run_points(vec![mk(), mk()], 2).unwrap();
        assert_eq!(r[0].stats.cycles, r[1].stats.cycles);
        assert_eq!(r[0].stats.traffic.total(), r[1].stats.traffic.total());
    }
}
