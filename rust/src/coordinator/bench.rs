//! The tracked benchmark pipeline (`tardis bench`, DESIGN.md §6).
//!
//! Runs the paper's Fig-4 sweep shape (all 12 signature workloads x
//! the 4 protocol variants) at a fixed core count and records **host**
//! throughput — events/sec and simulated cycles/sec — into a
//! machine-readable `BENCH_<n>.json` (schema [`SCHEMA`], validated by
//! `tools/validate_bench.py` and the CI `bench-smoke` job).  Every
//! perf-relevant PR appends a new `BENCH_<n>.json`, so the repo
//! carries its own performance trajectory.
//!
//! Timing protocol: each sweep point runs `iters` times; the reported
//! wall time is the minimum (least-noise estimator for a deterministic
//! computation), and simulated results are asserted identical across
//! iterations — the bench doubles as a determinism check.

use std::fmt::Write as _;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{ensure, Context, Result};

use super::experiments::{fig4_variants, EvalCtx};
use crate::api::SimBuilder;
use crate::config::{LeasePolicyKind, ProtocolKind};
use crate::workloads::all as all_workloads;

/// Schema identifier stamped into every report.
pub const SCHEMA: &str = "tardis-bench-v1";

/// One (workload, variant) sweep point.
#[derive(Debug, Clone)]
pub struct BenchPoint {
    pub workload: String,
    pub variant: String,
    /// Simulated completion time.
    pub sim_cycles: u64,
    /// Committed memory operations.
    pub memops: u64,
    /// Discrete events the engine dispatched.
    pub events: u64,
    /// Best host wall time over the iterations, seconds.
    pub wall_s: f64,
}

impl BenchPoint {
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_s.max(1e-9)
    }

    pub fn sim_cycles_per_sec(&self) -> f64 {
        self.sim_cycles as f64 / self.wall_s.max(1e-9)
    }
}

/// A full macro-bench run, serializable to the `BENCH_*.json` schema.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub label: String,
    /// "measured" for reports emitted by this pipeline; other values
    /// flag numbers that did not come from a local run.
    pub provenance: String,
    pub unix_time: u64,
    pub n_cores: u32,
    pub iters: u32,
    pub scale_down: u32,
    pub points: Vec<BenchPoint>,
}

impl BenchReport {
    pub fn total_wall_s(&self) -> f64 {
        self.points.iter().map(|p| p.wall_s).sum()
    }

    pub fn total_events(&self) -> u64 {
        self.points.iter().map(|p| p.events).sum()
    }

    pub fn total_sim_cycles(&self) -> u64 {
        self.points.iter().map(|p| p.sim_cycles).sum()
    }

    /// Aggregate host throughput (total events / total wall time).
    pub fn events_per_sec(&self) -> f64 {
        self.total_events() as f64 / self.total_wall_s().max(1e-9)
    }

    pub fn sim_cycles_per_sec(&self) -> f64 {
        self.total_sim_cycles() as f64 / self.total_wall_s().max(1e-9)
    }

    /// One-line human summary for the CLI.
    pub fn summary(&self) -> String {
        format!(
            "bench {}: {} points, {:.2}s wall, {:.2} M events/s, {:.2} M sim-cycles/s",
            self.label,
            self.points.len(),
            self.total_wall_s(),
            self.events_per_sec() / 1e6,
            self.sim_cycles_per_sec() / 1e6,
        )
    }

    /// Serialize to the `tardis-bench-v1` JSON schema (hand-rolled;
    /// serde is not in this image's offline registry).  All string
    /// fields are known-ASCII labels, so no escaping is needed beyond
    /// the assertion below.
    pub fn to_json(&self) -> String {
        fn lit(s: &str) -> String {
            assert!(
                s.chars().all(|c| c.is_ascii_alphanumeric() || "-_. /".contains(c)),
                "label {s:?} needs JSON escaping"
            );
            format!("\"{s}\"")
        }
        let mut j = String::new();
        j.push_str("{\n");
        let _ = writeln!(j, "  \"schema\": {},", lit(SCHEMA));
        let _ = writeln!(j, "  \"label\": {},", lit(&self.label));
        let _ = writeln!(j, "  \"provenance\": {},", lit(&self.provenance));
        let _ = writeln!(j, "  \"unix_time\": {},", self.unix_time);
        let _ = writeln!(j, "  \"n_cores\": {},", self.n_cores);
        let _ = writeln!(j, "  \"iters\": {},", self.iters);
        let _ = writeln!(j, "  \"scale_down\": {},", self.scale_down);
        j.push_str("  \"points\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            let _ = write!(
                j,
                "    {{\"workload\": {}, \"variant\": {}, \"sim_cycles\": {}, \
                 \"memops\": {}, \"events\": {}, \"wall_s\": {:.6}, \
                 \"events_per_sec\": {:.1}, \"sim_cycles_per_sec\": {:.1}}}",
                lit(&p.workload),
                lit(&p.variant),
                p.sim_cycles,
                p.memops,
                p.events,
                p.wall_s,
                p.events_per_sec(),
                p.sim_cycles_per_sec(),
            );
            j.push_str(if i + 1 < self.points.len() { ",\n" } else { "\n" });
        }
        j.push_str("  ],\n");
        let _ = writeln!(
            j,
            "  \"aggregate\": {{\"wall_s\": {:.6}, \"events\": {}, \"sim_cycles\": {}, \
             \"events_per_sec\": {:.1}, \"sim_cycles_per_sec\": {:.1}}}",
            self.total_wall_s(),
            self.total_events(),
            self.total_sim_cycles(),
            self.events_per_sec(),
            self.sim_cycles_per_sec(),
        );
        j.push_str("}\n");
        j
    }

    pub fn write(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json()).with_context(|| format!("writing {path}"))
    }
}

/// Run the fig-4-shaped macro bench at `n_cores` (the trajectory
/// default is 16, the paper's smallest sweep point — big enough to
/// stress the queue, small enough to iterate).
pub fn run_macro_bench(ctx: &mut EvalCtx, n_cores: u32, iters: u32) -> Result<BenchReport> {
    run_macro_bench_with_policy(ctx, n_cores, iters, None)
}

/// [`run_macro_bench`] with an optional lease-policy override applied
/// to every Tardis variant (the CI bench-smoke job runs a
/// `Predictive` point through the schema validator this way).
pub fn run_macro_bench_with_policy(
    ctx: &mut EvalCtx,
    n_cores: u32,
    iters: u32,
    policy: Option<LeasePolicyKind>,
) -> Result<BenchReport> {
    ensure!(iters > 0, "bench needs at least one iteration");
    let mut variants = fig4_variants(n_cores);
    if let Some(policy) = policy {
        for v in &mut variants {
            if v.cfg.protocol == ProtocolKind::Tardis {
                v.cfg.tardis.lease_policy = policy;
                v.label = format!("{}-{}", v.label, policy.name());
            }
        }
    }
    let mut points = Vec::new();
    for spec in &all_workloads() {
        let w = ctx.workload(spec, n_cores);
        for v in &variants {
            let mut best_wall = f64::INFINITY;
            let mut first: Option<crate::stats::SimStats> = None;
            for _ in 0..iters {
                let report = SimBuilder::from_config(v.cfg.clone())
                    .workload_arc(std::sync::Arc::clone(&w))
                    .run()?;
                match &first {
                    None => first = Some(report.stats.clone()),
                    Some(f) => ensure!(
                        *f == report.stats,
                        "nondeterministic bench point {}/{}: {:?} vs {:?}",
                        spec.name,
                        v.label,
                        f,
                        report.stats
                    ),
                }
                best_wall = best_wall.min(report.elapsed.as_secs_f64());
            }
            let stats = first.unwrap();
            let (sim_cycles, memops, events) = (stats.cycles, stats.memops, stats.events);
            points.push(BenchPoint {
                workload: spec.name.to_string(),
                variant: v.label.clone(),
                sim_cycles,
                memops,
                events,
                wall_s: best_wall,
            });
        }
    }
    let label = match policy {
        Some(p) => format!("fig4-{n_cores}c-{}", p.name()),
        None => format!("fig4-{n_cores}c"),
    };
    Ok(BenchReport {
        label,
        provenance: "measured".to_string(),
        unix_time: SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0),
        n_cores,
        iters,
        scale_down: ctx.scale_down,
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments::EvalCtx;

    fn tiny_report() -> BenchReport {
        let mut ctx = EvalCtx::new(None, 1);
        ctx.scale_down = 32; // 64-op traces: fast enough for a unit test
        run_macro_bench(&mut ctx, 2, 1).unwrap()
    }

    #[test]
    fn macro_bench_covers_the_fig4_grid() {
        let r = tiny_report();
        assert_eq!(r.points.len(), 12 * 4);
        assert!(r.points.iter().all(|p| p.sim_cycles > 0 && p.events > 0));
        assert!(r.events_per_sec() > 0.0);
        assert_eq!(r.label, "fig4-2c");
    }

    #[test]
    fn policy_override_relabels_tardis_variants() {
        let mut ctx = EvalCtx::new(None, 1);
        ctx.scale_down = 32;
        let r = run_macro_bench_with_policy(
            &mut ctx,
            2,
            1,
            Some(crate::config::LeasePolicyKind::Predictive { max_lease: 80 }),
        )
        .unwrap();
        assert_eq!(r.label, "fig4-2c-predictive");
        assert!(r.points.iter().any(|p| p.variant == "tardis-predictive"));
        assert!(r.points.iter().any(|p| p.variant == "msi"), "baselines untouched");
        // The relabeled report still serializes to valid schema shape.
        let j = r.to_json();
        assert!(j.contains("\"variant\": \"tardis-predictive\""));
    }

    #[test]
    fn json_matches_the_v1_schema_shape() {
        let r = tiny_report();
        let j = r.to_json();
        for key in [
            "\"schema\": \"tardis-bench-v1\"",
            "\"label\"",
            "\"provenance\": \"measured\"",
            "\"unix_time\"",
            "\"n_cores\"",
            "\"iters\"",
            "\"scale_down\"",
            "\"points\"",
            "\"workload\"",
            "\"variant\"",
            "\"sim_cycles\"",
            "\"memops\"",
            "\"events\"",
            "\"wall_s\"",
            "\"events_per_sec\"",
            "\"aggregate\"",
        ] {
            assert!(j.contains(key), "missing {key} in:\n{j}");
        }
        // Balanced braces/brackets (cheap well-formedness probe).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }
}
