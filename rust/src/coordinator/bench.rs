//! The tracked benchmark pipeline (`tardis bench`, DESIGN.md §6).
//!
//! Runs the paper's Fig-4 sweep shape (all 12 signature workloads x
//! the 4 protocol variants) at a fixed core count and records **host**
//! throughput — events/sec and simulated cycles/sec — into a
//! machine-readable `BENCH_<n>.json` (schema [`SCHEMA`], validated by
//! `tools/validate_bench.py` and the CI `bench-smoke` job).  Every
//! perf-relevant PR appends a new `BENCH_<n>.json`, so the repo
//! carries its own performance trajectory.
//!
//! Timing protocol: each sweep point runs `iters` times; the reported
//! wall time is the minimum (least-noise estimator for a deterministic
//! computation), and simulated results are asserted identical across
//! iterations — the bench doubles as a determinism check.

use std::fmt::Write as _;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{ensure, Context, Result};

use super::experiments::{
    fig4_variants, tardis_lease_variants, EvalCtx, Variant, LEASE_MATRIX_CORES,
};
use crate::api::SimBuilder;
use crate::config::{LeasePolicyKind, PdesMode, ProtocolKind, TopologyConfig};
use crate::workloads::all as all_workloads;

/// Schema identifier stamped into every report.
pub const SCHEMA: &str = "tardis-bench-v1";

/// One (workload, variant) sweep point.
#[derive(Debug, Clone)]
pub struct BenchPoint {
    pub workload: String,
    pub variant: String,
    /// Core count this point simulated (multi-scale suites like the
    /// lease matrix span several counts in one report, so the
    /// top-level `n_cores` alone cannot describe every point).
    pub cores: u32,
    /// Simulated completion time.
    pub sim_cycles: u64,
    /// Committed memory operations.
    pub memops: u64,
    /// Discrete events the engine dispatched.
    pub events: u64,
    /// Intra- / inter-socket network messages (the ccNUMA traffic
    /// split; inter is 0 — and both are omitted from the JSON — on
    /// flat topologies).
    pub intra_socket_msgs: u64,
    pub inter_socket_msgs: u64,
    /// Engine shards this point ran on (1 = the serial engine; both
    /// this and `parallel_efficiency` are omitted from the JSON for
    /// serial points, keeping the pre-PDES point shape).
    pub threads: u32,
    /// Σ per-shard busy time / wall time, in (0, threads] — from the
    /// best-wall iteration.  0 on serial points.
    pub parallel_efficiency: f64,
    /// Null messages (channel-clock promises without real mail) the
    /// run exchanged — 0 in epoch mode and on serial points.  Host
    /// timing-dependent, so reported from the best-wall iteration.
    pub null_msgs: u64,
    /// Count-driven repartitions the run performed (deterministic:
    /// driven by simulated event counts, identical every iteration).
    pub rebalances: u64,
    /// Max/mean per-shard busy-time ratio from the best-wall
    /// iteration, >= 1.0 (1.0 = perfectly even).  0 on serial points.
    pub imbalance: f64,
    /// Renew requests / LLC accesses, in [0, 1] (Fig. 5; deterministic
    /// like the other simulated counters).
    pub renew_rate: f64,
    /// Mean granted lease length (0 for non-Tardis variants).
    pub avg_lease: f64,
    /// Best host wall time over the iterations, seconds.
    pub wall_s: f64,
}

impl BenchPoint {
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_s.max(1e-9)
    }

    pub fn sim_cycles_per_sec(&self) -> f64 {
        self.sim_cycles as f64 / self.wall_s.max(1e-9)
    }
}

/// A full macro-bench run, serializable to the `BENCH_*.json` schema.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub label: String,
    /// "measured" for reports emitted by this pipeline; other values
    /// flag numbers that did not come from a local run.
    pub provenance: String,
    pub unix_time: u64,
    pub n_cores: u32,
    pub iters: u32,
    pub scale_down: u32,
    /// Fabric the points ran on ("flat" or "numa"); numa reports must
    /// carry per-point socket-split counters (validator-enforced).
    pub topology: String,
    pub sockets: u32,
    pub numa_ratio: u32,
    pub points: Vec<BenchPoint>,
}

impl BenchReport {
    pub fn total_wall_s(&self) -> f64 {
        self.points.iter().map(|p| p.wall_s).sum()
    }

    pub fn total_events(&self) -> u64 {
        self.points.iter().map(|p| p.events).sum()
    }

    pub fn total_sim_cycles(&self) -> u64 {
        self.points.iter().map(|p| p.sim_cycles).sum()
    }

    /// Aggregate host throughput (total events / total wall time).
    pub fn events_per_sec(&self) -> f64 {
        self.total_events() as f64 / self.total_wall_s().max(1e-9)
    }

    pub fn sim_cycles_per_sec(&self) -> f64 {
        self.total_sim_cycles() as f64 / self.total_wall_s().max(1e-9)
    }

    /// One-line human summary for the CLI.
    pub fn summary(&self) -> String {
        format!(
            "bench {}: {} points, {:.2}s wall, {:.2} M events/s, {:.2} M sim-cycles/s",
            self.label,
            self.points.len(),
            self.total_wall_s(),
            self.events_per_sec() / 1e6,
            self.sim_cycles_per_sec() / 1e6,
        )
    }

    /// Serialize to the `tardis-bench-v1` JSON schema (hand-rolled;
    /// serde is not in this image's offline registry).  All string
    /// fields are known-ASCII labels, so no escaping is needed beyond
    /// the assertion below.
    pub fn to_json(&self) -> String {
        fn lit(s: &str) -> String {
            assert!(
                s.chars().all(|c| c.is_ascii_alphanumeric() || "-_. /".contains(c)),
                "label {s:?} needs JSON escaping"
            );
            format!("\"{s}\"")
        }
        let mut j = String::new();
        j.push_str("{\n");
        let _ = writeln!(j, "  \"schema\": {},", lit(SCHEMA));
        let _ = writeln!(j, "  \"label\": {},", lit(&self.label));
        let _ = writeln!(j, "  \"provenance\": {},", lit(&self.provenance));
        let _ = writeln!(j, "  \"unix_time\": {},", self.unix_time);
        let _ = writeln!(j, "  \"n_cores\": {},", self.n_cores);
        let _ = writeln!(j, "  \"iters\": {},", self.iters);
        let _ = writeln!(j, "  \"scale_down\": {},", self.scale_down);
        let _ = writeln!(j, "  \"topology\": {},", lit(&self.topology));
        let _ = writeln!(j, "  \"sockets\": {},", self.sockets);
        let _ = writeln!(j, "  \"numa_ratio\": {},", self.numa_ratio);
        let numa = self.topology != "flat";
        j.push_str("  \"points\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            // Flat reports keep the pre-topology point shape; numa
            // reports add the socket-split counters the validator
            // requires.
            let socket_split = if numa {
                format!(
                    ", \"intra_socket_msgs\": {}, \"inter_socket_msgs\": {}",
                    p.intra_socket_msgs, p.inter_socket_msgs
                )
            } else {
                String::new()
            };
            // Threaded points record the shard count, efficiency, and
            // the PR-9 sync/balance counters; serial points keep the
            // pre-PDES shape.
            let pdes = if p.threads > 1 {
                format!(
                    ", \"threads\": {}, \"parallel_efficiency\": {:.4}, \"null_msgs\": {}, \
                     \"rebalances\": {}, \"imbalance\": {:.4}",
                    p.threads, p.parallel_efficiency, p.null_msgs, p.rebalances, p.imbalance
                )
            } else {
                String::new()
            };
            let _ = write!(
                j,
                "    {{\"workload\": {}, \"variant\": {}, \"cores\": {}, \"sim_cycles\": {}, \
                 \"memops\": {}, \"events\": {}, \"renew_rate\": {:.6}, \
                 \"avg_lease\": {:.6}{socket_split}{pdes}, \"wall_s\": {:.6}, \
                 \"events_per_sec\": {:.1}, \"sim_cycles_per_sec\": {:.1}}}",
                lit(&p.workload),
                lit(&p.variant),
                p.cores,
                p.sim_cycles,
                p.memops,
                p.events,
                p.renew_rate,
                p.avg_lease,
                p.wall_s,
                p.events_per_sec(),
                p.sim_cycles_per_sec(),
            );
            j.push_str(if i + 1 < self.points.len() { ",\n" } else { "\n" });
        }
        j.push_str("  ],\n");
        let _ = writeln!(
            j,
            "  \"aggregate\": {{\"wall_s\": {:.6}, \"events\": {}, \"sim_cycles\": {}, \
             \"events_per_sec\": {:.1}, \"sim_cycles_per_sec\": {:.1}}}",
            self.total_wall_s(),
            self.total_events(),
            self.total_sim_cycles(),
            self.events_per_sec(),
            self.sim_cycles_per_sec(),
        );
        j.push_str("}\n");
        j
    }

    pub fn write(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json()).with_context(|| format!("writing {path}"))
    }
}

/// Options for a macro-bench run beyond the sweep shape.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    /// Lease-policy override applied to every Tardis variant (the CI
    /// bench-smoke job runs a `Predictive` point this way).
    pub policy: Option<LeasePolicyKind>,
    /// Fabric topology applied to every variant (the CI numa-smoke
    /// point runs 2 sockets at ratio 4); default = flat.
    pub topology: TopologyConfig,
    /// Engine shards per point (0 and 1 both mean the serial engine;
    /// `Default` yields 0 so existing `..Default::default()` call
    /// sites stay serial).
    pub threads: u32,
    /// PDES synchronization mode for threaded points; non-Epoch modes
    /// suffix the report label (`-nullmsg`/`-auto`) so trajectory
    /// records stay distinguishable.
    pub pdes_mode: PdesMode,
    /// Count-driven rebalance interval in lookahead windows (0 = off);
    /// nonzero values suffix the label with `-rb<n>`.
    pub rebalance: u32,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            policy: None,
            topology: TopologyConfig::default(),
            threads: 0,
            pdes_mode: PdesMode::Epoch,
            rebalance: 0,
        }
    }
}

/// Run the fig-4-shaped macro bench at `n_cores` (the trajectory
/// default is 16, the paper's smallest sweep point — big enough to
/// stress the queue, small enough to iterate).
pub fn run_macro_bench(ctx: &mut EvalCtx, n_cores: u32, iters: u32) -> Result<BenchReport> {
    run_macro_bench_with_opts(ctx, n_cores, iters, BenchOpts::default())
}

/// [`run_macro_bench`] with lease-policy / topology overrides.
pub fn run_macro_bench_with_opts(
    ctx: &mut EvalCtx,
    n_cores: u32,
    iters: u32,
    opts: BenchOpts,
) -> Result<BenchReport> {
    let mut variants = fig4_variants(n_cores);
    for v in &mut variants {
        v.cfg.topology = opts.topology;
        if let Some(policy) = opts.policy {
            if v.cfg.protocol == ProtocolKind::Tardis {
                v.cfg.tardis.lease_policy = policy;
                v.label = format!("{}-{}", v.label, policy.name());
            }
        }
    }
    let threads = opts.threads.max(1);
    let points =
        measure_points(ctx, n_cores, iters, &variants, threads, opts.pdes_mode, opts.rebalance)?;
    let mut label = format!("fig4-{n_cores}c");
    if let Some(p) = opts.policy {
        label.push_str(&format!("-{}", p.name()));
    }
    if !opts.topology.is_flat() {
        label.push_str(&format!(
            "-s{}r{}",
            opts.topology.sockets, opts.topology.numa_ratio
        ));
    }
    if threads > 1 {
        label.push_str(&format!("-t{threads}"));
        if opts.pdes_mode != PdesMode::Epoch {
            label.push_str(&format!("-{}", opts.pdes_mode.name()));
        }
        if opts.rebalance > 0 {
            label.push_str(&format!("-rb{}", opts.rebalance));
        }
    }
    Ok(report_shell(label, n_cores, iters, ctx.scale_down, opts.topology, points))
}

/// The lease-matrix trajectory suite (`tardis bench --suite lease`,
/// BENCH_5): every lease policy x consistency model at 16 / 64 / 256
/// cores, all 12 workloads.  Each point's own `cores` field records
/// its scale (the variant label carries a `-<n>c` suffix too); the
/// top-level `n_cores` records the matrix's 64-core headline point.
pub fn run_lease_matrix_bench(ctx: &mut EvalCtx, iters: u32) -> Result<BenchReport> {
    let mut points = Vec::new();
    for &n_cores in &LEASE_MATRIX_CORES {
        // The same grid lease_matrix sweeps, with the core count
        // suffixed onto each label for the flat point list.
        let mut variants = tardis_lease_variants(n_cores);
        for v in &mut variants {
            v.label = format!("{}-{n_cores}c", v.label);
        }
        points.extend(measure_points(ctx, n_cores, iters, &variants, 1, PdesMode::Epoch, 0)?);
    }
    Ok(report_shell(
        "lease-matrix".to_string(),
        64,
        iters,
        ctx.scale_down,
        TopologyConfig::default(),
        points,
    ))
}

fn report_shell(
    label: String,
    n_cores: u32,
    iters: u32,
    scale_down: u32,
    topology: TopologyConfig,
    points: Vec<BenchPoint>,
) -> BenchReport {
    let flat = topology.is_flat();
    BenchReport {
        label,
        provenance: "measured".to_string(),
        unix_time: SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0),
        n_cores,
        iters,
        scale_down,
        topology: topology.name().to_string(),
        // Normalize flat stamps so an inert configured ratio can
        // never masquerade as a NUMA run in the trajectory record.
        sockets: if flat { 1 } else { topology.sockets },
        numa_ratio: if flat { 1 } else { topology.numa_ratio },
        points,
    }
}

/// Time `variants` x all 12 workloads at one core count, asserting
/// simulated results identical across iterations (the determinism
/// double-check every bench run performs).
fn measure_points(
    ctx: &mut EvalCtx,
    n_cores: u32,
    iters: u32,
    variants: &[Variant],
    threads: u32,
    pdes_mode: PdesMode,
    rebalance: u32,
) -> Result<Vec<BenchPoint>> {
    ensure!(iters > 0, "bench needs at least one iteration");
    let mut points = Vec::new();
    for spec in &all_workloads() {
        let w = ctx.workload(spec, n_cores);
        for v in variants {
            let mut best_wall = f64::INFINITY;
            let mut best_eff = 0.0;
            let mut best_null = 0u64;
            let mut best_reb = 0u64;
            let mut best_imb = 0.0;
            let mut first: Option<crate::stats::SimStats> = None;
            for _ in 0..iters {
                let report = SimBuilder::from_config(v.cfg.clone())
                    .workload_arc(std::sync::Arc::clone(&w))
                    .threads(threads)
                    .pdes_mode(pdes_mode)
                    .rebalance_every(rebalance)
                    .run()?;
                match &first {
                    None => first = Some(report.stats.clone()),
                    Some(f) => ensure!(
                        *f == report.stats,
                        "nondeterministic bench point {}/{}: {:?} vs {:?}",
                        spec.name,
                        v.label,
                        f,
                        report.stats
                    ),
                }
                let wall = report.elapsed.as_secs_f64();
                if wall < best_wall {
                    best_wall = wall;
                    best_eff = report.stats.parallel.efficiency();
                    best_null = report.stats.parallel.null_msgs;
                    best_reb = report.stats.parallel.rebalances;
                    best_imb = report.stats.parallel.imbalance();
                }
            }
            let stats = first.unwrap();
            points.push(BenchPoint {
                workload: spec.name.to_string(),
                variant: v.label.clone(),
                cores: n_cores,
                sim_cycles: stats.cycles,
                memops: stats.memops,
                events: stats.events,
                intra_socket_msgs: stats.socket.intra_msgs,
                inter_socket_msgs: stats.socket.inter_msgs,
                threads,
                parallel_efficiency: if threads > 1 { best_eff } else { 0.0 },
                null_msgs: if threads > 1 { best_null } else { 0 },
                rebalances: if threads > 1 { best_reb } else { 0 },
                imbalance: if threads > 1 { best_imb } else { 0.0 },
                renew_rate: stats.renew_rate(),
                avg_lease: stats.avg_lease(),
                wall_s: best_wall,
            });
        }
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments::EvalCtx;

    fn tiny_report() -> BenchReport {
        let mut ctx = EvalCtx::new(None, 1);
        ctx.scale_down = 32; // 64-op traces: fast enough for a unit test
        run_macro_bench(&mut ctx, 2, 1).unwrap()
    }

    #[test]
    fn macro_bench_covers_the_fig4_grid() {
        let r = tiny_report();
        assert_eq!(r.points.len(), 12 * 4);
        assert!(r.points.iter().all(|p| p.sim_cycles > 0 && p.events > 0));
        assert!(r.events_per_sec() > 0.0);
        assert_eq!(r.label, "fig4-2c");
    }

    #[test]
    fn policy_override_relabels_tardis_variants() {
        let mut ctx = EvalCtx::new(None, 1);
        ctx.scale_down = 32;
        let opts = BenchOpts {
            policy: Some(crate::config::LeasePolicyKind::Predictive { max_lease: 80 }),
            ..BenchOpts::default()
        };
        let r = run_macro_bench_with_opts(&mut ctx, 2, 1, opts).unwrap();
        assert_eq!(r.label, "fig4-2c-predictive");
        assert!(r.points.iter().any(|p| p.variant == "tardis-predictive"));
        assert!(r.points.iter().any(|p| p.variant == "msi"), "baselines untouched");
        // The relabeled report still serializes to valid schema shape.
        let j = r.to_json();
        assert!(j.contains("\"variant\": \"tardis-predictive\""));
    }

    #[test]
    fn numa_bench_reports_topology_and_socket_split() {
        let mut ctx = EvalCtx::new(None, 1);
        ctx.scale_down = 32;
        let opts = BenchOpts {
            policy: Some(crate::config::LeasePolicyKind::Predictive { max_lease: 80 }),
            topology: TopologyConfig { sockets: 2, numa_ratio: 4, ..TopologyConfig::default() },
            ..BenchOpts::default()
        };
        let r = run_macro_bench_with_opts(&mut ctx, 2, 1, opts).unwrap();
        assert_eq!(r.label, "fig4-2c-predictive-s2r4");
        assert_eq!(r.topology, "numa");
        assert!(
            r.points.iter().any(|p| p.inter_socket_msgs > 0),
            "a 2-socket run must cross sockets somewhere"
        );
        let j = r.to_json();
        assert!(j.contains("\"topology\": \"numa\""));
        assert!(j.contains("\"sockets\": 2"));
        assert!(j.contains("\"numa_ratio\": 4"));
        assert!(j.contains("\"intra_socket_msgs\""));
        assert!(j.contains("\"inter_socket_msgs\""));
        // Flat reports keep the pre-topology point shape.
        let flat = tiny_report().to_json();
        assert!(flat.contains("\"topology\": \"flat\""));
        assert!(!flat.contains("intra_socket_msgs"));
    }

    #[test]
    fn lease_matrix_bench_spans_policies_and_core_counts() {
        // Tiny scale: reuse the 2-core grid shape by checking labels
        // only (the full 16/64/256 suite is the CLI path; here we
        // assert the variant labeling contract on the real function
        // with a heavy scale-down).
        let mut ctx = EvalCtx::new(None, 1);
        ctx.scale_down = 1024; // 64-op traces even at 256 cores
        let r = run_lease_matrix_bench(&mut ctx, 1).unwrap();
        assert_eq!(r.label, "lease-matrix");
        assert_eq!(r.points.len(), 12 * 6 * 3);
        for cores in [16u32, 64, 256] {
            for v in ["static-sc", "dynamic-tso", "predictive-sc"] {
                let label = format!("{v}-{cores}c");
                assert!(
                    r.points.iter().any(|p| p.variant == label && p.cores == cores),
                    "missing variant {label} with per-point cores"
                );
            }
        }
        assert!(r.to_json().contains("\"cores\": 256"));
    }

    #[test]
    fn threaded_bench_records_shards_and_efficiency() {
        let mut ctx = EvalCtx::new(None, 1);
        ctx.scale_down = 32;
        let opts = BenchOpts { threads: 2, ..BenchOpts::default() };
        let r = run_macro_bench_with_opts(&mut ctx, 2, 1, opts).unwrap();
        assert_eq!(r.label, "fig4-2c-t2");
        assert!(r.points.iter().all(|p| p.threads == 2));
        assert!(
            r.points.iter().all(|p| p.parallel_efficiency > 0.0 && p.parallel_efficiency <= 2.0),
            "efficiency must land in (0, threads]"
        );
        assert!(
            r.points.iter().all(|p| p.imbalance >= 1.0),
            "max/mean busy ratio is >= 1 by construction"
        );
        assert!(
            r.points.iter().all(|p| p.null_msgs == 0),
            "epoch mode exchanges no null messages"
        );
        let j = r.to_json();
        assert!(j.contains("\"threads\": 2"));
        assert!(j.contains("\"parallel_efficiency\""));
        assert!(j.contains("\"null_msgs\""));
        assert!(j.contains("\"rebalances\""));
        assert!(j.contains("\"imbalance\""));
        // Serial reports keep the pre-PDES point shape.
        let flat = tiny_report().to_json();
        assert!(!flat.contains("parallel_efficiency"));
        assert!(!flat.contains("null_msgs"));
    }

    #[test]
    fn nullmsg_bench_labels_and_counts_null_messages() {
        let mut ctx = EvalCtx::new(None, 1);
        ctx.scale_down = 32;
        let opts =
            BenchOpts { threads: 2, pdes_mode: PdesMode::NullMsg, rebalance: 4, ..BenchOpts::default() };
        let r = run_macro_bench_with_opts(&mut ctx, 2, 1, opts).unwrap();
        assert_eq!(r.label, "fig4-2c-t2-nullmsg-rb4");
        assert!(
            r.points.iter().any(|p| p.null_msgs > 0),
            "a null-message run must exchange some channel-clock promises"
        );
    }

    #[test]
    fn json_matches_the_v1_schema_shape() {
        let r = tiny_report();
        let j = r.to_json();
        for key in [
            "\"schema\": \"tardis-bench-v1\"",
            "\"label\"",
            "\"provenance\": \"measured\"",
            "\"unix_time\"",
            "\"n_cores\"",
            "\"iters\"",
            "\"scale_down\"",
            "\"points\"",
            "\"workload\"",
            "\"variant\"",
            "\"cores\"",
            "\"sim_cycles\"",
            "\"memops\"",
            "\"events\"",
            "\"wall_s\"",
            "\"events_per_sec\"",
            "\"renew_rate\"",
            "\"avg_lease\"",
            "\"aggregate\"",
        ] {
            assert!(j.contains(key), "missing {key} in:\n{j}");
        }
        // The interval metrics are bounded like the validator demands.
        assert!(r
            .points
            .iter()
            .all(|p| (0.0..=1.0).contains(&p.renew_rate) && p.avg_lease >= 0.0));
        assert!(
            r.points.iter().any(|p| p.variant.starts_with("tardis") && p.avg_lease > 0.0),
            "tardis points grant leases"
        );
        // Balanced braces/brackets (cheap well-formedness probe).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }
}
