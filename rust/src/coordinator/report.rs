//! Table rendering: markdown + CSV emitters for the experiment
//! harness (results land in results/).

use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// A generic results table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Self {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as github-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s += &format!("| {} |\n", self.columns.join(" | "));
        s += &format!("|{}|\n", self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            s += &format!("| {} |\n", row.join(" | "));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.columns.join(",") + "\n";
        for row in &self.rows {
            s += &(row.join(",") + "\n");
        }
        s
    }

    /// Write markdown + CSV under `dir` using `stem`.
    pub fn write(&self, dir: impl AsRef<Path>, stem: &str) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let mut md = std::fs::File::create(dir.join(format!("{stem}.md")))?;
        md.write_all(self.to_markdown().as_bytes())?;
        let mut csv = std::fs::File::create(dir.join(format!("{stem}.csv")))?;
        csv.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }
}

/// Format a ratio to 3 decimals.
pub fn r3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a percentage to 2 decimals.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

/// Geometric mean of positive ratios.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn csv_shape() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn geomean_of_ones_is_one() {
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 0.5]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join("tardis_report_test");
        let mut t = Table::new("Demo", &["a"]);
        t.row(vec!["1".into()]);
        t.write(&dir, "demo").unwrap();
        assert!(dir.join("demo.md").exists());
        assert!(dir.join("demo.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
