//! In-tree property-testing utilities (proptest is not in this
//! image's crate registry): a deterministic PRNG and random program
//! generators used by the SC property tests.

use crate::api::{SimBuilder, SimReport};
use crate::config::SystemConfig;
use crate::prog::{Op, Program, Workload};
use crate::types::{LineAddr, LOCK_BASE, SHARED_BASE};

/// Run `w` under `cfg` with the SC access log enabled — the canonical
/// integration-test shape.
pub fn run_logged(cfg: SystemConfig, w: &Workload) -> anyhow::Result<SimReport> {
    SimBuilder::from_config(cfg).record_accesses(true).workload(w).run()
}

/// xorshift64* — deterministic, seedable, no dependencies.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.below(den) < num
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Configuration for random-program generation.
#[derive(Debug, Clone)]
pub struct ProgGen {
    pub n_cores: u32,
    pub ops_per_core: usize,
    /// Distinct shared lines the cores contend on.
    pub n_shared: u64,
    /// Probability (out of 100) that an op is a store.
    pub store_pct: u64,
    /// Probability (out of 100) of a lock-guarded critical section.
    pub lock_pct: u64,
    /// Insert a global barrier every this many ops (0 = never).
    pub barrier_every: usize,
    /// Max compute gap attached to an op.
    pub max_gap: u32,
}

impl Default for ProgGen {
    fn default() -> Self {
        Self {
            n_cores: 4,
            ops_per_core: 40,
            n_shared: 6,
            store_pct: 40,
            lock_pct: 10,
            barrier_every: 0,
            max_gap: 3,
        }
    }
}

impl ProgGen {
    /// Generate a random, deadlock-free workload: every LOCK is
    /// followed by its UNLOCK, barriers are emitted for all cores at
    /// the same per-core op index, and locks never nest.
    pub fn generate(&self, rng: &mut Rng) -> Workload {
        let mut programs = Vec::new();
        for core in 0..self.n_cores {
            let mut ops = Vec::new();
            let mut i = 0usize;
            while ops.len() < self.ops_per_core {
                i += 1;
                if self.barrier_every > 0 && ops.len() % self.barrier_every == self.barrier_every - 1
                {
                    ops.push(Op::Barrier);
                    continue;
                }
                if self.lock_pct > 0 && rng.chance(self.lock_pct, 100) {
                    // Critical section: lock; 1-2 accesses; unlock.
                    let lock = LOCK_BASE + rng.below(2);
                    ops.push(Op::Lock { addr: lock });
                    let n = 1 + rng.below(2);
                    for _ in 0..n {
                        ops.push(self.data_op(core, rng));
                    }
                    ops.push(Op::Unlock { addr: lock });
                    continue;
                }
                ops.push(self.data_op(core, rng));
                let _ = i;
            }
            // Join barrier so completion time is well-defined.
            ops.push(Op::Barrier);
            programs.push(Program::new(ops));
        }
        // Balance barrier counts across cores (sense-reversing barriers
        // hang otherwise).
        let max_barriers = programs
            .iter()
            .map(|p| p.ops.iter().filter(|o| matches!(o, Op::Barrier)).count())
            .max()
            .unwrap();
        for p in &mut programs {
            let mut have = p.ops.iter().filter(|o| matches!(o, Op::Barrier)).count();
            while have < max_barriers {
                p.ops.push(Op::Barrier);
                have += 1;
            }
        }
        Workload::new(programs)
    }

    fn data_op(&self, core: u32, rng: &mut Rng) -> Op {
        let shared = rng.chance(70, 100);
        let addr: LineAddr = if shared {
            SHARED_BASE + rng.below(self.n_shared)
        } else {
            crate::types::PRIV_BASE + core as u64 * crate::types::PRIV_STRIDE + rng.below(8)
        };
        let gap = rng.below(self.max_gap as u64 + 1) as u32;
        if rng.chance(self.store_pct, 100) {
            Op::Store { addr, value: None, gap }
        } else {
            Op::Load { addr, gap }
        }
    }
}

/// Run a closure over `cases` seeded generations — the poor man's
/// proptest harness.  Panics with the failing seed for reproduction.
pub fn prop_check(cases: u64, base_seed: u64, mut f: impl FnMut(u64, &mut Rng)) {
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(seed, &mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property failed with seed {seed:#x} (case {i})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert!(rng.below(10) < 10);
        }
    }

    #[test]
    fn generated_locks_are_balanced_and_unnested() {
        let gen = ProgGen { lock_pct: 30, ..Default::default() };
        let mut rng = Rng::new(42);
        let w = gen.generate(&mut rng);
        for p in &w.programs {
            let mut depth: i32 = 0;
            for op in &p.ops {
                match op {
                    Op::Lock { .. } => {
                        depth += 1;
                        assert_eq!(depth, 1, "nested lock");
                    }
                    Op::Unlock { .. } => {
                        depth -= 1;
                        assert_eq!(depth, 0, "unmatched unlock");
                    }
                    _ => {}
                }
            }
            assert_eq!(depth, 0, "lock held at end");
        }
    }

    #[test]
    fn generated_barriers_balanced() {
        let gen = ProgGen { barrier_every: 7, ..Default::default() };
        let mut rng = Rng::new(9);
        let w = gen.generate(&mut rng);
        let counts: Vec<usize> = w
            .programs
            .iter()
            .map(|p| p.ops.iter().filter(|o| matches!(o, Op::Barrier)).count())
            .collect();
        assert!(counts.windows(2).all(|c| c[0] == c[1]));
    }

    #[test]
    fn prop_check_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            prop_check(5, 1, |_, rng| {
                assert!(rng.below(10) < 11); // never fails
            });
        });
        assert!(r.is_ok());
    }
}
