//! Trace handling: decoding AOT tracegen artifacts into [`Workload`]s,
//! plus a bit-exact pure-rust mirror of the generator used as a
//! cross-language oracle and artifact-free fallback.

pub mod decode;
pub mod synth;

pub use decode::decode_workload;
pub use synth::{synth_raw, synth_workload, TraceParams};
