//! Pure-rust mirror of the Pallas tracegen kernel
//! (python/compile/kernels/tracegen.py).  Bit-exact by construction:
//! the integration test `runtime_artifacts.rs` asserts equality against
//! the PJRT-executed artifact, which validates both this port and the
//! artifact decode path.  Also the artifact-free fallback for tests.

use crate::types::{
    BARRIER_BASE, LOCK_BASE, LOCK_DATA_BASE, LOCK_DATA_SPAN, PRIV_BASE, PRIV_STRIDE, SHARED_BASE,
};

/// Parameter vector — mirrors python/compile/kernels/spec.py.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceParams {
    pub seed: u32,
    pub pattern: u32,
    pub priv_lines: u32,
    pub shared_lines: u32,
    pub pct_shared: u32,
    pub pct_write_shared: u32,
    pub pct_write_priv: u32,
    pub sync_kind: u32,
    pub sync_period: u32,
    pub crit_len: u32,
    pub n_locks: u32,
    pub compute_gap_max: u32,
    pub stride: u32,
    pub grid_dim: u32,
    pub barrier_period: u32,
}

impl Default for TraceParams {
    fn default() -> Self {
        Self {
            seed: 1,
            pattern: 0,
            priv_lines: 64,
            shared_lines: 256,
            pct_shared: 300,
            pct_write_shared: 200,
            pct_write_priv: 300,
            sync_kind: 0,
            sync_period: 0,
            crit_len: 4,
            n_locks: 16,
            compute_gap_max: 4,
            stride: 3,
            grid_dim: 8,
            barrier_period: 0,
        }
    }
}

impl TraceParams {
    /// Serialize to the int32[16] vector the artifacts take as input.
    pub fn to_vec(&self) -> [i32; 16] {
        [
            self.seed as i32,
            self.pattern as i32,
            self.priv_lines as i32,
            self.shared_lines as i32,
            self.pct_shared as i32,
            self.pct_write_shared as i32,
            self.pct_write_priv as i32,
            self.sync_kind as i32,
            self.sync_period as i32,
            self.crit_len as i32,
            self.n_locks as i32,
            self.compute_gap_max as i32,
            self.stride as i32,
            self.grid_dim as i32,
            self.barrier_period as i32,
            0,
        ]
    }
}

const OP_LOAD: i32 = 0;
const OP_STORE: i32 = 1;
const OP_LOCK: i32 = 2;
const OP_UNLOCK: i32 = 3;
const OP_BARRIER: i32 = 4;

const N_BLOCKS: u32 = 32;
const HOT_SET_LINES: u32 = 64;

/// The counter-based PRNG (xxhash-style finalizer) — must match
/// `_mix` in tracegen.py exactly.
#[inline]
pub fn mix(seed: u32, core: u32, slot: u32, stream: u32) -> u32 {
    let mut h = seed
        ^ core.wrapping_mul(0x85EB_CA6B)
        ^ slot.wrapping_mul(0xC2B2_AE35)
        ^ stream.wrapping_mul(0x27D4_EB2F);
    h ^= h >> 15;
    h = h.wrapping_mul(0x2C1B_3C6D);
    h ^= h >> 12;
    h = h.wrapping_mul(0x297A_2D39);
    h ^= h >> 15;
    h
}

/// Generate one slot — the scalar twin of `_gen_tile`.
fn gen_slot(p: &TraceParams, core: u32, slot: u32, trace_len: u32, n_cores: u32) -> (i32, i32, i32) {
    let seed = p.seed;
    let priv_lines = p.priv_lines.max(1);
    let shared_lines = p.shared_lines.max(1);
    let n_locks = p.n_locks.max(1);
    let stride = p.stride.max(1);
    let grid_dim = p.grid_dim.max(1);

    let h: Vec<u32> = (0..7).map(|k| mix(seed, core, slot, k)).collect();

    // Barriers.
    let use_barriers = (p.sync_kind & 2) != 0;
    let bp = p.barrier_period.max(1);
    let is_barrier = use_barriers && p.barrier_period > 0 && (slot + 1) % bp == 0;
    let barrier_epoch = (slot + 1) / bp;

    // Lock episodes.
    let use_locks = (p.sync_kind & 1) != 0;
    let sp = p.sync_period.max(1);
    let crit_len = p.crit_len.min(sp - sp.min(2));
    let m = slot % sp;
    let episode_start = slot - m;
    let lock_id = mix(seed, core, episode_start, 7) % n_locks;
    let episode_end = episode_start + crit_len + 1;
    let fits = episode_start >= 1 && episode_end <= trace_len - 2;
    let first_bar = bp * ((episode_start + bp) / bp) - 1;
    let no_bar_inside = !(use_barriers && p.barrier_period > 0 && first_bar <= episode_end);
    let in_lock_mode = use_locks && p.sync_period > 0 && fits && no_bar_inside;
    let is_lock = in_lock_mode && m == 0;
    let is_unlock = in_lock_mode && m == crit_len + 1;
    let is_crit = in_lock_mode && m >= 1 && m <= crit_len;
    let lock_addr = LOCK_BASE as u32 + lock_id;
    let crit_addr =
        LOCK_DATA_BASE as u32 + lock_id * LOCK_DATA_SPAN as u32 + h[3] % LOCK_DATA_SPAN as u32;
    let crit_store = h[2] % 1000 < 500;

    // Normal slots.
    let is_shared = h[0] % 1000 < p.pct_shared;
    let sh_store = h[1] % 1000 < p.pct_write_shared;
    let pr_store = h[1] % 1000 < p.pct_write_priv;

    let s_uniform = h[5] % shared_lines;
    // Strided reads sweep the whole array; writes stay in the core's
    // own 1/N output partition (SPLASH-2 kernels write core-
    // partitioned data).
    let part = (shared_lines / n_cores.max(1)).max(1);
    let s_strided_rd = (slot.wrapping_mul(stride).wrapping_add(core)) % shared_lines;
    let s_strided_wr =
        (core.wrapping_mul(part).wrapping_add(slot.wrapping_mul(stride) % part)) % shared_lines;
    let s_strided = if sh_store { s_strided_wr } else { s_strided_rd };
    let blk = (shared_lines / N_BLOCKS).max(1);
    let own_block = core % N_BLOCKS;
    let rd_block = h[5] % N_BLOCKS;
    let block_sel = if sh_store { own_block } else { rd_block };
    let s_blocked = (block_sel.wrapping_mul(blk).wrapping_add(h[6] % blk)) % shared_lines;
    let row = core % grid_dim;
    let drow = h[5] % 3;
    let row2 = (row + grid_dim + drow - 1) % grid_dim;
    // Stencil: reads may touch neighbor rows; writes only the own row.
    let row_sel = if sh_store { row } else { row2 };
    let s_stencil = (row_sel.wrapping_mul(grid_dim).wrapping_add(h[6] % grid_dim)) % shared_lines;
    let hot = shared_lines.min(HOT_SET_LINES);
    let s_hot = h[5] % hot;

    let s = match p.pattern {
        1 => s_strided,
        2 => s_blocked,
        3 => s_stencil,
        4 => s_hot,
        _ => s_uniform,
    };
    let shared_addr = SHARED_BASE as u32 + s;
    // Private accesses have temporal locality: 80% hit a hot 1/8
    // subset (benchmark-like L1 hit rates).
    let hot_priv = (priv_lines / 8).max(1);
    let priv_idx = if h[6] % 1000 < 800 { h[3] % hot_priv } else { h[3] % priv_lines };
    let priv_addr = PRIV_BASE as u32 + core * PRIV_STRIDE as u32 + priv_idx;

    let normal_store = if is_shared { sh_store } else { pr_store };
    let normal_addr = if is_shared { shared_addr } else { priv_addr };
    let normal_op = if normal_store { OP_STORE } else { OP_LOAD };

    // Priority composition: barrier > lock > unlock > crit > normal.
    let (mut op, mut addr) = (normal_op, normal_addr);
    if is_crit {
        op = if crit_store { OP_STORE } else { OP_LOAD };
        addr = crit_addr;
    }
    if is_unlock {
        op = OP_UNLOCK;
        addr = lock_addr;
    }
    if is_lock {
        op = OP_LOCK;
        addr = lock_addr;
    }
    if is_barrier {
        op = OP_BARRIER;
        addr = BARRIER_BASE as u32;
    }

    let gap = h[4] % (p.compute_gap_max + 1);
    let aux = if op == OP_LOAD || op == OP_STORE {
        gap
    } else if op == OP_BARRIER {
        barrier_epoch
    } else {
        0
    };
    (op, addr as i32, aux as i32)
}

/// Raw trace rows (op, addr, aux), flat [n_cores * trace_len * 3] —
/// the kernel output, including the L2 epilogue (warm-up slot 0 and
/// join barrier at the end, matching model.py).
pub fn synth_raw(p: &TraceParams, n_cores: u32, trace_len: u32) -> Vec<i32> {
    let mut out = Vec::with_capacity((n_cores * trace_len * 3) as usize);
    for core in 0..n_cores {
        for slot in 0..trace_len {
            let (op, addr, aux) = if slot == 0 {
                // Warm-up private load (model.py epilogue).
                (OP_LOAD, (PRIV_BASE + core as u64 * PRIV_STRIDE) as i32, 0)
            } else if slot == trace_len - 1 {
                // Join barrier.
                (OP_BARRIER, BARRIER_BASE as i32, 0)
            } else {
                gen_slot(p, core, slot, trace_len, n_cores)
            };
            out.extend_from_slice(&[op, addr, aux]);
        }
    }
    out
}

/// Generate straight to a [`crate::prog::Workload`].
pub fn synth_workload(p: &TraceParams, n_cores: u32, trace_len: u32) -> crate::prog::Workload {
    crate::trace::decode::decode_workload(&synth_raw(p, n_cores, trace_len), n_cores, trace_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let p = TraceParams::default();
        assert_eq!(synth_raw(&p, 2, 64), synth_raw(&p, 2, 64));
    }

    #[test]
    fn seed_changes_output() {
        let a = synth_raw(&TraceParams { seed: 1, ..Default::default() }, 2, 64);
        let b = synth_raw(&TraceParams { seed: 2, ..Default::default() }, 2, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn epilogue_applied() {
        let p = TraceParams::default();
        let raw = synth_raw(&p, 2, 64);
        // Core 0, slot 0: warm-up load of its private base.
        assert_eq!(&raw[0..3], &[OP_LOAD, 0, 0]);
        // Core 1, slot 0.
        let c1 = (64 * 3) as usize;
        assert_eq!(&raw[c1..c1 + 3], &[OP_LOAD, PRIV_STRIDE as i32, 0]);
        // Last slot of each core: join barrier.
        let last0 = (63 * 3) as usize;
        assert_eq!(raw[last0], OP_BARRIER);
        let last1 = c1 + last0;
        assert_eq!(raw[last1], OP_BARRIER);
    }

    #[test]
    fn opcodes_in_range() {
        let p = TraceParams {
            sync_kind: 3,
            sync_period: 16,
            barrier_period: 40,
            ..Default::default()
        };
        for v in synth_raw(&p, 4, 256).chunks(3) {
            assert!((0..=4).contains(&v[0]));
            assert!(v[1] >= 0);
            assert!(v[2] >= 0);
        }
    }

    #[test]
    fn lock_episodes_balanced() {
        let p = TraceParams { sync_kind: 1, sync_period: 16, crit_len: 3, ..Default::default() };
        let raw = synth_raw(&p, 2, 256);
        for core in 0..2usize {
            let ops: Vec<i32> =
                raw[core * 256 * 3..(core + 1) * 256 * 3].chunks(3).map(|c| c[0]).collect();
            let locks = ops.iter().filter(|&&o| o == OP_LOCK).count();
            let unlocks = ops.iter().filter(|&&o| o == OP_UNLOCK).count();
            assert_eq!(locks, unlocks);
            assert!(locks > 0);
        }
    }

    #[test]
    fn mix_avalanche() {
        // Flipping one input bit changes many output bits on average.
        let a = mix(1, 2, 3, 4);
        let b = mix(1, 2, 3, 5);
        assert!((a ^ b).count_ones() >= 8);
    }
}
