//! Decode raw trace tensors (PJRT artifact output or the rust synth
//! mirror) into executable [`Workload`]s.

use crate::prog::{Op, Program, Workload};
use crate::types::{LineAddr, OP_BARRIER, OP_LOAD, OP_LOCK, OP_STORE, OP_UNLOCK};

/// Decode a flat int32[n_cores * trace_len * 3] (op, addr, aux) tensor.
pub fn decode_workload(raw: &[i32], n_cores: u32, trace_len: u32) -> Workload {
    assert_eq!(
        raw.len(),
        (n_cores * trace_len * 3) as usize,
        "trace tensor shape mismatch"
    );
    let mut programs = Vec::with_capacity(n_cores as usize);
    for core in 0..n_cores as usize {
        let base = core * trace_len as usize * 3;
        let mut ops = Vec::with_capacity(trace_len as usize);
        for slot in 0..trace_len as usize {
            let i = base + slot * 3;
            let (op, addr, aux) = (raw[i], raw[i + 1] as LineAddr, raw[i + 2]);
            ops.push(match op {
                OP_LOAD => Op::Load { addr, gap: aux as u32 },
                OP_STORE => Op::Store { addr, value: None, gap: aux as u32 },
                OP_LOCK => Op::Lock { addr },
                OP_UNLOCK => Op::Unlock { addr },
                OP_BARRIER => Op::Barrier,
                other => panic!("bad opcode {other} at core {core} slot {slot}"),
            });
        }
        programs.push(Program::new(ops));
    }
    Workload::new(programs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decodes_all_op_kinds() {
        #[rustfmt::skip]
        let raw = vec![
            0, 10, 2,   // load addr 10 gap 2
            1, 11, 0,   // store addr 11
            2, 12, 0,   // lock
            3, 12, 0,   // unlock
            4, 99, 1,   // barrier
            0, 13, 0,   // load
        ];
        let w = decode_workload(&raw, 2, 3);
        assert_eq!(w.n_cores(), 2);
        assert_eq!(w.programs[0].ops[0], Op::Load { addr: 10, gap: 2 });
        assert_eq!(w.programs[0].ops[1], Op::Store { addr: 11, value: None, gap: 0 });
        assert_eq!(w.programs[0].ops[2], Op::Lock { addr: 12 });
        assert_eq!(w.programs[1].ops[0], Op::Unlock { addr: 12 });
        assert_eq!(w.programs[1].ops[1], Op::Barrier);
        assert_eq!(w.programs[1].ops[2], Op::Load { addr: 13, gap: 0 });
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn rejects_bad_shape() {
        decode_workload(&[0, 1, 2, 3], 1, 3);
    }

    #[test]
    #[should_panic(expected = "bad opcode")]
    fn rejects_bad_opcode() {
        decode_workload(&[9, 0, 0], 1, 1);
    }
}
