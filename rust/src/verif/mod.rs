//! Exhaustive protocol verification: a bounded model checker that
//! drives the *shipped* coherence controllers.
//!
//! Unlike a hand-written TLA+/Murphi re-model, the checker explores
//! the actual `proto/` implementations — [`crate::proto::tardis::Tardis`]
//! and [`crate::proto::msi::Msi`] — so a bug in the code (not just in
//! an abstraction of it) is caught.  A [`harness::World`] bundles one
//! protocol object with per-core issue state, per-channel in-flight
//! message queues, and a flat DRAM model; [`explore`] runs BFS over
//! every interleaving of issue / store-buffer-drain / message-delivery
//! transitions within small bounds (cores, lines, ops per core).
//!
//! At every explored state each [`Invariant`] is evaluated, and every
//! time an access commits the accumulated trace is re-linearized with
//! [`crate::prog::checker::check_model`] (SC or TSO).  A violation
//! yields a minimal counterexample: the BFS-shortest event path from
//! reset, replayable with [`replay`] and convertible to a
//! [`crate::prog::Workload`] for an engine-level regression run.
//!
//! DESIGN.md §9 documents the state encoding, the soundness argument
//! for what the state key excludes, and how to add an invariant.

mod harness;
mod msi;
mod report;
mod tardis;

pub use harness::{explore, explore_scheduled, replay};
pub use report::{RunReport, VerifReport};

use crate::config::{Consistency, ProtocolKind, SystemConfig};
use crate::proto::Coherence;
use crate::types::{CoreId, LineAddr};

/// Exploration bounds.  Deliberately tiny: exhaustive enumeration is
/// only tractable (and only needed) for a handful of cores and lines —
/// coherence bugs are interleaving bugs, not capacity bugs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VerifBounds {
    /// Cores issuing accesses (2..=3).
    pub cores: u32,
    /// Distinct cache lines touched (1..=2).
    pub lines: u32,
    /// Loads *and* stores each core may issue per line (1..=4); bounds
    /// the timestamps a run can reach.
    pub max_ts: u32,
    /// Tardis static lease used for the run.
    pub lease: u64,
    /// TSO store-buffer depth per core.
    pub sb_entries: u32,
}

impl Default for VerifBounds {
    fn default() -> Self {
        Self { cores: 2, lines: 1, max_ts: 3, lease: 2, sb_entries: 2 }
    }
}

impl VerifBounds {
    pub fn validate(&self) -> Result<(), String> {
        let range = |what: &str, v: u64, lo: u64, hi: u64| {
            if v < lo || v > hi {
                Err(format!("{what} must be in {lo}..={hi} (got {v})"))
            } else {
                Ok(())
            }
        };
        range("--cores", self.cores as u64, 2, 3)?;
        range("--lines", self.lines as u64, 1, 2)?;
        range("--max-ts", self.max_ts as u64, 1, 4)?;
        range("--lease", self.lease, 1, 16)?;
        range("--sb-entries", self.sb_entries as u64, 1, 2)
    }

    /// The concrete line addresses a run touches.
    pub fn line_addrs(&self) -> Vec<LineAddr> {
        (0..self.lines as u64)
            .map(|i| crate::types::SHARED_BASE + i)
            .collect()
    }

    /// System configuration for a verification run.  Geometry is sized
    /// so the bounded run can never evict (4-way caches vs <= 2 lines):
    /// replacement is out of scope for the checker, and no-eviction is
    /// what makes excluding LRU age from the state key sound.
    pub fn config(&self, protocol: ProtocolKind, model: Consistency) -> SystemConfig {
        let mut cfg = SystemConfig::small(self.cores, protocol);
        cfg.consistency = model;
        cfg.sb_entries = self.sb_entries;
        cfg.l1_sets = 4;
        cfg.l1_ways = 4;
        cfg.l2_sets = 4;
        cfg.l2_ways = 4;
        cfg.tardis.lease = self.lease;
        // Self increment is time-driven nondeterminism the harness does
        // not model (and with it off, timestamps stay tiny and exact).
        cfg.tardis.self_inc_period = 0;
        cfg.tardis.exclusive_state = false;
        cfg.tardis.livelock_threshold = 0;
        cfg
    }
}

/// A protocol the model checker can explore: clonable (snapshot /
/// branch), with an exact state key for the visited set and a set of
/// per-state invariants.
pub trait ModelProto: Coherence + Clone {
    /// Exact (lossless) encoding of all protocol state that can affect
    /// future behavior.  Two states with equal keys *must* behave
    /// identically — the explored-state count is only meaningful if
    /// this is true.
    type Key: std::hash::Hash + Eq + Clone + std::fmt::Debug;

    fn state_key(&self) -> Self::Key;

    fn invariants() -> Vec<Box<dyn Invariant<Self>>>;
}

/// A safety property evaluated at every explored state.
pub trait Invariant<P: ?Sized> {
    fn name(&self) -> &'static str;

    /// Check the property on one state; `lines` are the addresses the
    /// run touches.  Err carries a human-readable description of the
    /// violation.
    fn check(&self, proto: &P, lines: &[LineAddr]) -> Result<(), String>;

    /// Check a relation between consecutive states (e.g. timestamp
    /// monotonicity).  Default: nothing.
    fn check_step(&self, _before: &P, _after: &P) -> Result<(), String> {
        Ok(())
    }
}

/// Order in which [`explore`] enumerates a state's enabled
/// transitions.  The reachable-state space is enumeration-order
/// *invariant* (BFS with exact-state dedup visits the same set either
/// way), and `Sharded` exists to prove exactly that for the parallel
/// engine's partition: it groups transitions by the PDES ownership
/// rule ([`crate::sim::engine`]'s `shard_of_node` — contiguous tile
/// blocks, with a message handled by its destination's shard) and
/// enumerates shard 0's transitions first, then shard 1's, and so on.
/// `tardis verify --schedule sharded` and `tests/verif.rs` assert the
/// outcomes are identical, which is the model-checked counterpart of
/// the engine-level determinism matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExploreSchedule {
    /// The historical fixed order: cores ascending, then channels by
    /// (src, dst).
    Serial,
    /// Transitions regrouped by owning shard, shard-major.
    Sharded { shards: u32 },
}

/// What kind of access an [`VerifEvent::Issue`] performs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VerifOp {
    Load,
    Store,
}

impl VerifOp {
    pub fn name(&self) -> &'static str {
        match self {
            VerifOp::Load => "load",
            VerifOp::Store => "store",
        }
    }
}

/// One transition of the model-checked system.  The triple (event
/// sequence from reset) fully determines a state — counterexamples are
/// lists of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VerifEvent {
    /// A core issues a load or store to `line` (index into
    /// [`VerifBounds::line_addrs`]).
    Issue { core: CoreId, line: u32, op: VerifOp },
    /// A core drains the oldest entry of its store buffer (TSO only).
    Drain { core: CoreId },
    /// Deliver the head message of the (src, dst) channel (endpoint
    /// ids: cores, then slices, then memory controllers).
    Deliver { src: u32, dst: u32 },
}

/// Per-invariant evaluation counts for one run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InvariantStat {
    pub name: String,
    pub checked: u64,
    pub violations: u64,
}

/// A minimal violating run: the BFS-shortest event path from reset,
/// with human-readable labels resolved against the replayed states.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Counterexample {
    /// Violated invariant ("linearization" for trace-check failures,
    /// "deadlock-freedom" for stuck states).
    pub invariant: String,
    pub detail: String,
    pub events: Vec<VerifEvent>,
    pub labels: Vec<String>,
}

impl Counterexample {
    /// Project the per-core issue order onto a [`crate::prog::Workload`]
    /// so the counterexample can also be driven through the full engine
    /// (`SimBuilder`) as a coarse regression — the engine's fixed
    /// timing picks *one* interleaving, so only [`replay`] is
    /// guaranteed to reproduce the violation exactly.
    pub fn to_workload(&self, bounds: &VerifBounds) -> crate::prog::Workload {
        use crate::prog::{Op, Program, Workload};
        let addrs = bounds.line_addrs();
        let mut programs = vec![Program::default(); bounds.cores as usize];
        for ev in &self.events {
            if let VerifEvent::Issue { core, line, op } = *ev {
                let prog = &mut programs[core as usize];
                let addr = addrs[line as usize];
                prog.ops.push(match op {
                    VerifOp::Load => Op::Load { addr, gap: 0 },
                    // None = "use the core's unique per-op value", the
                    // same Workload::store_value the harness logs.
                    VerifOp::Store => Op::Store { addr, value: None, gap: 0 },
                });
            }
        }
        Workload::new(programs)
    }
}

/// Result of exhaustively exploring one (protocol, consistency) pair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunOutcome {
    /// Distinct states visited (exact-key dedup).
    pub states: u64,
    /// Transitions taken (explored edges, including ones that landed
    /// on already-visited states).
    pub transitions: u64,
    /// Deepest BFS frontier reached.
    pub max_depth: u32,
    /// Fully quiescent end states (all budgets spent, nothing in
    /// flight).
    pub terminal_states: u64,
    /// Incremental + end-state linearization checks run.
    pub trace_checks: u64,
    pub invariants: Vec<InvariantStat>,
    pub counterexample: Option<Counterexample>,
}

impl RunOutcome {
    pub fn passed(&self) -> bool {
        self.counterexample.is_none()
    }
}

/// Explore every (protocol, consistency) combination and collect a
/// report.  `Ackwise` is rejected: its `Sharers::Global` overflow set
/// is a deliberate over-approximation, so exact-state invariants do
/// not apply.
pub fn run_matrix(
    protocols: &[ProtocolKind],
    models: &[Consistency],
    bounds: VerifBounds,
) -> Result<VerifReport, String> {
    run_matrix_scheduled(protocols, models, bounds, ExploreSchedule::Serial)
}

/// [`run_matrix`] with an explicit frontier [`ExploreSchedule`].
pub fn run_matrix_scheduled(
    protocols: &[ProtocolKind],
    models: &[Consistency],
    bounds: VerifBounds,
    schedule: ExploreSchedule,
) -> Result<VerifReport, String> {
    bounds.validate()?;
    if let ExploreSchedule::Sharded { shards } = schedule {
        if shards == 0 {
            return Err("sharded schedule needs at least one shard".to_string());
        }
    }
    let mut runs = Vec::new();
    for &p in protocols {
        for &m in models {
            let cfg = bounds.config(p, m);
            let outcome = match p {
                ProtocolKind::Tardis => explore_scheduled(
                    &|| crate::proto::tardis::Tardis::new(&cfg),
                    bounds,
                    m,
                    schedule,
                ),
                ProtocolKind::Msi => {
                    explore_scheduled(&|| crate::proto::msi::Msi::new(&cfg), bounds, m, schedule)
                }
                ProtocolKind::Ackwise => {
                    return Err(
                        "verify does not support ackwise: the limited-pointer overflow \
                         (Sharers::Global) is a conservative over-approximation, so \
                         exact-state invariants do not apply"
                            .to_string(),
                    )
                }
            };
            runs.push(RunReport {
                protocol: p.name().to_string(),
                consistency: m.name().to_string(),
                outcome,
            });
        }
    }
    Ok(VerifReport::new(bounds, runs))
}
