//! Machine-readable verification reports (schema `tardis-verif-v1`),
//! mirroring the bench-JSON conventions: hand-written serialization
//! (no serde in the offline image), a `schema` discriminator, and a
//! validator (`tools/validate_verif.py`) that cross-checks repeat-run
//! state counts against a recorded baseline.

use super::{RunOutcome, VerifBounds};

pub const SCHEMA: &str = "tardis-verif-v1";

/// One (protocol, consistency) exploration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunReport {
    pub protocol: String,
    pub consistency: String,
    pub outcome: RunOutcome,
}

/// The full report for one `tardis verify` invocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifReport {
    pub unix_time: u64,
    pub bounds: VerifBounds,
    pub runs: Vec<RunReport>,
}

impl VerifReport {
    pub fn new(bounds: VerifBounds, runs: Vec<RunReport>) -> Self {
        let unix_time = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        Self { unix_time, bounds, runs }
    }

    pub fn passed(&self) -> bool {
        self.runs.iter().all(|r| r.outcome.passed())
    }

    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
        s.push_str(&format!("  \"unix_time\": {},\n", self.unix_time));
        s.push_str(&format!("  \"cores\": {},\n", self.bounds.cores));
        s.push_str(&format!("  \"lines\": {},\n", self.bounds.lines));
        s.push_str(&format!("  \"max_ts\": {},\n", self.bounds.max_ts));
        s.push_str(&format!("  \"lease\": {},\n", self.bounds.lease));
        s.push_str(&format!("  \"sb_entries\": {},\n", self.bounds.sb_entries));
        s.push_str(&format!("  \"passed\": {},\n", self.passed()));
        s.push_str("  \"runs\": [\n");
        for (i, r) in self.runs.iter().enumerate() {
            s.push_str(&run_json(r, "    "));
            s.push_str(if i + 1 < self.runs.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }
}

fn run_json(r: &RunReport, pad: &str) -> String {
    let o = &r.outcome;
    let mut s = String::new();
    s.push_str(&format!("{pad}{{\n"));
    s.push_str(&format!("{pad}  \"protocol\": \"{}\",\n", esc(&r.protocol)));
    s.push_str(&format!(
        "{pad}  \"consistency\": \"{}\",\n",
        esc(&r.consistency)
    ));
    s.push_str(&format!("{pad}  \"states_explored\": {},\n", o.states));
    s.push_str(&format!("{pad}  \"transitions\": {},\n", o.transitions));
    s.push_str(&format!("{pad}  \"max_depth\": {},\n", o.max_depth));
    s.push_str(&format!(
        "{pad}  \"terminal_states\": {},\n",
        o.terminal_states
    ));
    s.push_str(&format!("{pad}  \"trace_checks\": {},\n", o.trace_checks));
    s.push_str(&format!("{pad}  \"passed\": {},\n", o.passed()));
    s.push_str(&format!("{pad}  \"invariants\": [\n"));
    for (i, inv) in o.invariants.iter().enumerate() {
        s.push_str(&format!(
            "{pad}    {{\"name\": \"{}\", \"checked\": {}, \"violations\": {}}}{}",
            esc(&inv.name),
            inv.checked,
            inv.violations,
            if i + 1 < o.invariants.len() { ",\n" } else { "\n" }
        ));
    }
    s.push_str(&format!("{pad}  ],\n"));
    match &o.counterexample {
        None => s.push_str(&format!("{pad}  \"counterexample\": null\n")),
        Some(cex) => {
            s.push_str(&format!("{pad}  \"counterexample\": {{\n"));
            s.push_str(&format!(
                "{pad}    \"invariant\": \"{}\",\n",
                esc(&cex.invariant)
            ));
            s.push_str(&format!("{pad}    \"detail\": \"{}\",\n", esc(&cex.detail)));
            s.push_str(&format!("{pad}    \"events\": [\n"));
            for (i, label) in cex.labels.iter().enumerate() {
                s.push_str(&format!(
                    "{pad}      \"{}\"{}",
                    esc(label),
                    if i + 1 < cex.labels.len() { ",\n" } else { "\n" }
                ));
            }
            s.push_str(&format!("{pad}    ]\n"));
            s.push_str(&format!("{pad}  }}\n"));
        }
    }
    s.push_str(&format!("{pad}}}"));
    s
}

/// Minimal JSON string escaping (labels may quote protocol debug
/// output).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verif::{Counterexample, InvariantStat};

    fn outcome(passed: bool) -> RunOutcome {
        RunOutcome {
            states: 10,
            transitions: 20,
            max_depth: 5,
            terminal_states: 2,
            trace_checks: 8,
            invariants: vec![InvariantStat {
                name: "single-writer".into(),
                checked: 20,
                violations: u64::from(!passed),
            }],
            counterexample: if passed {
                None
            } else {
                Some(Counterexample {
                    invariant: "single-writer".into(),
                    detail: "two \"owners\"".into(),
                    events: vec![],
                    labels: vec!["core0: issue store to line0 (0x8000000)".into()],
                })
            },
        }
    }

    fn report(passed: bool) -> VerifReport {
        VerifReport::new(
            VerifBounds::default(),
            vec![RunReport {
                protocol: "tardis".into(),
                consistency: "sc".into(),
                outcome: outcome(passed),
            }],
        )
    }

    #[test]
    fn json_carries_schema_and_counts() {
        let j = report(true).to_json();
        assert!(j.contains("\"schema\": \"tardis-verif-v1\""));
        assert!(j.contains("\"states_explored\": 10"));
        assert!(j.contains("\"counterexample\": null"));
        assert!(j.contains("\"passed\": true"));
    }

    #[test]
    fn json_escapes_counterexample_text() {
        let j = report(false).to_json();
        assert!(j.contains("two \\\"owners\\\""));
        assert!(j.contains("\"invariant\": \"single-writer\""));
    }

    #[test]
    fn escaping_handles_control_chars() {
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }
}
