//! The model-checking harness.
//!
//! [`World`] is the closed system under exploration: one real protocol
//! object plus everything the engine normally provides around it —
//! per-core issue state (with a TSO store buffer), per-(src, dst)
//! FIFO message channels, and a flat DRAM backing store.  The harness
//! *is* the deterministic single-step driver: where
//! [`crate::sim::Engine`] advances the same controllers along one
//! timed path, `explore` branches over every enabled transition.
//!
//! Per-channel delivery stays FIFO (matching the engine's ChannelClock
//! ordering guarantee, which MSI's invalidation protocol relies on);
//! *cross*-channel delivery order is explored exhaustively — a strict
//! over-approximation of what any latency assignment can produce,
//! sound because the controllers never read `ctx.now` for correctness.

use std::collections::{BTreeMap, VecDeque};

use crate::config::Consistency;
use crate::hashing::FxHashMap;
use crate::net::{Message, MsgKind, Node};
use crate::prog::checker::{self, AccessLog, LogRecord};
use crate::prog::Workload;
use crate::proto::{AccessOutcome, Completion, CompletionKind, MemOp, ProtoCtx};
use crate::stats::SimStats;
use crate::types::{CoreId, LineAddr};

use super::{
    Counterexample, ExploreSchedule, InvariantStat, ModelProto, RunOutcome, VerifBounds,
    VerifEvent, VerifOp,
};

/// A memory access handed to the protocol and still pending.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct Outstanding {
    addr: LineAddr,
    op: MemOp,
    pc: u32,
}

/// One TSO store-buffer entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct SbEntry {
    addr: LineAddr,
    value: u64,
    pc: u32,
}

/// Harness-side state of one core: issue budgets plus whatever sits
/// between the core and the protocol.  Part of the exact state key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CoreState {
    next_pc: u32,
    /// Remaining loads / stores per line index.
    loads_left: Vec<u32>,
    stores_left: Vec<u32>,
    outstanding: Option<Outstanding>,
    sb: VecDeque<SbEntry>,
}

/// Exact key of a [`World`]: everything that can affect *future*
/// behavior.  The access log and the step/seq counters are excluded on
/// purpose — they record the *past* — which is what lets distinct
/// histories merge (see DESIGN.md §9 for the soundness discussion).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct StateKey<K> {
    proto: K,
    cores: Vec<CoreState>,
    /// Non-empty channels only, sorted by (src, dst).
    channels: Vec<(u32, u32, Vec<Message>)>,
    memory: Vec<(LineAddr, u64)>,
}

/// The closed system: protocol + cores + network + DRAM.
#[derive(Clone)]
struct World<P: ModelProto> {
    proto: P,
    cores: Vec<CoreState>,
    /// In-flight messages per (src, dst) endpoint pair, FIFO.
    channels: BTreeMap<(u32, u32), VecDeque<Message>>,
    /// Flat DRAM backing store (absent = 0).
    memory: BTreeMap<LineAddr, u64>,
    log: AccessLog,
    /// Logical step counter: `ctx.now` and `commit_cycle` for logged
    /// records (monotone along any path).
    step: u64,
    seq: u64,
    bounds: VerifBounds,
    model: Consistency,
    lines: Vec<LineAddr>,
}

impl<P: ModelProto> World<P> {
    fn new(proto: P, bounds: VerifBounds, model: Consistency) -> Self {
        let lines = bounds.line_addrs();
        let nl = lines.len();
        Self {
            proto,
            cores: (0..bounds.cores)
                .map(|_| CoreState {
                    next_pc: 0,
                    loads_left: vec![bounds.max_ts; nl],
                    stores_left: vec![bounds.max_ts; nl],
                    outstanding: None,
                    sb: VecDeque::new(),
                })
                .collect(),
            channels: BTreeMap::new(),
            memory: BTreeMap::new(),
            log: AccessLog::default(),
            step: 0,
            seq: 0,
            bounds,
            model,
            lines,
        }
    }

    /// Endpoint numbering: cores, then LLC slices, then MCs.
    fn node_id(&self, n: Node) -> u32 {
        let nc = self.bounds.cores;
        match n {
            Node::Core(c) => c,
            Node::Slice(s) => nc + s,
            Node::Mc(m) => 2 * nc + m,
        }
    }

    fn node_name(&self, id: u32) -> String {
        let nc = self.bounds.cores;
        if id < nc {
            format!("core{id}")
        } else if id < 2 * nc {
            format!("slice{}", id - nc)
        } else {
            format!("mc{}", id - 2 * nc)
        }
    }

    fn route(&mut self, m: Message) {
        let key = (self.node_id(m.src), self.node_id(m.dst));
        self.channels.entry(key).or_default().push_back(m);
    }

    /// Run one protocol call with a scratch context, then move its
    /// outgoing messages into the channels.  Returns the call's result
    /// plus any completions it pushed.
    fn call<R>(&mut self, f: impl FnOnce(&mut P, &mut ProtoCtx) -> R) -> (R, Vec<Completion>) {
        let mut msgs = Vec::new();
        let mut comps = Vec::new();
        let mut stats = SimStats::default();
        let mut trace = crate::obs::TraceBuf::default();
        let r = {
            let mut ctx = ProtoCtx {
                now: self.step,
                msgs: &mut msgs,
                completions: &mut comps,
                stats: &mut stats,
                trace: &mut trace,
            };
            f(&mut self.proto, &mut ctx)
        };
        for m in msgs {
            self.route(m);
        }
        (r, comps)
    }

    fn push_record(
        &mut self,
        core: CoreId,
        pc: u32,
        addr: LineAddr,
        op: MemOp,
        value: u64,
        ts: u64,
        forwarded: bool,
    ) {
        let (value_read, value_written) = match op {
            MemOp::Load => (Some(value), None),
            MemOp::Store { value: v } => (None, Some(v)),
            other => panic!("harness never issues {other:?}"),
        };
        let seq = self.seq;
        self.seq += 1;
        self.log.push(LogRecord {
            core,
            pc,
            addr,
            value_read,
            value_written,
            ts,
            commit_cycle: self.step,
            seq,
            valid: true,
            forwarded,
        });
    }

    /// Resolve protocol completions against outstanding accesses.
    /// Returns true if any log record was appended.
    fn handle_completions(&mut self, comps: Vec<Completion>) -> bool {
        let mut appended = false;
        for comp in comps {
            assert!(
                matches!(comp.kind, CompletionKind::Demand),
                "harness: unexpected completion kind {comp:?} (speculation and \
                 spinning are outside the verification bounds)"
            );
            let out = self.cores[comp.core as usize]
                .outstanding
                .take()
                .unwrap_or_else(|| {
                    panic!("harness: completion without outstanding access: {comp:?}")
                });
            assert_eq!(out.addr, comp.addr, "harness: completion for the wrong address");
            self.push_record(comp.core, out.pc, out.addr, out.op, comp.value, comp.ts, false);
            appended = true;
        }
        appended
    }

    /// All transitions enabled in this state, in a fixed deterministic
    /// order (cores ascending, then channels by (src, dst)).
    fn enabled(&self) -> Vec<VerifEvent> {
        let mut evs = Vec::new();
        for (c, core) in self.cores.iter().enumerate() {
            let cid = c as CoreId;
            if core.outstanding.is_some() {
                continue;
            }
            for li in 0..self.lines.len() {
                if core.loads_left[li] > 0 {
                    evs.push(VerifEvent::Issue { core: cid, line: li as u32, op: VerifOp::Load });
                }
                let sb_room = self.model == Consistency::Sc
                    || (core.sb.len() as u32) < self.bounds.sb_entries;
                if core.stores_left[li] > 0 && sb_room {
                    evs.push(VerifEvent::Issue { core: cid, line: li as u32, op: VerifOp::Store });
                }
            }
            if self.model == Consistency::Tso && !core.sb.is_empty() {
                evs.push(VerifEvent::Drain { core: cid });
            }
        }
        for &(s, d) in self.channels.keys() {
            evs.push(VerifEvent::Deliver { src: s, dst: d });
        }
        evs
    }

    /// The tile an endpoint id sits on (the same tile both fabrics
    /// route by): core c and slice c share tile c, MC m maps to tile
    /// m (the harness never has more MCs than cores).
    fn tile_of_endpoint(&self, id: u32) -> u32 {
        let nc = self.bounds.cores;
        if id < 2 * nc {
            id % nc
        } else {
            (id - 2 * nc) % nc
        }
    }

    /// The PDES shard that would *handle* `ev`: the issuing core's
    /// shard for Issue/Drain, the destination endpoint's shard for
    /// Deliver — mirroring `shard_of_node` in [`crate::sim::engine`]
    /// (contiguous tile blocks; a message is dispatched by the shard
    /// owning its destination reactor).
    fn shard_of_event(&self, ev: VerifEvent, shards: u32) -> u32 {
        let tile = match ev {
            VerifEvent::Issue { core, .. } | VerifEvent::Drain { core } => core,
            VerifEvent::Deliver { dst, .. } => self.tile_of_endpoint(dst),
        };
        (tile as u64 * shards as u64 / self.bounds.cores.max(1) as u64) as u32
    }

    /// Everything issued has fully resolved (distinct from merely
    /// having no enabled transition, which is a deadlock).
    fn is_complete(&self) -> bool {
        self.channels.is_empty()
            && self
                .cores
                .iter()
                .all(|c| c.outstanding.is_none() && c.sb.is_empty())
    }

    fn stuck_detail(&self) -> String {
        let stuck: Vec<String> = self
            .cores
            .iter()
            .enumerate()
            .filter_map(|(c, s)| {
                s.outstanding
                    .map(|o| format!("core{c} waiting on {:#x} ({:?})", o.addr, o.op))
            })
            .collect();
        format!(
            "no transition enabled but work remains: [{}], {} in-flight channel(s)",
            stuck.join(", "),
            self.channels.len()
        )
    }

    /// Human-readable label for `ev` as applied to *this* state (must
    /// be called before `apply`).
    fn describe(&self, ev: VerifEvent) -> String {
        match ev {
            VerifEvent::Issue { core, line, op } => format!(
                "core{core}: issue {} to line{line} ({:#x})",
                op.name(),
                self.lines[line as usize]
            ),
            VerifEvent::Drain { core } => match self.cores[core as usize].sb.front() {
                Some(e) => format!(
                    "core{core}: drain store buffer (store {:#x} to {:#x}, pc {})",
                    e.value, e.addr, e.pc
                ),
                None => format!("core{core}: drain store buffer (empty?)"),
            },
            VerifEvent::Deliver { src, dst } => {
                let head = self.channels.get(&(src, dst)).and_then(|q| q.front());
                match head {
                    Some(m) => format!(
                        "deliver {} -> {}: {:?} for {:#x}",
                        self.node_name(src),
                        self.node_name(dst),
                        m.kind,
                        m.addr
                    ),
                    None => format!(
                        "deliver {} -> {}: (empty channel?)",
                        self.node_name(src),
                        self.node_name(dst)
                    ),
                }
            }
        }
    }

    /// The access log is only a *checkable* TSO history once every
    /// store buffer has drained: a forwarded load commits to the log
    /// while its store is still buffered (unlogged), so mid-buffer
    /// prefixes legitimately fail `check_tso_forwarding`.  Under SC
    /// this is always true.  At such states the per-core logs are
    /// committed program prefixes, so `check_model` applies.
    fn log_checkable(&self) -> bool {
        self.cores.iter().all(|c| c.sb.is_empty())
    }

    /// Apply one transition.  Returns true if a log record was
    /// appended (the caller then re-runs the linearization check).
    fn apply(&mut self, ev: VerifEvent) -> bool {
        self.step += 1;
        match ev {
            VerifEvent::Issue { core, line, op } => {
                let c = core as usize;
                let li = line as usize;
                let addr = self.lines[li];
                let pc = self.cores[c].next_pc;
                self.cores[c].next_pc += 1;
                match op {
                    VerifOp::Load => self.cores[c].loads_left[li] -= 1,
                    VerifOp::Store => self.cores[c].stores_left[li] -= 1,
                }
                match op {
                    VerifOp::Store if self.model == Consistency::Tso => {
                        // TSO: stores retire into the FIFO store buffer;
                        // they reach the protocol on a later Drain.
                        let value = Workload::store_value(core, pc as usize);
                        self.cores[c].sb.push_back(SbEntry { addr, value, pc });
                        false
                    }
                    VerifOp::Load if self.model == Consistency::Tso
                        && self.cores[c].sb.iter().any(|e| e.addr == addr) =>
                    {
                        // Store-to-load forwarding from the newest
                        // matching buffered store; the value never
                        // touches the coherence substrate.
                        let value = self.cores[c]
                            .sb
                            .iter()
                            .rev()
                            .find(|e| e.addr == addr)
                            .unwrap()
                            .value;
                        self.push_record(core, pc, addr, MemOp::Load, value, 0, true);
                        true
                    }
                    _ => {
                        let memop = match op {
                            VerifOp::Load => MemOp::Load,
                            VerifOp::Store => MemOp::Store {
                                value: Workload::store_value(core, pc as usize),
                            },
                        };
                        self.access(core, addr, memop, pc)
                    }
                }
            }
            VerifEvent::Drain { core } => {
                let e = self.cores[core as usize]
                    .sb
                    .pop_front()
                    .expect("harness: Drain on an empty store buffer");
                self.access(core, e.addr, MemOp::Store { value: e.value }, e.pc)
            }
            VerifEvent::Deliver { src, dst } => {
                let q = self
                    .channels
                    .get_mut(&(src, dst))
                    .expect("harness: Deliver on an empty channel");
                let msg = q.pop_front().expect("harness: Deliver on an empty channel");
                if q.is_empty() {
                    self.channels.remove(&(src, dst));
                }
                if matches!(msg.dst, Node::Mc(_)) {
                    self.dram(msg);
                    false
                } else {
                    let ((), comps) = self.call(|p, ctx| p.on_message(msg, ctx));
                    self.handle_completions(comps)
                }
            }
        }
    }

    /// Hand one access to the protocol (speculation disabled: the
    /// harness wants every outcome deterministic and demand-ordered).
    fn access(&mut self, core: CoreId, addr: LineAddr, memop: MemOp, pc: u32) -> bool {
        let (outcome, comps) =
            self.call(|p, ctx| p.core_access(core, addr, memop, false, ctx));
        let mut appended = match outcome {
            AccessOutcome::Done(d) => {
                self.push_record(core, pc, addr, memop, d.value, d.ts, false);
                true
            }
            AccessOutcome::Pending => {
                self.cores[core as usize].outstanding = Some(Outstanding { addr, op: memop, pc });
                false
            }
            AccessOutcome::SpecDone(_) => {
                panic!("harness: protocol speculated with spec_ok=false")
            }
        };
        appended |= self.handle_completions(comps);
        appended
    }

    /// The engine-provided DRAM endpoint: immediate-service model, one
    /// request per Deliver transition (the round trip itself is still
    /// interleaved through the channels).
    fn dram(&mut self, msg: Message) {
        match msg.kind {
            MsgKind::DramLdReq => {
                let value = self.memory.get(&msg.addr).copied().unwrap_or(0);
                self.route(Message {
                    src: msg.dst,
                    dst: msg.src,
                    addr: msg.addr,
                    requester: msg.requester,
                    kind: MsgKind::DramLdRep { value },
                });
            }
            MsgKind::DramStReq { value } => {
                self.memory.insert(msg.addr, value);
            }
            other => panic!("harness: unexpected MC-bound message {other:?}"),
        }
    }

    fn key(&self) -> StateKey<P::Key> {
        StateKey {
            proto: self.proto.state_key(),
            cores: self.cores.clone(),
            channels: self
                .channels
                .iter()
                .map(|(&(s, d), q)| (s, d, q.iter().copied().collect()))
                .collect(),
            memory: self.memory.iter().map(|(&a, &v)| (a, v)).collect(),
        }
    }
}

/// Exhaustively explore one (protocol, consistency) configuration by
/// BFS over [`World`] transitions with exact-state deduplication.
/// BFS makes the first violation found a *shortest* counterexample.
pub fn explore<P: ModelProto>(
    mk: &dyn Fn() -> P,
    bounds: VerifBounds,
    model: Consistency,
) -> RunOutcome {
    explore_scheduled(mk, bounds, model, ExploreSchedule::Serial)
}

/// [`explore`] with an explicit frontier [`ExploreSchedule`].  Every
/// per-state transition list is a permutation of the serial one, and
/// BFS with exact-state dedup visits the same reachable set in the
/// same layers whatever the within-layer order — so all `RunOutcome`
/// counters (states, transitions, depth, terminal states, checks) are
/// schedule-invariant.  `tests/verif.rs` asserts this equality, which
/// is what licenses the PDES engine to dispatch shard-partitioned
/// work concurrently.
pub fn explore_scheduled<P: ModelProto>(
    mk: &dyn Fn() -> P,
    bounds: VerifBounds,
    model: Consistency,
    schedule: ExploreSchedule,
) -> RunOutcome {
    let invs = P::invariants();
    let mut stats: Vec<InvariantStat> = invs
        .iter()
        .map(|i| InvariantStat { name: i.name().to_string(), checked: 0, violations: 0 })
        .collect();
    let mut transitions = 0u64;
    let mut max_depth = 0u32;
    let mut terminal_states = 0u64;
    let mut trace_checks = 0u64;

    let root = World::new(mk(), bounds, model);
    let mut visited: FxHashMap<StateKey<P::Key>, u32> = FxHashMap::default();
    // nodes[i] = (parent node id, event that produced node i).
    let mut nodes: Vec<(u32, Option<VerifEvent>)> = vec![(0, None)];
    visited.insert(root.key(), 0);

    let outcome = |visited_len: usize,
                   transitions: u64,
                   max_depth: u32,
                   terminal_states: u64,
                   trace_checks: u64,
                   stats: Vec<InvariantStat>,
                   cex: Option<Counterexample>| RunOutcome {
        states: visited_len as u64,
        transitions,
        max_depth,
        terminal_states,
        trace_checks,
        invariants: stats,
        counterexample: cex,
    };

    // The reset state must satisfy the invariants too.
    for (i, inv) in invs.iter().enumerate() {
        stats[i].checked += 1;
        if let Err(detail) = inv.check(&root.proto, &root.lines) {
            stats[i].violations += 1;
            let cex = build_cex(mk, bounds, model, &nodes, 0, None, inv.name(), detail);
            return outcome(1, 0, 0, 0, 0, stats, Some(cex));
        }
    }

    let mut queue: VecDeque<(World<P>, u32, u32)> = VecDeque::new();
    queue.push_back((root, 0, 0));

    while let Some((world, node, depth)) = queue.pop_front() {
        max_depth = max_depth.max(depth);
        let mut evs = world.enabled();
        if let ExploreSchedule::Sharded { shards } = schedule {
            // Shard-major enumeration: stable, so within a shard the
            // serial order is preserved (the per-shard dispatch order
            // the PDES engine actually uses).
            evs.sort_by_key(|&ev| world.shard_of_event(ev, shards));
        }
        if evs.is_empty() {
            if world.is_complete() {
                terminal_states += 1;
                trace_checks += 1;
                if let Err(v) = checker::check_model(&world.log, model) {
                    let cex = build_cex(
                        mk, bounds, model, &nodes, node, None,
                        "linearization", format!("{v:?}"),
                    );
                    return outcome(
                        visited.len(), transitions, max_depth, terminal_states,
                        trace_checks, stats, Some(cex),
                    );
                }
            } else {
                let cex = build_cex(
                    mk, bounds, model, &nodes, node, None,
                    "deadlock-freedom", world.stuck_detail(),
                );
                return outcome(
                    visited.len(), transitions, max_depth, terminal_states,
                    trace_checks, stats, Some(cex),
                );
            }
            continue;
        }
        for ev in evs {
            transitions += 1;
            let mut next = world.clone();
            let appended = next.apply(ev);
            for (i, inv) in invs.iter().enumerate() {
                stats[i].checked += 1;
                let r = inv
                    .check(&next.proto, &next.lines)
                    .and_then(|()| inv.check_step(&world.proto, &next.proto));
                if let Err(detail) = r {
                    stats[i].violations += 1;
                    let cex =
                        build_cex(mk, bounds, model, &nodes, node, Some(ev), inv.name(), detail);
                    return outcome(
                        visited.len(), transitions, max_depth, terminal_states,
                        trace_checks, stats, Some(cex),
                    );
                }
            }
            if appended && next.log_checkable() {
                trace_checks += 1;
                if let Err(v) = checker::check_model(&next.log, model) {
                    let cex = build_cex(
                        mk, bounds, model, &nodes, node, Some(ev),
                        "linearization", format!("{v:?}"),
                    );
                    return outcome(
                        visited.len(), transitions, max_depth, terminal_states,
                        trace_checks, stats, Some(cex),
                    );
                }
            }
            if let std::collections::hash_map::Entry::Vacant(slot) = visited.entry(next.key()) {
                let id = nodes.len() as u32;
                slot.insert(id);
                nodes.push((node, Some(ev)));
                queue.push_back((next, id, depth + 1));
            }
        }
    }

    outcome(
        visited.len(), transitions, max_depth, terminal_states, trace_checks, stats, None,
    )
}

/// Re-execute an event path from reset, producing a label per event
/// and the violation it ends in (if any).  Deterministic: the same
/// path always reproduces the same states, which is what makes
/// counterexamples replayable regression tests.
pub fn replay<P: ModelProto>(
    mk: &dyn Fn() -> P,
    bounds: VerifBounds,
    model: Consistency,
    events: &[VerifEvent],
) -> (Vec<String>, Option<(String, String)>) {
    let invs = P::invariants();
    let mut world = World::new(mk(), bounds, model);
    let mut labels = Vec::new();
    for &ev in events {
        labels.push(world.describe(ev));
        let before = world.proto.clone();
        let appended = world.apply(ev);
        for inv in &invs {
            let r = inv
                .check(&world.proto, &world.lines)
                .and_then(|()| inv.check_step(&before, &world.proto));
            if let Err(detail) = r {
                return (labels, Some((inv.name().to_string(), detail)));
            }
        }
        if appended && world.log_checkable() {
            if let Err(v) = checker::check_model(&world.log, model) {
                return (labels, Some(("linearization".to_string(), format!("{v:?}"))));
            }
        }
    }
    if world.enabled().is_empty() && !world.is_complete() {
        return (
            labels,
            Some(("deadlock-freedom".to_string(), world.stuck_detail())),
        );
    }
    (labels, None)
}

/// Reconstruct the event path to `node` (plus `last`, the violating
/// edge) and label it by replaying.
fn build_cex<P: ModelProto>(
    mk: &dyn Fn() -> P,
    bounds: VerifBounds,
    model: Consistency,
    nodes: &[(u32, Option<VerifEvent>)],
    node: u32,
    last: Option<VerifEvent>,
    invariant: &str,
    detail: String,
) -> Counterexample {
    let mut events = Vec::new();
    let mut id = node as usize;
    while let (parent, Some(ev)) = nodes[id] {
        events.push(ev);
        id = parent as usize;
    }
    events.reverse();
    if let Some(ev) = last {
        events.push(ev);
    }
    let (labels, _) = replay(mk, bounds, model, &events);
    Counterexample {
        invariant: invariant.to_string(),
        detail,
        events,
        labels,
    }
}

// The clean-protocol expectations below are meaningless when a seeded
// fault is compiled in.
#[cfg(all(
    test,
    not(any(feature = "verif-mutate-wts-skip", feature = "verif-mutate-over-lease"))
))]
mod tests {
    use super::*;
    use crate::config::ProtocolKind;
    use crate::proto::msi::Msi;
    use crate::proto::tardis::Tardis;

    fn tiny() -> VerifBounds {
        VerifBounds { cores: 2, lines: 1, max_ts: 1, lease: 2, sb_entries: 2 }
    }

    #[test]
    fn tardis_sc_tiny_is_clean_and_deterministic() {
        let bounds = tiny();
        let cfg = bounds.config(ProtocolKind::Tardis, Consistency::Sc);
        let a = explore(&|| Tardis::new(&cfg), bounds, Consistency::Sc);
        assert!(a.passed(), "counterexample: {:#?}", a.counterexample);
        assert!(a.states > 1 && a.terminal_states > 0);
        let b = explore(&|| Tardis::new(&cfg), bounds, Consistency::Sc);
        assert_eq!(a, b, "repeat exploration must be bit-identical");
    }

    #[test]
    fn tardis_tso_tiny_exhibits_store_buffering_and_stays_clean() {
        let bounds = tiny();
        let cfg = bounds.config(ProtocolKind::Tardis, Consistency::Tso);
        let a = explore(&|| Tardis::new(&cfg), bounds, Consistency::Tso);
        assert!(a.passed(), "counterexample: {:#?}", a.counterexample);
        // TSO adds Drain transitions, so its graph is strictly larger
        // than the SC one for the same bounds.
        let sc_cfg = bounds.config(ProtocolKind::Tardis, Consistency::Sc);
        let sc = explore(&|| Tardis::new(&sc_cfg), bounds, Consistency::Sc);
        assert!(a.states > sc.states);
    }

    #[test]
    fn msi_sc_tiny_is_clean() {
        let bounds = tiny();
        let cfg = bounds.config(ProtocolKind::Msi, Consistency::Sc);
        let a = explore(&|| Msi::new(&cfg), bounds, Consistency::Sc);
        assert!(a.passed(), "counterexample: {:#?}", a.counterexample);
        assert!(a.terminal_states > 0);
    }

    #[test]
    fn sharded_schedule_reaches_the_same_state_space() {
        let bounds = tiny();
        for model in [Consistency::Sc, Consistency::Tso] {
            let cfg = bounds.config(ProtocolKind::Tardis, model);
            let serial = explore(&|| Tardis::new(&cfg), bounds, model);
            for shards in [2u32, 3] {
                let sharded = explore_scheduled(
                    &|| Tardis::new(&cfg),
                    bounds,
                    model,
                    ExploreSchedule::Sharded { shards },
                );
                assert_eq!(
                    serial, sharded,
                    "{model:?}/{shards} shards: exploration must be order-invariant"
                );
            }
        }
    }

    #[test]
    fn counterexamples_map_back_to_workloads() {
        // Build a synthetic counterexample and check the projection.
        let bounds = tiny();
        let cex = Counterexample {
            invariant: "x".into(),
            detail: "y".into(),
            events: vec![
                VerifEvent::Issue { core: 0, line: 0, op: VerifOp::Store },
                VerifEvent::Deliver { src: 0, dst: 2 },
                VerifEvent::Issue { core: 1, line: 0, op: VerifOp::Load },
            ],
            labels: vec![],
        };
        let w = cex.to_workload(&bounds);
        assert_eq!(w.n_cores(), 2);
        assert_eq!(w.programs[0].len(), 1);
        assert_eq!(w.programs[1].len(), 1);
    }
}
