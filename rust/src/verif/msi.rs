//! [`ModelProto`] adapter + invariants for the MSI directory — the
//! cross-check protocol: the same harness, transitions, and trace
//! linearization exercised against a classically ordered design.
//!
//! Directory transients are visible concrete states here (Inv in
//! flight, acks outstanding, ...), so the per-line checks are guarded
//! by "no pending transaction at the home slice for this address":
//! while a transaction is mid-flight the directory's sharer set and
//! value legitimately disagree with the L1s, and the protocol's
//! correctness claim is only about settled lines.

use crate::proto::msi::{Demand, DirLine, DirPending, Msi, MsiL1Line};
use crate::types::{CoreId, LineAddr};

use super::{Invariant, ModelProto};

/// Exact protocol-state key (hash-map contents sorted by address; LRU
/// age excluded — see DESIGN.md §9).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct MsiKey {
    cores: Vec<MsiCoreKey>,
    slices: Vec<MsiSliceKey>,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct MsiCoreKey {
    lines: Vec<(LineAddr, MsiL1Line)>,
    demand: Vec<(LineAddr, Demand)>,
    watch: Option<LineAddr>,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct MsiSliceKey {
    lines: Vec<(LineAddr, DirLine)>,
    pending: Vec<(LineAddr, DirPending)>,
}

impl ModelProto for Msi {
    type Key = MsiKey;

    fn state_key(&self) -> MsiKey {
        MsiKey {
            cores: self
                .l1
                .iter()
                .map(|l1| {
                    let mut lines = Vec::new();
                    l1.cache.for_each(|a, line| lines.push((a, line.clone())));
                    lines.sort_by_key(|e| e.0);
                    let mut demand: Vec<_> =
                        l1.demand.iter().map(|(&a, d)| (a, d.clone())).collect();
                    demand.sort_by_key(|e| e.0);
                    MsiCoreKey { lines, demand, watch: l1.watch }
                })
                .collect(),
            slices: self
                .dir
                .iter()
                .map(|d| {
                    let mut lines = Vec::new();
                    d.cache.for_each(|a, line| lines.push((a, line.clone())));
                    lines.sort_by_key(|e| e.0);
                    let mut pending: Vec<_> =
                        d.pending.iter().map(|(&a, p)| (a, p.clone())).collect();
                    pending.sort_by_key(|e| e.0);
                    MsiSliceKey { lines, pending }
                })
                .collect(),
        }
    }

    fn invariants() -> Vec<Box<dyn Invariant<Self>>> {
        vec![
            Box::new(SingleModified),
            Box::new(DirValueAgreement),
            Box::new(SharerAccounting),
        ]
    }
}

fn settled(p: &Msi, addr: LineAddr) -> bool {
    let s = p.slice_of(addr) as usize;
    !p.dir[s].pending.contains_key(&addr)
}

fn l1_copies(p: &Msi, addr: LineAddr) -> Vec<(CoreId, MsiL1Line)> {
    let mut out = Vec::new();
    for (c, l1) in p.l1.iter().enumerate() {
        if let Some(l) = l1.cache.peek(addr) {
            out.push((c as CoreId, l.clone()));
        }
    }
    out
}

/// At most one M copy system-wide; on settled lines the directory
/// agrees on who holds it.
struct SingleModified;

impl Invariant<Msi> for SingleModified {
    fn name(&self) -> &'static str {
        "single-modified"
    }

    fn check(&self, p: &Msi, lines: &[LineAddr]) -> Result<(), String> {
        for &addr in lines {
            let m: Vec<CoreId> = l1_copies(p, addr)
                .into_iter()
                .filter(|(_, l)| l.m)
                .map(|(c, _)| c)
                .collect();
            if m.len() > 1 {
                return Err(format!(
                    "line {addr:#x}: cores {m:?} hold M copies simultaneously"
                ));
            }
            if let Some(&c) = m.first() {
                if settled(p, addr) {
                    let s = p.slice_of(addr) as usize;
                    let owner = p.dir[s].cache.peek(addr).map(|d| d.owner);
                    if owner != Some(Some(c)) {
                        return Err(format!(
                            "line {addr:#x}: core{c} holds M but slice{s} records \
                             owner {owner:?}"
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// A settled, unowned directory line and its sharers hold one value.
struct DirValueAgreement;

impl Invariant<Msi> for DirValueAgreement {
    fn name(&self) -> &'static str {
        "dir-value-agreement"
    }

    fn check(&self, p: &Msi, lines: &[LineAddr]) -> Result<(), String> {
        for &addr in lines {
            if !settled(p, addr) {
                continue;
            }
            let s = p.slice_of(addr) as usize;
            let Some(dl) = p.dir[s].cache.peek(addr) else { continue };
            if dl.owner.is_some() || dl.busy {
                continue;
            }
            for (c, l) in l1_copies(p, addr) {
                if !l.m && l.value != dl.value {
                    return Err(format!(
                        "line {addr:#x}: core{c} caches {:#x} but slice{s} holds {:#x} \
                         with no owner",
                        l.value, dl.value
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Every cached copy is accounted for at the directory (the recorded
/// sharer set / owner is a superset of the true holders — the
/// direction invalidations depend on).
struct SharerAccounting;

impl Invariant<Msi> for SharerAccounting {
    fn name(&self) -> &'static str {
        "sharer-accounting"
    }

    fn check(&self, p: &Msi, lines: &[LineAddr]) -> Result<(), String> {
        for &addr in lines {
            if !settled(p, addr) {
                continue;
            }
            let s = p.slice_of(addr) as usize;
            for (c, _) in l1_copies(p, addr) {
                let known = p.dir[s]
                    .cache
                    .peek(addr)
                    .is_some_and(|d| d.owner == Some(c) || d.sharers.contains(c));
                if !known {
                    return Err(format!(
                        "line {addr:#x}: core{c} caches the line but slice{s} has no \
                         record of it"
                    ));
                }
            }
        }
        Ok(())
    }
}
