//! [`ModelProto`] adapter + invariants for the Tardis controllers.
//!
//! The invariants mirror the paper's correctness argument (§III-B,
//! Theorem 1): writes jump past every outstanding lease, so a stale
//! copy is never readable at or after a newer version's write
//! timestamp.  They are stated over *reachable concrete states* of the
//! shipped controllers, with in-flight transients (owner round trips)
//! excluded exactly where the protocol reuses the TM's wts/rts bits
//! for the owner id.

use crate::proto::tardis::{Demand, L1Line, Pending, Renewal, Tardis, TmLine};
use crate::types::{CoreId, LineAddr, Ts};

use super::{Invariant, ModelProto};

/// Exact protocol-state key: every L1 and TM field that can affect
/// future behavior, with hash-map contents sorted by address.  LRU age
/// is deliberately absent — verification geometry guarantees no
/// evictions, so replacement order is dead state (DESIGN.md §9).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TardisKey {
    cores: Vec<TardisCoreKey>,
    slices: Vec<TardisSliceKey>,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct TardisCoreKey {
    pts: Ts,
    bts: Ts,
    since_inc: u64,
    lines: Vec<(LineAddr, L1Line)>,
    demand: Vec<(LineAddr, Demand)>,
    renewals: Vec<(LineAddr, Renewal)>,
    watch: Option<LineAddr>,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct TardisSliceKey {
    mts: Ts,
    bts: Ts,
    max_ts: Ts,
    lines: Vec<(LineAddr, TmLine)>,
    pending: Vec<(LineAddr, Pending)>,
}

impl ModelProto for Tardis {
    type Key = TardisKey;

    fn state_key(&self) -> TardisKey {
        TardisKey {
            cores: self
                .l1
                .iter()
                .map(|l1| {
                    let mut lines = Vec::new();
                    l1.cache.for_each(|a, line| lines.push((a, line.clone())));
                    lines.sort_by_key(|e| e.0);
                    let mut demand: Vec<_> =
                        l1.demand.iter().map(|(&a, d)| (a, d.clone())).collect();
                    demand.sort_by_key(|e| e.0);
                    let mut renewals: Vec<_> =
                        l1.renewals.iter().map(|(&a, r)| (a, *r)).collect();
                    renewals.sort_by_key(|e| e.0);
                    TardisCoreKey {
                        pts: l1.pts,
                        bts: l1.bts,
                        since_inc: l1.accesses_since_inc,
                        lines,
                        demand,
                        renewals,
                        watch: l1.watch,
                    }
                })
                .collect(),
            slices: self
                .tm
                .iter()
                .map(|tm| {
                    let mut lines = Vec::new();
                    tm.cache.for_each(|a, line| lines.push((a, line.clone())));
                    lines.sort_by_key(|e| e.0);
                    let mut pending: Vec<_> =
                        tm.pending.iter().map(|(&a, p)| (a, p.clone())).collect();
                    pending.sort_by_key(|e| e.0);
                    TardisSliceKey {
                        mts: tm.mts,
                        bts: tm.bts,
                        max_ts: tm.max_ts,
                        lines,
                        pending,
                    }
                })
                .collect(),
        }
    }

    fn invariants() -> Vec<Box<dyn Invariant<Self>>> {
        vec![
            Box::new(SingleWriter),
            Box::new(LeaseContainment),
            Box::new(WriteAfterExpiry),
            Box::new(VersionValueAgreement),
            Box::new(TsSanity),
        ]
    }
}

/// One observable copy of a line: an L1 entry, or the TM's own entry
/// while unowned (while owned, the TM's wts/rts bits belong to the
/// owner id and carry no meaning — paper §III-F2).
struct LineCopy {
    who: String,
    wts: Ts,
    rts: Ts,
    value: u64,
    excl: bool,
}

fn copies(p: &Tardis, addr: LineAddr) -> Vec<LineCopy> {
    let mut out = Vec::new();
    for (c, l1) in p.l1.iter().enumerate() {
        if let Some(l) = l1.cache.peek(addr) {
            out.push(LineCopy {
                who: format!("core{c} L1"),
                wts: l.wts,
                rts: l.rts,
                value: l.value,
                excl: l.excl,
            });
        }
    }
    let s = p.slice_of(addr) as usize;
    if let Some(t) = p.tm[s].cache.peek(addr) {
        if t.owner.is_none() {
            out.push(LineCopy {
                who: format!("slice{s} TM"),
                wts: t.wts,
                rts: t.rts,
                value: t.value,
                excl: false,
            });
        }
    }
    out
}

/// At most one exclusive L1 copy per line, and the home TM must agree
/// on who owns it.
struct SingleWriter;

impl Invariant<Tardis> for SingleWriter {
    fn name(&self) -> &'static str {
        "single-writer"
    }

    fn check(&self, p: &Tardis, lines: &[LineAddr]) -> Result<(), String> {
        for &addr in lines {
            let excl: Vec<CoreId> = (0..p.n_cores)
                .filter(|&c| {
                    p.l1[c as usize]
                        .cache
                        .peek(addr)
                        .is_some_and(|l| l.excl)
                })
                .collect();
            if excl.len() > 1 {
                return Err(format!(
                    "line {addr:#x}: cores {excl:?} hold exclusive copies simultaneously"
                ));
            }
            if let Some(&c) = excl.first() {
                let s = p.slice_of(addr) as usize;
                let owner = p.tm[s].cache.peek(addr).map(|t| t.owner);
                if owner != Some(Some(c)) {
                    return Err(format!(
                        "line {addr:#x}: core{c} holds an exclusive copy but slice{s} \
                         records owner {owner:?}"
                    ));
                }
            }
        }
        Ok(())
    }
}

/// A sharer's lease never extends past what the home TM recorded for
/// that version: sharer rts <= TM rts whenever their wts match and the
/// line is unowned.  The over-lease seeded fault breaks exactly this.
struct LeaseContainment;

impl Invariant<Tardis> for LeaseContainment {
    fn name(&self) -> &'static str {
        "lease-containment"
    }

    fn check(&self, p: &Tardis, lines: &[LineAddr]) -> Result<(), String> {
        for &addr in lines {
            let s = p.slice_of(addr) as usize;
            let Some(tm) = p.tm[s].cache.peek(addr) else { continue };
            if tm.owner.is_some() {
                continue;
            }
            for (c, l1) in p.l1.iter().enumerate() {
                if let Some(l) = l1.cache.peek(addr) {
                    if !l.excl && l.wts == tm.wts && l.rts > tm.rts {
                        return Err(format!(
                            "line {addr:#x}: core{c} holds lease rts={} beyond the TM's \
                             rts={} for the same version (wts={})",
                            l.rts, tm.rts, l.wts
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// The paper's core ordering rule: a write must jump past every lease
/// on the previous version, so no stale copy stays readable at or
/// after a newer differing version's wts (Theorem 1's no-overlap
/// condition).  The equal-value exemption covers clean refills, where
/// a new version legitimately repeats the old data.
struct WriteAfterExpiry;

impl Invariant<Tardis> for WriteAfterExpiry {
    fn name(&self) -> &'static str {
        "write-after-expiry"
    }

    fn check(&self, p: &Tardis, lines: &[LineAddr]) -> Result<(), String> {
        for &addr in lines {
            let cps = copies(p, addr);
            for x in cps.iter().filter(|c| !c.excl) {
                for y in &cps {
                    if x.wts < y.wts && x.value != y.value && x.rts >= y.wts {
                        return Err(format!(
                            "line {addr:#x}: stale copy at {} (wts={} rts={} value={:#x}) \
                             is readable at/after the newer version at {} (wts={} value={:#x})",
                            x.who, x.wts, x.rts, x.value, y.who, y.wts, y.value
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// One version, one value: non-exclusive copies with equal wts must
/// carry equal data.  The wts-skip seeded fault (a write that keeps
/// the stale wts) surfaces here once the owner's data returns to the
/// TM while an old sharer still caches the true old version.
struct VersionValueAgreement;

impl Invariant<Tardis> for VersionValueAgreement {
    fn name(&self) -> &'static str {
        "version-value-agreement"
    }

    fn check(&self, p: &Tardis, lines: &[LineAddr]) -> Result<(), String> {
        for &addr in lines {
            let cps = copies(p, addr);
            for (i, x) in cps.iter().enumerate() {
                if x.excl {
                    continue;
                }
                for y in cps.iter().skip(i + 1) {
                    if !y.excl && x.wts == y.wts && x.value != y.value {
                        return Err(format!(
                            "line {addr:#x}: version wts={} has two values: {} holds \
                             {:#x}, {} holds {:#x}",
                            x.wts, x.who, x.value, y.who, y.value
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Local timestamp sanity: wts <= rts on every meaningful copy, and
/// the global clocks (per-core pts, per-slice mts / max_ts) never run
/// backwards across a transition.
struct TsSanity;

impl Invariant<Tardis> for TsSanity {
    fn name(&self) -> &'static str {
        "timestamp-sanity"
    }

    fn check(&self, p: &Tardis, lines: &[LineAddr]) -> Result<(), String> {
        for &addr in lines {
            for cp in copies(p, addr) {
                if cp.wts > cp.rts {
                    return Err(format!(
                        "line {addr:#x}: {} has wts={} > rts={}",
                        cp.who, cp.wts, cp.rts
                    ));
                }
            }
        }
        Ok(())
    }

    fn check_step(&self, before: &Tardis, after: &Tardis) -> Result<(), String> {
        for c in 0..after.n_cores {
            if after.pts(c) < before.pts(c) {
                return Err(format!(
                    "core{c}: pts moved backwards {} -> {}",
                    before.pts(c),
                    after.pts(c)
                ));
            }
        }
        for s in 0..after.tm.len() {
            if after.tm[s].mts < before.tm[s].mts {
                return Err(format!(
                    "slice{s}: mts moved backwards {} -> {}",
                    before.tm[s].mts, after.tm[s].mts
                ));
            }
            if after.tm[s].max_ts < before.tm[s].max_ts {
                return Err(format!(
                    "slice{s}: max_ts moved backwards {} -> {}",
                    before.tm[s].max_ts, after.tm[s].max_ts
                ));
            }
        }
        Ok(())
    }
}
