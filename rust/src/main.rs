//! `tardis` CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; clap is not in this image's
//! registry):
//!
//! ```text
//! tardis run   --workload fft --protocol tardis --cores 64 [--ooo]
//!              [--lease N] [--self-inc N] [--no-spec] [--delta-bits N]
//! tardis sweep --figure fig4|fig5|fig6|fig7|fig8|fig9|fig10|table6|table7
//!              [--threads N] [--scale-down N] [--out results/]
//! tardis litmus
//! tardis case-study
//! tardis reproduce [--threads N] [--out results/]
//! ```

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use tardis_dsm::config::{CoreModel, ProtocolKind, SystemConfig};
use tardis_dsm::coordinator::experiments::{self, EvalCtx};
use tardis_dsm::coordinator::report::Table;
use tardis_dsm::prog::litmus;
use tardis_dsm::runtime::TraceRuntime;
use tardis_dsm::sim::run_workload;
use tardis_dsm::workloads;

struct Args {
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(raw: &[String]) -> Self {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            if let Some(name) = raw[i].strip_prefix("--") {
                let value = raw
                    .get(i + 1)
                    .filter(|v| !v.starts_with("--"))
                    .cloned();
                if value.is_some() {
                    i += 1;
                }
                flags.push((name.to_string(), value));
            }
            i += 1;
        }
        Self { flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "litmus" => cmd_litmus(),
        "case-study" => cmd_case_study(),
        "reproduce" => cmd_reproduce(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `tardis help`)"),
    }
}

fn print_usage() {
    println!(
        "tardis — Tardis coherence simulator (Yu & Devadas 2015 reproduction)

USAGE:
  tardis run --workload <name> [--protocol tardis|msi|ackwise] [--cores N]
             [--ooo] [--lease N] [--self-inc N] [--no-spec] [--delta-bits N]
  tardis sweep --figure <fig4|fig5|fig6|fig7|fig8|fig9|fig10|table6|table7>
             [--threads N] [--scale-down N] [--out DIR]
  tardis litmus           run the litmus suite under all three protocols
  tardis case-study       cycle-by-cycle §V example, Tardis vs MSI
  tardis reproduce        regenerate every table and figure
  workloads: {}",
        workloads::all().iter().map(|w| w.name).collect::<Vec<_>>().join(", ")
    );
}

fn build_cfg(args: &Args) -> Result<SystemConfig> {
    let protocol = match args.get("protocol").unwrap_or("tardis") {
        p => ProtocolKind::parse(p).ok_or_else(|| anyhow!("unknown protocol {p:?}"))?,
    };
    let n_cores = args.get_u64("cores", 64)? as u32;
    let mut cfg = experiments::base_cfg(n_cores, protocol);
    if args.has("ooo") {
        cfg.core_model = CoreModel::OutOfOrder;
    }
    cfg.tardis.lease = args.get_u64("lease", cfg.tardis.lease)?;
    cfg.tardis.self_inc_period = args.get_u64("self-inc", cfg.tardis.self_inc_period)?;
    cfg.tardis.delta_ts_bits = args.get_u64("delta-bits", cfg.tardis.delta_ts_bits as u64)? as u32;
    if args.has("no-spec") {
        cfg.tardis.speculation = false;
    }
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let name = args.get("workload").unwrap_or("fft");
    let spec = workloads::by_name(name).ok_or_else(|| anyhow!("unknown workload {name:?}"))?;
    let cfg = build_cfg(args)?;
    let mut runtime = TraceRuntime::open_default().ok();
    let mut ctx = EvalCtx::new(None, 1);
    ctx.scale_down = args.get_u64("scale-down", 1)? as u32;
    let trace_len = ctx.trace_len(cfg.n_cores);
    let workload =
        tardis_dsm::runtime::workload_or_synth(&mut runtime, cfg.n_cores, trace_len, &spec.params);
    println!(
        "running {} on {} x{} cores ({} ops)...",
        spec.name,
        cfg.protocol.name(),
        cfg.n_cores,
        workload.total_ops()
    );
    let res = run_workload(cfg, &workload)?;
    let s = &res.stats;
    println!("cycles            {}", s.cycles);
    println!("memops            {}", s.memops);
    println!("throughput        {:.4} ops/cycle", s.throughput());
    println!("L1 miss rate      {:.3}%", s.l1_miss_rate() * 100.0);
    println!("traffic (flits)   {}", s.traffic.total());
    println!("  renew flits     {}", s.traffic.renew_flits);
    println!("  inv flits       {}", s.traffic.invalidation_flits);
    println!("renew requests    {} (success {})", s.renew_requests, s.renew_success);
    println!("misspeculations   {}", s.misspeculations);
    println!("locks acquired    {}", s.locks_acquired);
    println!("barriers passed   {}", s.barriers_passed);
    println!("ts incr rate      {:.0} cycles/ts", s.ts_incr_rate());
    println!("self incr share   {:.1}%", s.self_inc_fraction() * 100.0);
    Ok(())
}

fn eval_ctx(args: &Args) -> Result<EvalCtx> {
    let runtime = TraceRuntime::open_default().ok();
    if runtime.is_none() {
        eprintln!("note: artifacts not found, using rust synth fallback (run `make artifacts`)");
    }
    let mut ctx = EvalCtx::new(runtime, args.get_u64("threads", 0)? as usize);
    ctx.scale_down = args.get_u64("scale-down", 1)? as u32;
    Ok(ctx)
}

fn emit(table: &Table, out: &str, stem: &str) -> Result<()> {
    println!("\n{}", table.to_markdown());
    table.write(out, stem)?;
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let fig = args.get("figure").unwrap_or("fig4");
    let out = args.get("out").unwrap_or("results");
    let mut ctx = eval_ctx(args)?;
    match fig {
        "fig4" => emit(&experiments::fig4(&mut ctx)?, out, "fig4"),
        "fig5" => emit(&experiments::fig5(&mut ctx)?, out, "fig5"),
        "fig6" => emit(&experiments::fig6(&mut ctx)?, out, "fig6"),
        "fig7" => emit(&experiments::fig7(&mut ctx)?, out, "fig7"),
        "fig8" => {
            let (a, b) = experiments::fig8(&mut ctx)?;
            emit(&a, out, "fig8a")?;
            emit(&b, out, "fig8b")
        }
        "fig9" => emit(&experiments::fig9(&mut ctx)?, out, "fig9"),
        "fig10" => emit(&experiments::fig10(&mut ctx)?, out, "fig10"),
        "table6" => emit(&experiments::table6(&mut ctx)?, out, "table6"),
        "table7" => emit(&experiments::table7(), out, "table7"),
        other => bail!("unknown figure {other:?}"),
    }
}

fn cmd_litmus() -> Result<()> {
    for proto in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        println!("== {} ==", proto.name());
        for lt in litmus::all() {
            let n = lt.workload.n_cores();
            let mut forbidden = 0;
            // Perturb interleavings with per-run gap jitter.
            for seed in 0..50u64 {
                let w = jitter(&lt.workload, seed);
                let cfg = SystemConfig::small(n, proto);
                let res = run_workload(cfg, &w)?;
                let outcome = extract_outcome(&res, &lt.observed);
                if !(lt.allowed)(&outcome) {
                    forbidden += 1;
                }
                tardis_dsm::prog::checker::check(&res.log)
                    .map_err(|v| anyhow!("{}: SC violation {v:?}", lt.name))?;
            }
            println!(
                "  {:<6} {:>3} runs, forbidden outcomes: {}",
                lt.name,
                50,
                if forbidden == 0 { "none".to_string() } else { format!("{forbidden} !!") }
            );
            if forbidden > 0 {
                bail!("litmus {} observed a forbidden outcome under {}", lt.name, proto.name());
            }
        }
    }
    println!("all litmus tests clean");
    Ok(())
}

/// Jitter compute gaps to explore interleavings (deterministic per
/// seed).
fn jitter(w: &tardis_dsm::prog::Workload, seed: u64) -> tardis_dsm::prog::Workload {
    use tardis_dsm::prog::Op;
    use tardis_dsm::testutil::Rng;
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1);
    let mut w = w.clone();
    for p in &mut w.programs {
        for op in &mut p.ops {
            match op {
                Op::Load { gap, .. } | Op::Store { gap, .. } => *gap = rng.below(12) as u32,
                _ => {}
            }
        }
    }
    w
}

fn extract_outcome(res: &tardis_dsm::sim::SimResult, observed: &[(u32, u32)]) -> Vec<u64> {
    observed
        .iter()
        .map(|&(core, pc)| {
            res.log
                .records
                .iter()
                .find(|r| r.core == core && r.pc == pc && r.value_read.is_some())
                .map(|r| r.value_read.unwrap())
                .unwrap_or(u64::MAX)
        })
        .collect()
}

fn cmd_case_study() -> Result<()> {
    let w = litmus::case_study();
    for proto in [ProtocolKind::Msi, ProtocolKind::Tardis] {
        let cfg = SystemConfig::small(2, proto);
        let res = run_workload(cfg, &w)?;
        println!("== {} == finished in {} cycles", proto.name(), res.stats.cycles);
        for r in &res.log.records {
            println!(
                "  cyc {:>4}  core {}  pc {}  {}{:#x}  val {:?}  ts {}",
                r.commit_cycle,
                r.core,
                r.pc,
                if r.value_written.is_some() { "W " } else { "R " },
                r.addr,
                r.value_read.or(r.value_written),
                r.ts
            );
        }
    }
    Ok(())
}

fn cmd_reproduce(args: &Args) -> Result<()> {
    let out = args.get("out").unwrap_or("results");
    let mut ctx = eval_ctx(args)?;
    println!("Reproducing all paper tables and figures into {out}/ ...");
    emit(&experiments::fig4(&mut ctx)?, out, "fig4")?;
    emit(&experiments::fig5(&mut ctx)?, out, "fig5")?;
    emit(&experiments::table6(&mut ctx)?, out, "table6")?;
    emit(&experiments::fig6(&mut ctx)?, out, "fig6")?;
    emit(&experiments::fig7(&mut ctx)?, out, "fig7")?;
    let (a, b) = experiments::fig8(&mut ctx)?;
    emit(&a, out, "fig8a")?;
    emit(&b, out, "fig8b")?;
    emit(&experiments::table7(), out, "table7")?;
    emit(&experiments::fig9(&mut ctx)?, out, "fig9")?;
    emit(&experiments::fig10(&mut ctx)?, out, "fig10")?;
    println!("done.");
    Ok(())
}

// Arc is used by experiments through coordinator; silence unused import
// when compiled without it.
#[allow(unused)]
fn _keep(_: Arc<()>) {}
