//! `tardis` CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; clap is not in this image's
//! registry):
//!
//! ```text
//! tardis run   --workload fft --protocol tardis --cores 64 [--ooo]
//!              [--lease N] [--self-inc N] [--no-spec] [--delta-bits N]
//!              [--progress N] [--progress-format human|json]
//!              [--trace-out FILE] [--host-spans]
//! tardis trace --workload fft [every run flag] [--out FILE]
//!              [--host-spans] [--window N] [--top K]
//! tardis sweep --figure fig4|fig5|fig6|fig7|fig8|fig9|fig10|table6|table7
//!              [--threads N] [--scale-down N] [--out results/]
//! tardis litmus
//! tardis case-study
//! tardis verify [--protocol tardis|msi|all] [--consistency sc|tso|all]
//!              [--cores N] [--lines N] [--max-ts N] [--lease N]
//!              [--sb-entries N] [--out FILE]
//! tardis reproduce [--threads N] [--scale-down N] [--out results/]
//! tardis serve [--addr HOST:PORT | --port N] [--workers N]
//! tardis help
//! ```
//!
//! Unknown flags and stray positional arguments are rejected with an
//! error naming the offender; every simulation is constructed through
//! [`tardis_dsm::api::SimBuilder`].

use anyhow::{anyhow, bail, Result};

use tardis_dsm::api::{ProgressFormat, ProgressObserver, SimBuilder, SimSpec};
use tardis_dsm::config::{
    Consistency, CoreModel, LeasePolicyKind, PdesMode, ProtocolKind, SocketInterleave,
    TopologyConfig,
};
use tardis_dsm::coordinator::experiments::{self, EvalCtx};
use tardis_dsm::coordinator::report::Table;
use tardis_dsm::prog::litmus;
use tardis_dsm::runtime::TraceRuntime;
use tardis_dsm::serve::{ServeConfig, Server};
use tardis_dsm::verif::{self, VerifBounds};
use tardis_dsm::workloads;

struct Args {
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    /// Parse `--flag [value]` pairs; stray positional tokens are an
    /// error (they used to be silently ignored).
    fn parse(raw: &[String]) -> Result<Self> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let Some(name) = raw[i].strip_prefix("--") else {
                bail!(
                    "unexpected argument {:?} (flags look like --name [value]; try `tardis help`)",
                    raw[i]
                );
            };
            let value = raw.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
            if value.is_some() {
                i += 1;
            }
            if flags.iter().any(|(n, _)| n == name) {
                bail!("duplicate flag --{name}");
            }
            flags.push((name.to_string(), value));
            i += 1;
        }
        Ok(Self { flags })
    }

    /// Reject any flag outside the command's spec with a clear error,
    /// and reject values attached to boolean flags (otherwise
    /// `tardis run --ooo barnes` would silently swallow `barnes`).
    fn expect_only(&self, cmd: &str, value_flags: &[&str], bool_flags: &[&str]) -> Result<()> {
        let allowed = || {
            let all: Vec<String> =
                value_flags.iter().chain(bool_flags).map(|f| format!("--{f}")).collect();
            if all.is_empty() { "none".to_string() } else { all.join(", ") }
        };
        for (name, value) in &self.flags {
            let n = name.as_str();
            if !value_flags.contains(&n) && !bool_flags.contains(&n) {
                bail!("unknown flag --{name} for `tardis {cmd}` (allowed: {})", allowed());
            }
            if bool_flags.contains(&n) {
                if let Some(v) = value {
                    bail!("--{name} does not take a value (got {v:?})");
                }
            }
        }
        Ok(())
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    /// Value of a string flag, or `default` when the flag is absent;
    /// error when the flag is present without a value.
    fn get_str<'a>(&'a self, name: &str, default: &'a str) -> Result<&'a str> {
        if !self.has(name) {
            return Ok(default);
        }
        match self.get(name) {
            Some(v) => Ok(v),
            None => bail!("--{name} expects a value"),
        }
    }

    fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        if !self.has(name) {
            return Ok(default);
        }
        match self.get(name) {
            None => bail!("--{name} expects a number"),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "trace" => cmd_trace(&args),
        "sweep" => cmd_sweep(&args),
        "litmus" => {
            args.expect_only("litmus", &[], &[])?;
            cmd_litmus()
        }
        "case-study" => {
            args.expect_only("case-study", &[], &[])?;
            cmd_case_study()
        }
        "verify" => cmd_verify(&args),
        "reproduce" => cmd_reproduce(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `tardis help`)"),
    }
}

fn print_usage() {
    println!(
        "tardis — Tardis coherence simulator (Yu & Devadas 2015 reproduction)

USAGE:
  tardis run --workload <name> [--protocol tardis|msi|ackwise] [--cores N]
             [--ooo] [--consistency sc|tso] [--lease N]
             [--lease-policy static|dynamic|predictive] [--self-inc N]
             [--no-spec] [--delta-bits N] [--scale-down N] [--progress N]
             [--progress-format human|json] [--seed N] [--sockets N]
             [--numa-ratio N] [--interleave line|block] [--threads N]
             [--pdes-mode epoch|nullmsg|auto] [--rebalance N]
             [--trace-out FILE] [--host-spans]
  tardis trace --workload <name> [every `run` flag] [--out FILE]
             [--host-spans] [--window N] [--top K]
                          coherence flight recorder: run the point with
                          protocol-event tracing on, print the top-K
                          hot-line / hot-core attribution tables and the
                          interval timeline, and optionally write the
                          tardis-trace-v1 Chrome trace JSON (--out;
                          --host-spans adds the host-time PDES process)
  tardis sweep --figure <fig4|fig5|fig6|fig7|fig8|fig9|fig10|table6|table7|lease|numa>
             [--threads N] [--scale-down N] [--out DIR]
  tardis litmus           run the litmus suite under all three protocols
  tardis case-study       cycle-by-cycle §V example, Tardis vs MSI
  tardis verify [--protocol tardis|msi|all] [--consistency sc|tso|all]
               [--cores N] [--lines N] [--max-ts N] [--lease N]
               [--sb-entries N] [--schedule serial|sharded|sharded:N]
               [--out FILE]
                          exhaustive bounded model check of the shipped
                          controllers; writes the tardis-verif-v1 JSON
                          report (non-zero exit on any violation)
  tardis reproduce        regenerate every table and figure
  tardis bench [--suite fig4|lease] [--cores N] [--iters N] [--scale-down N]
               [--out FILE] [--lease-policy static|dynamic|predictive]
               [--sockets N] [--numa-ratio N] [--threads N]
               [--pdes-mode epoch|nullmsg|auto] [--rebalance N]
                          macro benchmark (fig-4 sweep, timed serially;
                          --threads N times the sharded PDES engine —
                          epoch-barrier or null-message synchronization,
                          optional count-driven rebalancing — and
                          records its parallel efficiency and shard
                          imbalance); writes the machine-readable
                          BENCH_*.json record
  tardis serve [--addr HOST:PORT | --port N] [--workers N]
                          simulation-as-a-service: long-lived batch sweep
                          server (newline-delimited JSON, columnar
                          tardis-serve-v1 results; python/client/ has the
                          reference clients)
  tardis help             this message
  workloads: {}",
        workloads::all().iter().map(|w| w.name).collect::<Vec<_>>().join(", ")
    );
}

/// Lower the `run` subcommand's flags into the shared [`SimSpec`]
/// point description (the serve subsystem lowers wire points into the
/// same struct, so both paths share one validation and one builder).
fn spec_from_args(args: &Args) -> Result<SimSpec> {
    let mut spec = SimSpec::new(args.get_str("workload", "fft")?);
    {
        let p = args.get_str("protocol", "tardis")?;
        spec.protocol = ProtocolKind::parse(p).ok_or_else(|| anyhow!("unknown protocol {p:?}"))?;
    }
    spec.cores = args.get_u64("cores", 64)? as u32;
    if args.has("ooo") {
        spec.core_model = CoreModel::OutOfOrder;
    }
    if args.has("consistency") {
        let c = args.get_str("consistency", "sc")?;
        spec.consistency = Some(
            Consistency::parse(c)
                .ok_or_else(|| anyhow!("unknown consistency model {c:?} (sc|tso)"))?,
        );
    }
    if args.has("lease-policy") {
        let p = args.get_str("lease-policy", "static")?;
        spec.lease_policy = Some(
            LeasePolicyKind::parse(p)
                .ok_or_else(|| anyhow!("unknown lease policy {p:?} (static|dynamic|predictive)"))?,
        );
    }
    if args.has("sockets") {
        spec.sockets = Some(args.get_u64("sockets", 1)? as u32);
    }
    if args.has("numa-ratio") {
        spec.numa_ratio = Some(args.get_u64("numa-ratio", 1)? as u32);
    }
    if args.has("interleave") {
        let i = args.get_str("interleave", "line")?;
        spec.interleave = Some(
            SocketInterleave::parse(i)
                .ok_or_else(|| anyhow!("unknown interleave {i:?} (line|block)"))?,
        );
    }
    if args.has("lease") {
        spec.lease = Some(args.get_u64("lease", 0)?);
    }
    if args.has("self-inc") {
        spec.self_inc = Some(args.get_u64("self-inc", 0)?);
    }
    if args.has("delta-bits") {
        spec.delta_bits = Some(args.get_u64("delta-bits", 0)? as u32);
    }
    spec.no_spec = args.has("no-spec");
    spec.scale_down = args.get_u64("scale-down", 1)? as u32;
    if spec.scale_down == 0 {
        bail!("--scale-down must be >= 1");
    }
    if args.has("seed") {
        spec.seed = Some(args.get_u64("seed", 0)?);
    }
    if args.has("threads") {
        spec.threads = Some(args.get_u64("threads", 1)? as u32);
    }
    if args.has("pdes-mode") {
        let m = args.get_str("pdes-mode", "epoch")?;
        spec.pdes_mode = Some(
            PdesMode::parse(m)
                .ok_or_else(|| anyhow!("unknown pdes mode {m:?} (epoch|nullmsg|auto)"))?,
        );
    }
    if args.has("rebalance") {
        spec.rebalance_every = Some(args.get_u64("rebalance", 0)? as u32);
    }
    Ok(spec)
}

/// Parse `--progress` / `--progress-format` into a configured
/// progress observer (`None` when progress is off).
fn progress_observer(args: &Args) -> Result<Option<(u64, ProgressObserver)>> {
    let progress = args.get_u64("progress", 0)?;
    let fmt = args.get_str("progress-format", "human")?;
    let fmt = ProgressFormat::parse(fmt)
        .ok_or_else(|| anyhow!("unknown progress format {fmt:?} (human|json)"))?;
    if progress == 0 {
        if args.has("progress-format") {
            bail!("--progress-format has no effect without --progress N");
        }
        return Ok(None);
    }
    let obs = match fmt {
        ProgressFormat::Human => ProgressObserver::default(),
        ProgressFormat::Json => ProgressObserver::json(""),
    };
    Ok(Some((progress, obs)))
}

/// Flags shared by `tardis run` and `tardis trace` (the SimSpec
/// surface).
const SPEC_VALUE_FLAGS: &[&str] = &[
    "workload",
    "protocol",
    "cores",
    "consistency",
    "lease",
    "lease-policy",
    "self-inc",
    "delta-bits",
    "scale-down",
    "seed",
    "sockets",
    "numa-ratio",
    "interleave",
    "threads",
    "pdes-mode",
    "rebalance",
];

fn cmd_run(args: &Args) -> Result<()> {
    let mut value_flags = SPEC_VALUE_FLAGS.to_vec();
    value_flags.extend(["progress", "progress-format", "trace-out"]);
    args.expect_only("run", &value_flags, &["ooo", "no-spec", "host-spans"])?;
    let trace_out = if args.has("trace-out") {
        match args.get("trace-out") {
            Some(p) => Some(p.to_string()),
            None => bail!("--trace-out expects a file path"),
        }
    } else {
        None
    };
    if args.has("host-spans") && trace_out.is_none() {
        bail!("--host-spans has no effect without --trace-out FILE");
    }
    let mut spec = spec_from_args(args)?;
    spec.trace = trace_out.is_some();
    let name = spec.workload.clone();
    let n_cores = spec.cores;
    let mut b = spec.builder()?;
    if let Some((every, obs)) = progress_observer(args)? {
        b = b.sample_every(every).observe(obs);
    }
    if let Ok(rt) = TraceRuntime::open_default() {
        b = b.trace_runtime(rt);
    } else {
        eprintln!("note: artifacts not found, using rust synth fallback (run `make artifacts`)");
    }
    let session = b.build()?;
    println!(
        "running {} on {} x{} cores ({} ops)...",
        name,
        session.cfg().protocol.name(),
        n_cores,
        session.workload().total_ops()
    );
    let res = session.run()?;
    let s = &res.stats;
    println!("cycles            {}", s.cycles);
    println!("memops            {}", s.memops);
    println!("throughput        {:.4} ops/cycle", s.throughput());
    println!("L1 miss rate      {:.3}%", s.l1_miss_rate() * 100.0);
    println!("traffic (flits)   {}", s.traffic.total());
    println!("  renew flits     {}", s.traffic.renew_flits);
    println!("  inv flits       {}", s.traffic.invalidation_flits);
    println!("renew requests    {} (success {})", s.renew_requests, s.renew_success);
    println!("misspeculations   {}", s.misspeculations);
    println!("locks acquired    {}", s.locks_acquired);
    println!("barriers passed   {}", s.barriers_passed);
    println!("ts incr rate      {:.0} cycles/ts", s.ts_incr_rate());
    println!("self incr share   {:.1}%", s.self_inc_fraction() * 100.0);
    println!("wall time         {:.3?}", res.elapsed);
    if let Some(path) = trace_out {
        write_trace(&path, &res, args.has("host-spans"))?;
    }
    Ok(())
}

/// Serialize a report's flight-recorder trace to `path`.
fn write_trace(path: &str, res: &tardis_dsm::api::SimReport, host_spans: bool) -> Result<()> {
    let opts = tardis_dsm::obs::ExportOpts { host_spans };
    std::fs::write(path, tardis_dsm::obs::export_chrome(&res.trace, &res.stats.parallel, &opts))?;
    println!(
        "wrote trace {path} ({} events, {} dropped)",
        res.trace.events.len(),
        res.trace.dropped
    );
    Ok(())
}

/// `tardis trace`: the flight-recorder view of one simulation point —
/// hot-line / hot-core attribution tables, the interval metrics
/// timeline, and (with `--out`) the tardis-trace-v1 Chrome trace JSON
/// (DESIGN.md §12).
fn cmd_trace(args: &Args) -> Result<()> {
    let mut value_flags = SPEC_VALUE_FLAGS.to_vec();
    value_flags.extend(["out", "window", "top"]);
    args.expect_only("trace", &value_flags, &["ooo", "no-spec", "host-spans"])?;
    if args.has("host-spans") && !args.has("out") {
        bail!("--host-spans has no effect without --out FILE");
    }
    let mut spec = spec_from_args(args)?;
    spec.trace = true;
    let name = spec.workload.clone();
    let mut b = spec.builder()?;
    if let Ok(rt) = TraceRuntime::open_default() {
        b = b.trace_runtime(rt);
    } else {
        eprintln!("note: artifacts not found, using rust synth fallback (run `make artifacts`)");
    }
    let res = b.run()?;
    let events = &res.trace.events;
    println!(
        "{} on {} x{} cores: {} cycles, {} protocol events recorded ({} dropped)",
        name,
        spec.protocol.name(),
        spec.cores,
        res.stats.cycles,
        events.len(),
        res.trace.dropped
    );

    let top = args.get_u64("top", 10)? as usize;
    println!();
    print!(
        "{}",
        tardis_dsm::obs::format_hot_table(
            &format!("hot lines (top {top} by coherence pressure)"),
            "line",
            true,
            &tardis_dsm::obs::hot_lines(events, top),
        )
    );
    println!();
    print!(
        "{}",
        tardis_dsm::obs::format_hot_table(
            &format!("hot cores (top {top} by coherence pressure)"),
            "core",
            false,
            &tardis_dsm::obs::hot_cores(events, top),
        )
    );

    // Timeline: explicit --window, or ~16 bins across the run.
    let window = match args.get_u64("window", 0)? {
        0 => (res.stats.cycles / 16).max(1),
        w => w,
    };
    let bins = tardis_dsm::obs::timeline(events, window);
    println!();
    println!("timeline (window {window} cycles):");
    println!(
        "  {:>12} {:>8} {:>9} {:>11} {:>10} {:>9}",
        "cycle", "demand", "expiries", "renew_rate", "avg_lease", "sb_stall"
    );
    const MAX_BINS: usize = 64;
    for bin in bins.iter().take(MAX_BINS) {
        println!(
            "  {:>12} {:>8} {:>9} {:>11.4} {:>10.1} {:>9}",
            bin.start,
            bin.demand,
            bin.expiries,
            bin.renewal_success_rate(),
            bin.avg_lease(),
            bin.sb_stalls
        );
    }
    if bins.len() > MAX_BINS {
        println!("  ... {} more window(s) (raise --window)", bins.len() - MAX_BINS);
    }

    if args.has("out") {
        let path = match args.get("out") {
            Some(p) => p.to_string(),
            None => bail!("--out expects a file path"),
        };
        write_trace(&path, &res, args.has("host-spans"))?;
    }
    Ok(())
}

fn eval_ctx(args: &Args) -> Result<EvalCtx> {
    let runtime = TraceRuntime::open_default().ok();
    if runtime.is_none() {
        eprintln!("note: artifacts not found, using rust synth fallback (run `make artifacts`)");
    }
    let mut ctx = EvalCtx::new(runtime, args.get_u64("threads", 0)? as usize);
    ctx.scale_down = args.get_u64("scale-down", 1)? as u32;
    Ok(ctx)
}

fn emit(table: &Table, out: &str, stem: &str) -> Result<()> {
    println!("\n{}", table.to_markdown());
    table.write(out, stem)?;
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    args.expect_only("sweep", &["figure", "threads", "scale-down", "out"], &[])?;
    let fig = args.get_str("figure", "fig4")?;
    let out = args.get_str("out", "results")?;
    let mut ctx = eval_ctx(args)?;
    match fig {
        "fig4" => emit(&experiments::fig4(&mut ctx)?, out, "fig4"),
        "fig5" => emit(&experiments::fig5(&mut ctx)?, out, "fig5"),
        "fig6" => emit(&experiments::fig6(&mut ctx)?, out, "fig6"),
        "fig7" => emit(&experiments::fig7(&mut ctx)?, out, "fig7"),
        "fig8" => {
            let (a, b) = experiments::fig8(&mut ctx)?;
            emit(&a, out, "fig8a")?;
            emit(&b, out, "fig8b")
        }
        "fig9" => emit(&experiments::fig9(&mut ctx)?, out, "fig9"),
        "fig10" => emit(&experiments::fig10(&mut ctx)?, out, "fig10"),
        "table6" => emit(&experiments::table6(&mut ctx)?, out, "table6"),
        "table7" => emit(&experiments::table7(), out, "table7"),
        "lease" => emit(&experiments::lease_matrix(&mut ctx)?, out, "lease_matrix"),
        "numa" => emit(&experiments::numa_sweep(&mut ctx)?, out, "numa_sweep"),
        other => bail!("unknown figure {other:?}"),
    }
}

fn cmd_litmus() -> Result<()> {
    for proto in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
        println!("== {} ==", proto.name());
        for lt in litmus::all() {
            let n = lt.workload.n_cores();
            let mut forbidden = 0;
            // Perturb interleavings with per-run gap jitter.
            for seed in 0..50u64 {
                let w = jitter(&lt.workload, seed);
                let res = SimBuilder::small(n, proto).workload(&w).run()?;
                let outcome = extract_outcome(&res, &lt.observed);
                if !(lt.allowed)(&outcome) {
                    forbidden += 1;
                }
                res.check_sc().map_err(|v| anyhow!("{}: SC violation {v:?}", lt.name))?;
            }
            println!(
                "  {:<6} {:>3} runs, forbidden outcomes: {}",
                lt.name,
                50,
                if forbidden == 0 { "none".to_string() } else { format!("{forbidden} !!") }
            );
            if forbidden > 0 {
                bail!("litmus {} observed a forbidden outcome under {}", lt.name, proto.name());
            }
        }
    }
    println!("all litmus tests clean");
    Ok(())
}

/// Jitter compute gaps to explore interleavings (deterministic per
/// seed).
fn jitter(w: &tardis_dsm::prog::Workload, seed: u64) -> tardis_dsm::prog::Workload {
    use tardis_dsm::prog::Op;
    use tardis_dsm::testutil::Rng;
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1);
    let mut w = w.clone();
    for p in &mut w.programs {
        for op in &mut p.ops {
            match op {
                Op::Load { gap, .. } | Op::Store { gap, .. } => *gap = rng.below(12) as u32,
                _ => {}
            }
        }
    }
    w
}

fn extract_outcome(res: &tardis_dsm::api::SimReport, observed: &[(u32, u32)]) -> Vec<u64> {
    observed
        .iter()
        .map(|&(core, pc)| {
            res.log
                .records
                .iter()
                .find(|r| r.core == core && r.pc == pc && r.value_read.is_some())
                .map(|r| r.value_read.unwrap())
                .unwrap_or(u64::MAX)
        })
        .collect()
}

fn cmd_case_study() -> Result<()> {
    let w = litmus::case_study();
    for proto in [ProtocolKind::Msi, ProtocolKind::Tardis] {
        let res = SimBuilder::small(2, proto).workload(&w).run()?;
        println!("== {} == finished in {} cycles", proto.name(), res.stats.cycles);
        for r in &res.log.records {
            println!(
                "  cyc {:>4}  core {}  pc {}  {}{:#x}  val {:?}  ts {}",
                r.commit_cycle,
                r.core,
                r.pc,
                if r.value_written.is_some() { "W " } else { "R " },
                r.addr,
                r.value_read.or(r.value_written),
                r.ts
            );
        }
    }
    Ok(())
}

/// `tardis bench`: the tracked perf pipeline (DESIGN.md §6).  Runs
/// the fig-4 macro sweep and writes a `tardis-bench-v1` JSON record.
fn cmd_bench(args: &Args) -> Result<()> {
    args.expect_only(
        "bench",
        &[
            "suite",
            "cores",
            "iters",
            "scale-down",
            "out",
            "lease-policy",
            "sockets",
            "numa-ratio",
            "threads",
            "pdes-mode",
            "rebalance",
        ],
        &[],
    )?;
    let suite = args.get_str("suite", "fig4")?;
    let n_cores = args.get_u64("cores", 16)? as u32;
    let iters = args.get_u64("iters", 3)? as u32;
    let out = args.get_str("out", "BENCH_local.json")?;
    // `--threads` here means *engine* shards per point (the thing the
    // bench times), never the EvalCtx worker pool — pool parallelism
    // would corrupt the timings, so the ctx below is built serial.
    let threads = args.get_u64("threads", 1)? as u32;
    if threads == 0 {
        bail!("--threads must be >= 1");
    }
    let pdes_mode = if args.has("pdes-mode") {
        let m = args.get_str("pdes-mode", "epoch")?;
        PdesMode::parse(m).ok_or_else(|| anyhow!("unknown pdes mode {m:?} (epoch|nullmsg|auto)"))?
    } else {
        PdesMode::Epoch
    };
    let rebalance = args.get_u64("rebalance", 0)? as u32;
    if (args.has("pdes-mode") || args.has("rebalance")) && threads <= 1 {
        bail!("--pdes-mode/--rebalance have no effect without --threads >= 2");
    }
    let policy = if args.has("lease-policy") {
        let p = args.get_str("lease-policy", "static")?;
        Some(
            LeasePolicyKind::parse(p)
                .ok_or_else(|| anyhow!("unknown lease policy {p:?} (static|dynamic|predictive)"))?,
        )
    } else {
        None
    };
    let topology = TopologyConfig {
        sockets: args.get_u64("sockets", 1)? as u32,
        numa_ratio: args.get_u64("numa-ratio", 4)? as u32,
        ..TopologyConfig::default()
    };
    if args.has("numa-ratio") && topology.is_flat() {
        bail!("--numa-ratio has no effect without --sockets >= 2");
    }
    let runtime = TraceRuntime::open_default().ok();
    if runtime.is_none() {
        eprintln!("note: artifacts not found, using rust synth fallback (run `make artifacts`)");
    }
    let mut ctx = EvalCtx::new(runtime, 0);
    ctx.scale_down = args.get_u64("scale-down", 1)? as u32;
    let report = match suite {
        "fig4" => {
            println!(
                "benchmarking fig-4 sweep at {n_cores} cores ({iters} iters, scale-down {}, \
                 {threads} engine thread(s))...",
                ctx.scale_down
            );
            tardis_dsm::coordinator::bench::run_macro_bench_with_opts(
                &mut ctx,
                n_cores,
                iters,
                tardis_dsm::coordinator::bench::BenchOpts {
                    policy,
                    topology,
                    threads,
                    pdes_mode,
                    rebalance,
                },
            )?
        }
        "lease" => {
            // The lease suite fixes its own grid (16/64/256 cores,
            // every policy, flat fabric): reject knobs it would
            // otherwise silently drop.
            for flag in
                ["cores", "lease-policy", "sockets", "numa-ratio", "threads", "pdes-mode",
                 "rebalance"]
            {
                if args.has(flag) {
                    bail!("--{flag} does not apply to `bench --suite lease` \
                           (the suite sweeps its own fixed grid)");
                }
            }
            println!(
                "benchmarking lease matrix at 16/64/256 cores ({iters} iters, scale-down {})...",
                ctx.scale_down
            );
            tardis_dsm::coordinator::bench::run_lease_matrix_bench(&mut ctx, iters)?
        }
        other => bail!("unknown bench suite {other:?} (fig4|lease)"),
    };
    println!("{}", report.summary());
    report.write(out)?;
    println!("wrote {out}");
    Ok(())
}

/// `tardis serve`: the long-lived batch sweep server (DESIGN.md §10).
/// Binds, prints the bound address (port 0 picks a free port, for
/// harnesses), and blocks until a client sends a `shutdown` frame;
/// in-flight sessions drain before exit.
fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_only("serve", &["addr", "port", "workers"], &[])?;
    if args.has("addr") && args.has("port") {
        bail!("--addr and --port are mutually exclusive (addr includes the port)");
    }
    let addr = if args.has("addr") {
        match args.get("addr") {
            Some(a) => a.to_string(),
            None => bail!("--addr expects host:port"),
        }
    } else {
        format!("127.0.0.1:{}", args.get_u64("port", 7436)?)
    };
    let workers = args.get_u64("workers", 0)? as usize;
    let server = Server::start(ServeConfig { addr, workers })?;
    println!(
        "tardis-serve listening on {} ({} workers, schema {})",
        server.addr(),
        server.workers(),
        tardis_dsm::serve::SCHEMA
    );
    server.join();
    println!("tardis-serve: drained and shut down");
    Ok(())
}

/// `tardis verify`: bounded exhaustive model check of the shipped
/// protocol controllers (DESIGN.md §9).  Explores every interleaving
/// within the bounds, checks the protocol invariants at every state,
/// re-linearizes the access trace on every commit, and writes a
/// `tardis-verif-v1` JSON report.  Any violation prints its minimal
/// counterexample trace and exits non-zero.
fn cmd_verify(args: &Args) -> Result<()> {
    args.expect_only(
        "verify",
        &[
            "protocol",
            "consistency",
            "cores",
            "lines",
            "max-ts",
            "lease",
            "sb-entries",
            "schedule",
            "out",
        ],
        &[],
    )?;
    let protocols: Vec<ProtocolKind> = match args.get_str("protocol", "all")? {
        "all" => vec![ProtocolKind::Tardis, ProtocolKind::Msi],
        p => vec![ProtocolKind::parse(p)
            .ok_or_else(|| anyhow!("unknown protocol {p:?} (tardis|msi|all)"))?],
    };
    let models: Vec<Consistency> = match args.get_str("consistency", "all")? {
        "all" => vec![Consistency::Sc, Consistency::Tso],
        c => vec![Consistency::parse(c)
            .ok_or_else(|| anyhow!("unknown consistency model {c:?} (sc|tso)"))?],
    };
    let defaults = VerifBounds::default();
    let bounds = VerifBounds {
        cores: args.get_u64("cores", defaults.cores as u64)? as u32,
        lines: args.get_u64("lines", defaults.lines as u64)? as u32,
        max_ts: args.get_u64("max-ts", defaults.max_ts as u64)? as u32,
        lease: args.get_u64("lease", defaults.lease)?,
        sb_entries: args.get_u64("sb-entries", defaults.sb_entries as u64)? as u32,
    };
    // Frontier schedule: `sharded` permutes the exploration order the
    // way the PDES engine's shard partition would, and must reach the
    // same state count (exploration-order invariance).
    let schedule = match args.get_str("schedule", "serial")? {
        "serial" => verif::ExploreSchedule::Serial,
        "sharded" => verif::ExploreSchedule::Sharded { shards: 2 },
        other => match other.strip_prefix("sharded:").and_then(|n| n.parse().ok()) {
            Some(n) if n >= 1 => verif::ExploreSchedule::Sharded { shards: n },
            _ => bail!("unknown schedule {other:?} (serial|sharded|sharded:N)"),
        },
    };
    let out = args.get_str("out", "VERIF_local.json")?;
    println!(
        "verifying {{{}}} x {{{}}} at {} cores, {} line(s), max-ts {}, lease {} ({schedule:?})...",
        protocols.iter().map(|p| p.name()).collect::<Vec<_>>().join(","),
        models.iter().map(|m| m.name()).collect::<Vec<_>>().join(","),
        bounds.cores,
        bounds.lines,
        bounds.max_ts,
        bounds.lease
    );
    let report = verif::run_matrix_scheduled(&protocols, &models, bounds, schedule)
        .map_err(|e| anyhow!(e))?;
    for r in &report.runs {
        let o = &r.outcome;
        println!(
            "  {:<6} {:<3} {:>9} states  {:>10} transitions  depth {:>3}  {:>6} terminal  {}",
            r.protocol,
            r.consistency,
            o.states,
            o.transitions,
            o.max_depth,
            o.terminal_states,
            if o.passed() { "ok" } else { "VIOLATION" }
        );
        if let Some(cex) = &o.counterexample {
            println!("    invariant : {}", cex.invariant);
            println!("    detail    : {}", cex.detail);
            println!("    counterexample trace ({} events):", cex.labels.len());
            for (i, label) in cex.labels.iter().enumerate() {
                println!("      {:>3}. {label}", i + 1);
            }
        }
    }
    std::fs::write(out, report.to_json())?;
    println!("wrote {out}");
    if !report.passed() {
        bail!("verification found a protocol violation (see counterexample above)");
    }
    println!("all runs clean");
    Ok(())
}

fn cmd_reproduce(args: &Args) -> Result<()> {
    args.expect_only("reproduce", &["threads", "scale-down", "out"], &[])?;
    let out = args.get_str("out", "results")?;
    let mut ctx = eval_ctx(args)?;
    println!("Reproducing all paper tables and figures into {out}/ ...");
    emit(&experiments::fig4(&mut ctx)?, out, "fig4")?;
    emit(&experiments::fig5(&mut ctx)?, out, "fig5")?;
    emit(&experiments::table6(&mut ctx)?, out, "table6")?;
    emit(&experiments::fig6(&mut ctx)?, out, "fig6")?;
    emit(&experiments::fig7(&mut ctx)?, out, "fig7")?;
    let (a, b) = experiments::fig8(&mut ctx)?;
    emit(&a, out, "fig8a")?;
    emit(&b, out, "fig8b")?;
    emit(&experiments::table7(), out, "table7")?;
    emit(&experiments::fig9(&mut ctx)?, out, "fig9")?;
    emit(&experiments::fig10(&mut ctx)?, out, "fig10")?;
    emit(&experiments::lease_matrix(&mut ctx)?, out, "lease_matrix")?;
    emit(&experiments::numa_sweep(&mut ctx)?, out, "numa_sweep")?;
    println!("done.");
    Ok(())
}
