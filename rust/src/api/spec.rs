//! Declarative simulation-point specification.
//!
//! [`SimSpec`] is the plain-data description of one simulation point —
//! workload name, protocol, core count, and every optional knob the
//! `tardis run` CLI exposes.  Both the CLI (`main.rs`) and the serve
//! subsystem (`crate::serve`) lower their inputs into a `SimSpec` and
//! call [`SimSpec::builder`], so a batch point submitted over the wire
//! passes exactly the validation (and produces exactly the
//! [`SimBuilder`]) that the equivalent CLI invocation would — the
//! bit-for-bit serve-vs-CLI equality the determinism suite asserts.
//!
//! Fields are `Option` where the CLI distinguishes "flag absent" from
//! "flag set to the default" (e.g. an explicit `--numa-ratio` on a
//! 1-socket system is an error, an absent one is not).

use anyhow::{anyhow, bail, Result};

use crate::config::{
    Consistency, CoreModel, LeasePolicyKind, PdesMode, ProtocolKind, SocketInterleave,
    SystemConfig,
};
use crate::trace::TraceParams;
use crate::workloads;

use super::builder::{scaled_trace_len, SimBuilder};

/// One simulation point, ready to validate and lower into a
/// [`SimBuilder`].
#[derive(Debug, Clone, PartialEq)]
pub struct SimSpec {
    /// Named SPLASH-2-signature workload ([`crate::workloads::all`]).
    pub workload: String,
    /// Display label for sweep/serve results; defaults to a
    /// protocol-derived label ([`SimSpec::variant_label`]).
    pub label: Option<String>,
    pub protocol: ProtocolKind,
    pub cores: u32,
    pub core_model: CoreModel,
    /// Consistency model; `None` keeps the config default (SC).
    pub consistency: Option<Consistency>,
    /// Lease policy; `None` keeps the config default (Static).
    pub lease_policy: Option<LeasePolicyKind>,
    /// ccNUMA sockets; `None` keeps the flat single-chip mesh.
    pub sockets: Option<u32>,
    /// Inter-socket cost ratio; setting it without `sockets >= 2` is
    /// an error (an inert knob must not look honored).
    pub numa_ratio: Option<u32>,
    /// Address interleave; same inert-knob rule as `numa_ratio`.
    pub interleave: Option<SocketInterleave>,
    /// Static lease override (Tardis).
    pub lease: Option<u64>,
    /// Self-increment period override (Tardis).
    pub self_inc: Option<u64>,
    /// Delta-timestamp width override (Tardis).
    pub delta_bits: Option<u32>,
    /// Disable expired-load speculation (Tardis).
    pub no_spec: bool,
    /// Divide the default trace length by this factor (>= 1).
    pub scale_down: u32,
    /// Explicit trace length; overrides `scale_down` scaling.
    pub trace_len: Option<u32>,
    /// Trace-seed override: replaces the workload's canonical
    /// [`TraceParams::seed`], giving a distinct but deterministic
    /// trace instance (the serve layer's per-session seeds).
    pub seed: Option<u64>,
    /// Simulation worker threads; `None`/`Some(1)` runs the serial
    /// engine, `Some(n > 1)` the sharded PDES driver.  Results are
    /// bit-for-bit identical either way, so this is a *performance*
    /// knob and deliberately absent from [`SimSpec::variant_label`].
    pub threads: Option<u32>,
    /// PDES synchronization mode for threaded runs; `None` keeps the
    /// builder default (lockstep epochs).  Performance knob, absent
    /// from labels like `threads`.
    pub pdes_mode: Option<PdesMode>,
    /// Rebalance interval in lookahead windows for threaded runs;
    /// `None`/`Some(0)` disables migration.  Performance knob, absent
    /// from labels like `threads`.
    pub rebalance_every: Option<u32>,
    /// Record the coherence flight recorder ([`crate::obs`]).  Purely
    /// additive observability — stats and SC log stay bit-identical —
    /// so it is absent from [`SimSpec::variant_label`] like `threads`.
    pub trace: bool,
}

impl SimSpec {
    /// A point running `workload` with every knob at its default.
    pub fn new(workload: impl Into<String>) -> Self {
        Self {
            workload: workload.into(),
            label: None,
            protocol: ProtocolKind::Tardis,
            cores: 64,
            core_model: CoreModel::InOrder,
            consistency: None,
            lease_policy: None,
            sockets: None,
            numa_ratio: None,
            interleave: None,
            lease: None,
            self_inc: None,
            delta_bits: None,
            no_spec: false,
            scale_down: 1,
            trace_len: None,
            seed: None,
            threads: None,
            pdes_mode: None,
            rebalance_every: None,
            trace: false,
        }
    }

    /// The workload's trace parameters with the seed override applied.
    /// Fails on an unknown workload name — the first validation any
    /// consumer hits.
    pub fn resolve_params(&self) -> Result<TraceParams> {
        let spec = workloads::by_name(&self.workload).ok_or_else(|| {
            anyhow!(
                "unknown workload {:?} (known: {})",
                self.workload,
                workloads::all().iter().map(|w| w.name).collect::<Vec<_>>().join(", ")
            )
        })?;
        let mut params = spec.params;
        if let Some(seed) = self.seed {
            params.seed = seed;
        }
        Ok(params)
    }

    /// Trace length this point runs: the explicit override, or the
    /// core-count default divided by `scale_down`.
    pub fn resolved_trace_len(&self) -> u32 {
        self.trace_len.unwrap_or_else(|| scaled_trace_len(self.cores, self.scale_down))
    }

    /// Result label: the explicit one, else derived from the protocol
    /// and its modifiers (`tardis-predictive-nospec`, `msi`...).
    pub fn variant_label(&self) -> String {
        if let Some(l) = &self.label {
            return l.clone();
        }
        let mut label = self.protocol.name().to_string();
        if self.protocol == ProtocolKind::Tardis {
            if let Some(p) = self.lease_policy {
                label.push('-');
                label.push_str(p.name());
            }
            if self.no_spec {
                label.push_str("-nospec");
            }
        }
        label
    }

    /// Validate the point and lower it into a configured
    /// [`SimBuilder`] (workload source attached, trace length set).
    /// Geometry checks that need the final config (socket
    /// divisibility) run later, in [`SimBuilder::build`].
    pub fn builder(&self) -> Result<SimBuilder> {
        if self.cores == 0 {
            bail!("a simulation needs at least one core");
        }
        let params = self.resolve_params()?;
        let mut b = SimBuilder::from_config(SystemConfig::for_point(self.cores, self.protocol));
        b = b.core_model(self.core_model);
        if let Some(c) = self.consistency {
            b = b.consistency(c);
        }
        if let Some(p) = self.lease_policy {
            b = b.lease_policy(p);
        }
        if let Some(s) = self.sockets {
            b = b.sockets(s);
        }
        if let Some(r) = self.numa_ratio {
            b = b.numa_ratio(r);
        }
        if let Some(i) = self.interleave {
            b = b.interleave(i);
        }
        if let Some(t) = self.threads {
            b = b.threads(t);
        }
        if let Some(m) = self.pdes_mode {
            b = b.pdes_mode(m);
        }
        if let Some(r) = self.rebalance_every {
            b = b.rebalance_every(r);
        }
        if self.trace {
            b = b.trace(true);
        }
        // NUMA knobs are inert on a 1-socket system: reject them
        // loudly instead of simulating flat while the spec looks
        // honored (the CLI surfaces this as its --flag variant).
        if b.cfg().topology.is_flat() {
            if self.numa_ratio.is_some() {
                bail!("numa-ratio has no effect without sockets >= 2");
            }
            if self.interleave.is_some() {
                bail!("interleave has no effect without sockets >= 2");
            }
        }
        let (lease, self_inc, delta_bits, no_spec) =
            (self.lease, self.self_inc, self.delta_bits, self.no_spec);
        b = b.tardis(|t| {
            if let Some(l) = lease {
                t.lease = l;
            }
            if let Some(s) = self_inc {
                t.self_inc_period = s;
            }
            if let Some(d) = delta_bits {
                t.delta_ts_bits = d;
            }
            if no_spec {
                t.speculation = false;
            }
        });
        Ok(b.synth_workload(params).trace_len(self.resolved_trace_len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_the_cli_defaults() {
        let s = SimSpec::new("fft");
        assert_eq!(s.protocol, ProtocolKind::Tardis);
        assert_eq!(s.cores, 64);
        assert_eq!(s.core_model, CoreModel::InOrder);
        assert_eq!(s.variant_label(), "tardis");
        let b = s.builder().unwrap();
        assert_eq!(b.cfg().n_cores, 64);
        assert_eq!(b.cfg().consistency, Consistency::Sc);
    }

    #[test]
    fn unknown_workload_is_rejected() {
        let err = SimSpec::new("nope").builder().unwrap_err().to_string();
        assert!(err.contains("unknown workload"), "{err}");
    }

    #[test]
    fn inert_numa_knobs_are_rejected() {
        let mut s = SimSpec::new("fft");
        s.numa_ratio = Some(4);
        let err = s.builder().unwrap_err().to_string();
        assert!(err.contains("numa-ratio has no effect"), "{err}");
        let mut s = SimSpec::new("fft");
        s.interleave = Some(SocketInterleave::Block);
        let err = s.builder().unwrap_err().to_string();
        assert!(err.contains("interleave has no effect"), "{err}");
        // With sockets set the same knobs are honored.
        let mut s = SimSpec::new("fft");
        s.cores = 8;
        s.sockets = Some(2);
        s.numa_ratio = Some(4);
        s.interleave = Some(SocketInterleave::Block);
        assert_eq!(s.builder().unwrap().cfg().topology.sockets, 2);
    }

    #[test]
    fn socket_divisibility_still_checked_at_build() {
        let mut s = SimSpec::new("fft");
        s.cores = 6;
        s.sockets = Some(4);
        let err = s.builder().unwrap().build().unwrap_err().to_string();
        assert!(err.contains("do not divide"), "{err}");
    }

    #[test]
    fn seed_override_changes_the_trace_deterministically() {
        let mut a = SimSpec::new("fft");
        a.cores = 2;
        a.trace_len = Some(64);
        let mut b = a.clone();
        b.seed = Some(999);
        let run = |s: &SimSpec| s.builder().unwrap().run().unwrap().stats;
        let (ra1, ra2, rb) = (run(&a), run(&a), run(&b));
        assert_eq!(ra1, ra2, "same spec must repeat bit-identically");
        assert_ne!(ra1, rb, "a reseeded trace must differ");
    }

    #[test]
    fn spec_run_matches_the_equivalent_manual_builder() {
        let mut s = SimSpec::new("barnes");
        s.cores = 4;
        s.protocol = ProtocolKind::Msi;
        s.scale_down = 8;
        let via_spec = s.builder().unwrap().run().unwrap();
        let params = workloads::by_name("barnes").unwrap().params;
        let manual = SimBuilder::from_config(SystemConfig::for_point(4, ProtocolKind::Msi))
            .synth_workload(params)
            .trace_len(scaled_trace_len(4, 8))
            .run()
            .unwrap();
        assert_eq!(via_spec.stats, manual.stats);
    }

    #[test]
    fn threads_lower_into_the_builder_and_keep_results_identical() {
        let mut s = SimSpec::new("fft");
        s.cores = 4;
        s.trace_len = Some(64);
        let serial = s.builder().unwrap().run().unwrap();
        s.threads = Some(2);
        let par = s.builder().unwrap().run().unwrap();
        assert_eq!(par.stats, serial.stats);
        assert_eq!(par.core_finish, serial.core_finish);
        assert_eq!(s.variant_label(), "tardis", "threads must not leak into labels");
        // Null-message mode and rebalancing lower through the spec and
        // keep the same bit-for-bit contract, without leaking into
        // labels either.
        s.pdes_mode = Some(PdesMode::NullMsg);
        s.rebalance_every = Some(2);
        let nm = s.builder().unwrap().run().unwrap();
        assert_eq!(nm.stats, serial.stats);
        assert_eq!(nm.core_finish, serial.core_finish);
        assert_eq!(s.variant_label(), "tardis", "pdes knobs must not leak into labels");
        // Bad thread counts surface through the builder validation.
        s.threads = Some(9);
        let err = s.builder().unwrap().build().unwrap_err().to_string();
        assert!(err.contains("exceed the 4 cores"), "{err}");
    }

    #[test]
    fn variant_labels_encode_the_modifiers() {
        let mut s = SimSpec::new("fft");
        s.lease_policy = Some(LeasePolicyKind::parse("predictive").unwrap());
        s.no_spec = true;
        assert_eq!(s.variant_label(), "tardis-predictive-nospec");
        s.label = Some("custom".into());
        assert_eq!(s.variant_label(), "custom");
        let mut m = SimSpec::new("fft");
        m.protocol = ProtocolKind::Msi;
        m.no_spec = true; // tardis-only modifier: not in msi labels
        assert_eq!(m.variant_label(), "msi");
    }
}
