//! Pluggable simulation instrumentation.
//!
//! The engine used to hard-wire one instrument: an [`AccessLog`]
//! toggled by a `record_accesses` flag on the system configuration.
//! Instrumentation is now a set of plugins behind the [`Observer`]
//! trait, assembled into an [`Observers`] registry by the
//! [`SimBuilder`](super::SimBuilder):
//!
//! * the **SC-checker log** (the old `AccessLog`) is one plugin slot,
//!   enabled with `.record_accesses(true)`;
//! * **stats taps** ([`StatsTap`]) run a closure over the final (and
//!   optionally sampled) statistics;
//! * the **progress observer** ([`ProgressObserver`]) prints
//!   cycle-sampled progress lines for long sweeps.
//!
//! Custom plugins implement [`Observer`] (all hooks default to no-ops)
//! and register with `.observe(..)`.

use crate::obs::MetricsWindow;
use crate::prog::checker::{AccessLog, LogRecord};
use crate::serve::json::escape;
use crate::stats::SimStats;
use crate::types::Cycle;

/// A simulation instrumentation plugin.  Hooks are called by the
/// engine on the simulation thread; all have empty defaults so a
/// plugin only implements what it cares about.
pub trait Observer {
    /// A memory operation committed (including spin re-loads and sync
    /// microcode accesses).
    fn on_commit(&mut self, _rec: &LogRecord) {}

    /// A previously committed record was squashed by a speculation
    /// rollback; `seq` is the global commit sequence of the squashed
    /// record (matching an earlier `on_commit`'s `rec.seq`).
    fn on_squash(&mut self, _seq: u64) {}

    /// Periodic sample, fired every `sample_every` simulated cycles
    /// (see [`Observers::set_sample_period`]).
    fn on_sample(&mut self, _now: Cycle, _stats: &SimStats) {}

    /// The simulation finished; `core_finish` holds per-core
    /// completion cycles.
    fn on_finish(&mut self, _stats: &SimStats, _core_finish: &[Cycle]) {}
}

/// Output style of the [`ProgressObserver`] (the CLI's
/// `--progress-format`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProgressFormat {
    /// Human-readable `[sim] cycle ...` lines (the default).
    #[default]
    Human,
    /// One JSON object per line, shaped like the serve subsystem's
    /// `progress` frames (`type`/`memops`/`renew_rate`/`avg_lease`,
    /// plus `cycle` and `label` in place of `batch_id`/`point`) so
    /// one parser handles both streams.
    Json,
}

impl ProgressFormat {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "human" => Some(Self::Human),
            "json" => Some(Self::Json),
            _ => None,
        }
    }
}

/// Cycle-sampled progress reporter: one stderr line per sample window
/// plus a completion line.  Enable with
/// `SimBuilder::progress_every(cycles)`.
#[derive(Debug, Default)]
pub struct ProgressObserver {
    /// Prefix for every line (e.g. the run label); empty means bare.
    pub label: String,
    /// Human lines or serve-frame-shaped JSON.
    pub format: ProgressFormat,
    window: MetricsWindow,
}

impl ProgressObserver {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), ..Self::default() }
    }

    /// Structured-output variant (`--progress-format json`).
    pub fn json(label: impl Into<String>) -> Self {
        Self { label: label.into(), format: ProgressFormat::Json, ..Self::default() }
    }

    fn prefix(&self) -> String {
        if self.label.is_empty() {
            "[sim]".to_string()
        } else {
            format!("[sim {}]", self.label)
        }
    }
}

impl Observer for ProgressObserver {
    fn on_sample(&mut self, now: Cycle, stats: &SimStats) {
        let m = self.window.tick(stats);
        if self.format == ProgressFormat::Json {
            eprintln!(
                "{{\"type\": \"progress\", \"label\": {}, \"cycle\": {now}, \"memops\": {}, \
                 \"renew_rate\": {:.6}, \"avg_lease\": {:.6}}}",
                escape(&self.label),
                stats.memops,
                m.renew_rate,
                m.avg_lease
            );
            return;
        }
        // `stats.cycles` is only written when the run completes, so
        // mid-run throughput must be derived from `now`.
        let thr = if now == 0 { 0.0 } else { stats.memops as f64 / now as f64 };
        eprintln!(
            "{} cycle {now}: {} memops, {thr:.4} ops/cycle, {} flits, \
             renew rate {:.4}, avg lease {:.1}",
            self.prefix(),
            stats.memops,
            stats.traffic.total(),
            m.renew_rate,
            m.avg_lease
        );
    }

    fn on_finish(&mut self, stats: &SimStats, core_finish: &[Cycle]) {
        if self.format == ProgressFormat::Json {
            eprintln!(
                "{{\"type\": \"finished\", \"label\": {}, \"cycles\": {}, \"memops\": {}, \
                 \"cores\": {}}}",
                escape(&self.label),
                stats.cycles,
                stats.memops,
                core_finish.len()
            );
            return;
        }
        eprintln!(
            "{} finished: {} cycles, {} memops across {} cores",
            self.prefix(),
            stats.cycles,
            stats.memops,
            core_finish.len()
        );
    }
}

/// Adapter turning a closure into a finish-time (and sample-time)
/// stats tap: `SimBuilder::observe(StatsTap::new(|s| ...))`.
pub struct StatsTap<F: FnMut(&SimStats)> {
    f: F,
    /// Also invoke the closure on every sample (default: finish only).
    pub on_samples: bool,
}

impl<F: FnMut(&SimStats)> StatsTap<F> {
    pub fn new(f: F) -> Self {
        Self { f, on_samples: false }
    }

    pub fn sampled(f: F) -> Self {
        Self { f, on_samples: true }
    }
}

impl<F: FnMut(&SimStats)> Observer for StatsTap<F> {
    fn on_sample(&mut self, _now: Cycle, stats: &SimStats) {
        if self.on_samples {
            (self.f)(stats);
        }
    }

    fn on_finish(&mut self, stats: &SimStats, _core_finish: &[Cycle]) {
        (self.f)(stats);
    }
}

/// The engine-side registry: the optional SC log plus every registered
/// plugin, with the shared sampling clock.  Built by `SimBuilder`;
/// consumed by the engine.
#[derive(Default)]
pub struct Observers {
    /// SC-checker log; `Some` iff access recording is enabled.
    log: Option<AccessLog>,
    plugins: Vec<Box<dyn Observer>>,
    /// Cycles between `on_sample` firings; 0 disables sampling.
    sample_period: Cycle,
    next_sample: Cycle,
}

impl std::fmt::Debug for Observers {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Observers")
            .field("sc_log", &self.log.is_some())
            .field("plugins", &self.plugins.len())
            .field("sample_period", &self.sample_period)
            .finish()
    }
}

impl Observers {
    /// No instrumentation at all (the sweep default).
    pub fn none() -> Self {
        Self::default()
    }

    /// SC logging only (the test/litmus default).
    pub fn with_sc_log() -> Self {
        let mut obs = Self::default();
        obs.enable_sc_log();
        obs
    }

    pub fn enable_sc_log(&mut self) {
        if self.log.is_none() {
            self.log = Some(AccessLog::default());
        }
    }

    pub fn disable_sc_log(&mut self) {
        self.log = None;
    }

    pub fn sc_log_enabled(&self) -> bool {
        self.log.is_some()
    }

    /// Records committed so far (0 with logging off) — the engine's
    /// per-dispatch log grouping for the PDES merge reads this.
    pub(crate) fn log_len(&self) -> usize {
        self.log.as_ref().map_or(0, |l| l.records.len())
    }

    /// Whether any plugin is registered.  Plugins hold thread-local
    /// state (`Rc`, closures), so the parallel engine refuses them.
    pub(crate) fn has_plugins(&self) -> bool {
        !self.plugins.is_empty()
    }

    /// Whether cycle sampling is enabled (also serial-only: samples
    /// would fire per-shard, not on the global cycle order).
    pub(crate) fn sampling_enabled(&self) -> bool {
        self.sample_period != 0
    }

    pub fn register(&mut self, plugin: Box<dyn Observer>) {
        self.plugins.push(plugin);
    }

    /// Fire `on_sample` every `period` simulated cycles (0 disables).
    pub fn set_sample_period(&mut self, period: Cycle) {
        self.sample_period = period;
        self.next_sample = period;
    }

    /// Record a committed access.  Returns the squash handle the
    /// cores pass back to [`Observers::squash`]: the SC-log index
    /// when logging is on, the commit `seq` when only plugins are
    /// attached, and `usize::MAX` (no squash needed) when nothing
    /// observes.
    #[inline]
    pub fn commit(&mut self, rec: LogRecord) -> usize {
        for p in &mut self.plugins {
            p.on_commit(&rec);
        }
        match &mut self.log {
            Some(log) => log.push(rec),
            None if self.plugins.is_empty() => usize::MAX,
            None => rec.seq as usize,
        }
    }

    /// Squash a previously committed access (speculation rollback
    /// re-executed the operation).  `handle` is whatever
    /// [`Observers::commit`] returned for it.
    pub fn squash(&mut self, handle: usize) {
        if handle == usize::MAX {
            return;
        }
        match &mut self.log {
            Some(log) => {
                let seq = log.records[handle].seq;
                log.squash(handle);
                for p in &mut self.plugins {
                    p.on_squash(seq);
                }
            }
            None => {
                for p in &mut self.plugins {
                    p.on_squash(handle as u64);
                }
            }
        }
    }

    /// Hot-loop sampling check: a single branch when sampling is off.
    #[inline]
    pub fn maybe_sample(&mut self, now: Cycle, stats: &SimStats) {
        if self.sample_period != 0 && now >= self.next_sample {
            while self.next_sample <= now {
                self.next_sample += self.sample_period;
            }
            for p in &mut self.plugins {
                p.on_sample(now, stats);
            }
        }
    }

    pub fn finish(&mut self, stats: &SimStats, core_finish: &[Cycle]) {
        for p in &mut self.plugins {
            p.on_finish(stats, core_finish);
        }
    }

    /// Extract the SC log (empty when logging was disabled).
    pub fn take_log(&mut self) -> AccessLog {
        self.log.take().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq: u64) -> LogRecord {
        LogRecord {
            core: 0,
            pc: 0,
            addr: 1,
            value_read: Some(0),
            value_written: None,
            ts: 0,
            commit_cycle: seq,
            seq,
            valid: true,
            forwarded: false,
        }
    }

    #[test]
    fn commit_indexes_only_with_log() {
        let mut off = Observers::none();
        assert_eq!(off.commit(rec(1)), usize::MAX);
        assert!(off.take_log().is_empty());

        let mut on = Observers::with_sc_log();
        assert_eq!(on.commit(rec(1)), 0);
        assert_eq!(on.commit(rec(2)), 1);
        assert_eq!(on.take_log().len(), 2);
    }

    #[test]
    fn squash_marks_record_invalid_and_notifies() {
        use std::cell::RefCell;
        use std::rc::Rc;
        struct SquashSpy(Rc<RefCell<Vec<u64>>>);
        impl Observer for SquashSpy {
            fn on_squash(&mut self, seq: u64) {
                self.0.borrow_mut().push(seq);
            }
        }
        let seen: Rc<RefCell<Vec<u64>>> = Rc::new(RefCell::new(Vec::new()));
        let mut obs = Observers::with_sc_log();
        obs.register(Box::new(SquashSpy(Rc::clone(&seen))));
        let idx = obs.commit(rec(7));
        obs.squash(idx);
        assert_eq!(seen.borrow().as_slice(), &[7]);
        let log = obs.take_log();
        assert!(!log.records[idx].valid);

        // Plugins also hear squashes when the SC log is disabled: the
        // handle degrades to the commit seq.
        seen.borrow_mut().clear();
        let mut obs = Observers::none();
        obs.register(Box::new(SquashSpy(Rc::clone(&seen))));
        let handle = obs.commit(rec(9));
        assert_eq!(handle, 9);
        obs.squash(handle);
        assert_eq!(seen.borrow().as_slice(), &[9]);
    }

    #[test]
    fn sampling_fires_on_period_boundaries() {
        use std::cell::RefCell;
        use std::rc::Rc;
        struct Counter(Rc<RefCell<u32>>);
        impl Observer for Counter {
            fn on_sample(&mut self, _now: Cycle, _stats: &SimStats) {
                *self.0.borrow_mut() += 1;
            }
        }
        let fired: Rc<RefCell<u32>> = Rc::new(RefCell::new(0));
        let mut obs = Observers::none();
        obs.register(Box::new(Counter(Rc::clone(&fired))));
        obs.set_sample_period(100);
        let stats = SimStats::default();
        obs.maybe_sample(50, &stats); // below the first boundary
        obs.maybe_sample(100, &stats); // fires
        obs.maybe_sample(150, &stats); // below the next boundary
        obs.maybe_sample(450, &stats); // fires once, catches up past 450
        assert_eq!(*fired.borrow(), 2);
        assert_eq!(obs.next_sample, 500);
    }

    #[test]
    fn stats_tap_sees_final_stats() {
        let mut cycles_seen = 0;
        {
            let mut tap = StatsTap::new(|s: &SimStats| cycles_seen = s.cycles);
            let stats = SimStats { cycles: 42, ..SimStats::default() };
            tap.on_finish(&stats, &[]);
        }
        assert_eq!(cycles_seen, 42);
    }
}
