//! The public simulation API: `SimBuilder -> SimSession -> SimReport`
//! (DESIGN.md §3).
//!
//! Every binary, test, bench, and example constructs simulations
//! through this module — the engine itself is crate-private.  The
//! builder composes:
//!
//! * **protocol** — Tardis / MSI / Ackwise, instantiated behind the
//!   monomorphized [`ProtocolDispatch`](crate::proto::ProtocolDispatch)
//!   enum (no vtable on the hot loop);
//! * **core model** — in-order or out-of-order;
//! * **workload source** — inline [`Program`](crate::prog::Program)s,
//!   a named SPLASH-2-signature spec, raw synthetic-trace parameters,
//!   or the PJRT artifact runtime;
//! * **cache geometry** and any other [`SystemConfig`
//!   ](crate::config::SystemConfig) override;
//! * **instrumentation** — the pluggable [`Observer`] registry (SC
//!   log, stats taps, cycle-sampled progress, custom plugins).
//!
//! ```no_run
//! use tardis_dsm::api::SimBuilder;
//! use tardis_dsm::config::ProtocolKind;
//!
//! let report = SimBuilder::new()
//!     .protocol(ProtocolKind::Tardis)
//!     .cores(64)
//!     .named_workload("volrend")
//!     .progress_every(1_000_000)
//!     .run()
//!     .unwrap();
//! println!("{:.3} ops/cycle", report.stats.throughput());
//! ```

pub mod builder;
pub mod observer;
pub mod spec;

pub use builder::{default_trace_len, scaled_trace_len, SimBuilder, SimReport, SimSession};
pub use observer::{Observer, Observers, ProgressFormat, ProgressObserver, StatsTap};
pub use spec::SimSpec;
