//! The fluent `SimBuilder -> SimSession -> SimReport` pipeline — the
//! one way to construct and run a simulation.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::{
    Consistency, CoreModel, LeasePolicyKind, PdesMode, ProtocolKind, SocketInterleave,
    SystemConfig, TardisConfig,
};
use crate::obs::TraceRecording;
use crate::prog::checker::{AccessLog, CheckReport, Violation};
use crate::prog::{Program, Workload};
use crate::runtime::TraceRuntime;
use crate::sim::engine::Engine;
use crate::stats::SimStats;
use crate::trace::TraceParams;
use crate::types::Cycle;
use crate::workloads;

use super::observer::{Observer, Observers, ProgressObserver};

/// Default trace length per core count (mirrors aot.py CONFIGS and the
/// artifact manifest).
pub fn default_trace_len(n_cores: u32) -> u32 {
    match n_cores {
        0..=2 => 256,
        3..=4 => 512,
        5..=16 => 2048,
        17..=64 => 4096,
        _ => 1024,
    }
}

/// [`default_trace_len`] divided by a sweep scale-down factor, clamped
/// so 0 (or huge) factors stay safe.  The single source of truth for
/// the CLI and the experiment harness.
pub fn scaled_trace_len(n_cores: u32, scale_down: u32) -> u32 {
    (default_trace_len(n_cores) / scale_down.max(1)).max(64)
}

/// Where a session's workload comes from.
enum WorkloadSource {
    /// Nothing configured yet; `build` fails with a pointer to the
    /// source methods.
    Unset,
    /// Inline programs, one per core.
    Inline(Arc<Workload>),
    /// A named SPLASH-2-signature spec from [`crate::workloads`].
    Named(String),
    /// Raw synthetic-trace parameters.
    Synth(TraceParams),
}

/// Fluent builder for one simulation run.
///
/// ```no_run
/// use tardis_dsm::api::SimBuilder;
/// use tardis_dsm::config::ProtocolKind;
///
/// let report = SimBuilder::new()
///     .protocol(ProtocolKind::Tardis)
///     .cores(16)
///     .named_workload("fft")
///     .record_accesses(true)
///     .run()
///     .unwrap();
/// println!("{} cycles", report.stats.cycles);
/// ```
pub struct SimBuilder {
    cfg: SystemConfig,
    source: WorkloadSource,
    observers: Observers,
    trace_len: Option<u32>,
    runtime: Option<TraceRuntime>,
    threads: u32,
    pdes_mode: PdesMode,
    rebalance_every: u32,
    trace: bool,
    #[cfg(any(test, feature = "legacy-queue"))]
    legacy_queue: bool,
}

impl Default for SimBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SimBuilder {
    /// Paper Table V defaults (64 in-order cores, Tardis).
    pub fn new() -> Self {
        Self::from_config(SystemConfig::default())
    }

    /// Start from an existing configuration.
    pub fn from_config(cfg: SystemConfig) -> Self {
        Self {
            cfg,
            source: WorkloadSource::Unset,
            observers: Observers::none(),
            trace_len: None,
            runtime: None,
            threads: 1,
            pdes_mode: PdesMode::Epoch,
            rebalance_every: 0,
            trace: false,
            #[cfg(any(test, feature = "legacy-queue"))]
            legacy_queue: false,
        }
    }

    /// Small test system (tiny caches, short deadlock cap) with the
    /// SC-checker log enabled — the litmus/unit-test shape.
    pub fn small(n_cores: u32, protocol: ProtocolKind) -> Self {
        Self::from_config(SystemConfig::small(n_cores, protocol)).record_accesses(true)
    }

    // ------------------------------------------------- configuration

    /// Inspect the configuration assembled so far.
    pub fn cfg(&self) -> &SystemConfig {
        &self.cfg
    }

    pub fn protocol(mut self, protocol: ProtocolKind) -> Self {
        self.cfg.protocol = protocol;
        self
    }

    pub fn cores(mut self, n_cores: u32) -> Self {
        self.cfg.n_cores = n_cores;
        self
    }

    pub fn core_model(mut self, model: CoreModel) -> Self {
        self.cfg.core_model = model;
        self
    }

    /// Memory consistency model (default [`Consistency::Sc`]; `Tso`
    /// adds per-core store buffers with forwarding and switches the
    /// report's checker to the TSO rules).
    pub fn consistency(mut self, model: Consistency) -> Self {
        self.cfg.consistency = model;
        self
    }

    /// Tardis lease-assignment policy (the [`crate::proto::ts`]
    /// layer): static, dynamic, or predictive.
    pub fn lease_policy(mut self, policy: LeasePolicyKind) -> Self {
        self.cfg.tardis.lease_policy = policy;
        self
    }

    /// ccNUMA socket count (default 1 = the flat single-chip mesh).
    /// Must divide the core and memory-controller counts; checked at
    /// [`SimBuilder::build`].
    pub fn sockets(mut self, sockets: u32) -> Self {
        self.cfg.topology.sockets = sockets;
        self
    }

    /// Remote-to-local cost multiplier on inter-socket links
    /// (latency and bandwidth; no effect on a 1-socket system).
    pub fn numa_ratio(mut self, ratio: u32) -> Self {
        self.cfg.topology.numa_ratio = ratio;
        self
    }

    /// Address -> home-socket interleaving policy for the LLC-slice
    /// and memory-controller maps.
    pub fn interleave(mut self, policy: SocketInterleave) -> Self {
        self.cfg.topology.interleave = policy;
        self
    }

    /// Tweak the Tardis knobs (lease, self-increment, speculation...).
    pub fn tardis(mut self, f: impl FnOnce(&mut TardisConfig)) -> Self {
        f(&mut self.cfg.tardis);
        self
    }

    /// Escape hatch: arbitrary [`SystemConfig`] edits.
    pub fn configure(mut self, f: impl FnOnce(&mut SystemConfig)) -> Self {
        f(&mut self.cfg);
        self
    }

    /// Private L1 geometry override.
    pub fn l1_geometry(mut self, sets: u32, ways: u32) -> Self {
        self.cfg.l1_sets = sets;
        self.cfg.l1_ways = ways;
        self
    }

    /// Shared-LLC slice geometry override.
    pub fn l2_geometry(mut self, sets: u32, ways: u32) -> Self {
        self.cfg.l2_sets = sets;
        self.cfg.l2_ways = ways;
        self
    }

    pub fn max_cycles(mut self, cap: Cycle) -> Self {
        self.cfg.max_cycles = cap;
        self
    }

    /// Simulation worker threads (default 1 = the serial engine).
    /// With `n > 1` the run shards along tile boundaries and executes
    /// under the parallel PDES driver ([`crate::sim::pdes`]),
    /// producing bit-for-bit the same stats, access log, and per-core
    /// finish times as the serial run.  Any count up to the core count
    /// works — tiles split into balanced contiguous blocks, the last
    /// shards one tile smaller when the division is uneven.  Plugins
    /// and cycle sampling are serial-only (checked at
    /// [`SimBuilder::build`]).
    pub fn threads(mut self, n: u32) -> Self {
        self.threads = n;
        self
    }

    /// PDES synchronization mode for threaded runs (default
    /// [`PdesMode::Epoch`]): lockstep epochs, per-edge null messages,
    /// or automatic selection from the lookahead matrix.  No effect
    /// at `threads(1)`.
    pub fn pdes_mode(mut self, mode: PdesMode) -> Self {
        self.pdes_mode = mode;
        self
    }

    /// Deterministic load rebalancing for threaded runs: every `n`
    /// lookahead windows, repartition tiles by cumulative simulated
    /// event counts and migrate tile state between shards (0 = off,
    /// the default).  Purely simulated quantities drive the decision,
    /// so results stay bit-for-bit identical to the serial run.
    pub fn rebalance_every(mut self, n: u32) -> Self {
        self.rebalance_every = n;
        self
    }

    // ----------------------------------------------- workload source

    /// Inline workload (cloned).
    pub fn workload(self, w: &Workload) -> Self {
        self.workload_arc(Arc::new(w.clone()))
    }

    /// Inline workload, shared (the sweep path — no clone per point).
    pub fn workload_arc(mut self, w: Arc<Workload>) -> Self {
        self.source = WorkloadSource::Inline(w);
        self
    }

    /// Inline programs, one per core.
    pub fn programs(self, programs: Vec<Program>) -> Self {
        self.workload_arc(Arc::new(Workload::new(programs)))
    }

    /// One of the 12 named SPLASH-2-signature workloads
    /// ([`crate::workloads::all`]); materialized at `build` time.
    pub fn named_workload(mut self, name: impl Into<String>) -> Self {
        self.source = WorkloadSource::Named(name.into());
        self
    }

    /// Synthesize a trace from raw parameters at `build` time.
    pub fn synth_workload(mut self, params: TraceParams) -> Self {
        self.source = WorkloadSource::Synth(params);
        self
    }

    /// Trace length for named/synth sources (defaults to
    /// [`default_trace_len`] for the configured core count).
    pub fn trace_len(mut self, len: u32) -> Self {
        self.trace_len = Some(len);
        self
    }

    /// Resolve named/synth sources through a PJRT trace runtime
    /// (AOT-compiled artifacts); generation falls back to the
    /// bit-exact rust mirror when the artifact is missing.
    pub fn trace_runtime(mut self, rt: TraceRuntime) -> Self {
        self.runtime = Some(rt);
        self
    }

    // ---------------------------------------------- instrumentation

    /// Record every committed access for the SC witness checker
    /// (memory-heavy; off by default, on under [`SimBuilder::small`]).
    pub fn record_accesses(mut self, on: bool) -> Self {
        if on {
            self.observers.enable_sc_log();
        } else {
            self.observers.disable_sc_log();
        }
        self
    }

    /// Register an instrumentation plugin.
    pub fn observe(mut self, plugin: impl Observer + 'static) -> Self {
        self.observers.register(Box::new(plugin));
        self
    }

    /// Fire every observer's `on_sample` each `period` simulated
    /// cycles (0 disables sampling).
    pub fn sample_every(mut self, period: Cycle) -> Self {
        self.observers.set_sample_period(period);
        self
    }

    /// Built-in cycle-sampled progress reporter on stderr.
    pub fn progress_every(self, period: Cycle) -> Self {
        self.sample_every(period).observe(ProgressObserver::default())
    }

    /// Record the coherence flight recorder ([`crate::obs`]): protocol
    /// events land in [`SimReport::trace`], in the same canonical
    /// order under the serial engine and every PDES mode/thread count.
    /// Off by default — a disabled run's stats and SC log are
    /// byte-identical to a build without this call.
    pub fn trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    /// Run on the pre-calendar all-heap event queue (§Perf determinism
    /// regression tests and old-vs-new benchmarking; needs the
    /// `legacy-queue` feature outside the crate's own tests).
    #[cfg(any(test, feature = "legacy-queue"))]
    pub fn legacy_event_queue(mut self, on: bool) -> Self {
        self.legacy_queue = on;
        self
    }

    // ------------------------------------------------------- launch

    /// Resolve the workload and validate the configuration.
    pub fn build(mut self) -> Result<SimSession> {
        let n_cores = self.cfg.n_cores;
        let topo = self.cfg.topology;
        if topo.sockets == 0 {
            bail!("topology needs at least one socket");
        }
        if topo.sockets > 1 {
            if n_cores % topo.sockets != 0 {
                bail!(
                    "{} cores do not divide evenly into {} sockets",
                    n_cores,
                    topo.sockets
                );
            }
            if self.cfg.n_mcs % topo.sockets != 0 {
                bail!(
                    "{} memory controllers do not divide evenly into {} sockets",
                    self.cfg.n_mcs,
                    topo.sockets
                );
            }
            if topo.numa_ratio == 0 {
                bail!("numa_ratio must be >= 1");
            }
        }
        if self.threads == 0 {
            bail!("threads must be >= 1");
        }
        if self.threads > 1 {
            if self.threads > n_cores {
                bail!(
                    "{} threads exceed the {n_cores} cores (every shard owns at least one tile)",
                    self.threads
                );
            }
            if self.observers.has_plugins() {
                bail!("observer plugins are serial-only (they hold thread-local state); drop .observe(..) or use .threads(1)");
            }
            if self.observers.sampling_enabled() {
                bail!("cycle sampling is serial-only (samples would fire per-shard); drop .sample_every(..) or use .threads(1)");
            }
            #[cfg(any(test, feature = "legacy-queue"))]
            if self.legacy_queue {
                bail!("the legacy event queue is serial-only; drop .legacy_event_queue(true) or use .threads(1)");
            }
        }
        let trace_len = self.trace_len.unwrap_or_else(|| default_trace_len(n_cores));
        let workload: Arc<Workload> = match self.source {
            WorkloadSource::Unset => bail!(
                "SimBuilder: no workload source (use .workload / .programs / \
                 .named_workload / .synth_workload)"
            ),
            WorkloadSource::Inline(w) => w,
            WorkloadSource::Named(name) => {
                let spec = workloads::by_name(&name).ok_or_else(|| {
                    anyhow!(
                        "unknown workload {name:?} (known: {})",
                        workloads::all().iter().map(|w| w.name).collect::<Vec<_>>().join(", ")
                    )
                })?;
                Arc::new(crate::runtime::workload_or_synth(
                    &mut self.runtime,
                    n_cores,
                    trace_len,
                    &spec.params,
                ))
            }
            WorkloadSource::Synth(params) => Arc::new(crate::runtime::workload_or_synth(
                &mut self.runtime,
                n_cores,
                trace_len,
                &params,
            )),
        };
        if workload.n_cores() != n_cores {
            bail!(
                "workload provides {} cores but the configuration asks for {n_cores} \
                 (call .cores({}) to match)",
                workload.n_cores(),
                workload.n_cores()
            );
        }
        Ok(SimSession {
            cfg: self.cfg,
            workload,
            observers: self.observers,
            threads: self.threads,
            pdes_mode: self.pdes_mode,
            rebalance_every: self.rebalance_every,
            trace: self.trace,
            #[cfg(any(test, feature = "legacy-queue"))]
            legacy_queue: self.legacy_queue,
        })
    }

    /// `build()` + `run()` in one call.
    pub fn run(self) -> Result<SimReport> {
        self.build()?.run()
    }
}

/// A fully resolved simulation, ready to run.
pub struct SimSession {
    cfg: SystemConfig,
    workload: Arc<Workload>,
    observers: Observers,
    threads: u32,
    pdes_mode: PdesMode,
    rebalance_every: u32,
    trace: bool,
    #[cfg(any(test, feature = "legacy-queue"))]
    legacy_queue: bool,
}

impl std::fmt::Debug for SimSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SimSession")
            .field("protocol", &self.cfg.protocol)
            .field("n_cores", &self.cfg.n_cores)
            .field("total_ops", &self.workload.total_ops())
            .field("observers", &self.observers)
            .finish_non_exhaustive()
    }
}

impl SimSession {
    pub fn cfg(&self) -> &SystemConfig {
        &self.cfg
    }

    pub fn workload(&self) -> &Arc<Workload> {
        &self.workload
    }

    /// Apply the (test/feature-gated) event-queue override.
    #[cfg(any(test, feature = "legacy-queue"))]
    fn configure_queue(legacy: bool, eng: &mut Engine) {
        if legacy {
            eng.set_legacy_queue();
        }
    }

    /// Run to completion.
    pub fn run(self) -> Result<SimReport> {
        let t0 = Instant::now();
        let consistency = self.cfg.consistency;
        if self.threads > 1 {
            let record_log = self.observers.sc_log_enabled();
            let res = crate::sim::pdes::run_parallel(
                self.cfg,
                &self.workload,
                self.threads,
                record_log,
                self.trace,
                self.pdes_mode,
                self.rebalance_every,
            )?;
            return Ok(SimReport {
                stats: res.stats,
                log: res.log,
                core_finish: res.core_finish,
                trace: res.trace,
                consistency,
                elapsed: t0.elapsed(),
            });
        }
        #[allow(unused_mut)]
        let mut eng = Engine::build(self.cfg, &self.workload, self.observers);
        if self.trace {
            eng.enable_trace();
        }
        #[cfg(any(test, feature = "legacy-queue"))]
        Self::configure_queue(self.legacy_queue, &mut eng);
        let res = eng.run()?;
        Ok(SimReport {
            stats: res.stats,
            log: res.log,
            core_finish: res.core_finish,
            trace: res.trace,
            consistency,
            elapsed: t0.elapsed(),
        })
    }
}

/// Result of a completed simulation.
pub struct SimReport {
    pub stats: SimStats,
    /// Consistency-checker access log (empty unless
    /// `.record_accesses(true)`).
    pub log: AccessLog,
    /// Per-core completion cycles.
    pub core_finish: Vec<Cycle>,
    /// Flight-recorder trace (empty unless `.trace(true)`).
    pub trace: TraceRecording,
    /// Consistency model the run enforced (selects the checker rules).
    pub consistency: Consistency,
    /// Host wall-clock time of the run.
    pub elapsed: Duration,
}

impl SimReport {
    /// Run the sequential-consistency witness checker over the log.
    ///
    /// Only meaningful for runs configured with [`Consistency::Sc`]:
    /// a TSO run's log legitimately reorders store commits past later
    /// loads, which this checker cannot see as program order — use
    /// [`SimReport::check_consistency`] to apply the rules matching
    /// the run's model.
    pub fn check_sc(&self) -> std::result::Result<CheckReport, Violation> {
        crate::prog::checker::check(&self.log)
    }

    /// Run the witness checker matching the consistency model this
    /// run was configured with (SC rules under `Sc`, the relaxed
    /// store-buffer rules under `Tso`).
    pub fn check_consistency(&self) -> std::result::Result<CheckReport, Violation> {
        crate::prog::checker::check_model(&self.log, self.consistency)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prog::{load, store};
    use crate::types::SHARED_BASE;

    fn two_core_programs() -> Vec<Program> {
        vec![
            Program::new(vec![store(SHARED_BASE, 7), load(SHARED_BASE)]),
            Program::new(vec![load(SHARED_BASE)]),
        ]
    }

    #[test]
    fn builder_runs_inline_programs() {
        for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            let report = SimBuilder::small(2, protocol)
                .programs(two_core_programs())
                .run()
                .unwrap();
            assert_eq!(report.core_finish.len(), 2);
            assert!(report.stats.cycles > 0);
            assert_eq!(report.stats.memops, 3);
            report.check_sc().unwrap();
        }
    }

    #[test]
    fn builder_requires_a_workload() {
        let err = SimBuilder::new().build().unwrap_err().to_string();
        assert!(err.contains("no workload source"), "{err}");
    }

    #[test]
    fn builder_rejects_core_count_mismatch() {
        let err = SimBuilder::small(4, ProtocolKind::Tardis)
            .programs(two_core_programs())
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("2 cores"), "{err}");
    }

    #[test]
    fn builder_rejects_unknown_named_workload() {
        let err = SimBuilder::small(4, ProtocolKind::Tardis)
            .named_workload("nope")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown workload"), "{err}");
    }

    #[test]
    fn named_workload_resolves_via_synth_mirror() {
        let session = SimBuilder::from_config(SystemConfig::small(4, ProtocolKind::Msi))
            .named_workload("fft")
            .trace_len(64)
            .build()
            .unwrap();
        assert_eq!(session.workload().n_cores(), 4);
        assert_eq!(session.workload().total_ops(), 4 * 64);
        let report = session.run().unwrap();
        assert!(report.stats.cycles > 0);
        // No SC log requested -> empty log.
        assert!(report.log.is_empty());
    }

    #[test]
    fn record_accesses_toggles_the_log() {
        let on = SimBuilder::small(2, ProtocolKind::Tardis)
            .programs(two_core_programs())
            .run()
            .unwrap();
        assert!(!on.log.is_empty());
        let off = SimBuilder::small(2, ProtocolKind::Tardis)
            .record_accesses(false)
            .programs(two_core_programs())
            .run()
            .unwrap();
        assert!(off.log.is_empty());
        assert_eq!(on.stats.cycles, off.stats.cycles, "logging must not change timing");
    }

    #[test]
    fn observers_see_commits_and_finish() {
        use std::cell::RefCell;
        use std::rc::Rc;
        #[derive(Default)]
        struct Spy {
            commits: u64,
            finished: bool,
        }
        struct SpyObs(Rc<RefCell<Spy>>);
        impl Observer for SpyObs {
            fn on_commit(&mut self, _rec: &crate::prog::checker::LogRecord) {
                self.0.borrow_mut().commits += 1;
            }
            fn on_finish(&mut self, _stats: &SimStats, _core_finish: &[Cycle]) {
                self.0.borrow_mut().finished = true;
            }
        }
        let spy: Rc<RefCell<Spy>> = Rc::default();
        let report = SimBuilder::small(2, ProtocolKind::Msi)
            .record_accesses(false)
            .programs(two_core_programs())
            .observe(SpyObs(Rc::clone(&spy)))
            .run()
            .unwrap();
        // Plugins fire even with the SC log disabled; sync microcode
        // may add accesses beyond the 3 trace ops.
        assert!(spy.borrow().commits >= report.stats.memops);
        assert!(spy.borrow().finished);
    }

    #[test]
    fn synth_workload_source_runs() {
        let report = SimBuilder::small(4, ProtocolKind::Tardis)
            .synth_workload(TraceParams::default())
            .trace_len(128)
            .run()
            .unwrap();
        assert!(report.stats.memops > 0);
        report.check_sc().unwrap();
    }

    #[test]
    fn threads_validation_catches_bad_combinations() {
        let base = || SimBuilder::small(4, ProtocolKind::Tardis).named_workload("fft").trace_len(64);
        let err = base().threads(0).build().unwrap_err().to_string();
        assert!(err.contains("threads must be >= 1"), "{err}");
        let err = base().threads(5).build().unwrap_err().to_string();
        assert!(err.contains("exceed the 4 cores"), "{err}");
        // Uneven counts are fine now: 4 cores over 3 threads.
        base().threads(3).build().unwrap();
        let err = base()
            .observe(ProgressObserver::default())
            .threads(2)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("serial-only"), "{err}");
        let err = base().sample_every(100).threads(2).build().unwrap_err().to_string();
        assert!(err.contains("sampling is serial-only"), "{err}");
        let err = base().legacy_event_queue(true).threads(2).build().unwrap_err().to_string();
        assert!(err.contains("legacy event queue is serial-only"), "{err}");
        base().threads(2).build().unwrap();
    }

    #[test]
    fn threaded_run_matches_serial_through_the_builder() {
        let mk = |threads: u32, mode: PdesMode| {
            SimBuilder::small(4, ProtocolKind::Tardis)
                .named_workload("lu-c")
                .trace_len(96)
                .threads(threads)
                .pdes_mode(mode)
                .run()
                .unwrap()
        };
        let serial = mk(1, PdesMode::Epoch);
        for mode in [PdesMode::Epoch, PdesMode::NullMsg] {
            let par = mk(4, mode);
            assert_eq!(par.stats, serial.stats);
            assert_eq!(par.log.records, serial.log.records);
            assert_eq!(par.core_finish, serial.core_finish);
            par.check_sc().unwrap();
            assert_eq!(par.stats.parallel.threads, 4);
        }
    }

    #[test]
    fn default_trace_len_matches_aot_configs() {
        assert_eq!(default_trace_len(2), 256);
        assert_eq!(default_trace_len(4), 512);
        assert_eq!(default_trace_len(16), 2048);
        assert_eq!(default_trace_len(64), 4096);
        assert_eq!(default_trace_len(256), 1024);
    }
}
