//! The discrete-event simulation engine: owns the event queue, cores,
//! protocol, mesh, DRAM, and memory image; runs a workload to
//! completion and produces [`SimStats`] plus whatever the attached
//! [`Observers`] collected.
//!
//! The engine is crate-private: construct runs through
//! [`crate::api::SimBuilder`].  The coherence protocol is stored as a
//! monomorphized [`ProtocolDispatch`] enum, so the per-event dispatch
//! below is a match over concrete types rather than a `Box<dyn
//! Coherence>` vtable call (§Perf; `benches/engine_hot.rs`).

use anyhow::{bail, Result};

use crate::api::observer::Observers;
use crate::config::{CoreModel, SystemConfig};
use crate::core::{inorder::InOrderCore, ooo::OooCore, CoreAction, CoreEnv, CoreUnit};
use crate::hashing::FxHashMap;
use crate::mem::Dram;
use crate::net::{Message, MsgClass, MsgKind, Node, Topology};
use crate::prog::checker::AccessLog;
use crate::prog::Workload;
use crate::proto::{Coherence, Completion, ProtoCtx, ProtocolDispatch};
use crate::stats::SimStats;
use crate::types::{Cycle, LineAddr};

use super::event::{Event, EventQueue};

/// Per-(src, dst) channel ordering: the NoC delivers messages between
/// any two endpoints in send order (ordered virtual channels, as
/// Graphite assumes).  Without this, 1-flit control messages overtake
/// 5-flit data messages and classic protocol races appear (an Inv
/// passing the DataS it chases, a WbReq passing the ExRep that created
/// the owner).
///
/// Stored as a dense `n_nodes x n_nodes` matrix over the flat node
/// index space (cores, then LLC slices, then memory controllers):
/// O(1) un-hashed lookup on every delivery, and — unlike the old
/// per-pair `HashMap`, which grew with every channel ever used and
/// was never pruned — memory is fixed at construction (§Perf; ~2 MiB
/// at 256 cores).
#[derive(Debug)]
struct ChannelClock {
    clocks: Vec<Cycle>,
    n_cores: u32,
    n_nodes: u32,
}

impl ChannelClock {
    fn new(n_cores: u32, n_mcs: u32) -> Self {
        let n_nodes = 2 * n_cores + n_mcs;
        Self { clocks: vec![0; (n_nodes as usize) * (n_nodes as usize)], n_cores, n_nodes }
    }

    #[inline]
    fn node_index(&self, n: Node) -> u32 {
        match n {
            Node::Core(c) => c,
            Node::Slice(s) => self.n_cores + s,
            Node::Mc(m) => 2 * self.n_cores + m,
        }
    }

    /// Mutable earliest-delivery slot for the (src, dst) channel.
    #[inline]
    fn slot(&mut self, src: Node, dst: Node) -> &mut Cycle {
        let i = self.node_index(src) as usize * self.n_nodes as usize
            + self.node_index(dst) as usize;
        &mut self.clocks[i]
    }
}

/// Result of a completed simulation.
pub struct SimResult {
    pub stats: SimStats,
    pub log: AccessLog,
    /// Per-core completion cycles.
    pub core_finish: Vec<Cycle>,
}

pub(crate) struct Engine {
    cfg: SystemConfig,
    queue: EventQueue,
    topology: Topology,
    dram: Dram,
    /// DRAM backing image (line values; absent = 0).  Fx-hashed: the
    /// SipHash default cost showed up in every DRAM endpoint access.
    memory: FxHashMap<LineAddr, u64>,
    proto: ProtocolDispatch,
    cores: Vec<CoreUnit>,
    obs: Observers,
    stats: SimStats,
    seq: u64,
    finished: u32,
    channel_clock: ChannelClock,
    /// Reused per-dispatch scratch buffers (no allocation on the hot
    /// path — §Perf).
    scratch_msgs: Vec<Message>,
    scratch_comps: Vec<Completion>,
}

impl Engine {
    pub(crate) fn build(cfg: SystemConfig, workload: &Workload, obs: Observers) -> Self {
        assert_eq!(
            cfg.n_cores,
            workload.n_cores(),
            "workload core count must match the system configuration"
        );
        if cfg.topology.sockets > 1 {
            assert_eq!(
                cfg.n_cores % cfg.topology.sockets,
                0,
                "core count must divide evenly into sockets (SimBuilder validates this)"
            );
        }
        let proto = ProtocolDispatch::new(&cfg);
        let cores = (0..cfg.n_cores)
            .map(|id| match cfg.core_model {
                CoreModel::InOrder => CoreUnit::InOrder(InOrderCore::new(id, workload)),
                CoreModel::OutOfOrder => CoreUnit::Ooo(OooCore::new(id, workload)),
            })
            .collect();
        Self {
            topology: Topology::new(&cfg),
            dram: Dram::new(cfg.n_mcs, cfg.dram_latency, cfg.dram_service_cycles),
            queue: EventQueue::new(),
            memory: FxHashMap::default(),
            proto,
            cores,
            obs,
            stats: SimStats { n_cores: cfg.n_cores, ..SimStats::default() },
            seq: 0,
            finished: 0,
            channel_clock: ChannelClock::new(cfg.n_cores, cfg.n_mcs),
            scratch_msgs: Vec::with_capacity(16),
            scratch_comps: Vec::with_capacity(16),
            cfg,
        }
    }

    /// Swap in the pre-calendar all-heap event queue (determinism
    /// regression tests and old-vs-new benchmarking only; must be
    /// called before [`Engine::run`] schedules anything).
    #[cfg(any(test, feature = "legacy-queue"))]
    pub(crate) fn set_legacy_queue(&mut self) {
        assert!(self.queue.is_empty(), "queue already in use");
        self.queue = EventQueue::legacy_heap();
    }

    /// Run to completion.
    pub(crate) fn run(mut self) -> Result<SimResult> {
        for c in 0..self.cfg.n_cores {
            self.cores[c as usize].set_next_wake(0);
            self.queue.push(0, Event::CoreWake(c));
        }
        let mut last_now = 0;
        while let Some((now, ev)) = self.queue.pop() {
            debug_assert!(now >= last_now, "time went backwards");
            last_now = now;
            self.stats.events += 1;
            self.obs.maybe_sample(now, &self.stats);
            if now > self.cfg.max_cycles {
                let dump: Vec<String> = self
                    .cores
                    .iter()
                    .filter(|c| c.finished_at().is_none())
                    .map(|c| c.state_string())
                    .collect();
                bail!(
                    "simulation exceeded max_cycles={} (livelock?)\n{}",
                    self.cfg.max_cycles,
                    dump.join("\n")
                );
            }
            self.dispatch(now, ev);
            if self.finished == self.cfg.n_cores {
                break;
            }
        }
        if self.finished != self.cfg.n_cores {
            let dump: Vec<String> = self
                .cores
                .iter()
                .filter(|c| c.finished_at().is_none())
                .map(|c| c.state_string())
                .collect();
            bail!(
                "deadlock: event queue drained with {}/{} cores finished at cycle {last_now}\n{}",
                self.finished,
                self.cfg.n_cores,
                dump.join("\n")
            );
        }
        let core_finish: Vec<Cycle> =
            self.cores.iter().map(|c| c.finished_at().unwrap_or(last_now)).collect();
        self.stats.cycles = core_finish.iter().copied().max().unwrap_or(last_now);
        self.obs.finish(&self.stats, &core_finish);
        let log = self.obs.take_log();
        Ok(SimResult { stats: self.stats, log, core_finish })
    }

    fn dispatch(&mut self, now: Cycle, ev: Event) {
        let mut msgs = std::mem::take(&mut self.scratch_msgs);
        let mut comps = std::mem::take(&mut self.scratch_comps);
        msgs.clear();
        comps.clear();

        match ev {
            Event::CoreWake(c) => {
                // Drop stale wakes (the core rescheduled since).
                if self.cores[c as usize].next_wake() != Some(now) {
                    self.scratch_msgs = msgs;
                    self.scratch_comps = comps;
                    return; // stale wake
                }
                let mut pctx = ProtoCtx {
                    now,
                    msgs: &mut msgs,
                    completions: &mut comps,
                    stats: &mut self.stats,
                };
                let mut env = CoreEnv {
                    proto: &mut self.proto,
                    pctx: &mut pctx,
                    obs: &mut self.obs,
                    seq: &mut self.seq,
                    n_cores: self.cfg.n_cores,
                    spin_poll: self.cfg.spin_poll_cycles,
                    rollback_penalty: self.cfg.rollback_penalty,
                    ooo_window: self.cfg.ooo_window,
                    consistency: self.cfg.consistency,
                    sb_entries: self.cfg.sb_entries,
                };
                let action = self.cores[c as usize].step(now, &mut env);
                drop(env);
                self.apply_action(c, action);
            }
            Event::Deliver(msg) => match msg.dst {
                Node::Mc(mc) => self.handle_dram(now, mc, msg, &mut msgs),
                _ => {
                    let mut pctx = ProtoCtx {
                        now,
                        msgs: &mut msgs,
                        completions: &mut comps,
                        stats: &mut self.stats,
                    };
                    self.proto.on_message(msg, &mut pctx);
                }
            },
        }

        // Drain side effects until quiescent: route messages, dispatch
        // completions (which may trigger more of both).
        loop {
            for m in msgs.drain(..) {
                self.route(now, m);
            }
            if comps.is_empty() {
                break;
            }
            let batch: Vec<Completion> = comps.drain(..).collect();
            for comp in batch {
                let mut pctx = ProtoCtx {
                    now,
                    msgs: &mut msgs,
                    completions: &mut comps,
                    stats: &mut self.stats,
                };
                let mut env = CoreEnv {
                    proto: &mut self.proto,
                    pctx: &mut pctx,
                    obs: &mut self.obs,
                    seq: &mut self.seq,
                    n_cores: self.cfg.n_cores,
                    spin_poll: self.cfg.spin_poll_cycles,
                    rollback_penalty: self.cfg.rollback_penalty,
                    ooo_window: self.cfg.ooo_window,
                    consistency: self.cfg.consistency,
                    sb_entries: self.cfg.sb_entries,
                };
                let action = self.cores[comp.core as usize].on_completion(&comp, now, &mut env);
                drop(env);
                self.apply_action(comp.core, action);
            }
        }
        self.scratch_msgs = msgs;
        self.scratch_comps = comps;
    }

    fn apply_action(&mut self, core: u32, action: CoreAction) {
        match action {
            CoreAction::WakeAt(t) => self.queue.push(t, Event::CoreWake(core)),
            CoreAction::Park => {}
            CoreAction::Finished => self.finished += 1,
        }
    }

    /// Send a message departing at `depart`: resolve its route through
    /// the topology, account traffic (by class, and by the intra- vs
    /// inter-socket split), add fabric latency, enqueue.
    fn route(&mut self, depart: Cycle, msg: Message) {
        let info = self.topology.route(&msg);
        if info.flits > 0 {
            let t = &mut self.stats.traffic;
            match msg.kind.class() {
                MsgClass::Request => t.request_flits += info.flits,
                MsgClass::Data => t.data_flits += info.flits,
                MsgClass::Control => t.control_flits += info.flits,
                MsgClass::Renew => t.renew_flits += info.flits,
                MsgClass::Invalidation => t.invalidation_flits += info.flits,
                MsgClass::Dram => t.dram_flits += info.flits,
            }
            let sk = &mut self.stats.socket;
            if info.socket_hops == 0 {
                sk.intra_msgs += 1;
                sk.intra_hops += info.mesh_hops as u64;
            } else {
                sk.inter_msgs += 1;
                sk.inter_hops += info.mesh_hops as u64;
                sk.link_crossings += info.socket_hops as u64;
                sk.inter_flits += info.flits;
            }
        }
        self.deliver_at(depart + info.latency, msg);
    }

    /// Enqueue a delivery, enforcing per-channel FIFO order.
    fn deliver_at(&mut self, t: Cycle, msg: Message) {
        let slot = self.channel_clock.slot(msg.src, msg.dst);
        let t = t.max(*slot);
        *slot = t;
        self.queue.push(t, Event::Deliver(msg));
    }

    /// Memory-controller endpoint: model DRAM occupancy + latency and
    /// answer reads from / apply writes to the backing image.
    fn handle_dram(&mut self, now: Cycle, mc: u32, msg: Message, msgs: &mut Vec<Message>) {
        match msg.kind {
            MsgKind::DramLdReq => {
                let done = self.dram.access(mc, now);
                let value = self.memory.get(&msg.addr).copied().unwrap_or(0);
                let reply = Message {
                    src: Node::Mc(mc),
                    dst: msg.src,
                    addr: msg.addr,
                    requester: msg.requester,
                    kind: MsgKind::DramLdRep { value },
                };
                // Reply leaves the controller when the access completes.
                self.route(done, reply);
            }
            MsgKind::DramStReq { value } => {
                let _done = self.dram.access(mc, now);
                self.memory.insert(msg.addr, value);
            }
            other => panic!("MC got unexpected message {other:?}"),
        }
        let _ = msgs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SimBuilder;
    use crate::config::ProtocolKind;
    use crate::prog::{load, store, Program};
    use crate::testutil::Rng;

    fn tiny(protocol: ProtocolKind) -> (SystemConfig, Workload) {
        let w = Workload::new(vec![
            Program::new(vec![store(crate::types::SHARED_BASE, 7), load(crate::types::SHARED_BASE)]),
            Program::new(vec![load(crate::types::SHARED_BASE)]),
        ]);
        (SystemConfig::small(2, protocol), w)
    }

    fn tiny_engine(protocol: ProtocolKind) -> Engine {
        let (cfg, w) = tiny(protocol);
        Engine::build(cfg, &w, Observers::with_sc_log())
    }

    #[test]
    fn runs_all_protocols_to_completion() {
        for p in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            let (cfg, w) = tiny(p);
            let res = SimBuilder::from_config(cfg).workload(&w).run().unwrap();
            assert_eq!(res.core_finish.len(), 2);
            assert!(res.stats.cycles > 0);
            assert_eq!(res.stats.memops, 3);
        }
    }

    #[test]
    fn channel_fifo_prevents_overtaking() {
        // A 1-flit message sent after a 5-flit message on the same
        // channel must not arrive earlier.
        let mut eng = tiny_engine(ProtocolKind::Msi);
        let data = Message {
            src: Node::Slice(0),
            dst: Node::Core(1),
            addr: 0,
            requester: 1,
            kind: MsgKind::DataS { value: 1 },
        };
        let ctrl = Message { kind: MsgKind::Inv, ..data };
        eng.route(100, data);
        eng.route(100, ctrl);
        // Drain the queue; the Inv must be delivered at or after the
        // DataS despite its smaller serialization latency.
        let mut deliveries = Vec::new();
        while let Some((t, ev)) = eng.queue.pop() {
            if let Event::Deliver(m) = ev {
                deliveries.push((t, m.kind));
            }
        }
        assert_eq!(deliveries.len(), 2);
        assert!(matches!(deliveries[0].1, MsgKind::DataS { .. }));
        assert!(matches!(deliveries[1].1, MsgKind::Inv));
        assert!(deliveries[1].0 >= deliveries[0].0);
    }

    #[test]
    fn channel_fifo_holds_under_random_send_order() {
        // Regression for the ChannelClock invariant: across many
        // channels and randomized send times, a 1-flit control message
        // enqueued after a 5-flit data message on the same (src, dst)
        // pair never arrives first, and every channel's deliveries
        // preserve send order.
        let mut rng = Rng::new(0xC1_0C);
        for _trial in 0..20 {
            let mut eng = tiny_engine(ProtocolKind::Msi);
            // (channel id, send index) in send order, per channel.
            let mut sent: Vec<(usize, u32)> = Vec::new();
            let channels =
                [(Node::Slice(0), Node::Core(0)), (Node::Slice(0), Node::Core(1)), (Node::Slice(1), Node::Core(0))];
            let mut now = 0;
            let mut per_channel_seq = [0u32; 3];
            for _ in 0..40 {
                now += rng.below(5);
                let ch = rng.below(3) as usize;
                let (src, dst) = channels[ch];
                // Alternate big data messages and tiny control ones so
                // later control messages chase earlier data messages.
                let kind = if rng.chance(1, 2) {
                    MsgKind::DataS { value: 1 }
                } else {
                    MsgKind::Inv
                };
                // Encode (channel, seq) in the address for recovery.
                let seq = per_channel_seq[ch];
                per_channel_seq[ch] += 1;
                let msg = Message {
                    src,
                    dst,
                    addr: (ch as u64) << 32 | seq as u64,
                    requester: 0,
                    kind,
                };
                eng.route(now, msg);
                sent.push((ch, seq));
            }
            // Drain and check per-channel arrival order and times.
            let mut last_seen: [(i64, Cycle); 3] = [(-1, 0); 3];
            while let Some((t, ev)) = eng.queue.pop() {
                if let Event::Deliver(m) = ev {
                    let ch = (m.addr >> 32) as usize;
                    let seq = (m.addr & 0xFFFF_FFFF) as i64;
                    let (prev_seq, prev_t) = last_seen[ch];
                    assert!(
                        seq > prev_seq,
                        "channel {ch}: message {seq} overtook {prev_seq}"
                    );
                    assert!(
                        t >= prev_t,
                        "channel {ch}: delivery time went backwards ({t} < {prev_t})"
                    );
                    last_seen[ch] = (seq, t);
                }
            }
            for (ch, &count) in per_channel_seq.iter().enumerate() {
                assert_eq!(
                    last_seen[ch].0 + 1,
                    count as i64,
                    "channel {ch} lost messages"
                );
            }
        }
    }

    #[test]
    fn traffic_accounted_by_class() {
        let mut eng = tiny_engine(ProtocolKind::Msi);
        let data = Message {
            src: Node::Slice(0),
            dst: Node::Core(1),
            addr: 0,
            requester: 1,
            kind: MsgKind::DataS { value: 1 },
        };
        eng.route(0, data);
        assert_eq!(eng.stats.traffic.data_flits, 5);
        let inv = Message { kind: MsgKind::Inv, ..data };
        eng.route(0, inv);
        assert_eq!(eng.stats.traffic.invalidation_flits, 1);
    }

    #[test]
    fn same_tile_messages_are_free() {
        let mut eng = tiny_engine(ProtocolKind::Msi);
        let local = Message {
            src: Node::Core(0),
            dst: Node::Slice(0),
            addr: 0,
            requester: 0,
            kind: MsgKind::GetS,
        };
        eng.route(0, local);
        assert_eq!(eng.stats.traffic.total(), 0);
    }

    #[test]
    fn dram_image_round_trips() {
        let mut eng = tiny_engine(ProtocolKind::Msi);
        let st = Message {
            src: Node::Slice(0),
            dst: Node::Mc(0),
            addr: 42,
            requester: 0,
            kind: MsgKind::DramStReq { value: 1234 },
        };
        let mut msgs = Vec::new();
        eng.handle_dram(0, 0, st, &mut msgs);
        assert_eq!(eng.memory.get(&42), Some(&1234));
        let ld = Message {
            src: Node::Slice(0),
            dst: Node::Mc(0),
            addr: 42,
            requester: 0,
            kind: MsgKind::DramLdReq,
        };
        eng.handle_dram(10, 0, ld, &mut msgs);
        // The reply is in the queue with the stored value.
        let mut found = false;
        while let Some((_, ev)) = eng.queue.pop() {
            if let Event::Deliver(m) = ev {
                if let MsgKind::DramLdRep { value } = m.kind {
                    assert_eq!(value, 1234);
                    found = true;
                }
            }
        }
        assert!(found, "DRAM load reply missing");
    }

    #[test]
    fn socket_split_accounts_cross_socket_messages() {
        let (mut cfg, w) = tiny(ProtocolKind::Msi);
        cfg.topology.sockets = 2;
        cfg.topology.numa_ratio = 4;
        let mut eng = Engine::build(cfg, &w, Observers::none());
        // 2 cores on 2 sockets: slice 0 and core 1 sit on different
        // sockets, slice 0 and core 0 share a tile.
        let remote = Message {
            src: Node::Slice(0),
            dst: Node::Core(1),
            addr: 0,
            requester: 1,
            kind: MsgKind::DataS { value: 1 },
        };
        eng.route(0, remote);
        assert_eq!(eng.stats.socket.inter_msgs, 1);
        assert_eq!(eng.stats.socket.link_crossings, 1);
        assert_eq!(eng.stats.socket.inter_flits, 5);
        assert_eq!(eng.stats.traffic.data_flits, 5, "class accounting unchanged");
        // Same-tile messages skip the network entirely — no split
        // entry, just like the flat free-local rule.
        let local = Message { dst: Node::Core(0), requester: 0, ..remote };
        eng.route(0, local);
        assert_eq!(eng.stats.socket.intra_msgs, 0);
        assert_eq!(eng.stats.socket.total_msgs(), 1);
    }

    #[test]
    fn flat_runs_report_all_traffic_as_intra_socket() {
        let (cfg, w) = tiny(ProtocolKind::Tardis);
        let res = SimBuilder::from_config(cfg).workload(&w).run().unwrap();
        assert!(res.stats.socket.intra_msgs > 0);
        assert_eq!(res.stats.socket.inter_msgs, 0);
        assert_eq!(res.stats.socket.link_crossings, 0);
    }

    #[test]
    fn stats_cycles_is_last_finisher() {
        let (cfg, w) = tiny(ProtocolKind::Tardis);
        let res = SimBuilder::from_config(cfg).workload(&w).run().unwrap();
        assert_eq!(res.stats.cycles, *res.core_finish.iter().max().unwrap());
    }

    #[test]
    fn mismatched_core_count_panics() {
        let (cfg, w) = tiny(ProtocolKind::Tardis);
        let mut cfg = cfg;
        cfg.n_cores = 4; // workload has 2
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            Engine::build(cfg, &w, Observers::none())
        }))
        .is_err());
    }

    /// The §Perf determinism regression: the calendar queue must
    /// reproduce the legacy heap's execution bit-for-bit — identical
    /// stats (including the event count), access log, and per-core
    /// finish times — for every protocol and both core models.
    #[test]
    fn calendar_queue_matches_legacy_heap_bit_for_bit() {
        let spec = crate::workloads::by_name("fft").unwrap();
        let w = crate::trace::synth_workload(&spec.params, 8, 256);
        for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
                let run = |legacy: bool| {
                    SimBuilder::from_config(SystemConfig::small(8, protocol))
                        .core_model(model)
                        .record_accesses(true)
                        .workload(&w)
                        .legacy_event_queue(legacy)
                        .run()
                        .unwrap()
                };
                let new = run(false);
                let old = run(true);
                assert_eq!(new.stats, old.stats, "{protocol:?}/{model:?} stats diverged");
                assert_eq!(
                    new.log.records, old.log.records,
                    "{protocol:?}/{model:?} access logs diverged"
                );
                assert_eq!(new.core_finish, old.core_finish);
                assert!(new.stats.events > 0);
            }
        }
    }
}
