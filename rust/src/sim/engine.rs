//! The discrete-event simulation engine: owns the event queue, cores,
//! protocol, mesh, DRAM, and memory image; runs a workload to
//! completion and produces [`SimStats`] plus whatever the attached
//! [`Observers`] collected.
//!
//! The engine is crate-private: construct runs through
//! [`crate::api::SimBuilder`].  The coherence protocol is stored as a
//! monomorphized [`ProtocolDispatch`] enum, so the per-event dispatch
//! below is a match over concrete types rather than a `Box<dyn
//! Coherence>` vtable call (§Perf; `benches/engine_hot.rs`).

use anyhow::{bail, Result};

use crate::api::observer::Observers;
use crate::config::{CoreModel, SystemConfig};
use crate::core::{inorder::InOrderCore, ooo::OooCore, CoreAction, CoreEnv, CoreUnit};
use crate::hashing::FxHashMap;
use crate::mem::{Dram, SliceMap};
use crate::net::{Message, MsgClass, MsgKind, Node, Topology};
use crate::obs::{TraceBuf, TraceEvent, TraceRecording};
use crate::prog::checker::AccessLog;
use crate::prog::Workload;
use crate::proto::{Coherence, Completion, ProtoCtx, ProtocolDispatch, TileProtoState};
use crate::stats::SimStats;
use crate::types::{Cycle, LineAddr};

use super::event::{Event, EventQueue, PushKey};

/// Which shard of a (possibly parallel) run this engine instance is.
/// The serial path is `solo()`: one shard owning every node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct ShardSpec {
    pub index: u32,
    pub count: u32,
}

impl ShardSpec {
    pub(crate) fn solo() -> Self {
        Self { index: 0, count: 1 }
    }
}

/// A contiguous assignment of tiles to shards: shard `s` owns tiles
/// `[starts[s], starts[s+1])`.  The unit of PDES ownership is the
/// *tile* (the unit both fabrics route by), so a shard owns a run of
/// cores, their co-located LLC/TM slices, and the memory controllers
/// homed on its tiles.  Two nodes on different shards always sit on
/// different tiles, so every cross-shard message pays >= 1 mesh hop —
/// the lookahead is never 0.  Contiguity is what keeps that true
/// under rebalancing: the dynamic load balancer only moves the block
/// boundaries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct TilePartition {
    /// `count + 1` block boundaries; `starts[0] == 0`, last == tiles.
    pub(crate) starts: Vec<u32>,
}

impl TilePartition {
    /// Even contiguous blocks; when `count` does not divide `n_tiles`
    /// the first `n_tiles % count` shards take one extra tile (so the
    /// last shards are the smaller ones).  For dividing counts this is
    /// exactly the fixed PR-8 split `tile / (n_tiles / count)`.
    pub(crate) fn balanced(n_tiles: u32, count: u32) -> Self {
        assert!(count >= 1 && count <= n_tiles, "need 1 <= shards <= tiles");
        let base = n_tiles / count;
        let rem = n_tiles % count;
        let mut starts = Vec::with_capacity(count as usize + 1);
        let mut at = 0;
        starts.push(0);
        for s in 0..count {
            at += base + u32::from(s < rem);
            starts.push(at);
        }
        Self { starts }
    }

    /// Repartition from cumulative per-tile event counts: block
    /// boundaries land where the weight prefix sums cross the even
    /// per-shard share, clamped so every shard keeps at least one
    /// tile.  A pure function of *simulated* counts — identical on
    /// every host and at every thread schedule, which is what keeps
    /// rebalancing decisions deterministic (DESIGN.md §11.6).
    pub(crate) fn from_counts(counts: &[u64], count: u32) -> Self {
        let n = counts.len() as u32;
        assert!(count >= 1 && count <= n, "need 1 <= shards <= tiles");
        let mut prefix = Vec::with_capacity(counts.len() + 1);
        let mut acc = 0u64;
        prefix.push(0u64);
        for &c in counts {
            // +1 per tile: all-idle stretches still spread out instead
            // of collapsing every boundary onto the first hot tile.
            acc += c + 1;
            prefix.push(acc);
        }
        let total = acc;
        let mut starts = Vec::with_capacity(count as usize + 1);
        starts.push(0u32);
        for s in 1..count {
            let target = total * s as u64 / count as u64;
            let raw = prefix.partition_point(|&p| p < target) as u32;
            let lo = starts[s as usize - 1] + 1;
            let hi = n - (count - s);
            starts.push(raw.clamp(lo, hi));
        }
        starts.push(n);
        Self { starts }
    }

    pub(crate) fn count(&self) -> u32 {
        self.starts.len() as u32 - 1
    }

    /// Owned tile range `[lo, hi)` of shard `s`.
    pub(crate) fn range(&self, s: u32) -> (u32, u32) {
        (self.starts[s as usize], self.starts[s as usize + 1])
    }

    pub(crate) fn shard_of_tile(&self, tile: u32) -> u32 {
        self.starts.partition_point(|&x| x <= tile) as u32 - 1
    }
}

/// The static PDES ownership rule (balanced blocks), shared by the
/// parallel driver's setup path and the sharded verify schedule.
pub(crate) fn shard_of_node(topo: &Topology, n_cores: u32, count: u32, node: Node) -> u32 {
    if count <= 1 {
        return 0;
    }
    TilePartition::balanced(n_cores, count).shard_of_tile(topo.tile_of(node))
}

/// Per-(src, dst) channel ordering: the NoC delivers messages between
/// any two endpoints in send order (ordered virtual channels, as
/// Graphite assumes).  Without this, 1-flit control messages overtake
/// 5-flit data messages and classic protocol races appear (an Inv
/// passing the DataS it chases, a WbReq passing the ExRep that created
/// the owner).
///
/// Stored as a dense `n_nodes x n_nodes` matrix over the flat node
/// index space (cores, then LLC slices, then memory controllers):
/// O(1) un-hashed lookup on every delivery, and — unlike the old
/// per-pair `HashMap`, which grew with every channel ever used and
/// was never pruned — memory is fixed at construction (§Perf; ~2 MiB
/// at 256 cores).
#[derive(Debug)]
struct ChannelClock {
    clocks: Vec<Cycle>,
    n_cores: u32,
    n_nodes: u32,
}

impl ChannelClock {
    fn new(n_cores: u32, n_mcs: u32) -> Self {
        let n_nodes = 2 * n_cores + n_mcs;
        Self { clocks: vec![0; (n_nodes as usize) * (n_nodes as usize)], n_cores, n_nodes }
    }

    #[inline]
    fn node_index(&self, n: Node) -> u32 {
        match n {
            Node::Core(c) => c,
            Node::Slice(s) => self.n_cores + s,
            Node::Mc(m) => 2 * self.n_cores + m,
        }
    }

    /// Mutable earliest-delivery slot for the (src, dst) channel.
    #[inline]
    fn slot(&mut self, src: Node, dst: Node) -> &mut Cycle {
        let i = self.node_index(src) as usize * self.n_nodes as usize
            + self.node_index(dst) as usize;
        &mut self.clocks[i]
    }

    /// Copy the full outbound row of flat node `src` (tile migration;
    /// a row is written only by `src`'s owning shard).
    fn row(&self, src: u32) -> Vec<Cycle> {
        let n = self.n_nodes as usize;
        let base = src as usize * n;
        self.clocks[base..base + n].to_vec()
    }

    fn set_row(&mut self, src: u32, row: &[Cycle]) {
        let n = self.n_nodes as usize;
        let base = src as usize * n;
        self.clocks[base..base + n].copy_from_slice(row);
    }
}

/// Result of a completed simulation.
pub struct SimResult {
    pub stats: SimStats,
    pub log: AccessLog,
    /// Per-core completion cycles.
    pub core_finish: Vec<Cycle>,
    /// Flight-recorder trace (empty unless the run enabled tracing).
    /// A simulated quantity like `stats`: identical serial or sharded.
    pub trace: TraceRecording,
}

/// What one shard hands the parallel driver when its run completes:
/// partial stats (commutative sums), the shard-local access log with
/// per-dispatch `(cycle, key, range)` groups for the canonical-order
/// merge, and finish times for the cores it owns.
pub(crate) struct ShardOutput {
    pub stats: SimStats,
    pub log: AccessLog,
    /// `(dispatch cycle, dispatch key, log range start, end)` — the
    /// records committed while dispatching that event, contiguous in
    /// the shard-local log.  Globally sorting groups by `(cycle, key)`
    /// and concatenating reproduces the serial log exactly.
    pub log_groups: Vec<(Cycle, PushKey, u32, u32)>,
    /// `(core, finish cycle)` for owned cores.
    pub core_finish: Vec<(u32, Cycle)>,
    /// Cycle of the last event this shard dispatched.
    pub last_now: Cycle,
    /// Shard-local flight-recorder events (empty unless tracing).
    pub trace_events: Vec<TraceEvent>,
    /// Events the recorder saw, kept or not (global drop accounting).
    pub trace_emitted: u64,
    /// Per-dispatch trace ranges, mirroring `log_groups`: sorting by
    /// `(cycle, key)` and concatenating reproduces the serial trace.
    pub trace_groups: Vec<(Cycle, PushKey, u32, u32)>,
}

/// Everything a tile owns, packaged when the load balancer moves it to
/// another shard: the core, the protocol-private tile state, pending
/// calendar events targeting the tile, the channel-clock rows and
/// push-mark counters of the tile's reactors, and (for tiles hosting a
/// memory controller) the DRAM service slot plus the controller's
/// backing-image entries.  Stats do NOT migrate — they are commutative
/// shard sums merged by `SimStats::absorb`, so it does not matter
/// which shard accumulated them.
pub(crate) struct TileMigration {
    pub tile: u32,
    core: CoreUnit,
    core_finished: bool,
    proto: TileProtoState,
    /// Pending events for this tile, in `(cycle, key)` order.
    pub events: Vec<(Cycle, PushKey, Event)>,
    /// `(flat node index, full clock row)` for each reactor on the
    /// tile.  Only *rows* move: `clock[src][dst]` is written solely by
    /// `src`'s owner.
    chan_rows: Vec<(u32, Vec<Cycle>)>,
    /// `(flat node index, (cycle, next k))` PushKey counters.
    marks: Vec<(u32, (Cycle, u64))>,
    /// `(mc, service slot, backing-image entries sorted by address)`.
    mcs: Vec<(u32, Cycle, Vec<(LineAddr, u64)>)>,
    /// Cumulative simulated event count the tile carries with it.
    tile_events: u64,
}

pub(crate) struct Engine {
    cfg: SystemConfig,
    queue: EventQueue,
    topology: Topology,
    dram: Dram,
    /// DRAM backing image (line values; absent = 0).  Fx-hashed: the
    /// SipHash default cost showed up in every DRAM endpoint access.
    memory: FxHashMap<LineAddr, u64>,
    proto: ProtocolDispatch,
    cores: Vec<CoreUnit>,
    obs: Observers,
    stats: SimStats,
    seq: u64,
    finished: u32,
    channel_clock: ChannelClock,
    /// Reused per-dispatch scratch buffers (no allocation on the hot
    /// path — §Perf).
    scratch_msgs: Vec<Message>,
    scratch_comps: Vec<Completion>,
    /// This engine's slice of a parallel run (`solo()` when serial).
    /// A shard constructs the full-size system image but only ever
    /// drives its owned nodes: only owned cores are seeded, and only
    /// events targeting owned nodes reach this queue (cross-shard
    /// sends leave through `outboxes`).
    shard: ShardSpec,
    /// Cycle of the event currently being dispatched.
    now: Cycle,
    /// Flat node index of the reactor handling the current event —
    /// the `src` of every [`PushKey`] minted during the dispatch.
    cur_src: u32,
    /// Per-reactor `(cycle, next k)` counters backing [`PushKey`]
    /// generation.  Keys are globally unique and identical between
    /// serial and sharded runs because each reactor's dispatch
    /// sequence is identical and the counter is reactor-local.
    push_marks: Vec<(Cycle, u64)>,
    /// Cross-shard sends awaiting the epoch barrier, one box per
    /// destination shard.  Full `Message` values, not slab indices:
    /// slabs are strictly shard-private (see the isolation test).
    outboxes: Vec<Vec<(Cycle, PushKey, Message)>>,
    /// Per-dispatch log ranges (sharded runs with logging only).
    log_groups: Vec<(Cycle, PushKey, u32, u32)>,
    record_groups: bool,
    /// Flight recorder (disabled unless [`Engine::enable_trace`] ran).
    trace: TraceBuf,
    /// Per-dispatch trace ranges (sharded traced runs only).
    trace_groups: Vec<(Cycle, PushKey, u32, u32)>,
    record_trace_groups: bool,
    /// Cycle of the last dispatched event.
    last_now: Cycle,
    /// Cores this shard owns (== n_cores when serial).
    n_owned: u32,
    /// Current tile -> shard assignment (rewritten on rebalance).
    part: TilePartition,
    /// Flat node index -> owning shard, derived from `part`.
    node_shard: Vec<u32>,
    /// Flat node index -> hosting tile (topology-fixed).
    node_tile: Vec<u32>,
    /// Cumulative *simulated* events dispatched per tile — the load
    /// balancer's deterministic weight signal (never host timings).
    tile_events: Vec<u64>,
}

impl Engine {
    pub(crate) fn build(cfg: SystemConfig, workload: &Workload, obs: Observers) -> Self {
        Self::build_shard(cfg, workload, obs, ShardSpec::solo())
    }

    /// Construct one shard of a parallel run.  The shard holds the
    /// full-size system image (cores, protocol state, channel clocks,
    /// DRAM image) — only owned indices are ever driven, and the flat
    /// indexing stays identical to the serial engine, which is what
    /// makes the per-reactor state bit-for-bit the same under any
    /// shard count.
    pub(crate) fn build_shard(
        cfg: SystemConfig,
        workload: &Workload,
        obs: Observers,
        shard: ShardSpec,
    ) -> Self {
        assert_eq!(
            cfg.n_cores,
            workload.n_cores(),
            "workload core count must match the system configuration"
        );
        if cfg.topology.sockets > 1 {
            assert_eq!(
                cfg.n_cores % cfg.topology.sockets,
                0,
                "core count must divide evenly into sockets (SimBuilder validates this)"
            );
        }
        assert!(shard.count >= 1 && shard.index < shard.count, "bad shard spec {shard:?}");
        assert!(
            shard.count <= cfg.n_cores,
            "shard count must not exceed the core count (SimBuilder validates this)"
        );
        let proto = ProtocolDispatch::new(&cfg);
        let cores = (0..cfg.n_cores)
            .map(|id| match cfg.core_model {
                CoreModel::InOrder => CoreUnit::InOrder(InOrderCore::new(id, workload)),
                CoreModel::OutOfOrder => CoreUnit::Ooo(OooCore::new(id, workload)),
            })
            .collect();
        let n_nodes = (2 * cfg.n_cores + cfg.n_mcs) as usize;
        let record_groups = shard.count > 1 && obs.sc_log_enabled();
        let topology = Topology::new(&cfg);
        let part = TilePartition::balanced(cfg.n_cores, shard.count);
        let node_tile: Vec<u32> = (0..n_nodes as u32)
            .map(|idx| {
                let node = if idx < cfg.n_cores {
                    Node::Core(idx)
                } else if idx < 2 * cfg.n_cores {
                    Node::Slice(idx - cfg.n_cores)
                } else {
                    Node::Mc(idx - 2 * cfg.n_cores)
                };
                topology.tile_of(node)
            })
            .collect();
        let node_shard: Vec<u32> = node_tile.iter().map(|&t| part.shard_of_tile(t)).collect();
        let (lo, hi) = part.range(shard.index);
        Self {
            topology,
            dram: Dram::new(cfg.n_mcs, cfg.dram_latency, cfg.dram_service_cycles),
            queue: EventQueue::new(),
            memory: FxHashMap::default(),
            proto,
            cores,
            obs,
            stats: SimStats { n_cores: cfg.n_cores, ..SimStats::default() },
            seq: 0,
            finished: 0,
            channel_clock: ChannelClock::new(cfg.n_cores, cfg.n_mcs),
            scratch_msgs: Vec::with_capacity(16),
            scratch_comps: Vec::with_capacity(16),
            now: 0,
            cur_src: 0,
            push_marks: vec![(0, 0); n_nodes],
            outboxes: (0..shard.count).map(|_| Vec::new()).collect(),
            log_groups: Vec::new(),
            record_groups,
            trace: TraceBuf::default(),
            trace_groups: Vec::new(),
            record_trace_groups: false,
            last_now: 0,
            n_owned: hi - lo,
            part,
            node_shard,
            tile_events: vec![0; cfg.n_cores as usize],
            node_tile,
            shard,
            cfg,
        }
    }

    #[inline]
    fn node_index(&self, n: Node) -> u32 {
        match n {
            Node::Core(c) => c,
            Node::Slice(s) => self.cfg.n_cores + s,
            Node::Mc(m) => 2 * self.cfg.n_cores + m,
        }
    }

    #[inline]
    fn owns(&self, n: Node) -> bool {
        self.shard.count == 1
            || self.node_shard[self.node_index(n) as usize] == self.shard.index
    }

    /// Mint the canonical key for the next push: `(push cycle,
    /// handling reactor, per-reactor counter)`.  Globally unique, and
    /// the same key serial or sharded — the foundation of the PDES
    /// determinism argument (DESIGN.md §11).
    #[inline]
    fn next_key(&mut self) -> PushKey {
        let m = &mut self.push_marks[self.cur_src as usize];
        if m.0 != self.now {
            *m = (self.now, 0);
        }
        let k = m.1;
        m.1 += 1;
        PushKey { cycle: self.now, src: self.cur_src, k }
    }

    /// Arm the flight recorder (DESIGN.md §12).  Sharded runs also
    /// record per-dispatch `(cycle, key)` groups so the driver can
    /// merge shard-local traces into the canonical serial order —
    /// exactly the SC-log mechanism.
    pub(crate) fn enable_trace(&mut self) {
        self.trace = TraceBuf::recording();
        self.record_trace_groups = self.shard.count > 1;
    }

    /// Swap in the pre-calendar all-heap event queue (determinism
    /// regression tests and old-vs-new benchmarking only; must be
    /// called before [`Engine::run`] schedules anything).
    #[cfg(any(test, feature = "legacy-queue"))]
    pub(crate) fn set_legacy_queue(&mut self) {
        assert!(self.queue.is_empty(), "queue already in use");
        self.queue = EventQueue::legacy_heap();
    }

    /// Schedule the cycle-0 wake for every *owned* core.  Key parity
    /// with the serial path: core `c`'s seed key is `(0, c, 0)` under
    /// any shard count.
    pub(crate) fn seed(&mut self) {
        self.now = 0;
        for c in 0..self.cfg.n_cores {
            if !self.owns(Node::Core(c)) {
                continue;
            }
            self.cur_src = c;
            let key = self.next_key();
            self.cores[c as usize].set_next_wake(0);
            self.queue.push_keyed(0, key, Event::CoreWake(c));
        }
    }

    /// Run to completion (the serial path).  Drains the queue to full
    /// quiescence — post-finish stragglers (in-flight writebacks,
    /// renewals to already-finished cores) are dispatched rather than
    /// dropped, so the processed-event multiset is identical to a
    /// sharded run, which has no global "all cores finished" signal
    /// to cut on mid-epoch.  Completion cycles are unaffected:
    /// finished cores never reschedule.
    pub(crate) fn run(mut self) -> Result<SimResult> {
        self.seed();
        self.run_window(Cycle::MAX)?;
        if self.finished != self.cfg.n_cores {
            bail!(
                "deadlock: event queue drained with {}/{} cores finished at cycle {}\n{}",
                self.finished,
                self.cfg.n_cores,
                self.last_now,
                self.stuck_cores().join("\n")
            );
        }
        let last_now = self.last_now;
        let core_finish: Vec<Cycle> =
            self.cores.iter().map(|c| c.finished_at().unwrap_or(last_now)).collect();
        self.stats.cycles = core_finish.iter().copied().max().unwrap_or(last_now);
        self.obs.finish(&self.stats, &core_finish);
        let log = self.obs.take_log();
        let trace = std::mem::take(&mut self.trace).into_recording();
        Ok(SimResult { stats: self.stats, log, core_finish, trace })
    }

    /// Dispatch every event firing strictly before `limit` — one PDES
    /// epoch window (`Cycle::MAX` = run to quiescence).  The queue
    /// cursor never passes an unpopped event, so events injected at
    /// the next barrier (which fire at or beyond `limit`) push cleanly.
    pub(crate) fn run_window(&mut self, limit: Cycle) -> Result<()> {
        loop {
            let next = if limit == Cycle::MAX {
                self.queue.pop_keyed()
            } else {
                self.queue.pop_before(limit)
            };
            let Some((now, key, ev)) = next else { return Ok(()) };
            debug_assert!(now >= self.last_now, "time went backwards");
            self.last_now = now;
            self.stats.events += 1;
            self.obs.maybe_sample(now, &self.stats);
            if now > self.cfg.max_cycles {
                bail!(
                    "simulation exceeded max_cycles={} (livelock?)\n{}",
                    self.cfg.max_cycles,
                    self.stuck_cores().join("\n")
                );
            }
            self.dispatch(now, key, ev);
        }
    }

    /// State dumps for owned cores that have not finished (livelock /
    /// deadlock diagnostics).
    pub(crate) fn stuck_cores(&self) -> Vec<String> {
        self.cores
            .iter()
            .enumerate()
            .filter(|(i, c)| self.owns(Node::Core(*i as u32)) && c.finished_at().is_none())
            .map(|(_, c)| c.state_string())
            .collect()
    }

    /// Fire cycle of the earliest pending event (the shard's epoch
    /// bound contribution), without disturbing the queue.
    pub(crate) fn next_fire(&self) -> Option<Cycle> {
        self.queue.next_fire()
    }

    /// Owned cores that have finished.
    pub(crate) fn finished_cores(&self) -> u32 {
        self.finished
    }

    /// Cores this shard owns.
    pub(crate) fn n_owned(&self) -> u32 {
        self.n_owned
    }

    /// Drain the box of cross-shard sends destined for shard `dest`.
    pub(crate) fn take_outbox(&mut self, dest: u32) -> Vec<(Cycle, PushKey, Message)> {
        std::mem::take(&mut self.outboxes[dest as usize])
    }

    /// Accept a cross-shard delivery exchanged at an epoch barrier.
    /// The sender minted the key, so the event lands at exactly its
    /// serial-order position; the sorted bucket insert makes arrival
    /// order across senders irrelevant.
    pub(crate) fn inject(&mut self, at: Cycle, key: PushKey, msg: Message) {
        self.queue.push_keyed(at, key, Event::Deliver(msg));
    }

    /// Cumulative per-tile simulated event counts (the rebalance
    /// weight signal); only this shard's owned range is meaningful.
    pub(crate) fn tile_counts(&self) -> &[u64] {
        &self.tile_events
    }

    /// Adopt a new tile partition: recompute node ownership.  Valid
    /// only at a rebalance rendezvous, after this shard's lost tiles
    /// were extracted and before its gained tiles are installed.
    pub(crate) fn set_partition(&mut self, part: &TilePartition) {
        assert_eq!(part.count(), self.shard.count, "rebalance cannot change the shard count");
        self.part = part.clone();
        for idx in 0..self.node_tile.len() {
            self.node_shard[idx] = self.part.shard_of_tile(self.node_tile[idx]);
        }
        let (lo, hi) = self.part.range(self.shard.index);
        self.n_owned = hi - lo;
    }

    /// Pop every pending event in `(cycle, key)` order, emptying the
    /// queue (rebalance: the caller partitions events by target tile,
    /// then re-pushes keeps + gains in sorted order).
    pub(crate) fn drain_events(&mut self) -> Vec<(Cycle, PushKey, Event)> {
        self.queue.drain_all()
    }

    /// The tile an event targets (CoreWake -> the core's tile,
    /// Deliver -> the destination node's tile).
    pub(crate) fn event_tile(&self, ev: &Event) -> u32 {
        match ev {
            Event::CoreWake(c) => self.node_tile[*c as usize],
            Event::Deliver(m) => self.node_tile[self.node_index(m.dst) as usize],
        }
    }

    /// Re-push drained/migrated events.  Must be sorted by `(cycle,
    /// key)`: the first push rewinds the empty queue's cursor, and
    /// sorted order keeps every later push at or beyond it.
    pub(crate) fn push_events(&mut self, events: Vec<(Cycle, PushKey, Event)>) {
        for (t, key, ev) in events {
            self.queue.push_keyed(t, key, ev);
        }
    }

    /// Package tile `tile` for migration to another shard.  `events`
    /// is the tile's slice of this shard's drained queue; `workload`
    /// seeds the placeholder core left behind (never driven again
    /// unless a later rebalance hands the tile back, which overwrites
    /// it).  All remaining events fire at or beyond the rendezvous
    /// checkpoint, so snapshotting reactor state here is cut-point
    /// consistent.
    pub(crate) fn extract_tile(
        &mut self,
        tile: u32,
        events: Vec<(Cycle, PushKey, Event)>,
        workload: &Workload,
    ) -> TileMigration {
        let fresh = match self.cfg.core_model {
            CoreModel::InOrder => CoreUnit::InOrder(InOrderCore::new(tile, workload)),
            CoreModel::OutOfOrder => CoreUnit::Ooo(OooCore::new(tile, workload)),
        };
        let core = std::mem::replace(&mut self.cores[tile as usize], fresh);
        let core_finished = core.finished_at().is_some();
        if core_finished {
            self.finished -= 1;
        }
        let proto = self.proto.take_tile(tile);
        let mut chan_rows = Vec::new();
        let mut marks = Vec::new();
        for idx in 0..self.node_tile.len() {
            if self.node_tile[idx] == tile {
                chan_rows.push((idx as u32, self.channel_clock.row(idx as u32)));
                marks.push((idx as u32, self.push_marks[idx]));
            }
        }
        let map = SliceMap::new(&self.cfg);
        let mut mcs = Vec::new();
        for m in 0..self.cfg.n_mcs {
            if self.topology.tile_of(Node::Mc(m)) == tile {
                let mut entries: Vec<(LineAddr, u64)> = self
                    .memory
                    .iter()
                    .filter(|&(&a, _)| map.home_mc(a) == m)
                    .map(|(&a, &v)| (a, v))
                    .collect();
                entries.sort_unstable_by_key(|&(a, _)| a);
                self.memory.retain(|&a, _| map.home_mc(a) != m);
                mcs.push((m, self.dram.slot(m), entries));
            }
        }
        let tile_events = std::mem::take(&mut self.tile_events[tile as usize]);
        TileMigration { tile, core, core_finished, proto, events, chan_rows, marks, mcs, tile_events }
    }

    /// Install a tile arriving from another shard, returning its
    /// pending events for the caller to merge into the sorted re-push.
    pub(crate) fn install_tile(&mut self, m: TileMigration) -> Vec<(Cycle, PushKey, Event)> {
        self.cores[m.tile as usize] = m.core;
        if m.core_finished {
            self.finished += 1;
        }
        self.proto.install_tile(m.tile, m.proto);
        for (idx, row) in &m.chan_rows {
            self.channel_clock.set_row(*idx, row);
        }
        for &(idx, mark) in &m.marks {
            self.push_marks[idx as usize] = mark;
        }
        for (mc, slot, entries) in m.mcs {
            self.dram.set_slot(mc, slot);
            for (a, v) in entries {
                self.memory.insert(a, v);
            }
        }
        self.tile_events[m.tile as usize] = m.tile_events;
        m.events
    }

    /// Tear down a completed shard into its mergeable output.
    pub(crate) fn finalize_shard(mut self) -> ShardOutput {
        let core_finish: Vec<(u32, Cycle)> = (0..self.cfg.n_cores)
            .filter(|&c| self.owns(Node::Core(c)))
            .map(|c| (c, self.cores[c as usize].finished_at().unwrap_or(self.last_now)))
            .collect();
        let log = self.obs.take_log();
        let (trace_events, trace_emitted) = self.trace.into_parts();
        ShardOutput {
            stats: self.stats,
            log,
            log_groups: self.log_groups,
            core_finish,
            last_now: self.last_now,
            trace_events,
            trace_emitted,
            trace_groups: self.trace_groups,
        }
    }

    fn dispatch(&mut self, now: Cycle, key: PushKey, ev: Event) {
        self.now = now;
        self.cur_src = match &ev {
            Event::CoreWake(c) => *c,
            Event::Deliver(m) => self.node_index(m.dst),
        };
        self.tile_events[self.node_tile[self.cur_src as usize] as usize] += 1;
        let log_start = if self.record_groups { self.obs.log_len() } else { 0 };
        let trace_start = if self.record_trace_groups { self.trace.len() } else { 0 };
        self.dispatch_inner(now, ev);
        if self.record_groups {
            let log_end = self.obs.log_len();
            if log_end > log_start {
                self.log_groups.push((now, key, log_start as u32, log_end as u32));
            }
        }
        if self.record_trace_groups {
            let trace_end = self.trace.len();
            if trace_end > trace_start {
                self.trace_groups.push((now, key, trace_start as u32, trace_end as u32));
            }
        }
    }

    fn dispatch_inner(&mut self, now: Cycle, ev: Event) {
        let mut msgs = std::mem::take(&mut self.scratch_msgs);
        let mut comps = std::mem::take(&mut self.scratch_comps);
        msgs.clear();
        comps.clear();

        match ev {
            Event::CoreWake(c) => {
                // Drop stale wakes (the core rescheduled since).
                if self.cores[c as usize].next_wake() != Some(now) {
                    self.scratch_msgs = msgs;
                    self.scratch_comps = comps;
                    return; // stale wake
                }
                let mut pctx = ProtoCtx {
                    now,
                    msgs: &mut msgs,
                    completions: &mut comps,
                    stats: &mut self.stats,
                    trace: &mut self.trace,
                };
                let mut env = CoreEnv {
                    proto: &mut self.proto,
                    pctx: &mut pctx,
                    obs: &mut self.obs,
                    seq: &mut self.seq,
                    n_cores: self.cfg.n_cores,
                    spin_poll: self.cfg.spin_poll_cycles,
                    rollback_penalty: self.cfg.rollback_penalty,
                    ooo_window: self.cfg.ooo_window,
                    consistency: self.cfg.consistency,
                    sb_entries: self.cfg.sb_entries,
                };
                let action = self.cores[c as usize].step(now, &mut env);
                drop(env);
                self.apply_action(c, action);
            }
            Event::Deliver(msg) => match msg.dst {
                Node::Mc(mc) => self.handle_dram(now, mc, msg, &mut msgs),
                _ => {
                    let mut pctx = ProtoCtx {
                        now,
                        msgs: &mut msgs,
                        completions: &mut comps,
                        stats: &mut self.stats,
                        trace: &mut self.trace,
                    };
                    self.proto.on_message(msg, &mut pctx);
                }
            },
        }

        // Drain side effects until quiescent: route messages, dispatch
        // completions (which may trigger more of both).
        loop {
            for m in msgs.drain(..) {
                self.route(now, m);
            }
            if comps.is_empty() {
                break;
            }
            let batch: Vec<Completion> = comps.drain(..).collect();
            for comp in batch {
                let mut pctx = ProtoCtx {
                    now,
                    msgs: &mut msgs,
                    completions: &mut comps,
                    stats: &mut self.stats,
                    trace: &mut self.trace,
                };
                let mut env = CoreEnv {
                    proto: &mut self.proto,
                    pctx: &mut pctx,
                    obs: &mut self.obs,
                    seq: &mut self.seq,
                    n_cores: self.cfg.n_cores,
                    spin_poll: self.cfg.spin_poll_cycles,
                    rollback_penalty: self.cfg.rollback_penalty,
                    ooo_window: self.cfg.ooo_window,
                    consistency: self.cfg.consistency,
                    sb_entries: self.cfg.sb_entries,
                };
                let action = self.cores[comp.core as usize].on_completion(&comp, now, &mut env);
                drop(env);
                self.apply_action(comp.core, action);
            }
        }
        self.scratch_msgs = msgs;
        self.scratch_comps = comps;
    }

    fn apply_action(&mut self, core: u32, action: CoreAction) {
        match action {
            CoreAction::WakeAt(t) => {
                let key = self.next_key();
                self.queue.push_keyed(t, key, Event::CoreWake(core));
            }
            CoreAction::Park => {}
            CoreAction::Finished => self.finished += 1,
        }
    }

    /// Send a message departing at `depart`: resolve its route through
    /// the topology, account traffic (by class, and by the intra- vs
    /// inter-socket split), add fabric latency, enqueue.
    fn route(&mut self, depart: Cycle, msg: Message) {
        let info = self.topology.route(&msg);
        if info.flits > 0 {
            let t = &mut self.stats.traffic;
            match msg.kind.class() {
                MsgClass::Request => t.request_flits += info.flits,
                MsgClass::Data => t.data_flits += info.flits,
                MsgClass::Control => t.control_flits += info.flits,
                MsgClass::Renew => t.renew_flits += info.flits,
                MsgClass::Invalidation => t.invalidation_flits += info.flits,
                MsgClass::Dram => t.dram_flits += info.flits,
            }
            let sk = &mut self.stats.socket;
            if info.socket_hops == 0 {
                sk.intra_msgs += 1;
                sk.intra_hops += info.mesh_hops as u64;
            } else {
                sk.inter_msgs += 1;
                sk.inter_hops += info.mesh_hops as u64;
                sk.link_crossings += info.socket_hops as u64;
                sk.inter_flits += info.flits;
            }
        }
        self.deliver_at(depart + info.latency, msg);
    }

    /// Enqueue a delivery, enforcing per-channel FIFO order.  A
    /// message's `src` is always a node the handling shard owns, so
    /// each channel-clock row is written by exactly one shard and the
    /// clamp sequence matches the serial run.  Cross-shard deliveries
    /// leave through the outbox as full `Message` values — the
    /// sender's slab never interns them — carrying the sender-minted
    /// key for the destination's canonical ordering.
    fn deliver_at(&mut self, t: Cycle, msg: Message) {
        let slot = self.channel_clock.slot(msg.src, msg.dst);
        let t = t.max(*slot);
        *slot = t;
        let key = self.next_key();
        if self.shard.count > 1 && !self.owns(msg.dst) {
            let dest = self.node_shard[self.node_index(msg.dst) as usize];
            self.outboxes[dest as usize].push((t, key, msg));
            return;
        }
        self.queue.push_keyed(t, key, Event::Deliver(msg));
    }

    /// Memory-controller endpoint: model DRAM occupancy + latency and
    /// answer reads from / apply writes to the backing image.
    fn handle_dram(&mut self, now: Cycle, mc: u32, msg: Message, msgs: &mut Vec<Message>) {
        match msg.kind {
            MsgKind::DramLdReq => {
                let done = self.dram.access(mc, now);
                let value = self.memory.get(&msg.addr).copied().unwrap_or(0);
                let reply = Message {
                    src: Node::Mc(mc),
                    dst: msg.src,
                    addr: msg.addr,
                    requester: msg.requester,
                    kind: MsgKind::DramLdRep { value },
                };
                // Reply leaves the controller when the access completes.
                self.route(done, reply);
            }
            MsgKind::DramStReq { value } => {
                let _done = self.dram.access(mc, now);
                self.memory.insert(msg.addr, value);
            }
            other => panic!("MC got unexpected message {other:?}"),
        }
        let _ = msgs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SimBuilder;
    use crate::config::ProtocolKind;
    use crate::prog::{load, store, Program};
    use crate::testutil::Rng;

    fn tiny(protocol: ProtocolKind) -> (SystemConfig, Workload) {
        let w = Workload::new(vec![
            Program::new(vec![store(crate::types::SHARED_BASE, 7), load(crate::types::SHARED_BASE)]),
            Program::new(vec![load(crate::types::SHARED_BASE)]),
        ]);
        (SystemConfig::small(2, protocol), w)
    }

    fn tiny_engine(protocol: ProtocolKind) -> Engine {
        let (cfg, w) = tiny(protocol);
        Engine::build(cfg, &w, Observers::with_sc_log())
    }

    #[test]
    fn runs_all_protocols_to_completion() {
        for p in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            let (cfg, w) = tiny(p);
            let res = SimBuilder::from_config(cfg).workload(&w).run().unwrap();
            assert_eq!(res.core_finish.len(), 2);
            assert!(res.stats.cycles > 0);
            assert_eq!(res.stats.memops, 3);
        }
    }

    #[test]
    fn channel_fifo_prevents_overtaking() {
        // A 1-flit message sent after a 5-flit message on the same
        // channel must not arrive earlier.
        let mut eng = tiny_engine(ProtocolKind::Msi);
        let data = Message {
            src: Node::Slice(0),
            dst: Node::Core(1),
            addr: 0,
            requester: 1,
            kind: MsgKind::DataS { value: 1 },
        };
        let ctrl = Message { kind: MsgKind::Inv, ..data };
        eng.route(100, data);
        eng.route(100, ctrl);
        // Drain the queue; the Inv must be delivered at or after the
        // DataS despite its smaller serialization latency.
        let mut deliveries = Vec::new();
        while let Some((t, ev)) = eng.queue.pop() {
            if let Event::Deliver(m) = ev {
                deliveries.push((t, m.kind));
            }
        }
        assert_eq!(deliveries.len(), 2);
        assert!(matches!(deliveries[0].1, MsgKind::DataS { .. }));
        assert!(matches!(deliveries[1].1, MsgKind::Inv));
        assert!(deliveries[1].0 >= deliveries[0].0);
    }

    #[test]
    fn channel_fifo_holds_under_random_send_order() {
        // Regression for the ChannelClock invariant: across many
        // channels and randomized send times, a 1-flit control message
        // enqueued after a 5-flit data message on the same (src, dst)
        // pair never arrives first, and every channel's deliveries
        // preserve send order.
        let mut rng = Rng::new(0xC1_0C);
        for _trial in 0..20 {
            let mut eng = tiny_engine(ProtocolKind::Msi);
            // (channel id, send index) in send order, per channel.
            let mut sent: Vec<(usize, u32)> = Vec::new();
            let channels =
                [(Node::Slice(0), Node::Core(0)), (Node::Slice(0), Node::Core(1)), (Node::Slice(1), Node::Core(0))];
            let mut now = 0;
            let mut per_channel_seq = [0u32; 3];
            for _ in 0..40 {
                now += rng.below(5);
                let ch = rng.below(3) as usize;
                let (src, dst) = channels[ch];
                // Alternate big data messages and tiny control ones so
                // later control messages chase earlier data messages.
                let kind = if rng.chance(1, 2) {
                    MsgKind::DataS { value: 1 }
                } else {
                    MsgKind::Inv
                };
                // Encode (channel, seq) in the address for recovery.
                let seq = per_channel_seq[ch];
                per_channel_seq[ch] += 1;
                let msg = Message {
                    src,
                    dst,
                    addr: (ch as u64) << 32 | seq as u64,
                    requester: 0,
                    kind,
                };
                eng.route(now, msg);
                sent.push((ch, seq));
            }
            // Drain and check per-channel arrival order and times.
            let mut last_seen: [(i64, Cycle); 3] = [(-1, 0); 3];
            while let Some((t, ev)) = eng.queue.pop() {
                if let Event::Deliver(m) = ev {
                    let ch = (m.addr >> 32) as usize;
                    let seq = (m.addr & 0xFFFF_FFFF) as i64;
                    let (prev_seq, prev_t) = last_seen[ch];
                    assert!(
                        seq > prev_seq,
                        "channel {ch}: message {seq} overtook {prev_seq}"
                    );
                    assert!(
                        t >= prev_t,
                        "channel {ch}: delivery time went backwards ({t} < {prev_t})"
                    );
                    last_seen[ch] = (seq, t);
                }
            }
            for (ch, &count) in per_channel_seq.iter().enumerate() {
                assert_eq!(
                    last_seen[ch].0 + 1,
                    count as i64,
                    "channel {ch} lost messages"
                );
            }
        }
    }

    #[test]
    fn traffic_accounted_by_class() {
        let mut eng = tiny_engine(ProtocolKind::Msi);
        let data = Message {
            src: Node::Slice(0),
            dst: Node::Core(1),
            addr: 0,
            requester: 1,
            kind: MsgKind::DataS { value: 1 },
        };
        eng.route(0, data);
        assert_eq!(eng.stats.traffic.data_flits, 5);
        let inv = Message { kind: MsgKind::Inv, ..data };
        eng.route(0, inv);
        assert_eq!(eng.stats.traffic.invalidation_flits, 1);
    }

    #[test]
    fn same_tile_messages_are_free() {
        let mut eng = tiny_engine(ProtocolKind::Msi);
        let local = Message {
            src: Node::Core(0),
            dst: Node::Slice(0),
            addr: 0,
            requester: 0,
            kind: MsgKind::GetS,
        };
        eng.route(0, local);
        assert_eq!(eng.stats.traffic.total(), 0);
    }

    #[test]
    fn dram_image_round_trips() {
        let mut eng = tiny_engine(ProtocolKind::Msi);
        let st = Message {
            src: Node::Slice(0),
            dst: Node::Mc(0),
            addr: 42,
            requester: 0,
            kind: MsgKind::DramStReq { value: 1234 },
        };
        let mut msgs = Vec::new();
        eng.handle_dram(0, 0, st, &mut msgs);
        assert_eq!(eng.memory.get(&42), Some(&1234));
        let ld = Message {
            src: Node::Slice(0),
            dst: Node::Mc(0),
            addr: 42,
            requester: 0,
            kind: MsgKind::DramLdReq,
        };
        eng.handle_dram(10, 0, ld, &mut msgs);
        // The reply is in the queue with the stored value.
        let mut found = false;
        while let Some((_, ev)) = eng.queue.pop() {
            if let Event::Deliver(m) = ev {
                if let MsgKind::DramLdRep { value } = m.kind {
                    assert_eq!(value, 1234);
                    found = true;
                }
            }
        }
        assert!(found, "DRAM load reply missing");
    }

    #[test]
    fn socket_split_accounts_cross_socket_messages() {
        let (mut cfg, w) = tiny(ProtocolKind::Msi);
        cfg.topology.sockets = 2;
        cfg.topology.numa_ratio = 4;
        let mut eng = Engine::build(cfg, &w, Observers::none());
        // 2 cores on 2 sockets: slice 0 and core 1 sit on different
        // sockets, slice 0 and core 0 share a tile.
        let remote = Message {
            src: Node::Slice(0),
            dst: Node::Core(1),
            addr: 0,
            requester: 1,
            kind: MsgKind::DataS { value: 1 },
        };
        eng.route(0, remote);
        assert_eq!(eng.stats.socket.inter_msgs, 1);
        assert_eq!(eng.stats.socket.link_crossings, 1);
        assert_eq!(eng.stats.socket.inter_flits, 5);
        assert_eq!(eng.stats.traffic.data_flits, 5, "class accounting unchanged");
        // Same-tile messages skip the network entirely — no split
        // entry, just like the flat free-local rule.
        let local = Message { dst: Node::Core(0), requester: 0, ..remote };
        eng.route(0, local);
        assert_eq!(eng.stats.socket.intra_msgs, 0);
        assert_eq!(eng.stats.socket.total_msgs(), 1);
    }

    #[test]
    fn flat_runs_report_all_traffic_as_intra_socket() {
        let (cfg, w) = tiny(ProtocolKind::Tardis);
        let res = SimBuilder::from_config(cfg).workload(&w).run().unwrap();
        assert!(res.stats.socket.intra_msgs > 0);
        assert_eq!(res.stats.socket.inter_msgs, 0);
        assert_eq!(res.stats.socket.link_crossings, 0);
    }

    #[test]
    fn stats_cycles_is_last_finisher() {
        let (cfg, w) = tiny(ProtocolKind::Tardis);
        let res = SimBuilder::from_config(cfg).workload(&w).run().unwrap();
        assert_eq!(res.stats.cycles, *res.core_finish.iter().max().unwrap());
    }

    #[test]
    fn mismatched_core_count_panics() {
        let (cfg, w) = tiny(ProtocolKind::Tardis);
        let mut cfg = cfg;
        cfg.n_cores = 4; // workload has 2
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            Engine::build(cfg, &w, Observers::none())
        }))
        .is_err());
    }

    /// The §Perf determinism regression: the calendar queue must
    /// reproduce the legacy heap's execution bit-for-bit — identical
    /// stats (including the event count), access log, and per-core
    /// finish times — for every protocol and both core models.
    #[test]
    fn calendar_queue_matches_legacy_heap_bit_for_bit() {
        let spec = crate::workloads::by_name("fft").unwrap();
        let w = crate::trace::synth_workload(&spec.params, 8, 256);
        for protocol in [ProtocolKind::Tardis, ProtocolKind::Msi, ProtocolKind::Ackwise] {
            for model in [CoreModel::InOrder, CoreModel::OutOfOrder] {
                let run = |legacy: bool| {
                    SimBuilder::from_config(SystemConfig::small(8, protocol))
                        .core_model(model)
                        .record_accesses(true)
                        .workload(&w)
                        .legacy_event_queue(legacy)
                        .run()
                        .unwrap()
                };
                let new = run(false);
                let old = run(true);
                assert_eq!(new.stats, old.stats, "{protocol:?}/{model:?} stats diverged");
                assert_eq!(
                    new.log.records, old.log.records,
                    "{protocol:?}/{model:?} access logs diverged"
                );
                assert_eq!(new.core_finish, old.core_finish);
                assert!(new.stats.events > 0);
            }
        }
    }

    /// The PDES ownership rule: every node maps to exactly one shard,
    /// cores and their co-located slices agree, and blocks are
    /// contiguous (shard = tile / tiles_per_shard).
    #[test]
    fn shard_ownership_partitions_all_nodes() {
        let cfg = SystemConfig::small(8, ProtocolKind::Tardis);
        let topo = Topology::new(&cfg);
        for count in [1u32, 2, 4, 8] {
            for c in 0..8u32 {
                let s = shard_of_node(&topo, 8, count, Node::Core(c));
                assert!(s < count);
                assert_eq!(s, shard_of_node(&topo, 8, count, Node::Slice(c)));
                assert_eq!(s, if count == 1 { 0 } else { c / (8 / count) });
            }
            for m in 0..cfg.n_mcs {
                assert!(shard_of_node(&topo, 8, count, Node::Mc(m)) < count);
            }
        }
        // The mapping is the same one the NUMA fabric sockets by: with
        // count == sockets, shard == socket for every node.
        let mut ncfg = SystemConfig::small(8, ProtocolKind::Tardis);
        ncfg.topology.sockets = 4;
        ncfg.topology.numa_ratio = 2;
        let ntopo = Topology::new(&ncfg);
        for c in 0..8u32 {
            assert_eq!(shard_of_node(&ntopo, 8, 4, Node::Core(c)), c / 2);
        }
    }

    /// Uneven shard counts: balanced blocks give the first shards the
    /// extra tiles and every tile lands in exactly one shard.
    #[test]
    fn balanced_partition_handles_uneven_counts() {
        let p = TilePartition::balanced(8, 3);
        assert_eq!(p.starts, vec![0, 3, 6, 8]);
        assert_eq!(p.count(), 3);
        for t in 0..8 {
            let s = p.shard_of_tile(t);
            let (lo, hi) = p.range(s);
            assert!(lo <= t && t < hi);
        }
        // 1 tile per shard is legal; 0 would not be.
        assert_eq!(TilePartition::balanced(4, 4).starts, vec![0, 1, 2, 3, 4]);
    }

    /// Count-driven repartitioning isolates hot tiles, reproduces the
    /// balanced split on uniform counts, and never starves a shard.
    #[test]
    fn count_driven_partition_shifts_toward_hot_tiles() {
        let counts = [1000u64, 1, 1, 1, 1, 1, 1, 1];
        let p = TilePartition::from_counts(&counts, 2);
        assert_eq!(p.range(0), (0, 1), "hot tile isolated on its own shard");
        assert_eq!(p.range(1), (1, 8));
        let even = [5u64; 8];
        assert_eq!(TilePartition::from_counts(&even, 4), TilePartition::balanced(8, 4));
        // All weight on the last tile: earlier shards keep >= 1 tile.
        let tail = [0u64, 0, 0, 0, 0, 0, 0, 1000];
        let t = TilePartition::from_counts(&tail, 4);
        for s in 0..4 {
            let (lo, hi) = t.range(s);
            assert!(hi > lo, "shard {s} starved: {:?}", t.starts);
        }
    }

    /// Satellite regression: slab slots are strictly shard-private.  A
    /// cross-shard send leaves the sender as a full `Message` (sender
    /// slab untouched — a slot it frees mid-epoch can never be
    /// observed by another shard) and is interned at the destination
    /// with the sender's key intact.
    #[test]
    fn cross_shard_messages_never_touch_the_senders_slab() {
        let (cfg, w) = tiny(ProtocolKind::Msi);
        let shard =
            |index| Engine::build_shard(cfg.clone(), &w, Observers::none(), ShardSpec { index, count: 2 });
        let mut a = shard(0);
        let mut b = shard(1);
        // Slice 1 sits on tile 1 = shard 1; core 0's shard-0 engine
        // must box the send instead of queueing it.
        let msg = Message {
            src: Node::Core(0),
            dst: Node::Slice(1),
            addr: 0,
            requester: 0,
            kind: MsgKind::GetS,
        };
        a.route(0, msg);
        assert!(a.queue.is_empty(), "cross-shard send leaked into the sender queue");
        assert_eq!(a.queue.msg_slab_capacity(), 0, "sender slab interned a cross-shard message");
        let out = a.take_outbox(1);
        assert_eq!(out.len(), 1);
        assert!(a.take_outbox(1).is_empty(), "outbox must drain");
        let (at, key, m) = out[0];
        assert!(at > 0, "cross-tile message has nonzero latency");
        b.inject(at, key, m);
        assert_eq!(b.queue.msg_slab_capacity(), 1, "destination slab interns the injection");
        let (t, k, ev) = b.queue.pop_keyed().unwrap();
        assert_eq!((t, k), (at, key), "sender-minted key survives the exchange");
        assert!(matches!(ev, Event::Deliver(d) if d.dst == Node::Slice(1)));
        // A local send on the same engine still uses the queue + slab.
        let local = Message { dst: Node::Slice(0), ..msg };
        a.route(0, local);
        assert_eq!(a.queue.len(), 1);
        assert!(a.take_outbox(1).is_empty());
    }

    /// Seeding a shard wakes only owned cores, with the same keys the
    /// serial engine would mint for them.
    #[test]
    fn shard_seed_covers_only_owned_cores() {
        let (cfg, w) = tiny(ProtocolKind::Tardis);
        let mut whole = Engine::build(cfg.clone(), &w, Observers::none());
        whole.seed();
        let mut serial_keys = Vec::new();
        while let Some((t, key, ev)) = whole.queue.pop_keyed() {
            if let Event::CoreWake(c) = ev {
                serial_keys.push((t, key, c));
            }
        }
        assert_eq!(serial_keys.len(), 2);
        let mut shard_keys = Vec::new();
        for index in 0..2 {
            let mut sh =
                Engine::build_shard(cfg.clone(), &w, Observers::none(), ShardSpec { index, count: 2 });
            sh.seed();
            assert_eq!(sh.n_owned(), 1);
            while let Some((t, key, ev)) = sh.queue.pop_keyed() {
                if let Event::CoreWake(c) = ev {
                    shard_keys.push((t, key, c));
                }
            }
        }
        shard_keys.sort();
        assert_eq!(shard_keys, serial_keys);
    }
}
