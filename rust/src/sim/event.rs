//! Deterministic discrete-event queue, rebuilt for throughput (§Perf).
//!
//! The engine's clock advances monotonically and almost every event is
//! scheduled a few cycles ahead (hop latencies, L2 latency, DRAM
//! round-trips), so the queue is a **calendar**: a ring of per-cycle
//! buckets covering the next [`HORIZON_BUCKETS`] cycles, with a binary
//! heap as fallback for the rare far-future event (deep DRAM queueing).
//! Pushing into the ring is an append; popping walks the cursor
//! forward.  Both are O(1) amortized, versus O(log n) sift costs on
//! the old all-heap queue.
//!
//! [`Message`] payloads are interned in a [`MsgSlab`], so what moves
//! through buckets and heap is an 8-byte [`CompactEvent`] index, not
//! an ~80-byte message struct.
//!
//! Firing order is bit-for-bit the old heap's (cycle, insertion-seq)
//! order — see the ordering argument on [`EventQueue::promote`] and
//! the randomized equivalence test against [`EventQueue::legacy_heap`]
//! below.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::net::{Message, MsgSlab};
use crate::types::{CoreId, Cycle};

/// Ring size (cycles covered without touching the heap).  Power of
/// two; must comfortably exceed hop + serialization + DRAM latency
/// (~100-150 cycles) so overflow is rare even under DRAM queueing.
const HORIZON_BUCKETS: usize = 2048;

/// Events dispatched by the engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A core is scheduled to make progress.
    CoreWake(CoreId),
    /// A network message reaches its destination controller.
    Deliver(Message),
}

/// Internal two-word event: messages live in the slab.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CompactEvent {
    Wake(CoreId),
    Deliver(u32),
}

/// The overflow heap orders by (cycle, seq) only; the event payload
/// must still be `Ord` for the tuple, so compare as always-equal.
impl Ord for CompactEvent {
    fn cmp(&self, _other: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}
impl PartialOrd for CompactEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug)]
pub struct EventQueue {
    /// Per-cycle buckets; bucket `c & mask` holds only events for the
    /// single cycle `c` in `[cursor, cursor + ring.len())`.  Empty in
    /// legacy mode.
    ring: Vec<Vec<CompactEvent>>,
    mask: u64,
    /// Earliest cycle the ring may still hold events for.
    cursor: Cycle,
    /// Consumed prefix of the current bucket (only the bucket at
    /// `cursor` is ever partially drained).
    cur_head: usize,
    /// Live events in the ring.
    ring_len: usize,
    /// Far-future overflow, ordered by (cycle, seq).  Invariant while
    /// the ring is active: every heap event's cycle is at or beyond
    /// `cursor + ring.len()`.  In legacy mode this holds everything.
    heap: BinaryHeap<Reverse<(Cycle, u64, CompactEvent)>>,
    seq: u64,
    msgs: MsgSlab,
    legacy: bool,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::with_horizon(HORIZON_BUCKETS)
    }

    /// Calendar queue with a custom ring size (tests use tiny rings to
    /// exercise the overflow and cursor-jump paths).
    pub fn with_horizon(buckets: usize) -> Self {
        assert!(buckets.is_power_of_two(), "ring size must be a power of two");
        Self {
            ring: (0..buckets).map(|_| Vec::new()).collect(),
            mask: buckets as u64 - 1,
            cursor: 0,
            cur_head: 0,
            ring_len: 0,
            heap: BinaryHeap::new(),
            seq: 0,
            msgs: MsgSlab::new(),
            legacy: false,
        }
    }

    /// The pre-calendar all-heap queue, kept for determinism
    /// regression tests and old-vs-new benchmarking (§Perf).
    pub fn legacy_heap() -> Self {
        Self {
            ring: Vec::new(),
            mask: 0,
            cursor: 0,
            cur_head: 0,
            ring_len: 0,
            heap: BinaryHeap::new(),
            seq: 0,
            msgs: MsgSlab::new(),
            legacy: true,
        }
    }

    fn compact(&mut self, ev: Event) -> CompactEvent {
        match ev {
            Event::CoreWake(c) => CompactEvent::Wake(c),
            Event::Deliver(m) => CompactEvent::Deliver(self.msgs.insert(m)),
        }
    }

    fn expand(&mut self, ev: CompactEvent) -> Event {
        match ev {
            CompactEvent::Wake(c) => Event::CoreWake(c),
            CompactEvent::Deliver(i) => Event::Deliver(self.msgs.take(i)),
        }
    }

    pub fn push(&mut self, at: Cycle, ev: Event) {
        self.seq += 1;
        let ev = self.compact(ev);
        if self.legacy {
            self.heap.push(Reverse((at, self.seq, ev)));
            return;
        }
        // An *empty* queue may legally be pushed below the cursor
        // (external callers reusing a drained queue); rewind the
        // cursor so the event fires at its true cycle, exactly as the
        // legacy heap would.  The old cursor bucket is the only one
        // that can hold consumed entries — clear it or the rewound
        // walk would replay them.  With events pending, a past push
        // is a contract violation (the engine's clock is monotonic);
        // fail loudly rather than silently clamp the firing time.
        if at < self.cursor && self.ring_len == 0 && self.heap.is_empty() {
            self.ring[(self.cursor & self.mask) as usize].clear();
            self.cur_head = 0;
            self.cursor = at;
        }
        assert!(
            at >= self.cursor,
            "push at cycle {at} is before the queue cursor {} with events pending",
            self.cursor
        );
        if at - self.cursor < self.ring.len() as u64 {
            self.ring[(at & self.mask) as usize].push(ev);
            self.ring_len += 1;
        } else {
            self.heap.push(Reverse((at, self.seq, ev)));
        }
    }

    /// Ring drained: jump the cursor straight to the earliest
    /// far-future event and refill the horizon from the heap.  The
    /// bucket at the old cursor is the only one that can hold
    /// consumed-but-uncleared entries; reset it before the jump.
    /// Returns `None` when the heap is empty too.
    fn jump_to_heap_min(&mut self) -> Option<()> {
        let &Reverse((t, _, _)) = self.heap.peek()?;
        self.ring[(self.cursor & self.mask) as usize].clear();
        self.cur_head = 0;
        self.cursor = t;
        self.promote();
        Some(())
    }

    /// Move heap events whose cycle entered the horizon into their
    /// bucket.  Ordering: a cycle's bucket can only receive direct
    /// pushes after that cycle is inside the horizon, and promotion
    /// runs the moment it enters, so promoted events (pushed earlier,
    /// with smaller seq) always precede later ring pushes; among
    /// themselves they arrive in heap (cycle, seq) order.  Appended
    /// bucket order therefore equals global seq order per cycle.
    fn promote(&mut self) {
        let horizon = self.cursor + self.ring.len() as u64;
        while let Some(&Reverse((t, _, _))) = self.heap.peek() {
            if t >= horizon {
                break;
            }
            let Reverse((t, _, ev)) = self.heap.pop().unwrap();
            self.ring[(t & self.mask) as usize].push(ev);
            self.ring_len += 1;
        }
    }

    pub fn pop(&mut self) -> Option<(Cycle, Event)> {
        if self.legacy {
            return self.heap.pop().map(|Reverse((t, _, e))| {
                let ev = self.expand(e);
                (t, ev)
            });
        }
        if self.ring_len == 0 {
            self.jump_to_heap_min()?;
        }
        loop {
            let b = (self.cursor & self.mask) as usize;
            if self.cur_head < self.ring[b].len() {
                let ev = self.ring[b][self.cur_head];
                self.cur_head += 1;
                self.ring_len -= 1;
                let at = self.cursor;
                let ev = self.expand(ev);
                return Some((at, ev));
            }
            // Bucket exhausted: recycle it and advance the cursor,
            // admitting newly in-horizon heap events as we go.
            self.ring[b].clear();
            self.cur_head = 0;
            self.cursor += 1;
            self.promote();
            if self.ring_len == 0 {
                self.jump_to_heap_min()?;
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.ring_len == 0 && self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.ring_len + self.heap.len()
    }

    /// Allocated message-slab slots (diagnostics: steady-state churn
    /// must reuse slots instead of growing).
    pub fn msg_slab_capacity(&self) -> usize {
        self.msgs.capacity()
    }
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{MsgKind, Node};
    use crate::testutil::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::CoreWake(3));
        q.push(10, Event::CoreWake(1));
        q.push(20, Event::CoreWake(2));
        assert_eq!(q.pop(), Some((10, Event::CoreWake(1))));
        assert_eq!(q.pop(), Some((20, Event::CoreWake(2))));
        assert_eq!(q.pop(), Some((30, Event::CoreWake(3))));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn same_cycle_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(5, Event::CoreWake(i));
        }
        for i in 0..10 {
            assert_eq!(q.pop(), Some((5, Event::CoreWake(i))));
        }
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(1, Event::CoreWake(0));
        assert_eq!(q.pop(), Some((1, Event::CoreWake(0))));
        q.push(3, Event::CoreWake(1));
        q.push(2, Event::CoreWake(2));
        assert_eq!(q.pop(), Some((2, Event::CoreWake(2))));
        q.push(2, Event::CoreWake(3));
        assert_eq!(q.pop(), Some((2, Event::CoreWake(3))));
        assert_eq!(q.pop(), Some((3, Event::CoreWake(1))));
        assert!(q.is_empty());
    }

    #[test]
    fn far_future_events_overflow_and_return() {
        // Tiny ring: cycle 100 starts far outside the horizon [0, 8).
        let mut q = EventQueue::with_horizon(8);
        q.push(100, Event::CoreWake(9));
        q.push(3, Event::CoreWake(1));
        q.push(101, Event::CoreWake(10));
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some((3, Event::CoreWake(1))));
        assert_eq!(q.pop(), Some((100, Event::CoreWake(9))));
        assert_eq!(q.pop(), Some((101, Event::CoreWake(10))));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn cursor_jumps_over_empty_gaps() {
        let mut q = EventQueue::with_horizon(8);
        q.push(0, Event::CoreWake(0));
        q.push(1_000_000, Event::CoreWake(1));
        assert_eq!(q.pop(), Some((0, Event::CoreWake(0))));
        assert_eq!(q.pop(), Some((1_000_000, Event::CoreWake(1))));
        assert!(q.is_empty());
    }

    #[test]
    fn empty_queue_accepts_past_pushes_like_the_legacy_heap() {
        // Drain the queue past cycle 100, then push at 5: the event
        // must fire at 5 (cursor rewind), not get clamped to 100.
        let mut cal = EventQueue::with_horizon(8);
        let mut leg = EventQueue::legacy_heap();
        for q in [&mut cal, &mut leg] {
            q.push(100, Event::CoreWake(0));
            assert_eq!(q.pop(), Some((100, Event::CoreWake(0))));
            q.push(5, Event::CoreWake(1));
            q.push(7, Event::CoreWake(2));
            assert_eq!(q.pop(), Some((5, Event::CoreWake(1))));
            assert_eq!(q.pop(), Some((7, Event::CoreWake(2))));
            assert_eq!(q.pop(), None);
        }
    }

    #[test]
    fn promoted_events_fire_before_later_same_cycle_pushes() {
        // Event A at cycle 100 pushed while 100 is beyond the horizon
        // (overflows to the heap), event B at cycle 100 pushed after
        // the cursor jumped close enough that 100 is in the ring.  A
        // has the smaller seq and must pop first.
        let mut q = EventQueue::with_horizon(8);
        q.push(100, Event::CoreWake(0)); // A -> heap
        q.push(95, Event::CoreWake(7)); // filler
        assert_eq!(q.pop(), Some((95, Event::CoreWake(7)))); // cursor jumps to 95
        q.push(100, Event::CoreWake(1)); // B -> ring (100 < 95 + 8)
        assert_eq!(q.pop(), Some((100, Event::CoreWake(0))));
        assert_eq!(q.pop(), Some((100, Event::CoreWake(1))));
    }

    #[test]
    fn deliver_round_trips_messages_and_reuses_slab_slots() {
        let mut q = EventQueue::new();
        let msg = |v| Message {
            src: Node::Core(0),
            dst: Node::Slice(1),
            addr: v,
            requester: 0,
            kind: MsgKind::GetS,
        };
        // Steady-state churn: one in-flight message at a time must not
        // grow the slab.
        for i in 0..1000u64 {
            q.push(i, Event::Deliver(msg(i)));
            assert_eq!(q.pop(), Some((i, Event::Deliver(msg(i)))));
        }
        assert!(q.msg_slab_capacity() <= 2, "slab grew: {}", q.msg_slab_capacity());
    }

    /// The load-bearing regression: drive the calendar queue and the
    /// legacy heap with an identical randomized push/pop schedule
    /// (small ring, so the overflow, promotion, and cursor-jump paths
    /// all trigger) and require bit-identical pop sequences.
    #[test]
    fn calendar_matches_legacy_heap_on_random_schedules() {
        for trial in 0..50u64 {
            let mut rng = Rng::new(0xCA1E_0000 + trial);
            let mut cal = EventQueue::with_horizon(16);
            let mut leg = EventQueue::legacy_heap();
            let mut now: Cycle = 0;
            let mut pending: usize = 0;
            for step in 0..400u64 {
                if pending == 0 || rng.chance(3, 5) {
                    // Push at now + small or occasionally far delta.
                    let dt = if rng.chance(1, 10) { 100 + rng.below(200) } else { rng.below(12) };
                    let ev = if rng.chance(1, 3) {
                        Event::CoreWake(step as u32)
                    } else {
                        Event::Deliver(Message {
                            src: Node::Core((step % 4) as u32),
                            dst: Node::Slice((step % 3) as u32),
                            addr: step,
                            requester: 0,
                            kind: MsgKind::DataS { value: step },
                        })
                    };
                    cal.push(now + dt, ev.clone());
                    leg.push(now + dt, ev);
                    pending += 1;
                } else {
                    let a = cal.pop();
                    let b = leg.pop();
                    assert_eq!(a, b, "trial {trial} step {step} diverged");
                    now = a.expect("pending > 0").0;
                    pending -= 1;
                }
            }
            loop {
                let a = cal.pop();
                let b = leg.pop();
                assert_eq!(a, b, "trial {trial} drain diverged");
                if a.is_none() {
                    break;
                }
            }
            assert!(cal.is_empty() && leg.is_empty());
        }
    }
}
