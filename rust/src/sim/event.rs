//! Deterministic discrete-event queue, rebuilt for throughput (§Perf).
//!
//! The engine's clock advances monotonically and almost every event is
//! scheduled a few cycles ahead (hop latencies, L2 latency, DRAM
//! round-trips), so the queue is a **calendar**: a ring of per-cycle
//! buckets covering the next [`HORIZON_BUCKETS`] cycles, with a binary
//! heap as fallback for the rare far-future event (deep DRAM queueing).
//! Pushing into the ring is a sorted insert (append in the common
//! case); popping walks the cursor forward.  Both are O(1) amortized,
//! versus O(log n) sift costs on the old all-heap queue.
//!
//! [`Message`] payloads are interned in a [`MsgSlab`], so what moves
//! through buckets and heap is a small [`CompactEvent`] index, not an
//! ~80-byte message struct.
//!
//! Firing order is the canonical `(cycle, PushKey)` total order shared
//! by the serial engine and the sharded PDES driver (DESIGN.md §11): a
//! [`PushKey`] names the push *provenance* — (push cycle, pushing
//! reactor, per-reactor counter) — so per-shard queues pop exactly the
//! restriction of the global serial order.  Raw [`EventQueue::push`]
//! derives a key from the insertion sequence, which reproduces the old
//! (cycle, seq) heap order bit-for-bit — see the randomized
//! equivalence test against [`EventQueue::legacy_heap`] below.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::net::{Message, MsgSlab};
use crate::types::{CoreId, Cycle};

/// Ring size (cycles covered without touching the heap).  Power of
/// two; must comfortably exceed hop + serialization + DRAM latency
/// (~100-150 cycles) so overflow is rare even under DRAM queueing.
const HORIZON_BUCKETS: usize = 2048;

/// Canonical push identity: the total event order is `(fire cycle,
/// PushKey)`, identical for a single global queue and for per-shard
/// queues merged at epoch barriers.  `cycle` is the cycle the push was
/// made, `src` the global node index of the pushing reactor, and `k` a
/// per-(cycle, reactor) running counter — globally unique because a
/// reactor's dispatches are totally ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct PushKey {
    pub cycle: Cycle,
    pub src: u32,
    pub k: u64,
}

/// Events dispatched by the engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A core is scheduled to make progress.
    CoreWake(CoreId),
    /// A network message reaches its destination controller.
    Deliver(Message),
}

/// Internal two-word event: messages live in the slab.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CompactEvent {
    Wake(CoreId),
    Deliver(u32),
}

/// The overflow heap orders by (cycle, key) only; the event payload
/// must still be `Ord` for the tuple, so compare as always-equal.
impl Ord for CompactEvent {
    fn cmp(&self, _other: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}
impl PartialOrd for CompactEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug)]
pub struct EventQueue {
    /// Per-cycle buckets; bucket `c & mask` holds only events for the
    /// single cycle `c` in `[cursor, cursor + ring.len())`, sorted by
    /// [`PushKey`].  Empty in legacy mode.
    ring: Vec<Vec<(PushKey, CompactEvent)>>,
    mask: u64,
    /// Earliest cycle the ring may still hold events for.
    cursor: Cycle,
    /// Consumed prefix of the current bucket (only the bucket at
    /// `cursor` is ever partially drained).
    cur_head: usize,
    /// Live events in the ring.
    ring_len: usize,
    /// Far-future overflow, ordered by (cycle, key).  Invariant while
    /// the ring is active: every heap event's cycle is at or beyond
    /// `cursor + ring.len()`.  In legacy mode this holds everything.
    heap: BinaryHeap<Reverse<(Cycle, PushKey, CompactEvent)>>,
    /// Raw-push counter: [`Self::push`] derives keys from it so
    /// key-less callers keep exact insertion order per cycle.
    seq: u64,
    msgs: MsgSlab,
    legacy: bool,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::with_horizon(HORIZON_BUCKETS)
    }

    /// Calendar queue with a custom ring size (tests use tiny rings to
    /// exercise the overflow and cursor-jump paths).
    pub fn with_horizon(buckets: usize) -> Self {
        assert!(buckets.is_power_of_two(), "ring size must be a power of two");
        Self {
            ring: (0..buckets).map(|_| Vec::new()).collect(),
            mask: buckets as u64 - 1,
            cursor: 0,
            cur_head: 0,
            ring_len: 0,
            heap: BinaryHeap::new(),
            seq: 0,
            msgs: MsgSlab::new(),
            legacy: false,
        }
    }

    /// The pre-calendar all-heap queue, kept for determinism
    /// regression tests and old-vs-new benchmarking (§Perf).
    pub fn legacy_heap() -> Self {
        Self {
            ring: Vec::new(),
            mask: 0,
            cursor: 0,
            cur_head: 0,
            ring_len: 0,
            heap: BinaryHeap::new(),
            seq: 0,
            msgs: MsgSlab::new(),
            legacy: true,
        }
    }

    fn compact(&mut self, ev: Event) -> CompactEvent {
        match ev {
            Event::CoreWake(c) => CompactEvent::Wake(c),
            Event::Deliver(m) => CompactEvent::Deliver(self.msgs.insert(m)),
        }
    }

    fn expand(&mut self, ev: CompactEvent) -> Event {
        match ev {
            CompactEvent::Wake(c) => Event::CoreWake(c),
            CompactEvent::Deliver(i) => Event::Deliver(self.msgs.take(i)),
        }
    }

    /// Key-less push: derives a key from the insertion sequence, which
    /// keeps the old (cycle, push order) firing order exactly.
    pub fn push(&mut self, at: Cycle, ev: Event) {
        self.seq += 1;
        let key = PushKey { cycle: 0, src: 0, k: self.seq };
        self.push_keyed(at, key, ev);
    }

    /// Push with an explicit canonical key (the engine's path; the
    /// PDES driver injects barrier-exchanged events through it too).
    pub fn push_keyed(&mut self, at: Cycle, key: PushKey, ev: Event) {
        let ev = self.compact(ev);
        if self.legacy {
            self.heap.push(Reverse((at, key, ev)));
            return;
        }
        // An *empty* queue may legally be pushed below the cursor
        // (external callers reusing a drained queue); rewind the
        // cursor so the event fires at its true cycle, exactly as the
        // legacy heap would.  The old cursor bucket is the only one
        // that can hold consumed entries — clear it or the rewound
        // walk would replay them.  With events pending, a past push
        // is a contract violation (the engine's clock is monotonic);
        // fail loudly rather than silently clamp the firing time.
        if at < self.cursor && self.ring_len == 0 && self.heap.is_empty() {
            self.ring[(self.cursor & self.mask) as usize].clear();
            self.cur_head = 0;
            self.cursor = at;
        }
        assert!(
            at >= self.cursor,
            "push at cycle {at} is before the queue cursor {} with events pending",
            self.cursor
        );
        if at - self.cursor < self.ring.len() as u64 {
            self.insert_ring(at, key, ev);
        } else {
            self.heap.push(Reverse((at, key, ev)));
        }
    }

    /// Sorted insert into `at`'s bucket.  Only the cursor bucket has a
    /// consumed prefix; an insert never lands inside it (see the
    /// ordering argument in DESIGN.md §11 — a mid-drain push's key
    /// always exceeds every consumed key), but clamping keeps the
    /// unconsumed suffix sorted even if a future caller violates that.
    fn insert_ring(&mut self, at: Cycle, key: PushKey, ev: CompactEvent) {
        let b = (at & self.mask) as usize;
        let lo = if at == self.cursor { self.cur_head } else { 0 };
        let bucket = &mut self.ring[b];
        let pos = lo + bucket[lo..].partition_point(|&(kk, _)| kk < key);
        bucket.insert(pos, (key, ev));
        self.ring_len += 1;
    }

    /// Ring drained: jump the cursor straight to the earliest
    /// far-future event and refill the horizon from the heap.  The
    /// bucket at the old cursor is the only one that can hold
    /// consumed-but-uncleared entries; reset it before the jump.
    /// Returns `None` when the heap is empty too.
    fn jump_to_heap_min(&mut self) -> Option<()> {
        let &Reverse((t, _, _)) = self.heap.peek()?;
        self.ring[(self.cursor & self.mask) as usize].clear();
        self.cur_head = 0;
        self.cursor = t;
        self.promote();
        Some(())
    }

    /// Move heap events whose cycle entered the horizon into their
    /// bucket.  The sorted insert puts each promoted event at its key
    /// position, so an event that overflowed to the heap and one
    /// pushed directly into the ring fire in exact `(cycle, key)`
    /// order regardless of which path they took — including when the
    /// horizon crossing happens at a PDES epoch boundary (see the
    /// epoch-boundary test below).
    fn promote(&mut self) {
        let horizon = self.cursor + self.ring.len() as u64;
        while let Some(&Reverse((t, _, _))) = self.heap.peek() {
            if t >= horizon {
                break;
            }
            let Reverse((t, key, ev)) = self.heap.pop().unwrap();
            self.insert_ring(t, key, ev);
        }
    }

    pub fn pop(&mut self) -> Option<(Cycle, Event)> {
        self.pop_keyed().map(|(t, _, ev)| (t, ev))
    }

    /// Pop the globally next event together with its canonical key.
    pub fn pop_keyed(&mut self) -> Option<(Cycle, PushKey, Event)> {
        if self.legacy {
            return self.heap.pop().map(|Reverse((t, key, e))| {
                let ev = self.expand(e);
                (t, key, ev)
            });
        }
        if self.ring_len == 0 {
            self.jump_to_heap_min()?;
        }
        loop {
            let b = (self.cursor & self.mask) as usize;
            if self.cur_head < self.ring[b].len() {
                let (key, ev) = self.ring[b][self.cur_head];
                self.cur_head += 1;
                self.ring_len -= 1;
                let at = self.cursor;
                let ev = self.expand(ev);
                return Some((at, key, ev));
            }
            // Bucket exhausted: recycle it and advance the cursor,
            // admitting newly in-horizon heap events as we go.
            self.ring[b].clear();
            self.cur_head = 0;
            self.cursor += 1;
            self.promote();
            if self.ring_len == 0 {
                self.jump_to_heap_min()?;
            }
        }
    }

    /// Cycle of the next event without consuming it (and, crucially,
    /// without moving the cursor: an epoch-bounded drain must be able
    /// to stop *before* a far-future event so barrier-injected events
    /// can still be pushed at their true cycles).
    pub fn next_fire(&self) -> Option<Cycle> {
        if self.legacy || self.ring_len == 0 {
            return self.heap.peek().map(|&Reverse((t, _, _))| t);
        }
        // Ring events always precede heap events (horizon invariant),
        // so scan buckets from the cursor; the first live one wins.
        for off in 0..self.ring.len() as u64 {
            let c = self.cursor + off;
            let b = (c & self.mask) as usize;
            let head = if off == 0 { self.cur_head } else { 0 };
            if self.ring[b].len() > head {
                return Some(c);
            }
        }
        unreachable!("ring_len > 0 but no live bucket");
    }

    /// Pop the next event only if it fires strictly before `limit` —
    /// the PDES epoch window drain.  The cursor never advances past an
    /// unpopped event, so events injected at the following barrier
    /// (which fire at or beyond `limit`) are never "in the past".
    pub fn pop_before(&mut self, limit: Cycle) -> Option<(Cycle, PushKey, Event)> {
        if self.next_fire()? < limit {
            self.pop_keyed()
        } else {
            None
        }
    }

    /// Pop every pending event in `(cycle, key)` order, leaving the
    /// queue (and its message slab) empty.  The rebalance migration
    /// path: at a rendezvous all pending events fire at or beyond the
    /// checkpoint cycle, so the survivors can be re-pushed in sorted
    /// order afterwards — the first push rewinds the cursor of the
    /// now-empty queue, and sorted order keeps every later push at or
    /// beyond it.
    pub fn drain_all(&mut self) -> Vec<(Cycle, PushKey, Event)> {
        let mut out = Vec::with_capacity(self.len());
        while let Some(e) = self.pop_keyed() {
            out.push(e);
        }
        out
    }

    pub fn is_empty(&self) -> bool {
        self.ring_len == 0 && self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.ring_len + self.heap.len()
    }

    /// Allocated message-slab slots (diagnostics: steady-state churn
    /// must reuse slots instead of growing).
    pub fn msg_slab_capacity(&self) -> usize {
        self.msgs.capacity()
    }
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{MsgKind, Node};
    use crate::testutil::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::CoreWake(3));
        q.push(10, Event::CoreWake(1));
        q.push(20, Event::CoreWake(2));
        assert_eq!(q.pop(), Some((10, Event::CoreWake(1))));
        assert_eq!(q.pop(), Some((20, Event::CoreWake(2))));
        assert_eq!(q.pop(), Some((30, Event::CoreWake(3))));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn same_cycle_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(5, Event::CoreWake(i));
        }
        for i in 0..10 {
            assert_eq!(q.pop(), Some((5, Event::CoreWake(i))));
        }
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(1, Event::CoreWake(0));
        assert_eq!(q.pop(), Some((1, Event::CoreWake(0))));
        q.push(3, Event::CoreWake(1));
        q.push(2, Event::CoreWake(2));
        assert_eq!(q.pop(), Some((2, Event::CoreWake(2))));
        q.push(2, Event::CoreWake(3));
        assert_eq!(q.pop(), Some((2, Event::CoreWake(3))));
        assert_eq!(q.pop(), Some((3, Event::CoreWake(1))));
        assert!(q.is_empty());
    }

    #[test]
    fn far_future_events_overflow_and_return() {
        // Tiny ring: cycle 100 starts far outside the horizon [0, 8).
        let mut q = EventQueue::with_horizon(8);
        q.push(100, Event::CoreWake(9));
        q.push(3, Event::CoreWake(1));
        q.push(101, Event::CoreWake(10));
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some((3, Event::CoreWake(1))));
        assert_eq!(q.pop(), Some((100, Event::CoreWake(9))));
        assert_eq!(q.pop(), Some((101, Event::CoreWake(10))));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn cursor_jumps_over_empty_gaps() {
        let mut q = EventQueue::with_horizon(8);
        q.push(0, Event::CoreWake(0));
        q.push(1_000_000, Event::CoreWake(1));
        assert_eq!(q.pop(), Some((0, Event::CoreWake(0))));
        assert_eq!(q.pop(), Some((1_000_000, Event::CoreWake(1))));
        assert!(q.is_empty());
    }

    #[test]
    fn empty_queue_accepts_past_pushes_like_the_legacy_heap() {
        // Drain the queue past cycle 100, then push at 5: the event
        // must fire at 5 (cursor rewind), not get clamped to 100.
        let mut cal = EventQueue::with_horizon(8);
        let mut leg = EventQueue::legacy_heap();
        for q in [&mut cal, &mut leg] {
            q.push(100, Event::CoreWake(0));
            assert_eq!(q.pop(), Some((100, Event::CoreWake(0))));
            q.push(5, Event::CoreWake(1));
            q.push(7, Event::CoreWake(2));
            assert_eq!(q.pop(), Some((5, Event::CoreWake(1))));
            assert_eq!(q.pop(), Some((7, Event::CoreWake(2))));
            assert_eq!(q.pop(), None);
        }
    }

    #[test]
    fn promoted_events_fire_before_later_same_cycle_pushes() {
        // Event A at cycle 100 pushed while 100 is beyond the horizon
        // (overflows to the heap), event B at cycle 100 pushed after
        // the cursor jumped close enough that 100 is in the ring.  A
        // has the smaller key and must pop first.
        let mut q = EventQueue::with_horizon(8);
        q.push(100, Event::CoreWake(0)); // A -> heap
        q.push(95, Event::CoreWake(7)); // filler
        assert_eq!(q.pop(), Some((95, Event::CoreWake(7)))); // cursor jumps to 95
        q.push(100, Event::CoreWake(1)); // B -> ring (100 < 95 + 8)
        assert_eq!(q.pop(), Some((100, Event::CoreWake(0))));
        assert_eq!(q.pop(), Some((100, Event::CoreWake(1))));
    }

    /// Satellite regression for the sharded drain: heap-overflowed
    /// events crossing the horizon exactly at an epoch boundary must
    /// still fire in exact `(cycle, key)` order, interleaved correctly
    /// with a direct ring push made mid-drain — and an epoch-bounded
    /// drain must never advance the cursor past an unpopped event.
    #[test]
    fn epoch_boundary_promotion_preserves_exact_key_order() {
        let mut q = EventQueue::with_horizon(8);
        let key = |src: u32, k: u64| PushKey { cycle: 0, src, k };
        // Both land in the heap (100 is far outside [0, 8)), pushed in
        // the *opposite* of their key order.
        q.push_keyed(100, key(2, 0), Event::CoreWake(102));
        q.push_keyed(100, key(0, 1), Event::CoreWake(100));
        q.push_keyed(5, key(0, 0), Event::CoreWake(5));
        // Epoch [0, 8): only cycle 5 fires; the heap events stay put.
        assert_eq!(q.pop_before(8), Some((5, key(0, 0), Event::CoreWake(5))));
        assert_eq!(q.pop_before(8), None);
        assert_eq!(q.next_fire(), Some(100), "cursor must not pass the heap events");
        // Next epoch crosses the horizon: the first pop jumps the
        // cursor, promoting both heap events in key order; a mid-drain
        // ring push with an in-between key lands exactly between them.
        assert_eq!(q.pop_before(104), Some((100, key(0, 1), Event::CoreWake(100))));
        q.push_keyed(100, key(1, 0), Event::CoreWake(101));
        assert_eq!(q.pop_before(104), Some((100, key(1, 0), Event::CoreWake(101))));
        assert_eq!(q.pop_before(104), Some((100, key(2, 0), Event::CoreWake(102))));
        assert_eq!(q.pop_before(104), None);
        assert!(q.is_empty());
    }

    /// Keyed pushes fire in key order within a cycle even when they
    /// arrive out of key order, on both queue implementations.
    #[test]
    fn keyed_pushes_pop_in_key_order_on_both_queues() {
        let keys = [
            PushKey { cycle: 3, src: 0, k: 0 },
            PushKey { cycle: 1, src: 2, k: 5 },
            PushKey { cycle: 1, src: 2, k: 1 },
            PushKey { cycle: 2, src: 1, k: 0 },
            PushKey { cycle: 1, src: 0, k: 9 },
        ];
        for mut q in [EventQueue::new(), EventQueue::legacy_heap()] {
            for (i, &k) in keys.iter().enumerate() {
                q.push_keyed(7, k, Event::CoreWake(i as u32));
            }
            let mut sorted = keys;
            sorted.sort();
            for &k in &sorted {
                let (at, key, _) = q.pop_keyed().unwrap();
                assert_eq!((at, key), (7, k));
            }
            assert!(q.is_empty());
        }
    }

    #[test]
    fn deliver_round_trips_messages_and_reuses_slab_slots() {
        let mut q = EventQueue::new();
        let msg = |v| Message {
            src: Node::Core(0),
            dst: Node::Slice(1),
            addr: v,
            requester: 0,
            kind: MsgKind::GetS,
        };
        // Steady-state churn: one in-flight message at a time must not
        // grow the slab.
        for i in 0..1000u64 {
            q.push(i, Event::Deliver(msg(i)));
            assert_eq!(q.pop(), Some((i, Event::Deliver(msg(i)))));
        }
        assert!(q.msg_slab_capacity() <= 2, "slab grew: {}", q.msg_slab_capacity());
    }

    /// The load-bearing regression: drive the calendar queue and the
    /// legacy heap with an identical randomized push/pop schedule
    /// (small ring, so the overflow, promotion, and cursor-jump paths
    /// all trigger) and require bit-identical pop sequences.
    #[test]
    fn calendar_matches_legacy_heap_on_random_schedules() {
        for trial in 0..50u64 {
            let mut rng = Rng::new(0xCA1E_0000 + trial);
            let mut cal = EventQueue::with_horizon(16);
            let mut leg = EventQueue::legacy_heap();
            let mut now: Cycle = 0;
            let mut pending: usize = 0;
            for step in 0..400u64 {
                if pending == 0 || rng.chance(3, 5) {
                    // Push at now + small or occasionally far delta.
                    let dt = if rng.chance(1, 10) { 100 + rng.below(200) } else { rng.below(12) };
                    let ev = if rng.chance(1, 3) {
                        Event::CoreWake(step as u32)
                    } else {
                        Event::Deliver(Message {
                            src: Node::Core((step % 4) as u32),
                            dst: Node::Slice((step % 3) as u32),
                            addr: step,
                            requester: 0,
                            kind: MsgKind::DataS { value: step },
                        })
                    };
                    cal.push(now + dt, ev.clone());
                    leg.push(now + dt, ev);
                    pending += 1;
                } else {
                    let a = cal.pop();
                    let b = leg.pop();
                    assert_eq!(a, b, "trial {trial} step {step} diverged");
                    now = a.expect("pending > 0").0;
                    pending -= 1;
                }
            }
            loop {
                let a = cal.pop();
                let b = leg.pop();
                assert_eq!(a, b, "trial {trial} drain diverged");
                if a.is_none() {
                    break;
                }
            }
            assert!(cal.is_empty() && leg.is_empty());
        }
    }
}
