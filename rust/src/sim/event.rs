//! Deterministic discrete-event queue: a binary heap keyed by
//! (cycle, sequence) so same-cycle events fire in insertion order.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::net::Message;
use crate::types::{CoreId, Cycle};

/// Events dispatched by the engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A core is scheduled to make progress.
    CoreWake(CoreId),
    /// A network message reaches its destination controller.
    Deliver(Message),
}

#[derive(Debug)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(Cycle, u64, EventBox)>>,
    seq: u64,
}

/// Wrapper giving `Event` a total order (by discriminant only; the
/// sequence number already breaks ties deterministically).
#[derive(Debug, Clone, PartialEq, Eq)]
struct EventBox(Event);

impl Ord for EventBox {
    fn cmp(&self, _other: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}
impl PartialOrd for EventBox {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl EventQueue {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn push(&mut self, at: Cycle, ev: Event) {
        self.seq += 1;
        self.heap.push(Reverse((at, self.seq, EventBox(ev))));
    }

    pub fn pop(&mut self) -> Option<(Cycle, Event)> {
        self.heap.pop().map(|Reverse((t, _, e))| (t, e.0))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::CoreWake(3));
        q.push(10, Event::CoreWake(1));
        q.push(20, Event::CoreWake(2));
        assert_eq!(q.pop(), Some((10, Event::CoreWake(1))));
        assert_eq!(q.pop(), Some((20, Event::CoreWake(2))));
        assert_eq!(q.pop(), Some((30, Event::CoreWake(3))));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn same_cycle_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(5, Event::CoreWake(i));
        }
        for i in 0..10 {
            assert_eq!(q.pop(), Some((5, Event::CoreWake(i))));
        }
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(1, Event::CoreWake(0));
        assert_eq!(q.pop(), Some((1, Event::CoreWake(0))));
        q.push(3, Event::CoreWake(1));
        q.push(2, Event::CoreWake(2));
        assert_eq!(q.pop(), Some((2, Event::CoreWake(2))));
        q.push(2, Event::CoreWake(3));
        assert_eq!(q.pop(), Some((2, Event::CoreWake(3))));
        assert_eq!(q.pop(), Some((3, Event::CoreWake(1))));
        assert!(q.is_empty());
    }
}
