//! Conservative-lookahead parallel simulation driver (PDES).
//!
//! The engine shards by tile ([`shard_of_node`]): each worker thread
//! owns a contiguous block of cores, their co-located LLC/TM slices,
//! and the memory controllers homed there, with a private event queue
//! and message slab.  Workers advance in lockstep epochs of width `L`
//! = the minimum cross-shard message latency ([`lookahead`]): every
//! event a shard dispatches in window `[T, T+L)` can only schedule
//! cross-shard work at `now + latency >= T + L`, so events exchanged
//! at the epoch barrier always land in a *future* window — conservative
//! synchronization with zero rollbacks (cf. DESIGN.md §11 for the full
//! soundness argument).
//!
//! Determinism is bit-for-bit: every push carries a canonical
//! [`PushKey`] minted by the *sending* reactor, identical in serial
//! and sharded runs, and per-shard queues pop in global `(cycle, key)`
//! order restricted to the shard.  Since shards partition the
//! reactors and a reactor's dispatch sequence fully determines its
//! state, an N-thread run produces the same per-shard stats — merged
//! with commutative sums — and the same access log — merged by
//! sorting per-dispatch record groups on `(cycle, key)` — as the
//! 1-thread run.  `tests/determinism.rs` asserts exactly this.

use std::sync::{Barrier, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::api::observer::Observers;
use crate::config::SystemConfig;
use crate::net::{Message, MsgKind, Node, Topology};
use crate::prog::checker::AccessLog;
use crate::prog::Workload;
use crate::stats::{ParallelStats, ShardLoad, SimStats};
use crate::types::Cycle;

use super::engine::{shard_of_node, Engine, ShardSpec, SimResult};
use super::event::PushKey;

/// The conservative lookahead for `shards` shards of `cfg`: the
/// minimum fabric latency over all cross-shard node pairs, probed
/// with a 1-flit control message (latency grows with flit count, so
/// the control probe is the true minimum).  Under `Topology::Numa`
/// with shards == sockets this is the inter-socket link latency; under
/// `Flat` it is the smallest cross-boundary mesh crossing.  Always
/// >= 1 because distinct shards occupy distinct tiles.
pub(crate) fn lookahead(cfg: &SystemConfig, shards: u32) -> Cycle {
    let topo = Topology::new(cfg);
    let mut nodes = Vec::new();
    for c in 0..cfg.n_cores {
        nodes.push(Node::Core(c));
        nodes.push(Node::Slice(c));
    }
    for m in 0..cfg.n_mcs {
        nodes.push(Node::Mc(m));
    }
    let mut min = Cycle::MAX;
    for &a in &nodes {
        let sa = shard_of_node(&topo, cfg.n_cores, shards, a);
        for &b in &nodes {
            if shard_of_node(&topo, cfg.n_cores, shards, b) == sa {
                continue;
            }
            let probe = Message { src: a, dst: b, addr: 0, requester: 0, kind: MsgKind::GetS };
            min = min.min(topo.route(&probe).latency);
        }
    }
    min
}

/// Post-injection shard state published at each epoch's second
/// barrier; every worker reads all slots and derives the same verdict.
#[derive(Default)]
struct ShardStatus {
    next_fire: Option<Cycle>,
    finished: u32,
    error: Option<String>,
}

struct WorkerDone {
    out: super::engine::ShardOutput,
    load: ShardLoad,
    epochs: u64,
}

type Mailbox = Mutex<Vec<(Cycle, PushKey, Message)>>;

/// Run `cfg` + `workload` across `threads` shards and merge the
/// results into the same `SimResult` the serial engine produces.
pub(crate) fn run_parallel(
    cfg: SystemConfig,
    workload: &Workload,
    threads: u32,
    record_log: bool,
) -> Result<SimResult> {
    assert!(threads >= 2, "run_parallel needs at least two shards");
    let la = lookahead(&cfg, threads);
    if la == 0 || la == Cycle::MAX {
        bail!("degenerate lookahead for {threads} shards (is the system shardable?)");
    }
    let n = threads as usize;
    let n_cores = cfg.n_cores;
    let statuses: Vec<Mutex<ShardStatus>> =
        (0..n).map(|_| Mutex::new(ShardStatus::default())).collect();
    // mailboxes[to][from]: senders fill before barrier A, the owner
    // drains between barriers A and B.
    let mailboxes: Vec<Vec<Mailbox>> =
        (0..n).map(|_| (0..n).map(|_| Mutex::new(Vec::new())).collect()).collect();
    let barrier = Barrier::new(n);
    let t0 = Instant::now();
    let results: Vec<std::result::Result<WorkerDone, String>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|me| {
                let cfg = cfg.clone();
                let (statuses, mailboxes, barrier) = (&statuses, &mailboxes, &barrier);
                s.spawn(move || {
                    run_shard(cfg, workload, me, threads, la, record_log, statuses, mailboxes, barrier)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard thread panicked")).collect()
    });

    let mut outs = Vec::with_capacity(n);
    let mut loads = Vec::with_capacity(n);
    let mut epochs = 0u64;
    let mut errs: Vec<String> = Vec::new();
    for r in results {
        match r {
            Ok(d) => {
                epochs = epochs.max(d.epochs);
                loads.push(d.load);
                outs.push(d.out);
            }
            Err(e) => errs.push(e),
        }
    }
    if !errs.is_empty() {
        errs.dedup();
        bail!("{}", errs.join("\n"));
    }
    let wall_ns = (t0.elapsed().as_nanos() as u64).max(1);

    let global_last = outs.iter().map(|o| o.last_now).max().unwrap_or(0);
    let mut core_finish = vec![global_last; n_cores as usize];
    let mut stats = SimStats { n_cores, ..SimStats::default() };
    for o in &outs {
        stats.absorb(&o.stats);
        for &(c, t) in &o.core_finish {
            core_finish[c as usize] = t;
        }
    }
    stats.cycles = core_finish.iter().copied().max().unwrap_or(0);
    stats.parallel = ParallelStats { threads, lookahead: la, epochs, wall_ns, shards: loads };

    // Canonical log merge: per-dispatch record groups, globally sorted
    // by the dispatched event's (cycle, key) — the exact order the
    // serial engine dispatched them in — then re-sequenced, because
    // serial `seq` is positional (1-based commit order).
    let mut order: Vec<(Cycle, PushKey, usize, u32, u32)> = Vec::new();
    for (i, o) in outs.iter().enumerate() {
        for &(cy, key, start, end) in &o.log_groups {
            order.push((cy, key, i, start, end));
        }
    }
    order.sort_unstable_by_key(|&(cy, key, ..)| (cy, key));
    let mut log = AccessLog::default();
    log.records.reserve(outs.iter().map(|o| o.log.records.len()).sum());
    for &(_, _, i, start, end) in &order {
        log.records.extend_from_slice(&outs[i].log.records[start as usize..end as usize]);
    }
    for (i, r) in log.records.iter_mut().enumerate() {
        r.seq = (i + 1) as u64;
    }

    Ok(SimResult { stats, log, core_finish })
}

#[allow(clippy::too_many_arguments)]
fn run_shard(
    cfg: SystemConfig,
    workload: &Workload,
    me: u32,
    threads: u32,
    la: Cycle,
    record_log: bool,
    statuses: &[Mutex<ShardStatus>],
    mailboxes: &[Vec<Mailbox>],
    barrier: &Barrier,
) -> std::result::Result<WorkerDone, String> {
    let n_cores = cfg.n_cores;
    let obs = if record_log { Observers::with_sc_log() } else { Observers::none() };
    let mut eng = Engine::build_shard(cfg, workload, obs, ShardSpec { index: me, count: threads });
    eng.seed();
    let mut window_start: Cycle = 0;
    let mut epochs: u64 = 0;
    let mut busy_ns: u64 = 0;
    let mut wait_ns: u64 = 0;
    let verdict: std::result::Result<(), String> = loop {
        epochs += 1;
        let limit = window_start.saturating_add(la);
        let b0 = Instant::now();
        let res = eng.run_window(limit).map_err(|e| format!("{e:#}"));
        if res.is_ok() {
            for dest in 0..threads {
                if dest == me {
                    continue;
                }
                let out = eng.take_outbox(dest);
                if !out.is_empty() {
                    mailboxes[dest as usize][me as usize].lock().unwrap().extend(out);
                }
            }
        }
        busy_ns += b0.elapsed().as_nanos() as u64;
        let w0 = Instant::now();
        barrier.wait(); // A: every shard's outboxes are published.
        wait_ns += w0.elapsed().as_nanos() as u64;

        let b1 = Instant::now();
        let mut err = res.err();
        if err.is_none() {
            for src in 0..threads {
                if src == me {
                    continue;
                }
                let mail = std::mem::take(&mut *mailboxes[me as usize][src as usize].lock().unwrap());
                for (at, key, msg) in mail {
                    eng.inject(at, key, msg);
                }
            }
        }
        {
            let mut st = statuses[me as usize].lock().unwrap();
            st.next_fire = eng.next_fire();
            st.finished = eng.finished_cores();
            st.error = err.take();
        }
        busy_ns += b1.elapsed().as_nanos() as u64;
        let w1 = Instant::now();
        barrier.wait(); // B: every shard's post-injection status is visible.
        wait_ns += w1.elapsed().as_nanos() as u64;

        // Symmetric decision: all workers read the same snapshot (the
        // slots can't be rewritten until every reader passes the next
        // barrier A) and derive the same verdict — no coordinator.
        let mut min_next: Option<Cycle> = None;
        let mut finished_total = 0u32;
        let mut error: Option<String> = None;
        for st in statuses {
            let st = st.lock().unwrap();
            if let Some(t) = st.next_fire {
                min_next = Some(min_next.map_or(t, |m: Cycle| m.min(t)));
            }
            finished_total += st.finished;
            if error.is_none() {
                error.clone_from(&st.error);
            }
        }
        if let Some(e) = error {
            break Err(e);
        }
        match min_next {
            // Every queue drained and every core done: quiescence,
            // matching the serial engine's drain-to-quiescence exit.
            None if finished_total == n_cores => break Ok(()),
            None => {
                let stuck = eng.stuck_cores().join("\n");
                break Err(format!(
                    "deadlock: all shards drained with {finished_total}/{n_cores} cores \
                     finished\nshard {me} stuck cores:\n{stuck}"
                ));
            }
            Some(t) => {
                // Conservative soundness: the earliest pending event
                // anywhere is at or past this window's end (locals
                // below `limit` were dispatched; cross-shard fires are
                // >= now + la >= limit).
                debug_assert!(t >= limit, "event at {t} fired inside closed window [.., {limit})");
                window_start = t;
            }
        }
    };
    verdict?;
    let out = eng.finalize_shard();
    let load = ShardLoad { shard: me, events: out.stats.events, busy_ns, wait_ns };
    Ok(WorkerDone { out, load, epochs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ProtocolKind;

    #[test]
    fn lookahead_reflects_the_shard_boundary_cost() {
        let flat = SystemConfig::small(8, ProtocolKind::Tardis);
        let la2 = lookahead(&flat, 2);
        assert!(la2 >= 2, "cross-shard pairs differ in tile, so latency >= hop + flit");
        assert!(lookahead(&flat, 4) <= la2, "finer shards can only shrink the window");
        // On a NUMA fabric with shards == sockets, every cross-shard
        // route crosses a socket link, so the window widens by the
        // numa factor.
        let mut numa = SystemConfig::small(8, ProtocolKind::Tardis);
        numa.topology.sockets = 2;
        numa.topology.numa_ratio = 4;
        let nla = lookahead(&numa, 2);
        assert!(nla > la2, "socket-link lookahead {nla} should exceed mesh lookahead {la2}");
    }

    /// End-to-end canary (the full matrix lives in
    /// tests/determinism.rs): a 2-shard Tardis run is bit-for-bit the
    /// serial run — stats, access log, and per-core finish times.
    #[test]
    fn two_shards_match_serial_bit_for_bit() {
        let spec = crate::workloads::by_name("fft").unwrap();
        let w = crate::trace::synth_workload(&spec.params, 4, 128);
        let cfg = SystemConfig::small(4, ProtocolKind::Tardis);
        let serial = Engine::build(cfg.clone(), &w, Observers::with_sc_log()).run().unwrap();
        let par = run_parallel(cfg, &w, 2, true).unwrap();
        assert_eq!(par.stats, serial.stats);
        assert_eq!(par.log.records, serial.log.records);
        assert_eq!(par.core_finish, serial.core_finish);
        assert_eq!(par.stats.parallel.threads, 2);
        assert!(par.stats.parallel.epochs > 0);
        assert!(par.stats.parallel.lookahead >= 1);
        assert_eq!(par.stats.parallel.shards.len(), 2);
        let shard_events: u64 = par.stats.parallel.shards.iter().map(|s| s.events).sum();
        assert_eq!(shard_events, par.stats.events, "per-shard event loads sum to the total");
    }
}
